"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Artifacts (geometry constants live in model.py and must match
rust/src/runtime/mod.rs):

  verify_jnp.hlo.txt        fast verifier graph (CHUNK=65536, TABLE=2048)
  verify_pallas.hlo.txt     Pallas-kernel verifier (interpret lowering)
  extrema_jnp_N{N}.hlo.txt  diagonal-extrema graph, N in {256, 1024}
  extrema_pallas_N256.hlo.txt

Usage: python -m compile.aot --out-dir ../artifacts [--skip-pallas]
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to(path: str, fn, example_args) -> int:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--skip-pallas",
        action="store_true",
        help="skip the interpret-mode Pallas artifacts (slower to trace)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "chunk": model.CHUNK,
        "table": model.TABLE,
        "extrema_ns": list(model.EXTREMA_NS),
        "artifacts": {},
    }

    jobs = [("verify_jnp.hlo.txt", model.verify_jnp, model.verify_example_args())]
    for n in model.EXTREMA_NS:
        jobs.append(
            (f"extrema_jnp_N{n}.hlo.txt", model.extrema_jnp, model.extrema_example_args(n))
        )
    if not args.skip_pallas:
        jobs.append(("verify_pallas.hlo.txt", model.verify_pallas, model.verify_example_args()))
        jobs.append(
            ("extrema_pallas_N256.hlo.txt", model.extrema_pallas,
             model.extrema_example_args(256))
        )

    for name, fn, ex in jobs:
        path = os.path.join(args.out_dir, name)
        size = lower_to(path, fn, ex)
        manifest["artifacts"][name] = size
        print(f"wrote {path} ({size} chars)", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


if __name__ == "__main__":
    main()
