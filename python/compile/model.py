"""L2: the JAX compute graphs that get AOT-lowered for the Rust runtime.

Two graph families, each in a fast pure-jnp variant (what the Rust hot
path executes on CPU PJRT) and a Pallas variant (the TPU-shaped kernel,
lowered under interpret mode; bit-identical, exported for cross-checking
and as the TPU artifact):

- ``verify_*``: evaluate a generated design on a chunk of the input space
  and count bound violations (E7, the HECTOR-substitute verifier);
- ``extrema_*``: per-diagonal divided-difference extrema of a region
  (design-space generation offload).

Python never runs at request time: ``aot.py`` lowers these once to HLO
text under ``artifacts/`` and the Rust side loads + executes them.
"""

import jax
import jax.numpy as jnp

from .kernels import datapath, extrema, ref

# Export geometry — must match rust/src/runtime/mod.rs.
CHUNK = 65536
TABLE = datapath.TABLE
EXTREMA_NS = (256, 1024)


def verify_jnp(z, la, lb, lc, l, u, params):
    """Fast path: pure-jnp datapath check over one chunk.

    params: int64[5] = (xbits, sq_trunc, lin_trunc, k, out_max).
    Returns (out int64[CHUNK], viol int64[1]).
    """
    out, viol = ref.datapath_check(
        z, la, lb, lc, l, u, params[0], params[1], params[2], params[3], params[4]
    )
    return out, viol.reshape((1,))


def verify_pallas(z, la, lb, lc, l, u, params):
    """Pallas-kernel variant of ``verify_jnp`` (bit-identical)."""
    out, viol = datapath.datapath_check_pallas(z, la, lb, lc, l, u, params)
    return out, viol.reshape((1,))


def extrema_jnp(l, u):
    """Fast path: diagonal extrema of one region (N = l.shape[0])."""
    return ref.diagonal_extrema(l, u)


def extrema_pallas(l, u):
    """Pallas-kernel variant of ``extrema_jnp`` (bit-identical on the
    first 2N-3 entries)."""
    return extrema.diagonal_extrema_pallas(l, u)


def verify_example_args():
    """ShapeDtypeStructs for lowering the verify graphs."""
    i64 = jnp.int64
    return (
        jax.ShapeDtypeStruct((CHUNK,), i64),  # z
        jax.ShapeDtypeStruct((TABLE,), i64),  # a table
        jax.ShapeDtypeStruct((TABLE,), i64),  # b table
        jax.ShapeDtypeStruct((TABLE,), i64),  # c table
        jax.ShapeDtypeStruct((CHUNK,), i64),  # l
        jax.ShapeDtypeStruct((CHUNK,), i64),  # u
        jax.ShapeDtypeStruct((5,), i64),  # params
    )


def extrema_example_args(n):
    i64 = jnp.int64
    return (
        jax.ShapeDtypeStruct((n,), i64),
        jax.ShapeDtypeStruct((n,), i64),
    )
