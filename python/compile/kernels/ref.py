"""Pure-jnp oracles for the L1 kernels.

These are the correctness references the Pallas kernels are tested against
(pytest + hypothesis in ``python/tests/``), and they double as the *fast*
XLA-CPU lowering shipped to the Rust runtime (the interpret-mode Pallas
lowering is structurally faithful to a TPU kernel but slow on CPU; both are
exported and must agree bit-for-bit).

All arithmetic is int64 and exact. Fraction comparisons in the extrema
oracle use integer cross-multiplication — never floating point — because a
single mis-ordered divided difference corrupts the design space.
"""

import jax.numpy as jnp

# Sentinels for masked lanes in the extrema reductions. Cross products stay
# within +-2^62 provided |num| <= 2^50 and den <= 2^12, which holds for every
# format this repo supports (bounds < 2^30, region size <= 2^11 per kernel
# variant).
_NEG_INF = -(1 << 50)
_POS_INF = 1 << 50


def datapath_eval(z, la, lb, lc, xbits, i, j, k, out_max):
    """Bit-accurate interpolator datapath over a batch of input codes.

    With r = z >> xbits, x = z & (2^xbits - 1), T_i(x) = (x >> i) << i,
    S_j(x) = (x >> j) << j:

        out = clamp((a[r] * T_i(x)**2 + b[r] * S_j(x) + c[r]) >> k,
                    0, out_max)

    (arithmetic shift = floor division, plus the output saturation stage,
    matching ``Implementation::eval`` on the Rust side and the emitted
    RTL).
    """
    z = z.astype(jnp.int64)
    r = jnp.right_shift(z, xbits)
    x = z - jnp.left_shift(r, xbits)
    a = jnp.take(la, r, axis=0, mode="clip")
    b = jnp.take(lb, r, axis=0, mode="clip")
    c = jnp.take(lc, r, axis=0, mode="clip")
    xt = jnp.left_shift(jnp.right_shift(x, i), i)
    xl = jnp.left_shift(jnp.right_shift(x, j), j)
    acc = a * xt * xt + b * xl + c
    y = jnp.right_shift(acc, k)
    return jnp.clip(y, 0, out_max)  # output saturation stage


def datapath_check(z, la, lb, lc, l, u, xbits, i, j, k, out_max):
    """Datapath eval plus bound check: returns (out, violation count)."""
    out = datapath_eval(z, la, lb, lc, xbits, i, j, k, out_max)
    viol = jnp.sum(((out < l) | (out > u)).astype(jnp.int64))
    return out, viol


def frac_max(num, den, axis):
    """Exact elementwise-max of fractions num/den (den > 0) along ``axis``
    via a manual tree reduction with cross-multiplied i64 comparisons.
    The axis length must be a power of two (mask padding lanes with
    ``_NEG_INF``/1)."""
    n = num.shape[axis]
    assert n & (n - 1) == 0, "reduction axis must be a power of two"
    num = jnp.moveaxis(num, axis, -1)
    den = jnp.moveaxis(den, axis, -1)
    while num.shape[-1] > 1:
        h = num.shape[-1] // 2
        n0, n1 = num[..., :h], num[..., h:]
        d0, d1 = den[..., :h], den[..., h:]
        take1 = n1 * d0 > n0 * d1  # n1/d1 > n0/d0  (both d > 0)
        num = jnp.where(take1, n1, n0)
        den = jnp.where(take1, d1, d0)
    return num[..., 0], den[..., 0]


def diagonal_extrema(l, u):
    """Per-diagonal divided-difference extrema of one region (paper §II).

    For t in [1, 2N-3] over pairs x < y with x + y = t:

        M(t) = max (l[y] - u[x] - 1) / (y - x)
        m(t) = min (u[y] + 1 - l[x]) / (y - x)

    Returns four int64 arrays of length 2N-3: (M_num, M_den, m_num, m_den),
    all denominators > 0. N = l.shape[0] must be a power of two.
    """
    n = l.shape[0]
    l = l.astype(jnp.int64)
    u = u.astype(jnp.int64)
    t = jnp.arange(1, 2 * n - 2, dtype=jnp.int64)[:, None]  # (2N-3, 1)
    x = jnp.arange(n, dtype=jnp.int64)[None, :]  # (1, N)
    y = t - x
    valid = (x < y) & (y < n)
    yc = jnp.clip(y, 0, n - 1).astype(jnp.int64)
    den = jnp.where(valid, y - x, jnp.int64(1))

    ly = jnp.take(l, yc, axis=0)  # (2N-3, N) gather l[y]
    uy = jnp.take(u, yc, axis=0)
    lx = l[None, :]
    ux = u[None, :]
    big_cand = jnp.where(valid, ly - ux - 1, _NEG_INF)
    small_cand = jnp.where(valid, uy + 1 - lx, _POS_INF)

    big_num, big_den = frac_max(big_cand, den, axis=1)
    # min f = -max(-f).
    neg_num, small_den = frac_max(-small_cand, den, axis=1)
    return big_num, big_den, -neg_num, small_den
