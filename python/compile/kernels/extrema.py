"""L1 Pallas kernel: per-diagonal divided-difference extrema.

The generation hot-spot (paper §II-A): for one region's bound slices
``l, u`` of length N, compute for every diagonal ``t``

    M(t) = max_{x<y, x+y=t} (l[y] - u[x] - 1) / (y - x)
    m(t) = min_{x<y, x+y=t} (u[y] + 1 - l[x]) / (y - x)

as exact integer fractions. This is the vector-friendly reformulation of
the search the paper prunes sequentially with Claim II.1 (its
"parallelism" future-work item): each diagonal maps to a grid row, the
pair dimension maps to VPU lanes, and fraction comparison is an integer
cross-multiply — no data-dependent control flow, so it vectorizes cleanly,
at the cost of evaluating all O(N²) pairs.

Grid/TPU shape: ``l``/``u`` (8 B · N each) are VMEM-resident across the
whole grid; each step emits ``TBLOCK`` diagonals; the O(N) reduction per
diagonal is a log-depth select tree.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Diagonal rows emitted per grid step in the Pallas variant.
TBLOCK = 64


def _kernel(n, l_ref, u_ref, mnum_ref, mden_ref, snum_ref, sden_ref):
    g = pl.program_id(0)
    l = l_ref[...].astype(jnp.int64)
    u = u_ref[...].astype(jnp.int64)
    # Diagonals handled by this step: t = 1 + g*TBLOCK + [0, TBLOCK).
    t = 1 + g * TBLOCK + jnp.arange(TBLOCK, dtype=jnp.int64)[:, None]
    x = jnp.arange(n, dtype=jnp.int64)[None, :]
    y = t - x
    valid = (x < y) & (y < n)
    yc = jnp.clip(y, 0, n - 1)
    den = jnp.where(valid, y - x, jnp.int64(1))
    ly = jnp.take(l, yc, axis=0)
    uy = jnp.take(u, yc, axis=0)
    big = jnp.where(valid, ly - u[None, :] - 1, ref._NEG_INF)
    small = jnp.where(valid, uy + 1 - l[None, :], ref._POS_INF)
    bn, bd = ref.frac_max(big, den, axis=1)
    nn, sd = ref.frac_max(-small, den, axis=1)
    mnum_ref[...] = bn
    mden_ref[...] = bd
    snum_ref[...] = -nn
    sden_ref[...] = sd


@functools.partial(jax.jit, static_argnames=("n",))
def diagonal_extrema_pallas(l, u, *, n=None):
    """Pallas-tiled equivalent of ``ref.diagonal_extrema``.

    N must be a power of two; output arrays are padded up to a multiple of
    ``TBLOCK`` diagonals (valid entries are the first 2N-3; padding rows
    carry sentinel fractions and are discarded by the caller).
    """
    if n is None:
        n = l.shape[0]
    assert n & (n - 1) == 0, "N must be a power of two"
    tmax = 2 * n - 3
    tpad = -(-tmax // TBLOCK) * TBLOCK
    grid = (tpad // TBLOCK,)
    resident = pl.BlockSpec((n,), lambda g: (0,))
    rows = pl.BlockSpec((TBLOCK,), lambda g: (g,))
    out = pl.pallas_call(
        functools.partial(_kernel, n),
        grid=grid,
        in_specs=[resident, resident],
        out_specs=[rows, rows, rows, rows],
        out_shape=[jax.ShapeDtypeStruct((tpad,), jnp.int64)] * 4,
        interpret=True,
    )(l.astype(jnp.int64), u.astype(jnp.int64))
    return tuple(o[:tmax] for o in out)
