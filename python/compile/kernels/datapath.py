"""L1 Pallas kernel: batched interpolator-datapath evaluation.

The verification hot-spot: evaluate the generated piecewise-polynomial
hardware on a block of input codes and count bound violations. TPU-shaped
structure (see DESIGN.md §Hardware-Adaptation):

- the coefficient LUT (three int64 vectors of length ``TABLE``; ≤ 48 KiB)
  is VMEM-resident for the *whole* grid — its BlockSpec index map is
  constant, so Pallas keeps one copy on-chip;
- the input stream ``z`` and the bound streams ``l, u`` are tiled into
  ``BLOCK``-element chunks (3 × 8 B × BLOCK per step) and double-buffered
  HBM -> VMEM by the pipeline;
- the body is pure VPU element-wise int64 work: shifts, two multiplies, a
  gather into the resident LUT, compares, and a per-block violation count
  accumulated into SMEM-like (1,)-shaped output.

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; numerics are identical either way and are
pinned to ``ref.datapath_check`` by the hypothesis suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default export geometry (must match rust/src/runtime/mod.rs).
BLOCK = 4096
TABLE = 2048


def _kernel(params_ref, la_ref, lb_ref, lc_ref, z_ref, l_ref, u_ref,
            out_ref, viol_ref):
    xbits = params_ref[0]
    i = params_ref[1]
    j = params_ref[2]
    k = params_ref[3]
    out_max = params_ref[4]
    z = z_ref[...]
    r = jnp.right_shift(z, xbits)
    x = z - jnp.left_shift(r, xbits)
    a = jnp.take(la_ref[...], r, axis=0, mode="clip")
    b = jnp.take(lb_ref[...], r, axis=0, mode="clip")
    c = jnp.take(lc_ref[...], r, axis=0, mode="clip")
    xt = jnp.left_shift(jnp.right_shift(x, i), i)
    xl = jnp.left_shift(jnp.right_shift(x, j), j)
    out = jnp.clip(jnp.right_shift(a * xt * xt + b * xl + c, k), 0, out_max)
    out_ref[...] = out
    viol = jnp.sum(((out < l_ref[...]) | (out > u_ref[...])).astype(jnp.int64))
    viol_ref[0] = viol


@functools.partial(jax.jit, static_argnames=("block",))
def datapath_check_pallas(z, la, lb, lc, l, u, params, block=BLOCK):
    """Pallas-tiled equivalent of ``ref.datapath_check``.

    Args:
      z, l, u: int64[B] with B a multiple of ``block``.
      la, lb, lc: int64[TABLE] coefficient tables.
      params: int64[5] = (xbits, sq_trunc, lin_trunc, k, out_max).

    Returns (out int64[B], viol int64 scalar).
    """
    n = z.shape[0]
    assert n % block == 0, f"batch {n} not a multiple of block {block}"
    grid = (n // block,)
    table_spec = pl.BlockSpec(la.shape, lambda g: (0,))  # VMEM-resident
    stream_spec = pl.BlockSpec((block,), lambda g: (g,))
    out, viol = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(params.shape, lambda g: (0,)),
            table_spec,
            table_spec,
            table_spec,
            stream_spec,
            stream_spec,
            stream_spec,
        ],
        out_specs=[
            stream_spec,
            pl.BlockSpec((1,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int64),
            jax.ShapeDtypeStruct((grid[0],), jnp.int64),
        ],
        interpret=True,
    )(params, la, lb, lc, z, l, u)
    return out, jnp.sum(viol)


def vmem_footprint_bytes(block=BLOCK, table=TABLE):
    """Estimated per-step VMEM residency of the kernel (DESIGN.md §Perf):
    3 coefficient tables + 3 streamed operands + 1 output block + params,
    times 2 for double buffering of the streams."""
    tables = 3 * table * 8
    streams = 3 * block * 8 * 2
    out = block * 8 * 2
    return tables + streams + out + 4 * 8
