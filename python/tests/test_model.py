"""L2 model graphs + AOT lowering: shapes, manifest, and HLO-text sanity."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import to_hlo_text


def test_verify_jnp_shapes_and_dtypes():
    args = model.verify_example_args()
    z = np.zeros(model.CHUNK, dtype=np.int64)
    la = np.zeros(model.TABLE, dtype=np.int64)
    l = np.full(model.CHUNK, -(1 << 40), dtype=np.int64)
    u = np.full(model.CHUNK, 1 << 40, dtype=np.int64)
    params = np.array([8, 0, 0, 0, (1 << 40)], dtype=np.int64)
    out, viol = model.verify_jnp(z, la, la, la, l, u, params)
    assert out.shape == (model.CHUNK,)
    assert out.dtype == jnp.int64
    assert viol.shape == (1,)
    assert int(viol[0]) == 0
    # Example-arg specs match what we just ran.
    assert args[0].shape == (model.CHUNK,)
    assert args[-1].shape == (5,)


def test_extrema_jnp_shapes():
    for n in model.EXTREMA_NS:
        l = np.arange(n, dtype=np.int64)
        out = model.extrema_jnp(l, l + 1)
        assert len(out) == 4
        for arr in out:
            assert arr.shape == (2 * n - 3,)


def test_hlo_text_lowering_parses():
    """The exported artifact format: HLO text with the expected entry
    computation and parameter count (7 for verify, 2 for extrema)."""
    lowered = jax.jit(model.verify_jnp).lower(*model.verify_example_args())
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert text.count("parameter(") >= 7
    lowered2 = jax.jit(model.extrema_jnp).lower(*model.extrema_example_args(256))
    text2 = to_hlo_text(lowered2)
    assert "HloModule" in text2


def test_aot_cli_writes_manifest(tmp_path):
    """Run the aot module as the Makefile does (skip the slow Pallas
    lowering) and check the manifest."""
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--skip-pallas"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr
    assert (tmp_path / "manifest.json").exists()
    assert (tmp_path / "verify_jnp.hlo.txt").exists()
    for n in model.EXTREMA_NS:
        assert (tmp_path / f"extrema_jnp_N{n}.hlo.txt").exists()
    import json

    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["chunk"] == model.CHUNK
    assert man["table"] == model.TABLE
