"""Extrema kernel vs oracle vs brute force, with exact fraction semantics."""

from fractions import Fraction

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import extrema, ref


def brute_force(l, u):
    """Scalar reference with exact Fractions."""
    n = len(l)
    big, small = [], []
    for t in range(1, 2 * n - 2):
        bm, sm = None, None
        for x in range(n):
            y = t - x
            if x < y < n:
                fm = Fraction(int(l[y]) - int(u[x]) - 1, y - x)
                fs = Fraction(int(u[y]) + 1 - int(l[x]), y - x)
                bm = fm if bm is None else max(bm, fm)
                sm = fs if sm is None else min(sm, fs)
        big.append(bm)
        small.append(sm)
    return big, small


@st.composite
def bounds_case(draw):
    logn = draw(st.integers(1, 5))
    n = 1 << logn
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    l = rng.integers(-(1 << 20), 1 << 20, n).astype(np.int64)
    u = l + rng.integers(0, 8, n).astype(np.int64)
    return l, u


@settings(max_examples=30, deadline=None)
@given(bounds_case())
def test_jnp_extrema_match_bruteforce(case):
    l, u = case
    bn, bd, sn, sd = (np.asarray(a) for a in ref.diagonal_extrema(l, u))
    big, small = brute_force(l, u)
    for t in range(len(big)):
        assert bd[t] > 0 and sd[t] > 0
        assert Fraction(int(bn[t]), int(bd[t])) == big[t], f"M(t), t={t + 1}"
        assert Fraction(int(sn[t]), int(sd[t])) == small[t], f"m(t), t={t + 1}"


@settings(max_examples=8, deadline=None)
@given(bounds_case())
def test_pallas_extrema_match_jnp(case):
    l, u = case
    got = extrema.diagonal_extrema_pallas(l, u)
    want = ref.diagonal_extrema(l, u)
    for g, w, name in zip(got, want, ("Mnum", "Mden", "mnum", "mden")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


def test_tie_handling_is_value_exact():
    """Different (num, den) pairs representing the same extremum value are
    acceptable; value equality is what the design space consumes. This case
    has deliberate ties across pair distances."""
    l = np.array([0, 2, 4, 6], dtype=np.int64)
    u = l + 1
    bn, bd, sn, sd = (np.asarray(a) for a in ref.diagonal_extrema(l, u))
    big, small = brute_force(l, u)
    for t in range(len(big)):
        assert Fraction(int(bn[t]), int(bd[t])) == big[t]
        assert Fraction(int(sn[t]), int(sd[t])) == small[t]


def test_chord_condition_detection():
    """Eqn 9 (M(t) < m(t)) must fail on an infeasible zig-zag and hold on a
    smooth quadratic — the kernel output drives this decision in Rust."""
    # Zig-zag with zero slack: infeasible.
    l = np.array([0, 10, 0, 10, 0, 10, 0, 10], dtype=np.int64)
    bn, bd, sn, sd = (np.asarray(a) for a in ref.diagonal_extrema(l, l))
    ok = all(
        Fraction(int(bn[t]), int(bd[t])) < Fraction(int(sn[t]), int(sd[t]))
        for t in range(len(bn))
    )
    assert not ok
    # Smooth quadratic with slack: feasible.
    x = np.arange(8, dtype=np.int64)
    q = x * x + 3 * x + 7
    bn, bd, sn, sd = (np.asarray(a) for a in ref.diagonal_extrema(q - 1, q + 1))
    ok = all(
        Fraction(int(bn[t]), int(bd[t])) < Fraction(int(sn[t]), int(sd[t]))
        for t in range(len(bn))
    )
    assert ok
