import jax

# int64 datapath arithmetic everywhere (must precede any tracing).
jax.config.update("jax_enable_x64", True)
