"""L1 datapath kernel vs pure-jnp oracle vs a python scalar model.

The core correctness signal for the verification hot path: the Pallas
kernel, the jnp reference, and an independent scalar re-implementation
must agree bit-for-bit across hypothesis-driven shapes/params.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import datapath, ref


def scalar_model(z, la, lb, lc, xbits, i, j, k, out_max):
    """Independent scalar semantics (mirrors Implementation::eval in Rust)."""
    out = []
    for zz in z:
        r = zz >> xbits
        x = zz & ((1 << xbits) - 1)
        xt = (x >> i) << i
        xl = (x >> j) << j
        acc = int(la[r]) * xt * xt + int(lb[r]) * xl + int(lc[r])
        y = acc >> k  # python >> is floor division by 2^k
        out.append(min(max(y, 0), out_max))
    return np.array(out, dtype=np.int64)


@st.composite
def datapath_case(draw):
    in_bits = draw(st.integers(4, 11))
    lookup = draw(st.integers(1, min(8, in_bits - 1)))
    xbits = in_bits - lookup
    i = draw(st.integers(0, xbits))
    j = draw(st.integers(0, xbits))
    k = draw(st.integers(0, 16))
    nreg = 1 << lookup
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    la = np.zeros(datapath.TABLE, dtype=np.int64)
    lb = np.zeros(datapath.TABLE, dtype=np.int64)
    lc = np.zeros(datapath.TABLE, dtype=np.int64)
    la[:nreg] = rng.integers(-(1 << 10), 1 << 10, nreg)
    lb[:nreg] = rng.integers(-(1 << 18), 1 << 18, nreg)
    lc[:nreg] = rng.integers(-(1 << 24), 1 << 24, nreg)
    z = np.arange(1 << in_bits, dtype=np.int64)
    out_max = (1 << draw(st.integers(4, 30))) - 1
    return z, la, lb, lc, xbits, i, j, k, out_max


@settings(max_examples=40, deadline=None)
@given(datapath_case())
def test_jnp_matches_scalar_model(case):
    z, la, lb, lc, xbits, i, j, k, out_max = case
    got = np.asarray(ref.datapath_eval(z, la, lb, lc, xbits, i, j, k, out_max))
    want = scalar_model(z, la, lb, lc, xbits, i, j, k, out_max)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(datapath_case())
def test_pallas_matches_jnp(case):
    z, la, lb, lc, xbits, i, j, k, out_max = case
    # Pad the batch to a block multiple; padding lanes use region 0 and
    # permissive bounds.
    block = 512
    n = len(z)
    npad = -(-n // block) * block
    zp = np.zeros(npad, dtype=np.int64)
    zp[:n] = z
    l = np.full(npad, -(1 << 40), dtype=np.int64)
    u = np.full(npad, 1 << 40, dtype=np.int64)
    params = np.array([xbits, i, j, k, out_max], dtype=np.int64)
    out_p, viol_p = datapath.datapath_check_pallas(
        zp, la, lb, lc, l, u, params, block=block
    )
    out_r, viol_r = ref.datapath_check(
        zp, la, lb, lc, l, u, xbits, i, j, k, out_max
    )
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))
    assert int(viol_p) == int(viol_r) == 0


def test_violation_counting_exact():
    z = np.arange(1024, dtype=np.int64)
    la = np.zeros(datapath.TABLE, dtype=np.int64)
    lb = np.zeros(datapath.TABLE, dtype=np.int64)
    lc = np.zeros(datapath.TABLE, dtype=np.int64)
    lc[:4] = [10, 20, 30, 40]
    # xbits=8 -> 4 regions of 256; out = c[r].
    l = np.full(1024, 0, dtype=np.int64)
    u = np.full(1024, 25, dtype=np.int64)  # regions 2,3 violate entirely
    params = np.array([8, 0, 0, 0, 255], dtype=np.int64)
    out, viol = datapath.datapath_check_pallas(z, la, lb, lc, l, u, params, block=256)
    assert int(viol) == 512
    out_r, viol_r = ref.datapath_check(z, la, lb, lc, l, u, 8, 0, 0, 0, 255)
    assert int(viol_r) == 512
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_r))


def test_negative_acc_floor_semantics():
    """Arithmetic shift must floor (not truncate toward zero) — a classic
    RTL/ISA mismatch this pins down."""
    la = np.zeros(datapath.TABLE, dtype=np.int64)
    lb = np.zeros(datapath.TABLE, dtype=np.int64)
    lc = np.zeros(datapath.TABLE, dtype=np.int64)
    lb[0] = 1
    lc[0] = -7
    z = np.zeros(256, dtype=np.int64)
    z[1] = 9  # region 0, x=9: acc = 9 - 7 = 2 -> 0 after >> 2
    # Saturation disabled via a wide out_max, negative clamps to 0:
    got = np.asarray(ref.datapath_eval(z, la, lb, lc, 4, 0, 0, 2, (1 << 40)))
    assert got[0] == 0  # floor(-7/4) = -2, saturated to 0
    assert got[1] == 0
    # Unclamped floor semantics still visible above zero:
    z2 = np.full(256, 11, dtype=np.int64)  # acc = 11-7 = 4 -> 1
    got2 = np.asarray(ref.datapath_eval(z2, la, lb, lc, 4, 0, 0, 2, (1 << 40)))
    assert got2[0] == 1
    params = np.array([4, 0, 0, 2, 1 << 40], dtype=np.int64)
    l = np.full(256, -100, dtype=np.int64)
    u = np.full(256, 100, dtype=np.int64)
    out, _ = datapath.datapath_check_pallas(z, la, lb, lc, l, u, params, block=256)
    assert np.asarray(out)[0] == 0


def test_vmem_footprint_within_tpu_budget():
    # The TPU adaptation claim in DESIGN.md: the working set fits VMEM
    # (16 MiB on current TPUs) with ample headroom.
    assert datapath.vmem_footprint_bytes() < 4 * 1024 * 1024
