#!/usr/bin/env python3
"""Bench regression gate for the gen_engine smoke profile.

Compares a freshly regenerated BENCH_gen.json against the committed
baseline and fails (exit 1) when any smoke metric regressed by more than
the threshold:

- time metrics (lazy_1t_s / envelope_1t_s / envelope_mt_s / naive_1t_s):
  fail when new > (1 + threshold) * baseline;
- the dimensionless speedup_vs_naive ratio: fail when
  new < (1 - threshold) * baseline.

Rows are matched by (func, bits, lookup_bits); rows present on one side
only are reported but never fail the gate (case sets evolve). Metrics
whose baseline is missing/null, or below --min-time (timer noise floor),
are compared informationally only. Baselines recorded by the python
mirror (mode "mirror-estimate", from the no-toolchain authoring
container) are not comparable wall-clock sources: their time metrics are
informational, but the machine-independent speedup ratio is still gated.

--prefer-native FILE names an optional second baseline (CI passes the
BENCH_gen.json artifact of the previous successful run on the same
runner class): when it exists and was natively measured, it replaces the
positional baseline, which *arms the wall-clock gates* even while the
committed baseline is still a mirror estimate. A missing/unreadable/
mirror-mode FILE silently falls back to the positional baseline.

A markdown comparison table is appended to the file named by
$GITHUB_STEP_SUMMARY (or --summary) when set.

Usage: bench_gate.py BASELINE.json NEW.json [--threshold 0.25]
                     [--min-time 0.005] [--summary FILE]
                     [--prefer-native FILE]
"""

import argparse
import json
import os
import sys

TIME_METRICS = ["lazy_1t_s", "envelope_1t_s", "envelope_mt_s", "naive_1t_s"]
RATIO_METRICS = ["speedup_vs_naive"]


def key(row):
    return (row.get("func"), row.get("bits"), row.get("lookup_bits"))


def load(path):
    with open(path) as f:
        return json.load(f)


def is_mirror(doc):
    return "mirror" in str(doc.get("mode", "")) or "python-mirror" in str(
        doc.get("harness", "")
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional regression (default 0.25)")
    ap.add_argument("--min-time", type=float, default=0.005,
                    help="seconds; baseline times below this are too noisy to gate")
    ap.add_argument("--summary", default=os.environ.get("GITHUB_STEP_SUMMARY"))
    ap.add_argument("--prefer-native", default=None, metavar="FILE",
                    help="use FILE as the baseline instead when it is a native "
                         "measurement (e.g. the previous CI run's artifact)")
    args = ap.parse_args()

    base = load(args.baseline)
    baseline_source = args.baseline
    if args.prefer_native:
        try:
            preferred = load(args.prefer_native)
        except (OSError, ValueError):
            preferred = None
        if preferred is not None and not is_mirror(preferred):
            base = preferred
            baseline_source = f"{args.prefer_native} (previous native artifact)"
    new = load(args.new)
    base_rows = {key(r): r for r in base.get("results", [])}
    new_rows = {key(r): r for r in new.get("results", [])}
    mirror_baseline = is_mirror(base)

    lines = ["# gen_engine bench regression gate", ""]
    lines += [f"baseline: `{baseline_source}`", ""]
    if mirror_baseline:
        lines += [
            "> baseline is a python-mirror estimate (authored without a rust "
            "toolchain): wall-clock metrics are informational; only the "
            "machine-independent `speedup_vs_naive` ratio is gated. Commit the "
            "CI artifact `BENCH_gen.json` to turn the time gates on.",
            "",
        ]
    lines += [
        "| case | metric | baseline | new | change | verdict |",
        "|---|---|---:|---:|---:|---|",
    ]
    failures = []

    for k in sorted(new_rows, key=str):
        nrow = new_rows[k]
        brow = base_rows.get(k)
        label = "{} {}b R={}".format(*k)
        if brow is None:
            lines.append(f"| {label} | — | (not in baseline) | | | ℹ️ new case |")
            continue
        for metric in TIME_METRICS + RATIO_METRICS:
            b, n = brow.get(metric), nrow.get(metric)
            if b is None or n is None:
                continue
            is_ratio = metric in RATIO_METRICS
            if is_ratio:
                change = (n - b) / b if b else 0.0
                bad = n < (1.0 - args.threshold) * b
                gated = True
            else:
                change = (n - b) / b if b else 0.0
                bad = n > (1.0 + args.threshold) * b
                gated = (not mirror_baseline) and b >= args.min_time
            if bad and gated:
                verdict = "❌ regression"
                failures.append(f"{label} {metric}: {b:.6g} -> {n:.6g} ({change:+.1%})")
            elif bad:
                verdict = "⚠️ ungated"
            else:
                verdict = "✅"
            fmt = (lambda v: f"{v:.3f}x") if is_ratio else (lambda v: f"{v * 1e3:.2f} ms")
            lines.append(
                f"| {label} | {metric} | {fmt(b)} | {fmt(n)} | {change:+.1%} | {verdict} |"
            )
    for k in sorted(set(base_rows) - set(new_rows), key=str):
        lines.append("| {} {}b R={} | — | (missing from new run) | | | ℹ️ |".format(*k))

    lines.append("")
    lines.append(
        f"threshold: ±{args.threshold:.0%}; time metrics gated only when "
        f"baseline ≥ {args.min_time * 1e3:.0f} ms and native"
    )
    report = "\n".join(lines)
    print(report)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(report + "\n")

    if failures:
        print("\nFAIL: bench regression gate", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("\nbench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
