#!/usr/bin/env python3
"""Bit-exact mirror of rust/src/bounds/exact.rs activation floors.

The authoring container has no rust toolchain, so the exact-floor
algorithms for tanh/sigmoid/softplus/gelu are validated here first: this
file re-implements the integer algorithms bit-for-bit (python ints stand
in for u128/U256; `//` and `>>` are the same floors) and checks them
against a 80-digit Decimal reference over exhaustive small domains.

Outputs:
  - the two fixed-point constants to paste into exact.rs,
  - per-function max |computed - true| error and margin headroom,
  - FNV-1a golden hashes over the floor tables, pinned by Rust tests.

Run: python3 python/activation_mirror.py [--quick]
"""

import math
import sys
from decimal import Decimal, getcontext

getcontext().prec = 80

F = 120
MARGIN = 1 << 20

# --- high-precision constants (Decimal, then fixed-point) ---------------


def pi_dec() -> Decimal:
    """Machin: pi = 16 atan(1/5) - 4 atan(1/239)."""

    def atan_inv(n: int) -> Decimal:
        total = Decimal(0)
        term = Decimal(1) / n
        n2 = n * n
        k = 0
        while term != 0:
            total += term / (2 * k + 1) * (1 if k % 2 == 0 else -1)
            term /= n2
            k += 1
        return total

    return 16 * atan_inv(5) - 4 * atan_inv(239)


PI = pi_dec()
LOG2E_Q126 = int((1 / Decimal(2).ln()) * (1 << 126))
SQRT2_OVER_PI_Q126 = int((2 / PI).sqrt() * (1 << 126))


def erf_dec(w: Decimal) -> Decimal:
    """erf(w) = 2/sqrt(pi) * sum (-1)^n w^(2n+1) / (n! (2n+1))."""
    total = Decimal(0)
    term = w  # w^(2n+1)/n!
    w2 = w * w
    n = 0
    while True:
        contrib = term / (2 * n + 1) * (1 if n % 2 == 0 else -1)
        total += contrib
        if abs(contrib) < Decimal(10) ** -78 and n > int(w2):
            break
        n += 1
        term = term * w2 / n
    return 2 / PI.sqrt() * total


# --- the integer algorithms, mirrored statement-for-statement -----------

_CHAIN = None


def sqrt2_chain(depth: int):
    """[2^(2^-1), ..., 2^(2^-depth)] in Q1.127 (isqrt-based, as Rust)."""
    global _CHAIN
    if _CHAIN is None or len(_CHAIN) < depth:
        roots = []
        s = math.isqrt(1 << 255)  # isqrt(2 << 254) = sqrt(2) in Q1.127
        roots.append(s)
        for _ in range(1, depth):
            s = math.isqrt(s << 127)
            roots.append(s)
        _CHAIN = roots
    return _CHAIN


def exp2w_q127(f: int) -> int:
    """2^f for a Q0.120 fraction f (0 < f < 2^120), in Q1.127."""
    assert 0 < f < (1 << 120)
    roots = sqrt2_chain(120)
    g = 1 << 127
    for i in range(120):
        if (f >> i) & 1:
            g = (g * roots[120 - i - 1]) >> 127
    return g


def exp2neg_q124(z: int, m: int, lk: int) -> int:
    """E = e^(-lk*x) for x = z/2^(m-3), lk in {1, 2}, as Q0.124."""
    assert lk in (1, 2) and z > 0
    sh = m - 3 - (1 if lk == 2 else 0)
    p = z * LOG2E_Q126  # t = lk*x*log2(e) at Q.(126+sh)
    t_int = p >> (126 + sh)
    tf = (p >> (6 + sh)) & ((1 << 120) - 1)
    if tf == 0:
        return 1 << (124 - t_int)
    # 2^(-tf) = 2^(1-tf)/2, so E*2^124 = exp2w(1-tf) >> (4 + T).
    g2 = exp2w_q127((1 << 120) - tf)
    return g2 >> (4 + t_int)


def log2_frac_q120(v: int) -> int:
    assert v > 0
    a = v << (128 - v.bit_length())
    frac = 0
    for _ in range(F):
        sq = a * a
        bit = (sq >> 255) & 1
        frac = (frac << 1) | bit
        a = sq >> 128 if bit else sq >> 127
    return frac


def split_floor(frac: int, shift: int):
    fl = frac >> shift
    rem = frac & ((1 << shift) - 1)
    top = 1 << shift
    assert MARGIN < rem < top - MARGIN, f"ambiguous floor: rem={rem:#x} shift={shift}"
    return fl, False, min(rem, top - rem) / top


def floor_tanh_scaled(z: int, m: int, q: int, lk: int):
    """floor(2^q * (1-E)/(1+E)), E = e^(-lk*x): tanh (lk=2) / 2*sigmoid-1 (lk=1)."""
    if z == 0:
        return 0, True, 0.5
    e = exp2neg_q124(z, m, lk)
    num = ((1 << 124) - e) << (q + 110)
    den = (1 << 124) + e
    return split_floor(num // den, 110)


def floor_softplus_scaled(z: int, m: int, q: int):
    """floor(2^q * log2(1 + e^-x)), x = z/2^(m-3)."""
    if z == 0:
        return 1 << q, True, 0.5
    e = exp2neg_q124(z, m, 1)
    return split_floor(log2_frac_q120((1 << 124) + e), 120 - q)


def floor_gelu_scaled(z: int, m: int, q: int):
    """floor(2^(q+2) * x * Phi(-x)), x = z/2^(m-2), via the erf series."""
    if z == 0:
        return 0, True, 0.5
    assert q + 3 >= m
    uf = 2 * m - 3  # u = x^2/2 = z^2 / 2^uf, u < 8
    z2 = z * z
    term = 1 << 160  # u^n/n! at Q.160
    pos = neg = 0
    n = 0
    while term != 0:
        if n % 2 == 0:
            pos += term // (2 * n + 1)
        else:
            neg += term // (2 * n + 1)
        term = ((term * z2) // (n + 1)) >> uf
        n += 1
        assert n < 500, "series failed to terminate"
    s = pos - neg  # S(u) = sum (-1)^n u^n/(n!(2n+1)) at Q.160, > 0
    assert s > 0
    us = (s * z2) >> (uf + 36)  # u*S at Q.124, < 2^127
    assert us < (1 << 128)
    v = us * SQRT2_OVER_PI_Q126  # sqrt(2/pi)*u*S at Q.250
    d110 = v >> (138 - q)  # D*2^110, D = 2^(q+2)*sqrt(2/pi)*u*S
    y110 = (z << (q + 3 - m + 110)) - d110  # Y*2^110 = (2^(q+1)x - D)*2^110
    assert y110 > 0
    return split_floor(y110, 110)


# --- Decimal reference ---------------------------------------------------


def ref_y(func: str, z: int, m: int, q: int) -> Decimal:
    if func == "gelu":
        x = Decimal(z) / (1 << (m - 2))
        w = x / Decimal(2).sqrt()
        return (1 << (q + 1)) * x * (1 - erf_dec(w))
    x = Decimal(z) / (1 << (m - 3))
    if func == "tanh":
        e = (-2 * x).exp()
        return (1 << q) * (1 - e) / (1 + e)
    if func == "sigmoid":  # Y = 2^(q+1)*sigma(x) - 2^q = 2^q*tanh(x/2)
        e = (-x).exp()
        return (1 << q) * (1 - e) / (1 + e)
    if func == "softplus":
        e = (-x).exp()
        return (1 << q) * ((1 + e).ln() / Decimal(2).ln())
    raise ValueError(func)


def mirror(func: str, z: int, m: int, q: int):
    if func == "tanh":
        return floor_tanh_scaled(z, m, q, 2)
    if func == "sigmoid":
        return floor_tanh_scaled(z, m, q, 1)
    if func == "softplus":
        return floor_softplus_scaled(z, m, q)
    if func == "gelu":
        return floor_gelu_scaled(z, m, q)
    raise ValueError(func)


FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
U64 = (1 << 64) - 1


def fnv1a(h: int, v: int) -> int:
    return ((h ^ (v & U64)) * FNV_PRIME) & U64


def main():
    quick = "--quick" in sys.argv
    funcs = ["tanh", "sigmoid", "softplus", "gelu"]
    print(f"LOG2E_Q126         = {LOG2E_Q126:#034x}")
    print(f"SQRT2_OVER_PI_Q126 = {SQRT2_OVER_PI_Q126:#034x}")

    exhaustive = [4, 6, 8, 10, 12] if not quick else [4, 8]
    sampled = [14, 16] if not quick else [16]
    golden = {}
    for func in funcs:
        min_dist = 1.0
        checked = 0
        for m in exhaustive + sampled:
            q = m
            zs = (
                range(1 << m)
                if m in exhaustive
                else list(range(0, 1 << m, 97)) + [(1 << m) - 1]
            )
            h = FNV_OFFSET
            for z in zs:
                fl, ex, _ = mirror(func, z, m, q)
                h = fnv1a(fnv1a(h, fl), 1 if ex else 0)
                y = ref_y(func, z, m, q)
                true_fl = int(y.to_integral_value(rounding="ROUND_FLOOR"))
                assert fl == true_fl, f"{func} m={m} z={z}: {fl} != {true_fl} (y={y})"
                checked += 1
                if ex:
                    assert y == fl, f"{func} m={m} z={z}: claimed exact, y={y}"
                else:
                    # distance of the true value to the nearest integer: the
                    # headroom under the 2^-90 split_floor margin at shift 110.
                    frac = y - true_fl
                    d_true = min(frac, 1 - frac)
                    min_dist = min(min_dist, float(d_true))
            if m in exhaustive:
                golden[(func, m)] = h
        print(
            f"{func:9s} ok  ({checked} points, "
            f"min |Y - nearest int| = 2^{math.log2(min_dist):.1f} output ulp)"
        )
    print("\ngolden FNV-1a hashes (func, bits) -> hash:")
    for (func, m), h in sorted(golden.items()):
        print(f'    ("{func}", {m}, {h:#018x}),')


if __name__ == "__main__":
    main()
