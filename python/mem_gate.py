#!/usr/bin/env python3
"""Peak-RSS gate for the CI memory smoke.

Parses `/usr/bin/time -v` output (the "Maximum resident set size
(kbytes)" line) and fails (exit 1) when peak RSS exceeds the threshold.
The threshold for the 20-bit lazy-generate smoke is documented in
DESIGN.md §Scaling — update both together.

Appends a one-line result to $GITHUB_STEP_SUMMARY (or --summary) when set.

Usage: mem_gate.py TIME_OUTPUT_FILE THRESHOLD_KB [--summary FILE]
"""

import argparse
import os
import re
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("time_output")
    ap.add_argument("threshold_kb", type=int)
    ap.add_argument("--label", default="memory smoke")
    ap.add_argument("--summary", default=os.environ.get("GITHUB_STEP_SUMMARY"))
    args = ap.parse_args()

    with open(args.time_output) as f:
        text = f.read()
    m = re.search(r"Maximum resident set size \(kbytes\):\s*(\d+)", text)
    if not m:
        print(f"FAIL: no 'Maximum resident set size' line in {args.time_output}",
              file=sys.stderr)
        print(text, file=sys.stderr)
        return 1
    peak_kb = int(m.group(1))
    ok = peak_kb <= args.threshold_kb
    line = (
        f"{args.label}: peak RSS {peak_kb / 1024:.0f} MiB "
        f"(threshold {args.threshold_kb / 1024:.0f} MiB) — "
        f"{'OK' if ok else 'EXCEEDED'}"
    )
    print(line)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(f"### {args.label}\n\n{line}\n")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
