//! Technology comparison: the paper's closing claim, live.
//!
//! "Targeting alternative hardware technologies simply requires a
//! modified decision procedure to explore the space." Here the SAME
//! complete design space (recip 8-bit, R = 3 — naturally quadratic) is
//! explored by each shipped technology's default decision procedure and
//! costed by its own model. The ASIC ordering maximizes square-input
//! truncation; the FPGA cost model instead trades truncation for a
//! narrower `b` coefficient (narrow soft multipliers beat shallow
//! tables), selecting a different implementation — every one of which
//! still verifies exhaustively.
//!
//! Run: `cargo run --release --example tech_compare`

use polygen::pipeline::{Implementation, Pipeline, PipelineError, TechKind};

fn main() -> Result<(), PipelineError> {
    let (func, bits, lub) = ("recip", 8, 3);
    println!("one design space: {func} {bits}-bit, R = {lub}\n");
    println!(
        "{:<10} {:<13} {:>4} {:>2} {:>2} {:>16} {:>10} {:>12}",
        "tech", "procedure", "deg", "i", "j", "LUT [a,b,c]", "delay ns", "area"
    );

    let mut asic_impl: Option<Implementation> = None;
    for tech in TechKind::ALL {
        // Same function, same R — only the technology target changes.
        let v = Pipeline::function(func)
            .bits(bits)
            .lub(lub)
            .technology(tech)
            .run()?; // includes exhaustive verification
        assert!(v.report.ok());
        let im = &v.implementation;
        let differs = asic_impl.as_ref().is_some_and(|base| !base.same_selection(im));
        let marker = if differs { "  <- differs from asic-ge" } else { "" };
        if tech == TechKind::AsicGe {
            asic_impl = Some(im.clone());
        }
        let cm = tech.technology().cost_model();
        println!(
            "{:<10} {:<13} {:>4?} {:>2} {:>2} {:>16} {:>10.3} {:>7.1} {:<4}{}",
            tech.label(),
            tech.technology().default_procedure().name(),
            im.degree,
            im.sq_trunc,
            im.lin_trunc,
            im.lut_width_label(),
            v.synth.delay_ns,
            v.synth.area_um2,
            cm.area_unit(),
            marker
        );
    }

    println!(
        "\nAll three implementations verified exhaustively against the same \
         bound tables — different selections, same guarantee."
    );
    Ok(())
}
