use std::time::Instant;
use polygen::bounds::{builtin, AccuracySpec, BoundTable};
use polygen::designspace::{generate, GenOptions};
fn main() {
    let f = builtin("recip", 16).unwrap();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    for threads in [1usize, 8] {
        let t0 = Instant::now();
        let ds = generate(&bt, &GenOptions { lookup_bits: 6, threads, ..Default::default() }).unwrap();
        println!("threads={threads}: {:?} k={}", t0.elapsed(), ds.k);
    }
}
