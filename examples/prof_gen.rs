//! Profiling helper: time the generation stage of the pipeline alone,
//! serial vs parallel, on the paper's 16-bit reciprocal workload.
//!
//! Run: `cargo run --release --example prof_gen`

use polygen::pipeline::Pipeline;

fn main() -> Result<(), polygen::pipeline::PipelineError> {
    for threads in [1usize, 8] {
        let spaced = Pipeline::function("recip")
            .bits(16)
            .lub(6)
            .threads(threads)
            .prepare()?
            .generate()?;
        println!("threads={threads}: {:?} k={}", spaced.gen_time, spaced.space.k);
    }
    Ok(())
}
