//! LUT-height exploration for the base-2 logarithm (paper Fig. 3): the
//! optimal height is non-obvious and metric-dependent — this example
//! regenerates the tradeoff and reports the best height under three
//! different objectives.
//!
//! Run: `cargo run --release --example log2_lut_sweep`

use polygen::bounds::AccuracySpec;
use polygen::coordinator::{default_r_range, sweep_lub, Workload};
use polygen::designspace::GenOptions;
use polygen::dse::{Degree, DseOptions};

fn main() {
    for bits in [10u32, 16] {
        let w = Workload::prepare("log2", bits, AccuracySpec::Ulp(1)).unwrap();
        let pts = sweep_lub(
            &w,
            &default_r_range(bits),
            &GenOptions::default(),
            &DseOptions::default(),
            8,
        );
        println!("log2 {bits}-bit (0.y = log2(1.x), {} -> {} bits):", bits, bits + 1);
        println!(
            "  {:>4} {:>6} {:>10} {:>11} {:>11} {:>4}",
            "LUB", "deg", "delay ns", "area um2", "area*delay", "k"
        );
        let mut best_area: Option<(u32, f64)> = None;
        let mut best_delay: Option<(u32, f64)> = None;
        let mut best_adp: Option<(u32, f64)> = None;
        for p in &pts {
            let (Some(im), Some(sp)) = (&p.implementation, &p.synth) else {
                println!("  {:>4} infeasible (needs more regions)", p.lookup_bits);
                continue;
            };
            let deg = if im.degree == Degree::Linear { "lin" } else { "quad" };
            println!(
                "  {:>4} {:>6} {:>10.3} {:>11.1} {:>11.1} {:>4}",
                p.lookup_bits,
                deg,
                sp.delay_ns,
                sp.area_um2,
                sp.area_delay(),
                im.k
            );
            let upd = |slot: &mut Option<(u32, f64)>, v: f64| {
                if slot.map_or(true, |(_, b)| v < b) {
                    *slot = Some((p.lookup_bits, v));
                }
            };
            upd(&mut best_area, sp.area_um2);
            upd(&mut best_delay, sp.delay_ns);
            upd(&mut best_adp, sp.area_delay());
        }
        // The Fig. 3 takeaway: different metrics pick different heights.
        println!(
            "  optima: area -> LUB {}, delay -> LUB {}, area*delay -> LUB {}\n",
            best_area.map(|(r, _)| r).unwrap_or(0),
            best_delay.map(|(r, _)| r).unwrap_or(0),
            best_adp.map(|(r, _)| r).unwrap_or(0),
        );
    }
}
