//! LUT-height exploration for the base-2 logarithm (paper Fig. 3): the
//! optimal height is non-obvious and metric-dependent — this example
//! regenerates the tradeoff with `Pipeline::sweep` and reports the best
//! height under three different objectives.
//!
//! Run: `cargo run --release --example log2_lut_sweep`

use polygen::pipeline::{Degree, LubObjective, Pipeline};

fn main() {
    for bits in [10u32, 16] {
        let swept = Pipeline::function("log2")
            .bits(bits)
            .threads(8)
            .sweep()
            .expect("log2 is a built-in");
        println!("log2 {bits}-bit (0.y = log2(1.x), {} -> {} bits):", bits, bits + 1);
        println!(
            "  {:>4} {:>6} {:>10} {:>11} {:>11} {:>4}",
            "LUB", "deg", "delay ns", "area um2", "area*delay", "k"
        );
        for p in &swept.points {
            let (Some(im), Some(sp)) = (&p.implementation, &p.synth) else {
                println!("  {:>4} infeasible (needs more regions)", p.lookup_bits);
                continue;
            };
            let deg = if im.degree == Degree::Linear { "lin" } else { "quad" };
            println!(
                "  {:>4} {:>6} {:>10.3} {:>11.1} {:>11.1} {:>4}",
                p.lookup_bits,
                deg,
                sp.delay_ns,
                sp.area_um2,
                sp.area_delay(),
                im.k
            );
        }
        // The Fig. 3 takeaway: different metrics pick different heights.
        let winner = |obj| swept.best(obj).map(|p| p.lookup_bits).unwrap_or(0);
        println!(
            "  optima: area -> LUB {}, delay -> LUB {}, area*delay -> LUB {}\n",
            winner(LubObjective::Area),
            winner(LubObjective::Delay),
            winner(LubObjective::AreaDelay),
        );
    }
}
