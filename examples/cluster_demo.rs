//! A three-node polygen cluster in one process: a coordinator and two
//! shard workers on ephemeral ports, a worker agent registering each
//! worker (what `polygen serve --worker --coordinator <url>` runs), one
//! sharded generation job — and proof that the merged result is
//! identical to a single-node run. Then the chaos leg: one worker is
//! killed, a second job must still come back correct, and a `/metrics`
//! scrape must show the dispatch and failure machinery firing. This is
//! the CI cluster smoke test.
//!
//! ```text
//! cargo run --release --example cluster_demo
//! ```

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use polygen::pipeline::{JobSpec, LookupBits};
use polygen::service::http::HttpServer;
use polygen::service::{run_worker_agent, Service};

/// Minimal one-shot HTTP client (the server closes after each response).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: client\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let code = raw.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0);
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (code, body)
}

/// Read one sample from a Prometheus text scrape (`name value` lines;
/// `# HELP` / `# TYPE` lines never match because of the exact prefix).
fn prom_value(scrape: &str, name: &str) -> u64 {
    scrape
        .lines()
        .find_map(|l| l.strip_prefix(&format!("{name} ")))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or_else(|| panic!("{name} missing from /metrics scrape:\n{scrape}"))
}

fn main() {
    // Coordinator: the node jobs are submitted to.
    let coord_svc = Service::builder().workers(2).build();
    let coord = HttpServer::spawn(coord_svc.clone(), "127.0.0.1:0").expect("bind coordinator");
    println!("coordinator listening on http://{}", coord.addr());

    // Two workers, each running the register/heartbeat agent against the
    // coordinator — the in-process equivalent of two
    // `polygen serve --worker --coordinator http://{coord}` processes.
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    let mut agents = Vec::new();
    for i in 0..2 {
        let svc = Service::builder().workers(1).build();
        let server = HttpServer::spawn(svc, "127.0.0.1:0").expect("bind worker");
        println!("worker {i} listening on http://{}", server.addr());
        agents.push(run_worker_agent(
            coord.addr().to_string(),
            server.addr().to_string(),
            None,
            Arc::clone(&stop),
        ));
        workers.push(server);
    }

    // Wait until both workers have registered themselves.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (code, list) = http(coord.addr(), "GET", "/workers", "");
        assert_eq!(code, 200, "{list}");
        if list.matches("\"live\":true").count() >= 2 {
            println!("both workers registered: {list}");
            break;
        }
        assert!(Instant::now() < deadline, "workers never registered: {list}");
        std::thread::sleep(Duration::from_millis(50));
    }

    // One job, sharded across the cluster...
    let mut spec = JobSpec::new("recip", 10);
    spec.lookup = LookupBits::Fixed(5);
    let t0 = Instant::now();
    let via_cluster = coord_svc.submit(spec.clone()).wait().expect("recip 10b R=5 feasible");
    println!(
        "cluster run: R={} k={} delay {:.3} ns ({:?})",
        via_cluster.lookup_bits,
        via_cluster.implementation.k,
        via_cluster.synth.delay_ns,
        t0.elapsed()
    );

    // ...must match a single-node run exactly.
    let direct = spec.run().expect("single-node run feasible");
    assert_eq!(via_cluster.implementation.coeffs, direct.implementation.coeffs);
    assert_eq!(via_cluster.implementation.k, direct.implementation.k);
    assert_eq!(via_cluster.synth.delay_ns, direct.synth.delay_ns);
    println!("merged sharded result is identical to single-node: ok");

    // Chaos leg: kill one worker's server (its agent keeps heartbeating,
    // so the coordinator still dispatches to it and hits refused
    // connections). The job must still come back bit-identical, and the
    // failure machinery must leave a visible trail in /metrics.
    workers.remove(1).stop();
    let t1 = Instant::now();
    let degraded_run =
        coord_svc.submit(spec.clone()).wait().expect("recip 10b R=5 feasible with a dead worker");
    assert_eq!(degraded_run.implementation.coeffs, direct.implementation.coeffs);
    println!("one-dead-worker run is still correct ({:?})", t1.elapsed());

    let (code, scrape) = http(coord.addr(), "GET", "/metrics", "");
    assert_eq!(code, 200, "{scrape}");
    if polygen::obs::metrics::COMPILED {
        let dispatched = prom_value(&scrape, "polygen_cluster_shards_dispatched_total");
        let calls = prom_value(&scrape, "polygen_net_calls_total");
        let recovery = prom_value(&scrape, "polygen_net_call_failures_total")
            + prom_value(&scrape, "polygen_net_retries_total")
            + prom_value(&scrape, "polygen_cluster_shards_reassigned_total")
            + prom_value(&scrape, "polygen_cluster_degraded_total");
        assert!(dispatched > 0, "no shards dispatched\n{scrape}");
        assert!(calls > 0, "no policy-wrapped calls recorded\n{scrape}");
        assert!(recovery > 0, "dead worker left no failure trail in /metrics\n{scrape}");
        println!("metrics: dispatched={dispatched} calls={calls} recovery_events={recovery}");
    }

    stop.store(true, Ordering::Relaxed);
    for agent in agents {
        let _ = agent.join();
    }
    for w in workers {
        w.stop();
    }
    coord.stop();
    polygen::pipeline::shutdown();
    println!("cluster demo complete; bye");
}
