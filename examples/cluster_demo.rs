//! A three-node polygen cluster in one process: a coordinator and two
//! shard workers on ephemeral ports, a worker agent registering each
//! worker (what `polygen serve --worker --coordinator <url>` runs), one
//! sharded generation job — and proof that the merged result is
//! identical to a single-node run. This is the CI cluster smoke test.
//!
//! ```text
//! cargo run --release --example cluster_demo
//! ```

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use polygen::pipeline::{JobSpec, LookupBits};
use polygen::service::http::HttpServer;
use polygen::service::{run_worker_agent, Service};

/// Minimal one-shot HTTP client (the server closes after each response).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: client\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let code = raw.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0);
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (code, body)
}

fn main() {
    // Coordinator: the node jobs are submitted to.
    let coord_svc = Service::builder().workers(2).build();
    let coord = HttpServer::spawn(coord_svc.clone(), "127.0.0.1:0").expect("bind coordinator");
    println!("coordinator listening on http://{}", coord.addr());

    // Two workers, each running the register/heartbeat agent against the
    // coordinator — the in-process equivalent of two
    // `polygen serve --worker --coordinator http://{coord}` processes.
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    let mut agents = Vec::new();
    for i in 0..2 {
        let svc = Service::builder().workers(1).build();
        let server = HttpServer::spawn(svc, "127.0.0.1:0").expect("bind worker");
        println!("worker {i} listening on http://{}", server.addr());
        agents.push(run_worker_agent(
            coord.addr().to_string(),
            server.addr().to_string(),
            None,
            Arc::clone(&stop),
        ));
        workers.push(server);
    }

    // Wait until both workers have registered themselves.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (code, list) = http(coord.addr(), "GET", "/workers", "");
        assert_eq!(code, 200, "{list}");
        if list.matches("\"live\":true").count() >= 2 {
            println!("both workers registered: {list}");
            break;
        }
        assert!(Instant::now() < deadline, "workers never registered: {list}");
        std::thread::sleep(Duration::from_millis(50));
    }

    // One job, sharded across the cluster...
    let mut spec = JobSpec::new("recip", 10);
    spec.lookup = LookupBits::Fixed(5);
    let t0 = Instant::now();
    let via_cluster = coord_svc.submit(spec.clone()).wait().expect("recip 10b R=5 feasible");
    println!(
        "cluster run: R={} k={} delay {:.3} ns ({:?})",
        via_cluster.lookup_bits,
        via_cluster.implementation.k,
        via_cluster.synth.delay_ns,
        t0.elapsed()
    );

    // ...must match a single-node run exactly.
    let direct = spec.run().expect("single-node run feasible");
    assert_eq!(via_cluster.implementation.coeffs, direct.implementation.coeffs);
    assert_eq!(via_cluster.implementation.k, direct.implementation.k);
    assert_eq!(via_cluster.synth.delay_ns, direct.synth.delay_ns);
    println!("merged sharded result is identical to single-node: ok");

    stop.store(true, Ordering::Relaxed);
    for agent in agents {
        let _ = agent.join();
    }
    for w in workers {
        w.stop();
    }
    coord.stop();
    polygen::pipeline::shutdown();
    println!("cluster demo complete; bye");
}
