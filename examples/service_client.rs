//! Drive the polygen job service over HTTP: spawn an in-process
//! `polygen serve` equivalent on an ephemeral port, submit several jobs
//! concurrently, poll their statuses, cancel one, and fetch results —
//! exactly the workflow a remote client would run against
//! `polygen serve --port 7878`.
//!
//! ```text
//! cargo run --release --example service_client
//! ```

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use polygen::service::http::HttpServer;
use polygen::service::Service;

/// Minimal one-shot HTTP client (the server closes after each response).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: client\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let code = raw.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0);
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (code, body)
}

fn field_u64(body: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat).expect(key);
    body[at + pat.len()..].chars().take_while(char::is_ascii_digit).collect::<String>()
        .parse()
        .expect(key)
}

fn status_of(body: &str) -> String {
    let pat = "\"status\":\"";
    let at = body.find(pat).map(|i| i + pat.len()).unwrap_or(0);
    body[at..].chars().take_while(|c| *c != '"').collect()
}

fn main() {
    // Server side: what `polygen serve` does, on an ephemeral port.
    let service = Service::builder().workers(4).build();
    let server = HttpServer::spawn(service, "127.0.0.1:0").expect("bind");
    let addr = server.addr();
    println!("service listening on http://{addr}");

    // Client side: concurrent submissions — three quick jobs (TOML and
    // JSON bodies) and one heavy auto-LUB sweep we will abandon.
    let jobs: Vec<(&str, String)> = vec![
        ("recip 8b R=4 (toml)", "func = recip\nbits = 8\n[generate]\nlookup_bits = 4\n".into()),
        ("log2 8b R=4 (json)", r#"{"func":"log2","bits":8,"generate":{"lookup_bits":4}}"#.into()),
        ("exp2 8b R=4 (toml)", "func = exp2\nbits = 8\n[generate]\nlookup_bits = 4\n".into()),
        (
            "recip 16b auto (doomed)",
            "func = recip\nbits = 16\n[generate]\nlookup_bits = auto\nthreads = 2\n\
             [job]\nverify = false\n"
                .into(),
        ),
    ];
    let ids: Vec<(u64, &str)> = std::thread::scope(|scope| {
        jobs.iter()
            .map(|(name, body)| {
                scope.spawn(move || {
                    let (code, resp) = http(addr, "POST", "/jobs", body);
                    assert_eq!(code, 201, "{resp}");
                    let id = field_u64(&resp, "id");
                    println!("submitted {name} -> id {id}");
                    (id, *name)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });

    // Change of plans: cancel the sweep.
    let (doomed_id, doomed_name) = *ids.last().expect("four jobs submitted");
    let (code, resp) = http(addr, "DELETE", &format!("/jobs/{doomed_id}"), "");
    println!("cancelling {doomed_name}: DELETE /jobs/{doomed_id} -> {code} ({})", status_of(&resp));
    assert_eq!(code, 200);

    // Poll everything to a terminal state, printing live phase/progress.
    let mut pending: Vec<(u64, &str)> = ids.clone();
    while !pending.is_empty() {
        std::thread::sleep(Duration::from_millis(150));
        pending.retain(|(id, name)| {
            let (_, body) = http(addr, "GET", &format!("/jobs/{id}"), "");
            let status = status_of(&body);
            match status.as_str() {
                "done" | "failed" | "cancelled" => {
                    println!("{name}: {status}");
                    false
                }
                "running" => {
                    let phase = body
                        .split("\"phase\":\"")
                        .nth(1)
                        .map(|s| s.chars().take_while(|c| *c != '"').collect::<String>())
                        .unwrap_or_default();
                    println!("{name}: running ({phase})");
                    true
                }
                other => {
                    println!("{name}: {other}");
                    true
                }
            }
        });
    }

    // Fetch results: the three quick jobs must deliver, the doomed one
    // must report 409/cancelled.
    for (id, name) in &ids {
        let (code, body) = http(addr, "GET", &format!("/jobs/{id}/result"), "");
        if *id == doomed_id {
            assert_eq!(code, 409, "{body}");
            println!("{name}: result -> 409 cancelled (as requested)");
        } else {
            assert_eq!(code, 200, "{body}");
            println!(
                "{name}: R={} LUT {} delay {} ns",
                field_u64(&body, "lookup_bits"),
                body.split("\"lut_width\":\"").nth(1).map(|s| s.split('"').next().unwrap_or(""))
                    .unwrap_or(""),
                body.split("\"delay_ns\":").nth(1).map(|s| s.split(',').next().unwrap_or(""))
                    .unwrap_or("")
            );
        }
    }
    server.stop();
    polygen::pipeline::shutdown();
    println!("all jobs settled; scheduler drained; bye");
}
