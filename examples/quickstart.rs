//! Quickstart: one staged pipeline run — generate the complete design
//! space for a 10-bit reciprocal, explore it with the paper's decision
//! procedure, cost it, verify exhaustively, and emit Verilog.
//!
//! Run: `cargo run --release --example quickstart`

use polygen::pipeline::{emit_module, Pipeline};

fn main() -> Result<(), polygen::pipeline::PipelineError> {
    // 1. The target: 0.1y = 1/1.x at 10 input / 10 output bits, 1 ULP,
    //    32 regions (R = 5 lookup bits).
    let prepared = Pipeline::function("recip").bits(10).lub(5).prepare()?;
    println!("target: {}", prepared.workload.func.mapping());

    // 2. Complete design space — an inspectable artifact, not an
    //    intermediate. Regions are lazy: the size metrics below stream
    //    over the stored envelopes, and entries materialize only when
    //    the decision procedure (step 3) touches them.
    let spaced = prepared.generate()?;
    println!(
        "design space: k = {}, {} regions, {} (a,b) pairs, linear feasible = {}",
        spaced.space.k,
        spaced.space.num_regions(),
        spaced.space.num_ab_pairs(),
        spaced.space.linear_feasible()
    );

    // 3. Decision procedure: truncations + Algorithm 1 width minimization.
    let explored = spaced.explore()?;
    println!(
        "implementation: {:?}, sq_trunc = {}, lin_trunc = {}, LUT {}",
        explored.implementation.degree,
        explored.implementation.sq_trunc,
        explored.implementation.lin_trunc,
        explored.implementation.lut_width_label()
    );

    // 4. Cost model, then exhaustive verification (the HECTOR
    //    substitute). A violation would surface as
    //    PipelineError::VerifyFailed with its first counterexample.
    let verified = explored.synthesize().verify()?;
    println!("verified all {} inputs: 0 violations", verified.report.total);
    println!(
        "cost model: {:.3} ns, {:.1} um2 at minimum delay",
        verified.synth.delay_ns, verified.synth.area_um2
    );

    // 5. RTL.
    let verilog = emit_module(&verified.implementation, "recip10");
    println!("--- first lines of generated Verilog ---");
    for line in verilog.lines().take(12) {
        println!("{line}");
    }
    Ok(())
}
