//! Quickstart: generate the complete design space for a 10-bit reciprocal,
//! explore it with the paper's decision procedure, verify exhaustively,
//! and emit Verilog.
//!
//! Run: `cargo run --release --example quickstart`

use polygen::bounds::{builtin, AccuracySpec, BoundTable};
use polygen::designspace::{generate, GenOptions};
use polygen::dse::{explore, DseOptions};
use polygen::rtl;
use polygen::synth::synth_min_delay;
use polygen::verify::{verify_exhaustive, Engine};

fn main() -> anyhow::Result<()> {
    // 1. The target: 0.1y = 1/1.x at 10 input / 10 output bits, 1 ULP.
    let f = builtin("recip", 10).expect("built-in function");
    println!("target: {}", f.mapping());
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));

    // 2. Complete design space at R = 5 lookup bits (32 regions).
    let ds = generate(&bt, &GenOptions { lookup_bits: 5, ..Default::default() })
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "design space: k = {}, {} regions, {} (a,b) pairs, linear feasible = {}",
        ds.k,
        ds.regions.len(),
        ds.num_ab_pairs(),
        ds.linear_feasible()
    );

    // 3. Decision procedure: truncations + Algorithm 1 width minimization.
    let im = explore(&bt, &ds, &DseOptions::default()).expect("DSE");
    println!(
        "implementation: {:?}, sq_trunc = {}, lin_trunc = {}, LUT {}",
        im.degree,
        im.sq_trunc,
        im.lin_trunc,
        im.lut_width_label()
    );

    // 4. Exhaustive verification (the HECTOR substitute).
    let rep = verify_exhaustive(&bt, &im, &Engine::Scalar)?;
    anyhow::ensure!(rep.ok(), "verification failed: {rep:?}");
    println!("verified all {} inputs: 0 violations", rep.total);

    // 5. Cost and RTL.
    let p = synth_min_delay(&im);
    println!("cost model: {:.3} ns, {:.1} um2 at minimum delay", p.delay_ns, p.area_um2);
    let verilog = rtl::emit_module(&im, "recip10");
    println!("--- first lines of generated Verilog ---");
    for line in verilog.lines().take(12) {
        println!("{line}");
    }
    Ok(())
}
