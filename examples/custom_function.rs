//! Bring your own function: the generator is not limited to the paper's
//! three workloads. This example approximates `sin(pi/4 * x)` on `[0, 1)`
//! — a common range-reduced sine segment — from an `f64` closure, then
//! generates, explores, verifies and emits RTL.
//!
//! (For production bounds implement `TargetFunction` with exact integer
//! arithmetic as `bounds::functions` does; the closure path guards its
//! floors with an ambiguity margin — see `CustomF64`.)
//!
//! Run: `cargo run --release --example custom_function`

use polygen::bounds::{AccuracySpec, BoundTable, CustomF64};
use polygen::designspace::{generate, min_lookup_bits, GenOptions};
use polygen::dse::{explore, DseOptions};
use polygen::rtl;
use polygen::synth::synth_min_delay;
use polygen::verify::{verify_exhaustive, Engine};

fn main() -> anyhow::Result<()> {
    let f = CustomF64 {
        name: "sin_pi4".into(),
        in_bits: 12,
        out_bits: 12,
        f: |x: f64| (std::f64::consts::FRAC_PI_4 * x).sin(),
        margin: 1e-7,
    };
    let bt = BoundTable::build(&f, AccuracySpec::Ulp(1));

    // How many regions does this function *need*? (paper §I: the complete
    // space determines the minimum.)
    let opts = GenOptions::default();
    let rmin = min_lookup_bits(&bt, &opts, 10).expect("feasible at some R");
    println!("sin(pi/4 x) @ 12 bits: minimum lookup bits = {rmin}");

    // Generate at rmin and one relaxed height; compare hardware.
    for r in [rmin, rmin + 2] {
        let ds = generate(&bt, &GenOptions { lookup_bits: r, ..opts })
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let im = explore(&bt, &ds, &DseOptions::default()).expect("DSE");
        let rep = verify_exhaustive(&bt, &im, &Engine::Scalar)?;
        anyhow::ensure!(rep.ok(), "verification failed at R={r}: {rep:?}");
        let p = synth_min_delay(&im);
        println!(
            "  R={r}: {:?}, LUT {}, verified {} inputs, {:.3} ns / {:.1} um2",
            im.degree,
            im.lut_width_label(),
            rep.total,
            p.delay_ns,
            p.area_um2
        );
        if r == rmin {
            let v = rtl::emit_module(&im, "sin_pi4");
            println!("  generated {} lines of Verilog (module sin_pi4)", v.lines().count());
        }
    }
    Ok(())
}
