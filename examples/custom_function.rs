//! Bring your own function: the generator is not limited to the paper's
//! built-in workloads. This example approximates `sin(pi/4 * x)` on
//! `[0, 1)` — a common range-reduced sine segment — from an `f64`
//! closure, then runs the pipeline at the minimum feasible LUT height
//! and one relaxed height.
//!
//! (For production bounds implement `TargetFunction` with exact integer
//! arithmetic as `bounds::functions` does; the closure path guards its
//! floors with an ambiguity margin — see `CustomF64`.)
//!
//! Run: `cargo run --release --example custom_function`

use polygen::pipeline::{CustomF64, Pipeline, PipelineError};

fn sin_pi4() -> CustomF64<fn(f64) -> f64> {
    CustomF64 {
        name: "sin_pi4".into(),
        in_bits: 12,
        out_bits: 12,
        f: |x: f64| (std::f64::consts::FRAC_PI_4 * x).sin(),
        margin: 1e-7,
    }
}

fn main() -> Result<(), PipelineError> {
    // How many regions does this function *need*? (paper §I: the complete
    // space determines the minimum.)
    let rmin = Pipeline::custom(Box::new(sin_pi4()))
        .prepare()?
        .min_lookup_bits(10)
        .expect("feasible at some R");
    println!("sin(pi/4 x) @ 12 bits: minimum lookup bits = {rmin}");

    // Run the pipeline at rmin and one relaxed height; compare hardware.
    for r in [rmin, rmin + 2] {
        let verified = Pipeline::custom(Box::new(sin_pi4())).lub(r).run()?;
        println!(
            "  R={r}: {:?}, LUT {}, verified {} inputs, {:.3} ns / {:.1} um2",
            verified.implementation.degree,
            verified.implementation.lut_width_label(),
            verified.report.total,
            verified.synth.delay_ns,
            verified.synth.area_um2
        );
        if r == rmin {
            let dir = std::env::temp_dir().join("polygen_sin_pi4_rtl");
            let emitted = verified.emit_rtl(&dir)?;
            println!(
                "  emitted {} (+{} more files) under {}",
                emitted.module,
                emitted.files.len().saturating_sub(1),
                dir.display()
            );
        }
    }
    Ok(())
}
