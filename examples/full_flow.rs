//! End-to-end driver (DESIGN.md E7): the full three-layer system on a real
//! workload — 16-bit reciprocal, the paper's Table I row.
//!
//! generate (parallel, Claim II.1-pruned) -> DSE -> RTL emission ->
//! exhaustive verification through the AOT-compiled XLA graph (all 65 536
//! inputs in one PJRT chunk) -> Pallas-flavor cross-check -> behavioural
//! RTZ/R+inf bracket -> cost-model report.
//!
//! Requires artifacts: `make artifacts` first.
//! Run: `cargo run --release --example full_flow`

use std::time::Instant;

use polygen::bounds::{builtin, AccuracySpec, BoundTable};
use polygen::designspace::{generate, GenOptions};
use polygen::dse::{explore, DseOptions};
use polygen::rtl::{self, DatapathSim};
use polygen::runtime::{Flavor, XlaRuntime};
use polygen::synth::{breakdown, synth_min_delay};
use polygen::verify::{cross_check_sample, verify_exhaustive, Engine};

fn main() -> anyhow::Result<()> {
    let bits = 16u32;
    let lub = 8u32;
    println!("=== polygen full flow: recip {bits}-bit, R = {lub} ===");

    // --- L3: generation (the paper's core algorithm) ---
    let f = builtin("recip", bits).unwrap();
    let t0 = Instant::now();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    println!("[bounds ] exact l/u over 2^{bits} inputs in {:?}", t0.elapsed());

    let t0 = Instant::now();
    let ds = generate(
        &bt,
        &GenOptions { lookup_bits: lub, threads: 8, ..Default::default() },
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "[space  ] k = {}, {} regions, {} (a,b) pairs, linear = {}, {:?} ({} dd evals)",
        ds.k,
        ds.regions.len(),
        ds.num_ab_pairs(),
        ds.linear_feasible(),
        t0.elapsed(),
        ds.dd_evals
    );

    let t0 = Instant::now();
    let im = explore(&bt, &ds, &DseOptions::default()).expect("DSE");
    println!(
        "[dse    ] {:?}, i = {}, j = {}, LUT {} in {:?}",
        im.degree,
        im.sq_trunc,
        im.lin_trunc,
        im.lut_width_label(),
        t0.elapsed()
    );

    // --- RTL + netlist-level simulation spot check ---
    let verilog = rtl::emit_module(&im, "recip16");
    let sim = DatapathSim::new(&im);
    for z in (0..(1u64 << bits)).step_by(997) {
        assert_eq!(sim.eval(z), im.eval(z));
    }
    println!("[rtl    ] {} lines of Verilog; netlist sim spot check ok", verilog.lines().count());

    // --- L1/L2: exhaustive verification through PJRT ---
    let rt = XlaRuntime::load("artifacts")?;
    let t0 = Instant::now();
    let rep = verify_exhaustive(&bt, &im, &Engine::Xla { rt: &rt, flavor: Flavor::Jnp })?;
    let t_xla = t0.elapsed();
    anyhow::ensure!(rep.ok(), "XLA verification failed: {rep:?}");
    println!("[verify ] XLA(jnp): {} inputs, 0 violations, {:?}", rep.total, t_xla);

    let t0 = Instant::now();
    let rep_s = verify_exhaustive(&bt, &im, &Engine::Scalar)?;
    println!(
        "[verify ] scalar  : {} inputs, 0 violations, {:?} (xla speedup {:.1}x)",
        rep_s.total,
        t0.elapsed(),
        t0.elapsed().as_secs_f64() / t_xla.as_secs_f64().max(1e-9)
    );
    anyhow::ensure!(rep == rep_s, "engine disagreement");

    if rt.has_flavor(Flavor::Pallas) {
        let ok = cross_check_sample(&bt, &im, &rt, Flavor::Pallas, 33)?;
        anyhow::ensure!(ok, "pallas flavor disagreed with scalar eval");
        println!("[verify ] pallas flavor cross-check: ok");
    }

    // --- Behavioural bracket (the paper's HECTOR check for recip) ---
    rtl::behavioral::recip_between_roundings(&im)
        .map_err(|(z, y, lo, hi)| anyhow::anyhow!("bracket failed at z={z}: {y} not in [{lo},{hi}]"))?;
    println!("[hector~] output between RTZ and R+inf behavioural references");

    // --- Cost model ---
    let b = breakdown(&im);
    let p = synth_min_delay(&im);
    println!(
        "[synth  ] min delay {:.3} ns, area {:.1} um2 (LUT {:.0} GE, sq {:.0} GE, \
         mults {:.0} GE, add {:.0} GE)",
        p.delay_ns,
        p.area_um2,
        b.lut.area_ge,
        b.squarer.area_ge,
        b.mult_a.area_ge + b.mult_b.area_ge,
        b.accumulate.area_ge
    );
    println!("=== full flow complete ===");
    Ok(())
}
