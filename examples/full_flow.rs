//! End-to-end driver (DESIGN.md E7): the full three-layer system on a real
//! workload — 16-bit reciprocal, the paper's Table I row — as one staged
//! pipeline.
//!
//! prepare -> generate (parallel, Claim II.1-pruned) -> explore ->
//! synthesize -> exhaustive verification through the AOT-compiled XLA
//! graph (all 65 536 inputs in one PJRT chunk) -> Pallas-flavor
//! cross-check -> behavioural RTZ/R+inf bracket -> cost-model report.
//!
//! Requires artifacts: `make artifacts` first.
//! Run: `cargo run --release --example full_flow`

use std::time::Instant;

use polygen::pipeline::{breakdown, DatapathSim, Engine, Flavor, Pipeline, XlaRuntime};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let bits = 16u32;
    let lub = 8u32;
    println!("=== polygen full flow: recip {bits}-bit, R = {lub} ===");

    // --- L3: generation (the paper's core algorithm) ---
    let t0 = Instant::now();
    let prepared = Pipeline::function("recip").bits(bits).lub(lub).threads(8).prepare()?;
    println!("[bounds ] exact l/u over 2^{bits} inputs in {:?}", t0.elapsed());

    let spaced = prepared.generate()?;
    println!(
        "[space  ] k = {}, {} regions, {} (a,b) pairs, linear = {}, {:?} ({} dd evals)",
        spaced.space.k,
        spaced.space.num_regions(),
        spaced.space.num_ab_pairs(),
        spaced.space.linear_feasible(),
        spaced.gen_time,
        spaced.space.dd_evals
    );

    let t0 = Instant::now();
    let synthesized = spaced.explore()?.synthesize();
    let im = &synthesized.implementation;
    println!(
        "[dse    ] {:?}, i = {}, j = {}, LUT {} in {:?}",
        im.degree,
        im.sq_trunc,
        im.lin_trunc,
        im.lut_width_label(),
        t0.elapsed()
    );

    // --- RTL + netlist-level simulation spot check ---
    let sim = DatapathSim::new(im);
    for z in (0..(1u64 << bits)).step_by(997) {
        assert_eq!(sim.eval(z), im.eval(z));
    }
    println!("[rtl    ] netlist sim spot check ok");

    // --- L1/L2: exhaustive verification through PJRT ---
    let rt = XlaRuntime::load("artifacts")?;
    let t0 = Instant::now();
    let verified = synthesized.verify_with(&rt, Flavor::Jnp)?;
    let t_xla = t0.elapsed();
    println!(
        "[verify ] XLA(jnp): {} inputs, 0 violations, {:?}",
        verified.report.total, t_xla
    );

    // Scalar re-run (the trust anchor) must agree bit for bit.
    let t0 = Instant::now();
    let rep_s = polygen::pipeline::verify_implementation(
        &verified.workload.bt,
        &verified.implementation,
        &Engine::Scalar,
    )?;
    println!(
        "[verify ] scalar  : {} inputs, 0 violations, {:?} (xla speedup {:.1}x)",
        rep_s.total,
        t0.elapsed(),
        t0.elapsed().as_secs_f64() / t_xla.as_secs_f64().max(1e-9)
    );
    assert_eq!(verified.report, rep_s, "engine disagreement");

    if rt.has_flavor(Flavor::Pallas) {
        let ok = verified.cross_check(&rt, Flavor::Pallas, 33)?;
        assert!(ok, "pallas flavor disagreed with scalar eval");
        println!("[verify ] pallas flavor cross-check: ok");
    }

    // --- Behavioural bracket (the paper's HECTOR check for recip) ---
    verified.check_behavioural_bracket()?;
    println!("[hector~] output between RTZ and R+inf behavioural references");

    // --- Cost model ---
    let b = breakdown(&verified.implementation);
    println!(
        "[synth  ] min delay {:.3} ns, area {:.1} um2 (LUT {:.0} GE, sq {:.0} GE, \
         mults {:.0} GE, add {:.0} GE)",
        verified.synth.delay_ns,
        verified.synth.area_um2,
        b.lut.area_ge,
        b.squarer.area_ge,
        b.mult_a.area_ge + b.mult_b.area_ge,
        b.accumulate.area_ge
    );
    println!("=== full flow complete ===");
    Ok(())
}
