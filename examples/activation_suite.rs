//! Activation suite: the four activation-function workloads (tanh,
//! sigmoid, GELU, softplus) at 8 input bits. For each one, find the
//! smallest LUT height whose complete quadratic space exists and the
//! smallest whose degree-1 (linear) slice exists, then run the full
//! staged pipeline — generate, explore, cost, exhaustively verify — at
//! both minima.
//!
//! Run: `cargo run --release --example activation_suite`

use polygen::bounds::AccuracySpec;
use polygen::coordinator::Workload;
use polygen::designspace::{min_lookup_bits, GenOptions};
use polygen::pipeline::Pipeline;

fn main() -> Result<(), polygen::pipeline::PipelineError> {
    for func in ["tanh", "sigmoid", "gelu", "softplus"] {
        // Probe the bound table directly; R = bits (one point per
        // region) is always feasible, so both minima exist.
        let w = Workload::prepare(func, 8, AccuracySpec::Ulp(1)).expect("builtin activation");
        let quad = GenOptions::default();
        let r2 = min_lookup_bits(&w.bt, &quad, 8).expect("degree-2 minimum");
        let r1 = min_lookup_bits(&w.bt, &GenOptions { degree: 1, ..quad }, 8)
            .expect("degree-1 minimum");
        println!("{func}: minimal lookup bits = {r2} (quadratic), {r1} (linear)");

        // Full run at the quadratic minimum: a violation would surface
        // as PipelineError::VerifyFailed with its first counterexample.
        let verified = Pipeline::function(func).bits(8).lub(r2).run()?;
        println!(
            "  degree 2: k = {}, {} (a,b) pairs, picked {:?}, verified {} inputs",
            verified.space.k,
            verified.space.num_ab_pairs(),
            verified.implementation.degree,
            verified.report.total
        );

        // Degree-1 generation keeps only the a = 0 row of every region,
        // so the explorer can only pick a linear interpolator.
        let linear = Pipeline::function(func).bits(8).lub(r1).gen_degree(1).run()?;
        println!(
            "  degree 1: k = {}, all-linear space of {} entries, verified {} inputs",
            linear.space.k,
            linear.space.num_ab_pairs(),
            linear.report.total
        );
    }
    Ok(())
}
