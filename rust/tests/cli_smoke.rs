//! CLI smoke tests: drive the `polygen` binary end to end via
//! `std::process` (the closest thing to a user's shell).

use std::path::PathBuf;
use std::process::Command;

fn polygen() -> Command {
    let exe = PathBuf::from(env!("CARGO_BIN_EXE_polygen"));
    Command::new(exe)
}

#[test]
fn generate_prints_space_summary() {
    let out = polygen()
        .args(["generate", "--func", "recip", "--bits", "10", "--lub", "5"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("design space: recip 10b R=5"), "{s}");
    assert!(s.contains("linear_ok"), "{s}");
}

#[test]
fn dse_prints_coefficients() {
    let out = polygen()
        .args(["dse", "--func", "log2", "--bits", "10", "--lub", "5"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("impl [asic-ge]:"), "{s}");
    assert!(s.contains("r=0:"), "{s}");
}

#[test]
fn dse_accepts_technology_flag() {
    let out = polygen()
        .args(["dse", "--func", "recip", "--bits", "8", "--lub", "3", "--tech", "fpga-lut6"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("impl [fpga-lut6]:"), "{s}");
    // Unknown technologies fail with a helpful message.
    let bad = polygen()
        .args(["dse", "--func", "recip", "--bits", "8", "--lub", "3", "--tech", "tpu"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("bad tech"));
}

#[test]
fn verify_scalar_passes() {
    let out = polygen()
        .args(["verify", "--func", "exp2", "--bits", "10", "--lub", "5"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("0 violations"), "{s}");
}

#[test]
fn rtl_writes_files() {
    let dir = std::env::temp_dir().join(format!("polygen_rtl_{}", std::process::id()));
    let out = polygen()
        .args([
            "rtl", "--func", "recip", "--bits", "8", "--lub", "4", "--tb", "--out",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("recip_8b_r4.v").exists());
    assert!(dir.join("recip_8b_r4_tb.v").exists());
    assert!(dir.join("recip_8b_r4_golden.hex").exists());
    assert!(dir.join("recip_behavioral.v").exists());
    let v = std::fs::read_to_string(dir.join("recip_8b_r4.v")).unwrap();
    assert!(v.contains("module recip_8b_r4"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn report_linear_runs() {
    let out = polygen().args(["report", "linear"]).output().unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("linear feasible"), "{s}");
}

#[test]
fn config_file_flow() {
    let cfg = std::env::temp_dir().join(format!("polygen_cfg_{}.toml", std::process::id()));
    std::fs::write(&cfg, "func = exp2\nbits = 10\n[generate]\nlookup_bits = 5\n").unwrap();
    let out = polygen()
        .args(["config", "--file", cfg.to_str().unwrap(), "--set", "generate.lookup_bits=6"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("exp2 10b R=6"), "{s}");
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = polygen().args(["frobnicate"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn bad_function_reports_error() {
    let out = polygen()
        .args(["generate", "--func", "tan", "--bits", "10", "--lub", "5"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown function"));
}
