//! End-to-end tests for the HTTP/JSON front-end: a live `serve` loop on
//! an ephemeral port, driven over real TCP — including the acceptance
//! scenario (≥ 4 concurrent submissions, one cancelled, correct results
//! and statuses for all).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use polygen::pipeline::{JobSpec, LookupBits};
use polygen::service::http::HttpServer;
use polygen::service::Service;

fn server() -> HttpServer {
    let svc = Service::builder().workers(4).build();
    HttpServer::spawn(svc, "127.0.0.1:0").expect("bind ephemeral port")
}

/// One-shot HTTP/1.1 client: returns (status code, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("server closes after one response");
    let code: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (code, body)
}

/// Extract `"key":<integer>` from a flat JSON body.
fn json_u64(body: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat).unwrap_or_else(|| panic!("{key} missing in {body}"));
    body[at + pat.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or_else(|_| panic!("{key} not an integer in {body}"))
}

fn poll_until(
    addr: SocketAddr,
    id: u64,
    target: &str,
    timeout: Duration,
) -> String {
    let deadline = Instant::now() + timeout;
    loop {
        let (code, body) = http(addr, "GET", &format!("/jobs/{id}"), "");
        assert_eq!(code, 200, "{body}");
        if body.contains(&format!("\"status\":\"{target}\"")) {
            return body;
        }
        assert!(
            Instant::now() < deadline,
            "job {id} never reached {target}; last status: {body}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn http_round_trips_a_recip8_job_end_to_end() {
    let server = server();
    let addr = server.addr();

    // Submit the job-file TOML the CLI batch command takes.
    let spec_toml = "func = recip\nbits = 8\n[generate]\nlookup_bits = 4\n";
    let (code, body) = http(addr, "POST", "/jobs", spec_toml);
    assert_eq!(code, 201, "{body}");
    assert!(body.contains("\"label\":\"recip_8b_R4\""), "{body}");
    let id = json_u64(&body, "id");

    poll_until(addr, id, "done", Duration::from_secs(120));
    let (code, result) = http(addr, "GET", &format!("/jobs/{id}/result"), "");
    assert_eq!(code, 200, "{result}");

    // The wire result must match an in-process run of the same spec.
    let mut spec = JobSpec::new("recip", 8);
    spec.lookup = LookupBits::Fixed(4);
    let direct = spec.run().expect("recip 8b R=4 feasible");
    assert_eq!(json_u64(&result, "lookup_bits"), u64::from(direct.lookup_bits));
    assert_eq!(json_u64(&result, "k"), u64::from(direct.implementation.k));
    assert_eq!(
        json_u64(&result, "verified"),
        direct.verify.as_ref().unwrap().total,
        "verification count differs: {result}"
    );
    for co in &direct.implementation.coeffs {
        let frag = format!("{{\"a\":{},\"b\":{},\"c\":{}}}", co.a, co.b, co.c);
        assert!(result.contains(&frag), "coeff {frag} missing in {result}");
    }

    // The registry listing contains the job.
    let (code, list) = http(addr, "GET", "/jobs", "");
    assert_eq!(code, 200);
    assert!(list.starts_with('[') && list.contains("recip_8b_R4"), "{list}");

    server.stop();
}

#[test]
fn http_accepts_json_specs_and_rejects_bad_ones() {
    let server = server();
    let addr = server.addr();

    let (code, body) = http(
        addr,
        "POST",
        "/jobs",
        r#"{"func":"exp2","bits":8,"generate":{"lookup_bits":4},"job":{"verify":true}}"#,
    );
    assert_eq!(code, 201, "{body}");
    let id = json_u64(&body, "id");
    poll_until(addr, id, "done", Duration::from_secs(120));
    let (code, result) = http(addr, "GET", &format!("/jobs/{id}/result"), "");
    assert_eq!(code, 200);
    assert!(result.contains("\"func\":\"exp2\""), "{result}");

    // Bad spec value → 400 with a message; bad JSON likewise.
    let (code, body) = http(addr, "POST", "/jobs", "bits = many\n");
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("error"), "{body}");
    let (code, _) = http(addr, "POST", "/jobs", "{\"a\":[1]}");
    assert_eq!(code, 400);

    // Unknown ids and routes.
    let (code, _) = http(addr, "GET", "/jobs/999", "");
    assert_eq!(code, 404);
    let (code, _) = http(addr, "GET", "/jobs/999/result", "");
    assert_eq!(code, 404);
    let (code, _) = http(addr, "DELETE", "/jobs/999", "");
    assert_eq!(code, 404);
    let (code, _) = http(addr, "GET", "/nope", "");
    assert_eq!(code, 404);
    let (code, _) = http(addr, "PUT", "/jobs", "");
    assert_eq!(code, 405);

    server.stop();
}

#[test]
fn http_concurrent_submissions_with_one_cancel() {
    // The acceptance scenario: >= 4 jobs submitted concurrently over
    // HTTP, one (long) job cancelled via DELETE; the cancelled job ends
    // `cancelled` and every other job delivers a correct result.
    let server = server();
    let addr = server.addr();

    let quick = ["recip", "log2", "exp2"];
    // recip 16-bit auto-LUB: seconds of sweep work, so the DELETE below
    // always lands while it is running (or still queued).
    let long_toml =
        "func = recip\nbits = 16\n[generate]\nlookup_bits = auto\nthreads = 2\n\
         [job]\nverify = false\n";

    let mut ids: Vec<(u64, Option<&str>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        handles.push(scope.spawn(move || {
            let (code, body) = http(addr, "POST", "/jobs", long_toml);
            assert_eq!(code, 201, "{body}");
            (json_u64(&body, "id"), None)
        }));
        for func in quick {
            handles.push(scope.spawn(move || {
                let toml = format!("func = {func}\nbits = 8\n[generate]\nlookup_bits = 4\n");
                let (code, body) = http(addr, "POST", "/jobs", &toml);
                assert_eq!(code, 201, "{body}");
                (json_u64(&body, "id"), Some(func))
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(ids.len(), 4);

    // Cancel the long job.
    let (long_id, _) = ids.remove(0);
    let (code, body) = http(addr, "DELETE", &format!("/jobs/{long_id}"), "");
    assert_eq!(code, 200, "{body}");
    poll_until(addr, long_id, "cancelled", Duration::from_secs(120));
    let (code, body) = http(addr, "GET", &format!("/jobs/{long_id}/result"), "");
    assert_eq!(code, 409, "cancelled result must be 409: {body}");
    assert!(body.contains("\"status\":\"cancelled\""), "{body}");

    // Every other job completes with a correct result.
    for (id, func) in ids {
        let func = func.unwrap();
        poll_until(addr, id, "done", Duration::from_secs(120));
        let (code, result) = http(addr, "GET", &format!("/jobs/{id}/result"), "");
        assert_eq!(code, 200, "{result}");
        let mut spec = JobSpec::new(func, 8);
        spec.lookup = LookupBits::Fixed(4);
        let direct = spec.run().unwrap();
        assert!(result.contains(&format!("\"func\":\"{func}\"")), "{result}");
        assert_eq!(json_u64(&result, "lookup_bits"), 4);
        for co in &direct.implementation.coeffs {
            let frag = format!("{{\"a\":{},\"b\":{},\"c\":{}}}", co.a, co.b, co.c);
            assert!(result.contains(&frag), "{func}: coeff {frag} missing in {result}");
        }
    }

    // DELETE is idempotent on a finished job.
    let (code, body) = http(addr, "DELETE", &format!("/jobs/{long_id}"), "");
    assert_eq!(code, 200);
    assert!(body.contains("\"status\":\"cancelled\""), "{body}");

    server.stop();
    polygen::pipeline::shutdown();
}
