//! Integration: full pipeline against the AOT artifacts.
//!
//! generate -> DSE -> XLA verify (jnp + pallas flavors) must agree with the
//! scalar engine bit-for-bit. Skips (with a loud message) when
//! `artifacts/` has not been built — `make test` always builds it first.

use polygen::bounds::{builtin, AccuracySpec, BoundTable};
use polygen::designspace::{generate, GenOptions};
use polygen::dse::{explore, DseOptions};
use polygen::runtime::{Flavor, XlaRuntime};
use polygen::verify::{cross_check_sample, verify_exhaustive, Engine};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn pipeline(name: &str, bits: u32, r: u32) -> (BoundTable, polygen::dse::Implementation) {
    let f = builtin(name, bits).unwrap();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
        .unwrap_or_else(|e| panic!("{name}/{bits} R={r}: {e}"));
    let im = explore(&bt, &ds, &DseOptions::default()).expect("DSE failed");
    (bt, im)
}

#[test]
fn xla_verify_matches_scalar_all_functions() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(&dir).expect("artifact load");
    for (name, bits, r) in
        [("recip", 10u32, 5u32), ("log2", 10, 5), ("exp2", 10, 4), ("sqrt", 10, 4)]
    {
        let (bt, im) = pipeline(name, bits, r);
        let scalar = verify_exhaustive(&bt, &im, &Engine::Scalar).unwrap();
        let xla = verify_exhaustive(&bt, &im, &Engine::Xla { rt: &rt, flavor: Flavor::Jnp })
            .unwrap();
        assert_eq!(scalar, xla, "{name}: engine disagreement");
        assert!(scalar.ok(), "{name}: generated design violates bounds: {scalar:?}");
    }
}

#[test]
fn pallas_flavor_is_bit_identical() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(&dir).expect("artifact load");
    if !rt.has_flavor(Flavor::Pallas) {
        eprintln!("SKIP: pallas artifact not built");
        return;
    }
    let (bt, im) = pipeline("recip", 10, 5);
    let jnp = verify_exhaustive(&bt, &im, &Engine::Xla { rt: &rt, flavor: Flavor::Jnp })
        .unwrap();
    let pallas =
        verify_exhaustive(&bt, &im, &Engine::Xla { rt: &rt, flavor: Flavor::Pallas }).unwrap();
    assert_eq!(jnp, pallas);
}

#[test]
fn xla_catches_injected_fault() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(&dir).expect("artifact load");
    let (bt, mut im) = pipeline("log2", 10, 5);
    im.coeffs[3].c -= 32 << im.k; // fault injection
    let scalar = verify_exhaustive(&bt, &im, &Engine::Scalar).unwrap();
    let xla =
        verify_exhaustive(&bt, &im, &Engine::Xla { rt: &rt, flavor: Flavor::Jnp }).unwrap();
    assert_eq!(scalar, xla);
    assert!(!xla.ok());
    assert_eq!(xla.first_violation.map(|z| z >> im.x_bits()), Some(3));
}

#[test]
fn eval_cross_check_strided() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(&dir).expect("artifact load");
    let (bt, im) = pipeline("exp2", 10, 5);
    assert!(cross_check_sample(&bt, &im, &rt, Flavor::Jnp, 7).unwrap());
}

#[test]
fn xla_extrema_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(&dir).expect("artifact load");
    // recip 12-bit with R=4 gives regions of exactly N=256.
    let f = builtin("recip", 12).unwrap();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    for r in [0u64, 7, 15] {
        let (l, u) = bt.region(4, r);
        assert_eq!(l.len(), 256);
        let got = rt.extrema(l, u).expect("N=256 variant compiled");
        let want = polygen::designspace::extrema::diagonal_extrema(l, u);
        // Values must agree exactly as rationals (pairs may differ).
        assert_eq!(got.big_m.len(), want.big_m.len());
        for t in 0..want.big_m.len() {
            assert_eq!(got.big_m[t], want.big_m[t], "M(t) r={r} t={}", t + 1);
            assert_eq!(got.small_m[t], want.small_m[t], "m(t) r={r} t={}", t + 1);
        }
    }
}

#[test]
fn generate_with_xla_extrema_provider_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(&dir).expect("artifact load");
    let f = builtin("recip", 12).unwrap();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    let opts = GenOptions { lookup_bits: 4, ..Default::default() };
    let provider = |l: &[i32], u: &[i32]| rt.extrema(l, u);
    let a = polygen::designspace::generate_with(&bt, &opts, Some(&provider)).unwrap();
    let b = generate(&bt, &opts).unwrap();
    assert_eq!(a.k, b.k);
    for (ra, rb) in a.region_views().zip(b.region_views()) {
        assert_eq!(ra.entries(), rb.entries(), "region {}", ra.r());
    }
}
