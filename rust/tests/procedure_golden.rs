//! Procedure-equivalence golden tests.
//!
//! The trait-based exploration layer (PR: pluggable technology targets)
//! refactored the SquareFirst/LutFirst monolith into composable
//! lexicographic passes. The acceptance bar is *byte-identical*
//! selections: `legacy` below is the pre-refactor `dse::explore`
//! preserved verbatim (only rewritten against the public API), and every
//! test pins the refactored engine — and the `AsicGe` technology default
//! — to its exact output (coefficients, truncations, encodings) on the
//! bundled recip/log2/exp2 (+sqrt) examples.

use polygen::bounds::{builtin, AccuracySpec, BoundTable};
use polygen::designspace::{generate, DesignSpace, GenOptions};
use polygen::dse::{explore, Degree, DseOptions, Implementation, Procedure};
use polygen::tech::TechKind;

/// The pre-refactor decision procedure, frozen as the oracle.
mod legacy {
    use polygen::bounds::BoundTable;
    use polygen::designspace::region::{polynomial_valid, CEnvelope, RegionSpace};
    use polygen::designspace::DesignSpace;
    use polygen::dse::precision::{algorithm1, Encoding, IntervalSet};
    use polygen::dse::{Coeffs, Degree, Implementation};

    #[derive(Clone, Copy, PartialEq, Eq)]
    pub enum Procedure {
        SquareFirst,
        LutFirst,
    }

    #[derive(Clone, Debug, Default)]
    struct RegionCands {
        cands: Vec<(i64, Vec<i64>)>,
    }

    impl RegionCands {
        fn is_empty(&self) -> bool {
            self.cands.iter().all(|(_, bs)| bs.is_empty())
        }
    }

    pub fn explore(
        bt: &BoundTable,
        ds: &DesignSpace,
        procedure: Procedure,
        degree_opt: Option<Degree>,
        cap: usize,
    ) -> Option<Implementation> {
        let degree = match degree_opt {
            Some(d) => d,
            None => {
                if ds.linear_feasible() {
                    Degree::Linear
                } else {
                    Degree::Quadratic
                }
            }
        };
        if degree == Degree::Linear && !ds.linear_feasible() {
            return None;
        }
        let xbits = ds.x_bits();

        match procedure {
            Procedure::SquareFirst => {
                let (i, j) = match degree {
                    Degree::Linear => {
                        let j = max_feasible_trunc(bt, ds, degree, cap, |j| (xbits, j));
                        (xbits, j)
                    }
                    Degree::Quadratic => {
                        let i = max_feasible_trunc(bt, ds, degree, cap, |i| (i, 0));
                        let j = max_feasible_trunc(bt, ds, degree, cap, |j| (i, j));
                        (i, j)
                    }
                };
                let cands = filter_all(bt, ds, degree, i, j, cap);
                finish(bt, ds, degree, i, j, cands, cap)
            }
            Procedure::LutFirst => {
                let cands = filter_all(bt, ds, degree, 0, 0, cap);
                let pre = finish(bt, ds, degree, 0, 0, cands, cap)?;
                let admits = |co: &Coeffs| {
                    pre.enc_a.admits(co.a) && pre.enc_b.admits(co.b) && pre.enc_c.admits(co.c)
                };
                let mut best = pre.clone();
                for i in (0..=xbits).rev() {
                    if let Some(impl_) =
                        reselect_at_trunc(bt, ds, &pre, i, pre.lin_trunc, &admits)
                    {
                        best = impl_;
                        break;
                    }
                }
                Some(best)
            }
        }
    }

    fn max_feasible_trunc(
        bt: &BoundTable,
        ds: &DesignSpace,
        degree: Degree,
        cap: usize,
        map: impl Fn(u32) -> (u32, u32),
    ) -> u32 {
        let xbits = ds.x_bits();
        let feasible = |p: u32| {
            let (i, j) = map(p);
            all_regions_survive(bt, ds, degree, i, j, cap)
        };
        let (mut lo, mut hi) = (0u32, xbits);
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if feasible(mid) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }

    fn all_regions_survive(
        bt: &BoundTable,
        ds: &DesignSpace,
        degree: Degree,
        i: u32,
        j: u32,
        cap: usize,
    ) -> bool {
        // Access path modernized with the lazy-region refactor (entries
        // now come through memoizing views); the algorithm is unchanged.
        ds.region_views().all(|rv| {
            let sp = rv.space();
            let (l, u) = bt.region(ds.lookup_bits, sp.r);
            !filter_region(l, u, ds.k, sp, degree, i, j, cap, true).is_empty()
        })
    }

    fn filter_all(
        bt: &BoundTable,
        ds: &DesignSpace,
        degree: Degree,
        i: u32,
        j: u32,
        cap: usize,
    ) -> Vec<RegionCands> {
        ds.region_views()
            .map(|rv| {
                let sp = rv.space();
                let (l, u) = bt.region(ds.lookup_bits, sp.r);
                filter_region(l, u, ds.k, sp, degree, i, j, cap, false)
            })
            .collect()
    }

    #[allow(clippy::too_many_arguments)]
    fn filter_region(
        l: &[i32],
        u: &[i32],
        k: u32,
        sp: &RegionSpace,
        degree: Degree,
        i: u32,
        j: u32,
        cap: usize,
        early_out: bool,
    ) -> RegionCands {
        let mut out = RegionCands::default();
        let mut entries: Vec<_> = sp.entries.iter().collect();
        entries.sort_by_key(|e| (e.a.abs(), e.a));
        for e in entries {
            if degree == Degree::Linear && e.a != 0 {
                continue;
            }
            let width = (e.b_hi - e.b_lo + 1) as usize;
            let bs: Vec<i64> = if width <= cap {
                (e.b_lo..=e.b_hi).collect()
            } else {
                let stride = width.div_ceil(cap);
                let mut v: Vec<i64> = (e.b_lo..=e.b_hi).step_by(stride).collect();
                if *v.last().unwrap() != e.b_hi {
                    v.push(e.b_hi);
                }
                v
            };
            let env = CEnvelope::build(l, u, k, e.a, i, j);
            let mut cur = env.cursor();
            let surviving: Vec<i64> =
                bs.into_iter().filter(|&b| cur.interval_at(b).is_some()).collect();
            if !surviving.is_empty() {
                out.cands.push((e.a, surviving));
                if early_out {
                    return out;
                }
            }
        }
        out
    }

    fn finish(
        bt: &BoundTable,
        ds: &DesignSpace,
        degree: Degree,
        i: u32,
        j: u32,
        mut cands: Vec<RegionCands>,
        cap: usize,
    ) -> Option<Implementation> {
        let sampled = ds.region_views().any(|rv| {
            rv.entries().iter().any(|e| (e.b_hi - e.b_lo + 1) as usize > cap)
        });

        let a_sets: Vec<IntervalSet> = cands
            .iter()
            .map(|rc| rc.cands.iter().map(|&(a, _)| (a, a)).collect())
            .collect();
        let enc_a = algorithm1(&a_sets)?;
        for rc in &mut cands {
            rc.cands.retain(|&(a, _)| enc_a.admits(a));
            if rc.is_empty() {
                return None;
            }
        }

        let b_sets: Vec<IntervalSet> = cands
            .iter()
            .map(|rc| {
                rc.cands
                    .iter()
                    .flat_map(|(_, bs)| bs.iter().map(|&b| (b, b)))
                    .collect()
            })
            .collect();
        let enc_b = algorithm1(&b_sets)?;
        for rc in &mut cands {
            for (_, bs) in &mut rc.cands {
                bs.retain(|&b| enc_b.admits(b));
            }
            rc.cands.retain(|(_, bs)| !bs.is_empty());
            if rc.is_empty() {
                return None;
            }
        }

        let mut c_sets: Vec<IntervalSet> = Vec::with_capacity(cands.len());
        for (rc, rv) in cands.iter().zip(ds.region_views()) {
            let (l, u) = bt.region(ds.lookup_bits, rv.r());
            let mut set: IntervalSet = Vec::new();
            for (a, bs) in &rc.cands {
                let env = CEnvelope::build(l, u, ds.k, *a, i, j);
                let mut cur = env.cursor();
                for &b in bs {
                    if let Some(iv) = cur.interval_at(b) {
                        set.push(iv);
                    }
                }
            }
            if set.is_empty() {
                return None;
            }
            c_sets.push(set);
        }
        let enc_c = algorithm1(&c_sets)?;

        let mut coeffs = Vec::with_capacity(cands.len());
        for (rc, rv) in cands.iter().zip(ds.region_views()) {
            let (l, u) = bt.region(ds.lookup_bits, rv.r());
            let mut chosen: Option<Coeffs> = None;
            'outer: for (a, bs) in &rc.cands {
                let env = CEnvelope::build(l, u, ds.k, *a, i, j);
                let mut cur = env.cursor();
                for &b in bs {
                    let Some((c0, c1)) = cur.interval_at(b) else { continue };
                    if let Some(c) = first_admissible_in(&enc_c, c0, c1) {
                        assert!(polynomial_valid(l, u, ds.k, *a, b, c, i, j));
                        chosen = Some(Coeffs { a: *a, b, c });
                        break 'outer;
                    }
                }
            }
            coeffs.push(chosen?);
        }

        Some(Implementation {
            func: ds.func.clone(),
            accuracy: ds.accuracy.clone(),
            in_bits: ds.in_bits,
            out_bits: ds.out_bits,
            lookup_bits: ds.lookup_bits,
            k: ds.k,
            degree,
            sq_trunc: i,
            lin_trunc: j,
            enc_a,
            enc_b,
            enc_c,
            coeffs,
            sampled,
        })
    }

    fn first_admissible_in(enc: &Encoding, c0: i64, c1: i64) -> Option<i64> {
        let step = 1i64 << enc.trunc;
        let mut v = c0.div_euclid(step) * step;
        if v < c0 {
            v += step;
        }
        while v <= c1 {
            if enc.admits(v) {
                return Some(v);
            }
            v += step;
        }
        None
    }

    fn reselect_at_trunc(
        bt: &BoundTable,
        ds: &DesignSpace,
        pre: &Implementation,
        i: u32,
        j: u32,
        admits: &impl Fn(&Coeffs) -> bool,
    ) -> Option<Implementation> {
        let mut coeffs = Vec::with_capacity(ds.num_regions());
        for rv in ds.region_views() {
            let sp = rv.space();
            let (l, u) = bt.region(ds.lookup_bits, sp.r);
            let mut chosen = None;
            'outer: for e in &sp.entries {
                if pre.degree == Degree::Linear && e.a != 0 {
                    continue;
                }
                if !pre.enc_a.admits(e.a) {
                    continue;
                }
                let env = CEnvelope::build(l, u, ds.k, e.a, i, j);
                let mut cur = env.cursor();
                for b in e.b_lo..=e.b_hi {
                    if !pre.enc_b.admits(b) {
                        continue;
                    }
                    let Some((c0, c1)) = cur.interval_at(b) else { continue };
                    if let Some(c) = first_admissible_in(&pre.enc_c, c0, c1) {
                        let co = Coeffs { a: e.a, b, c };
                        if admits(&co) {
                            chosen = Some(co);
                            break 'outer;
                        }
                    }
                }
            }
            coeffs.push(chosen?);
        }
        Some(Implementation { sq_trunc: i, lin_trunc: j, coeffs, ..pre.clone() })
    }
}

fn setup(name: &str, bits: u32, r: u32) -> Option<(BoundTable, DesignSpace)> {
    let f = builtin(name, bits)?;
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() }).ok()?;
    Some((bt, ds))
}

/// Byte-identical comparison of every selection-determining field.
fn assert_identical(case: &str, a: &Implementation, b: &Implementation) {
    assert_eq!(a.degree, b.degree, "{case}: degree");
    assert_eq!(a.k, b.k, "{case}: k");
    assert_eq!(a.sq_trunc, b.sq_trunc, "{case}: sq_trunc");
    assert_eq!(a.lin_trunc, b.lin_trunc, "{case}: lin_trunc");
    assert_eq!(a.enc_a, b.enc_a, "{case}: enc_a");
    assert_eq!(a.enc_b, b.enc_b, "{case}: enc_b");
    assert_eq!(a.enc_c, b.enc_c, "{case}: enc_c");
    assert_eq!(a.coeffs, b.coeffs, "{case}: coeffs");
    assert_eq!(a.sampled, b.sampled, "{case}: sampled");
}

/// The bundled example set: recip/log2/exp2 (the paper's functions, the
/// quadratic low-R corners included) plus sqrt.
const CASES: &[(&str, u32, u32)] = &[
    ("recip", 8, 3),
    ("recip", 8, 4),
    ("recip", 10, 4),
    ("recip", 10, 5),
    ("log2", 10, 4),
    ("log2", 10, 5),
    ("exp2", 8, 4),
    ("exp2", 10, 3),
    ("exp2", 10, 4),
    ("sqrt", 10, 5),
];

#[test]
fn square_first_matches_pre_refactor_byte_for_byte() {
    let mut checked = 0;
    for &(name, bits, r) in CASES {
        let Some((bt, ds)) = setup(name, bits, r) else { continue };
        let want = legacy::explore(&bt, &ds, legacy::Procedure::SquareFirst, None, 512)
            .unwrap_or_else(|| panic!("{name}-{bits} R={r}: legacy found nothing"));
        let got = explore(&bt, &ds, &DseOptions::default())
            .unwrap_or_else(|| panic!("{name}-{bits} R={r}: refactor found nothing"));
        assert_identical(&format!("{name}-{bits} R={r} square_first"), &want, &got);
        checked += 1;
    }
    assert!(checked >= 8, "only {checked} cases generated");
}

#[test]
fn lut_first_matches_pre_refactor_byte_for_byte() {
    for &(name, bits, r) in CASES {
        let Some((bt, ds)) = setup(name, bits, r) else { continue };
        let want = legacy::explore(&bt, &ds, legacy::Procedure::LutFirst, None, 512)
            .unwrap_or_else(|| panic!("{name}-{bits} R={r}: legacy found nothing"));
        let got = explore(
            &bt,
            &ds,
            &DseOptions { procedure: Some(Procedure::LutFirst), ..Default::default() },
        )
        .unwrap_or_else(|| panic!("{name}-{bits} R={r}: refactor found nothing"));
        assert_identical(&format!("{name}-{bits} R={r} lut_first"), &want, &got);
    }
}

#[test]
fn forced_degrees_match_pre_refactor() {
    for &(name, bits, r, degree) in &[
        ("recip", 8u32, 6u32, Degree::Quadratic),
        ("recip", 8, 4, Degree::Linear),
        ("log2", 10, 5, Degree::Quadratic),
    ] {
        let Some((bt, ds)) = setup(name, bits, r) else { continue };
        let want =
            legacy::explore(&bt, &ds, legacy::Procedure::SquareFirst, Some(degree), 512);
        let got = explore(
            &bt,
            &ds,
            &DseOptions { degree: Some(degree), ..Default::default() },
        );
        match (want, got) {
            (None, None) => {}
            (Some(w), Some(g)) => {
                assert_identical(&format!("{name}-{bits} R={r} {degree:?}"), &w, &g)
            }
            (w, g) => panic!(
                "{name}-{bits} R={r}: legacy={} refactor={}",
                w.is_some(),
                g.is_some()
            ),
        }
    }
}

#[test]
fn asic_technology_default_is_the_paper_procedure() {
    // The AsicGe technology's default ordering must be the same
    // SquareFirst selection — forcing tech = AsicGe explicitly (as
    // pipelines do) changes nothing.
    for &(name, bits, r) in &[("recip", 10u32, 4u32), ("exp2", 10, 4)] {
        let Some((bt, ds)) = setup(name, bits, r) else { continue };
        let want = legacy::explore(&bt, &ds, legacy::Procedure::SquareFirst, None, 512).unwrap();
        let got = explore(
            &bt,
            &ds,
            &DseOptions { tech: TechKind::AsicGe, ..Default::default() },
        )
        .unwrap();
        assert_identical(&format!("{name}-{bits} R={r} asic default"), &want, &got);
    }
}

#[test]
fn subsampled_b_enumeration_stays_identical() {
    // A tiny max_b_per_a forces the strided-subsample path through both
    // engines; the refactor must keep stride arithmetic identical.
    let (bt, ds) = setup("recip", 10, 4).unwrap();
    let want = legacy::explore(&bt, &ds, legacy::Procedure::SquareFirst, None, 16).unwrap();
    let got = explore(&bt, &ds, &DseOptions { max_b_per_a: 16, ..Default::default() }).unwrap();
    assert_identical("recip-10 R=4 cap=16", &want, &got);
    assert!(want.sampled, "cap=16 must engage subsampling for this space");
}
