//! Integration tests for `polygen::service`: concurrent submit / poll /
//! cancel from multiple threads, cancellation mid-generation leaving the
//! process-wide scheduler drained-but-reusable, and the Batch shim's
//! equivalence with direct runs.

use std::time::{Duration, Instant};

use polygen::pipeline::{Batch, JobSpec, LookupBits, LubObjective, Phase, PipelineError};
use polygen::service::{JobStatus, Service};

/// A sub-second job (recip 8-bit R=4).
fn quick_spec(func: &str) -> JobSpec {
    let mut s = JobSpec::new(func, 8);
    s.lookup = LookupBits::Fixed(4);
    s
}

/// A long job: recip 16-bit auto-LUB sweeps the whole default R range —
/// multiple seconds of generation work, so a cancel fired as soon as the
/// Generate phase is observed always lands mid-generation. Verification
/// is off: the generation phase is the one under test.
fn long_spec() -> JobSpec {
    let mut s = JobSpec::new("recip", 16);
    s.lookup = LookupBits::Auto(LubObjective::AreaDelay);
    s.threads = 2;
    s.verify = false;
    s
}

/// A fixed-R heavy job whose progress ticks per region (64 of them).
fn long_fixed_spec() -> JobSpec {
    let mut s = long_spec();
    s.lookup = LookupBits::Fixed(6);
    s
}

fn in_generate(status: &JobStatus) -> bool {
    matches!(status, JobStatus::Running { phase: Phase::Generate, .. })
}

fn wait_for<F: FnMut() -> bool>(what: &str, timeout: Duration, mut pred: F) {
    let deadline = Instant::now() + timeout;
    while !pred() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn concurrent_submit_poll_cancel_from_many_threads() {
    let svc = Service::builder().workers(4).build();
    // One long job, submitted first so it occupies an executor while the
    // quick jobs flow around it.
    let long = svc.submit(long_spec());
    let long_id = long.id();
    std::thread::scope(|scope| {
        let svc = &svc;
        // Three submitter threads, each polling its own job to completion.
        let quick: Vec<_> = ["recip", "log2", "exp2"]
            .iter()
            .map(|func| {
                scope.spawn(move || {
                    let h = svc.submit(quick_spec(func));
                    wait_for("quick job", Duration::from_secs(120), || {
                        h.status().is_finished()
                    });
                    h.wait()
                })
            })
            .collect();
        // A canceller thread kills the long job once its generation
        // phase has begun (the sweep then still has seconds of work).
        let canceller = scope.spawn(move || {
            wait_for("long job generating", Duration::from_secs(120), || {
                in_generate(&long.status()) || long.status().is_finished()
            });
            long.cancel();
            long.wait()
        });
        for (h, func) in quick.into_iter().zip(["recip", "log2", "exp2"]) {
            let res = h.join().unwrap().unwrap_or_else(|e| panic!("{func}: {e}"));
            assert_eq!(res.func, func);
            assert!(res.verify.as_ref().unwrap().ok());
        }
        match canceller.join().unwrap() {
            Err(PipelineError::Cancelled) => {}
            Ok(_) => panic!("a full 16-bit auto-LUB sweep outran a 2ms-poll cancel"),
            Err(other) => panic!("expected Cancelled, got {other}"),
        }
    });
    assert_eq!(svc.status_of(long_id), Some(JobStatus::Cancelled));
}

#[test]
fn cancel_mid_generation_leaves_scheduler_drained_and_reusable() {
    let svc = Service::builder().workers(2).build();
    let h = svc.submit(long_spec());
    wait_for("mid-generation", Duration::from_secs(120), || {
        in_generate(&h.status()) || h.status().is_finished()
    });
    h.cancel();
    match h.wait() {
        Err(PipelineError::Cancelled) => {}
        Ok(_) => panic!("a full 16-bit auto-LUB sweep outran the cancel"),
        Err(other) => panic!("expected Cancelled, got {other}"),
    }
    // The contract under test: cooperative cancellation retires every
    // scheduler task, so a drain completes (rather than hanging on an
    // abandoned job) and the pool keeps working for the next caller.
    polygen::pipeline::shutdown();
    let direct = polygen::pool::run_indexed(16, 4, |i| i * i);
    assert_eq!(direct, (0..16).map(|i| i * i).collect::<Vec<_>>());
    // And the same service keeps executing new jobs after the cancel.
    let again = svc.submit(quick_spec("recip"));
    assert!(again.wait().is_ok());
    polygen::pipeline::shutdown();
}

#[test]
fn batch_shim_matches_direct_runs_exactly() {
    // The acceptance criterion: Batch over the service is byte-identical
    // to running each spec alone.
    let specs =
        vec![quick_spec("recip"), quick_spec("log2"), quick_spec("tan"), quick_spec("exp2")];
    let batched = Batch::run(&specs, 3);
    assert_eq!(batched.len(), 4);
    for (spec, got) in specs.iter().zip(&batched) {
        let direct = spec.run();
        match (got, direct) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.implementation.coeffs, b.implementation.coeffs);
                assert_eq!(a.lookup_bits, b.lookup_bits);
                assert_eq!(a.synth, b.synth);
            }
            (Err(PipelineError::UnknownFunction(a)), Err(PipelineError::UnknownFunction(b))) => {
                assert_eq!((a.as_str(), spec.func.as_str()), (b.as_str(), "tan"));
            }
            (a, b) => panic!("{}: shim/direct divergence (ok={} vs ok={})",
                spec.label(), a.is_ok(), b.is_ok()),
        }
    }
    polygen::pipeline::shutdown();
}

#[test]
fn service_progress_reports_generate_phase_regions() {
    let svc = Service::builder().workers(1).build();
    let h = svc.submit(long_fixed_spec());
    // Observe a mid-generation snapshot with sane bounds: 2^6 regions.
    let mut saw_generate = false;
    wait_for("progress snapshot", Duration::from_secs(120), || match h.status() {
        JobStatus::Running { phase, done, total, .. } => {
            if phase == Phase::Generate && total == 64 {
                assert!(done <= total, "done {done} > total {total}");
                saw_generate = done >= 1;
            }
            saw_generate
        }
        s => s.is_finished(),
    });
    h.cancel();
    let _ = h.wait();
    assert!(saw_generate, "never observed a generate-phase region count");
    polygen::pipeline::shutdown();
}
