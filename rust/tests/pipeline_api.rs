//! Integration tests for the `polygen::pipeline` surface: the staged
//! builder, structured errors, RTL emission, disk-cache reuse, and batch
//! job execution — the API contract DESIGN.md §5 commits to.

use polygen::pipeline::{
    Batch, JobSpec, LookupBits, LubObjective, Pipeline, PipelineError,
};

/// A staged run exposes every intermediate artifact, and the end-to-end
/// `run()` reaches the same implementation.
#[test]
fn staged_artifacts_are_inspectable() {
    let prepared = Pipeline::function("log2").bits(10).lub(5).prepare().unwrap();
    assert_eq!(prepared.workload.bt.in_bits, 10);

    let spaced = prepared.generate().unwrap();
    assert_eq!(spaced.space.num_regions(), 32);
    assert!(spaced.space.num_ab_pairs() > 0);

    let explored = spaced.explore().unwrap();
    assert_eq!(explored.implementation.coeffs.len(), 32);

    let synthesized = explored.synthesize();
    assert!(synthesized.synth.delay_ns > 0.0 && synthesized.synth.area_um2 > 0.0);

    let verified = synthesized.verify().unwrap();
    assert!(verified.report.ok());
    assert_eq!(verified.report.total, 1 << 10);

    let direct = Pipeline::function("log2").bits(10).lub(5).run().unwrap();
    assert_eq!(direct.implementation.coeffs, verified.implementation.coeffs);
}

/// The pipeline's generation stage reuses the coordinator disk cache:
/// a second run parses the `.pgds` file and must drive the DSE to the
/// identical implementation.
#[test]
fn cache_dir_roundtrips_through_pipeline() {
    let dir = std::env::temp_dir().join(format!("polygen_pipe_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let run = || {
        Pipeline::function("exp2")
            .bits(8)
            .lub(4)
            .cache_dir(&dir)
            .run()
            .unwrap()
    };
    let first = run();
    assert!(
        std::fs::read_dir(&dir).unwrap().count() > 0,
        "no cache file written"
    );
    let second = run(); // cache hit
    assert_eq!(first.implementation.coeffs, second.implementation.coeffs);
    assert_eq!(first.space.k, second.space.k);
    std::fs::remove_dir_all(&dir).ok();
}

/// Verilog emission from the verified stage writes the module and (with
/// `testbench(true)`) the self-checking testbench + golden vector.
#[test]
fn emit_rtl_writes_all_artifacts() {
    let dir = std::env::temp_dir().join(format!("polygen_pipe_rtl_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let verified = Pipeline::function("recip")
        .bits(8)
        .lub(4)
        .testbench(true)
        .run()
        .unwrap();
    let emitted = verified.emit_rtl(&dir).unwrap();
    assert_eq!(emitted.module, "recip_8b_r4");
    // module + tb + golden + recip behavioural reference
    assert_eq!(emitted.files.len(), 4, "{:?}", emitted.files);
    for f in &emitted.files {
        assert!(f.exists(), "{} missing", f.display());
    }
    let v = std::fs::read_to_string(dir.join("recip_8b_r4.v")).unwrap();
    assert!(v.contains("module recip_8b_r4"));
    std::fs::remove_dir_all(&dir).ok();
}

/// Every fallible stage returns `Result<_, PipelineError>` with the
/// cause attached — no bare `Option` anywhere on the public path.
#[test]
fn errors_carry_their_cause() {
    // Unknown function at prepare().
    let e = Pipeline::function("cosh").bits(8).prepare().err().unwrap();
    assert!(matches!(e, PipelineError::UnknownFunction(ref n) if n == "cosh"), "{e}");

    // Infeasible generation at generate(), with the failing R attached.
    let e = Pipeline::function("recip")
        .bits(10)
        .lub(1)
        .prepare()
        .unwrap()
        .generate()
        .err()
        .unwrap();
    assert!(matches!(e, PipelineError::Generation { lookup_bits: 1, .. }), "{e}");

    // Auto selection over an all-infeasible range reports the sweep.
    let e = Pipeline::function("recip")
        .bits(10)
        .auto_lub(LubObjective::AreaDelay)
        .sweep_range(vec![0, 1])
        .run()
        .err()
        .unwrap();
    match e {
        PipelineError::SweepExhausted { func, tried, last } => {
            assert_eq!(func, "recip");
            assert_eq!(tried, vec![0, 1]);
            assert!(last.is_some(), "generation failures should surface");
        }
        other => panic!("expected SweepExhausted, got {other}"),
    }
}

/// Job specs written to disk as TOML drive the same pipeline (the
/// `polygen batch` flow), and batch results line up with their specs.
#[test]
fn jobspec_files_drive_batch() {
    let dir = std::env::temp_dir().join(format!("polygen_jobs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut specs = Vec::new();
    for (func, lub) in [("recip", 4u32), ("exp2", 4)] {
        let mut s = JobSpec::new(func, 8);
        s.lookup = LookupBits::Fixed(lub);
        let path = dir.join(format!("{}.toml", s.label()));
        std::fs::write(&path, s.to_toml()).unwrap();
        // Reload from disk — the file, not the in-memory spec, is the input.
        let text = std::fs::read_to_string(&path).unwrap();
        let loaded = JobSpec::from_toml(&text).unwrap();
        assert_eq!(loaded, s);
        specs.push(loaded);
    }
    let cache = dir.join("cache");
    let results = Batch::new().threads(2).cache_dir(&cache).execute(&specs);
    assert_eq!(results.len(), 2);
    for (spec, res) in specs.iter().zip(&results) {
        let job = res.as_ref().unwrap_or_else(|e| panic!("{}: {e}", spec.label()));
        assert_eq!(job.func, spec.func);
        assert!(job.verify.as_ref().unwrap().ok());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Auto lookup-bit selection agrees with an explicit sweep's best point.
#[test]
fn auto_lub_matches_manual_sweep() {
    let auto = Pipeline::function("exp2")
        .bits(8)
        .auto_lub(LubObjective::AreaDelay)
        .run()
        .unwrap();
    let swept = Pipeline::function("exp2").bits(8).sweep().unwrap();
    let best = swept.best(LubObjective::AreaDelay).unwrap();
    assert_eq!(auto.implementation.lookup_bits, best.lookup_bits);
    assert_eq!(
        &auto.implementation.coeffs,
        &best.implementation.as_ref().unwrap().coeffs
    );
}
