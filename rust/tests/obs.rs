//! End-to-end tests for `polygen::obs`: the `/metrics` Prometheus
//! surface (two-way: every registered metric is scraped, every scraped
//! metric is registered), the per-job span tracer and its Chrome
//! trace_events export (stable phase-span names and ordering on a
//! recip-8 job), the `/store` summary vs. the store gauges, the
//! `recovered` latch in job status JSON, and — behind the `obs-stub` /
//! `fault-injection` features — the compile-out and fault-metric paths.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::Duration;

use polygen::obs::metrics;
use polygen::pipeline::{JobCtrl, JobSpec, LookupBits};
use polygen::service::http::HttpServer;
use polygen::service::Service;
use polygen::sync::Arc;

fn quick_spec(func: &str) -> JobSpec {
    let mut s = JobSpec::new(func, 8);
    s.lookup = LookupBits::Fixed(4);
    s
}

/// A fresh scratch directory under the system temp dir.
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("polygen_obs_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// One-shot HTTP/1.1 exchange returning (status, head, body). `None`
/// when the connection failed mid-flight (fault-injection tests drive
/// requests into deliberate disconnects).
fn try_http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Option<(u16, String, String)> {
    let mut s = TcpStream::connect(addr).ok()?;
    s.set_read_timeout(Some(Duration::from_secs(30))).ok()?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).ok()?;
    let mut raw = String::new();
    s.read_to_string(&mut raw).ok()?;
    let code: u16 = raw.split_whitespace().nth(1).and_then(|c| c.parse().ok())?;
    let (head, body) = raw.split_once("\r\n\r\n")?;
    Some((code, head.to_string(), body.to_string()))
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let (code, _, body) =
        try_http(addr, method, path, body).expect("server closes after one response");
    (code, body)
}

/// Extract `"key":<integer>` from a flat JSON body.
fn json_u64(body: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat).unwrap_or_else(|| panic!("{key} missing in {body}"));
    body[at + pat.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or_else(|_| panic!("{key} not an integer in {body}"))
}

#[test]
fn metrics_endpoint_scrapes_the_whole_registry_both_ways() {
    let svc = Service::builder().workers(1).build();
    let server = HttpServer::spawn(svc.clone(), "127.0.0.1:0").expect("bind");
    svc.submit(quick_spec("recip")).wait().expect("recip 8b R=4 feasible");

    let (code, head, body) =
        try_http(server.addr(), "GET", "/metrics", "").expect("scrape succeeds");
    assert_eq!(code, 200, "{body}");
    assert!(head.contains("text/plain; version=0.0.4"), "wrong content type: {head}");

    // Registry → scrape: every registered metric renders, zeros included.
    for m in metrics::METRICS {
        let name = metrics::prom_name(m);
        assert!(
            body.contains(&format!("# TYPE {name} {}\n", m.kind.label())),
            "{name} missing from scrape"
        );
    }
    // Scrape → registry: every `# TYPE` line maps back to a registered
    // metric (no ad-hoc names sneak into the exposition).
    let registered: Vec<String> = metrics::METRICS.iter().map(metrics::prom_name).collect();
    for line in body.lines().filter(|l| l.starts_with("# TYPE ")) {
        let name = line.split_whitespace().nth(2).expect("TYPE line has a name");
        assert!(registered.iter().any(|r| r == name), "unregistered metric scraped: {name}");
    }

    // The finished job is visible in the counters (unless compiled out).
    if metrics::COMPILED {
        assert!(metrics::value("service.submitted") >= 1, "submit not counted");
        assert!(metrics::value("service.done") >= 1, "completion not counted");
        assert!(metrics::value("service.job_ms") >= 1, "job duration not observed");
        assert!(body.contains("polygen_service_job_ms_bucket"), "{body}");
    }
    server.stop();
}

#[test]
fn traced_run_exports_stable_phase_spans() {
    let ctrl = Arc::new(JobCtrl::traced());
    quick_spec("recip")
        .run_controlled(None, Some(Arc::clone(&ctrl)))
        .expect("recip 8b R=4 feasible");
    ctrl.finish_trace();

    let tracer = ctrl.tracer().expect("ctrl built with JobCtrl::traced");
    let phases: Vec<String> = tracer
        .spans()
        .iter()
        .filter(|s| s.cat == "phase")
        .map(|s| s.name.clone())
        .collect();
    // The golden sequence: one span per pipeline phase, in pipeline
    // order. This is the stability contract trace consumers rely on.
    assert_eq!(phases, ["prepare", "generate", "explore", "synthesize", "verify"]);

    let json = tracer.export_chrome();
    assert!(json.starts_with("{\"traceEvents\":["), "{json}");
    assert!(json.ends_with("}"), "{json}");
    for p in &phases {
        assert!(json.contains(&format!("\"name\":\"{p}\"")), "{p} missing in {json}");
    }
    assert!(json.contains("\"ph\":\"X\""), "complete events expected: {json}");

    // `timings()` aggregates the phase spans in first-seen order.
    let timings = ctrl.timings().expect("traced run has timings");
    let names: Vec<&str> = timings.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names, phases.iter().map(String::as_str).collect::<Vec<_>>());

    // An untraced ctrl reports neither tracer nor timings.
    let plain = JobCtrl::new();
    assert!(plain.tracer().is_none());
    assert!(plain.timings().is_none());
}

#[test]
fn service_tracing_surfaces_timings_and_trace_endpoint() {
    let svc = Service::builder().workers(1).tracing(true).build();
    let server = HttpServer::spawn(svc.clone(), "127.0.0.1:0").expect("bind");
    let handle = svc.submit(quick_spec("recip"));
    let id = handle.id();
    handle.wait().expect("recip 8b R=4 feasible");

    let (code, body) = http(server.addr(), "GET", &format!("/jobs/{id}"), "");
    assert_eq!(code, 200, "{body}");
    assert!(body.contains("\"timings\":{"), "timings missing: {body}");
    for phase in ["prepare", "generate", "explore", "synthesize", "verify"] {
        assert!(body.contains(&format!("\"{phase}\":")), "{phase} missing: {body}");
    }

    let (code, trace) = http(server.addr(), "GET", &format!("/jobs/{id}/trace"), "");
    assert_eq!(code, 200, "{trace}");
    assert!(trace.starts_with("{\"traceEvents\":["), "{trace}");
    assert!(trace.contains("\"cat\":\"phase\""), "{trace}");
    server.stop();

    // Without `--trace` the endpoint explains itself instead of 500ing,
    // and the status object carries no timings.
    let svc2 = Service::builder().workers(1).build();
    let server2 = HttpServer::spawn(svc2.clone(), "127.0.0.1:0").expect("bind");
    let h2 = svc2.submit(quick_spec("recip"));
    let id2 = h2.id();
    h2.wait().expect("recip 8b R=4 feasible");
    let (code, body) = http(server2.addr(), "GET", &format!("/jobs/{id2}/trace"), "");
    assert_eq!(code, 404, "{body}");
    assert!(body.contains("not traced"), "{body}");
    let (_, status) = http(server2.addr(), "GET", &format!("/jobs/{id2}"), "");
    assert!(!status.contains("\"timings\""), "{status}");
    server2.stop();
}

#[test]
fn store_summary_agrees_with_the_store_gauges() {
    let dir = temp_dir("store");
    let svc = Service::builder().workers(1).state_dir(&dir).build();
    let server = HttpServer::spawn(svc.clone(), "127.0.0.1:0").expect("bind");
    svc.submit(quick_spec("recip")).wait().expect("recip 8b R=4 feasible");

    let (code, body) = http(server.addr(), "GET", "/store", "");
    assert_eq!(code, 200, "{body}");
    let count = json_u64(&body, "count");
    let total = json_u64(&body, "bytes");
    assert!(count >= 1 && total > 0, "{body}");
    // The summary object duplicates the flat keys exactly.
    assert!(
        body.contains(&format!(
            "\"summary\":{{\"entries\":{count},\"total_bytes\":{total}}}"
        )),
        "{body}"
    );
    // The inventory pass published the same numbers as gauges.
    if metrics::COMPILED {
        assert_eq!(metrics::value("store.entries"), count, "{body}");
        assert_eq!(metrics::value("store.bytes"), total, "{body}");
    }
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Rot one byte in the middle of the first file under `dir` with
/// extension `ext`, returning its path.
fn corrupt_artifact(dir: &std::path::Path, ext: &str) -> PathBuf {
    let path = std::fs::read_dir(dir)
        .expect("artifact dir exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .find(|p| p.extension().map_or(false, |x| x == ext))
        .unwrap_or_else(|| panic!("no .{ext} under {}", dir.display()));
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();
    path
}

#[test]
fn quarantine_recovery_is_latched_into_job_status() {
    let dir = temp_dir("recovered");
    let spec = quick_spec("exp2");
    let svc = Service::builder().workers(1).state_dir(&dir).build();
    let server = HttpServer::spawn(svc.clone(), "127.0.0.1:0").expect("bind");
    let first = svc.submit(spec.clone()).wait().expect("exp2 8b R=4 feasible");

    // Rot the stored .pgjr while the service is live: the resubmission's
    // store fast path must quarantine it, fall through to a real run,
    // and latch the recovery — on the handle and in the wire status
    // (next to `degraded`).
    corrupt_artifact(&dir.join("results"), "pgjr");
    let handle = svc.submit(spec.clone());
    let id = handle.id();
    assert!(handle.recovered() >= 1, "store quarantine must latch at submit");
    let again = handle.wait().expect("recompute succeeds");
    assert_eq!(again.implementation.coeffs, first.implementation.coeffs);

    let (code, body) = http(server.addr(), "GET", &format!("/jobs/{id}"), "");
    assert_eq!(code, 200, "{body}");
    assert!(json_u64(&body, "recovered") >= 1, "{body}");
    if metrics::COMPILED {
        assert!(metrics::value("store.result_quarantined") >= 1);
    }

    // A clean job reports no `recovered` key at all.
    let clean = svc.submit(quick_spec("recip"));
    let clean_id = clean.id();
    clean.wait().expect("recip 8b R=4 feasible");
    let (_, clean_body) = http(server.addr(), "GET", &format!("/jobs/{clean_id}"), "");
    assert!(!clean_body.contains("\"recovered\""), "{clean_body}");
    server.stop();
    drop(svc); // the "restart"

    // The run above re-saved the artifact (self-healing). Rot it again
    // and restart: startup replay quarantines it and the replayed entry
    // carries the same latch.
    corrupt_artifact(&dir.join("results"), "pgjr");
    let svc = Service::builder().workers(1).state_dir(&dir).build();
    let server = HttpServer::spawn(svc.clone(), "127.0.0.1:0").expect("bind");
    let (code, list) = http(server.addr(), "GET", "/jobs", "");
    assert_eq!(code, 200, "{list}");
    assert!(list.contains("\"recovered\":"), "replay latch missing: {list}");
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_generation_cache_recovery_latches_on_ctrl() {
    let dir = temp_dir("cache");
    let spec = quick_spec("log2");
    spec.run_with(Some(&dir)).expect("log2 8b R=4 feasible (populates .pgds cache)");

    // A rotten cached design space is quarantined mid-run and the
    // regeneration is counted on the job's control block.
    corrupt_artifact(&dir, "pgds");
    let ctrl = Arc::new(JobCtrl::new());
    spec.run_controlled(Some(&dir), Some(Arc::clone(&ctrl))).expect("recompute succeeds");
    assert!(ctrl.recovered() >= 1, "cache quarantine must latch on the ctrl");
    let quarantined = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .any(|e| e.path().to_string_lossy().ends_with(".pgds.quarantined"));
    assert!(quarantined, "corrupt cache entry should be set aside, not deleted");
    if metrics::COMPILED {
        assert!(metrics::value("cache.quarantined") >= 1);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// With `--features obs-stub` every recorder is an empty inline
/// function: handles resolve, `/metrics` still renders the full
/// registry, but no cell ever moves.
#[cfg(feature = "obs-stub")]
#[test]
fn stub_build_compiles_recording_out() {
    assert!(!metrics::COMPILED);
    const SPANS: metrics::Counter = metrics::counter("trace.spans");
    SPANS.inc();
    SPANS.add(10);
    assert_eq!(SPANS.get(), 0, "stub build must not record");
    const DEPTH: metrics::Gauge = metrics::gauge("pool.queue_depth");
    DEPTH.set(42);
    assert_eq!(DEPTH.get(), 0, "stub build must not record");
    let text = metrics::render_prometheus();
    assert!(text.contains("polygen_trace_spans_total 0"), "{text}");
    assert!(text.contains("polygen_pool_queue_depth 0"), "{text}");
}

/// Chaos cross-check: armed fault injection on the HTTP taps must show
/// up in `faults.injected`.
#[cfg(feature = "fault-injection")]
#[test]
fn injected_faults_surface_in_metrics() {
    use polygen::faults::{arm_guard, FaultPlan};

    let _serial = polygen::faults::test_serial_lock();
    let before = metrics::value("faults.injected");
    let svc = Service::builder().workers(1).build();
    let server = HttpServer::spawn(svc, "127.0.0.1:0").expect("bind");
    {
        // Every eligible http.* site fires (rate 1000‰): reads are
        // delayed, responses are cut mid-body. The client tolerates
        // both; the counter must not.
        let _armed = arm_guard(FaultPlan::new(42).rate(1000).only("http."));
        for _ in 0..8 {
            let _ = try_http(server.addr(), "GET", "/jobs", "");
        }
    }
    server.stop();
    if metrics::COMPILED {
        assert!(
            metrics::value("faults.injected") > before,
            "armed http faults did not move faults.injected"
        );
    }
}
