//! Loom models of the crate's locking protocols (DESIGN.md §Static
//! analysis). Compiled only under `RUSTFLAGS="--cfg loom"`; run with
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom
//! ```
//!
//! These are not hand-written abstractions of the scheduler — they drive
//! the shipping `Scheduler` and `TaskQueue` code through the
//! `crate::sync` shim, so loom explores every interleaving of the exact
//! lock/condvar/atomic protocol the product runs:
//!
//! - nested submit-executes-own-job (`run_on` from inside a task) never
//!   deadlocks, because the submitter always works its own job;
//! - an idle worker donates itself to *any* under-budget job, so two
//!   concurrent submitters sharing one worker both complete;
//! - `drain` leaves the pool parked but reusable, and `shutdown` wakes
//!   parked workers so every spawned thread joins;
//! - `TaskQueue::close` lets executors drain the pre-close backlog
//!   (never abandon it) and wakes parked executors so they exit;
//! - the service's last-clone `Gate` drop closes the queue exactly once
//!   while an executor is mid-drain;
//! - `net::CircuitBreaker` opens exactly once under concurrent failures
//!   and re-closes from half-open on a successful probe;
//! - `net::RetryBudget` never goes negative nor above its cap under
//!   concurrent spends and deposits.

// Same unexpected-cfg escape hatch as lib.rs: `--cfg loom` is injected
// only by the loom CI job, and MSRV 1.75 predates `check-cfg`.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]
#![cfg(loom)]

use std::time::Duration;

use loom::model::Builder;

use polygen::net::{CircuitBreaker, RetryBudget};
use polygen::pool::Scheduler;
use polygen::service::exec::TaskQueue;
use polygen::sync::atomic::{AtomicUsize, Ordering};
use polygen::sync::Arc;

/// Exhaustive exploration is exponential in preemption points. A bound
/// of two forced preemptions per thread is loom's recommended setting:
/// it still finds lost wakeups, missed notifies, and accounting races,
/// while keeping each model tractable in CI.
fn model(f: impl Fn() + Sync + Send + 'static) {
    let mut b = Builder::new();
    b.preemption_bound = Some(2);
    b.check(f);
}

#[test]
fn nested_submit_completes_without_deadlock() {
    // A task that itself submits a job to the same scheduler: the claim
    // (pool.rs module docs) is that progress never depends on worker
    // availability, because every submitter executes its own indices.
    model(|| {
        let sched = Scheduler::new_standalone(1);
        let hits = Arc::new(AtomicUsize::new(0));
        let outer_hits = Arc::clone(&hits);
        let outer_sched = Arc::clone(&sched);
        let outer = move |i: usize| {
            if i == 0 {
                let inner_hits = Arc::clone(&outer_hits);
                let inner = move |_: usize| {
                    inner_hits.fetch_add(1, Ordering::Relaxed);
                };
                outer_sched.run_on(1, 1, &inner);
            }
            outer_hits.fetch_add(1, Ordering::Relaxed);
        };
        sched.run_on(2, 2, &outer);
        assert_eq!(hits.load(Ordering::Relaxed), 3, "2 outer + 1 nested index");
        sched.shutdown();
    });
}

#[test]
fn worker_donates_across_concurrent_jobs() {
    // Two submitters, one pool worker: the worker must be free to join
    // either job (pick_job donation), and both jobs must complete with
    // exact accounting no matter which one it helps, or when.
    model(|| {
        let sched = Scheduler::new_standalone(1);
        let a = Arc::new(AtomicUsize::new(0));
        let b = Arc::new(AtomicUsize::new(0));
        let submitter = {
            let sched = Arc::clone(&sched);
            let b = Arc::clone(&b);
            loom::thread::spawn(move || {
                let task = move |_: usize| {
                    b.fetch_add(1, Ordering::Relaxed);
                };
                sched.run_on(2, 2, &task);
            })
        };
        let a2 = Arc::clone(&a);
        let task = move |_: usize| {
            a2.fetch_add(1, Ordering::Relaxed);
        };
        sched.run_on(2, 2, &task);
        submitter.join().unwrap();
        assert_eq!(a.load(Ordering::Relaxed), 2);
        assert_eq!(b.load(Ordering::Relaxed), 2);
        sched.shutdown();
    });
}

#[test]
fn drain_leaves_pool_parked_but_reusable() {
    // `drain` must block until the worker is fully parked (busy == 0,
    // not merely "the submitter saw completion"), and the parked pool
    // must accept and complete a second job.
    model(|| {
        let sched = Scheduler::new_standalone(1);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        let task = move |_: usize| {
            h.fetch_add(1, Ordering::Relaxed);
        };
        sched.run_on(2, 2, &task);
        sched.drain();
        assert_eq!(sched.outstanding_jobs(), 0, "drain left a job behind");
        sched.run_on(2, 2, &task);
        assert_eq!(hits.load(Ordering::Relaxed), 4);
        sched.shutdown();
        assert_eq!(sched.outstanding_jobs(), 0);
    });
}

#[test]
fn shutdown_unparks_and_joins_a_parked_worker() {
    // One index, two executors: whichever of submitter/worker loses the
    // cursor race parks (or never runs), and shutdown must wake and
    // join it — loom fails the model if any spawned thread leaks.
    model(|| {
        let sched = Scheduler::new_standalone(1);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        let task = move |_: usize| {
            h.fetch_add(1, Ordering::Relaxed);
        };
        sched.run_on(1, 2, &task);
        assert_eq!(hits.load(Ordering::Relaxed), 1, "the single index ran exactly once");
        sched.shutdown();
    });
}

#[test]
fn queue_close_drains_backlog_before_exit() {
    // The TaskQueue invariant (exec.rs module docs): items pushed
    // before `close` are popped by someone, never abandoned — whatever
    // order the executor, the second push, and the close interleave in.
    model(|| {
        let q: Arc<TaskQueue<u32>> = Arc::new(TaskQueue::new());
        assert!(q.push_and_plan(1, 1), "first push reserves the executor slot");
        let exec = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || {
                let mut sum = 0u32;
                while let Some(v) = q.pop_or_exit() {
                    sum += v;
                }
                sum
            })
        };
        assert!(!q.push_and_plan(2, 1), "at cap: no second executor");
        q.close();
        assert_eq!(exec.join().unwrap(), 3, "both pre-close items popped");
    });
}

#[test]
fn queue_close_wakes_parked_executor() {
    // After the backlog empties the executor parks; `close` must wake
    // it so it exits instead of waiting forever (the lost-wakeup shape
    // loom is best at finding).
    model(|| {
        let q: Arc<TaskQueue<u32>> = Arc::new(TaskQueue::new());
        assert!(q.push_and_plan(7, 1));
        let exec = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || {
                let mut n = 0usize;
                while q.pop_or_exit().is_some() {
                    n += 1;
                }
                n
            })
        };
        q.close();
        assert_eq!(exec.join().unwrap(), 1);
    });
}

/// The service's close trigger, reduced to its protocol: the last
/// public clone's drop closes the executor queue (service/mod.rs
/// `Gate`). Executors hold only the queue, never the gate.
struct Gate {
    q: Arc<TaskQueue<u32>>,
}

impl Drop for Gate {
    fn drop(&mut self) {
        self.q.close();
    }
}

#[test]
fn last_clone_drop_closes_exactly_once_and_drains() {
    model(|| {
        let q: Arc<TaskQueue<u32>> = Arc::new(TaskQueue::new());
        assert!(q.push_and_plan(5, 1));
        let exec = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(v) = q.pop_or_exit() {
                    seen.push(v);
                }
                seen
            })
        };
        let gate = Arc::new(Gate { q: Arc::clone(&q) });
        let other = Arc::clone(&gate);
        let dropper = loom::thread::spawn(move || drop(other));
        drop(gate);
        dropper.join().unwrap();
        assert_eq!(exec.join().unwrap(), vec![5], "backlog survived the gated close");
    });
}

#[test]
fn breaker_opens_exactly_once_under_concurrent_failures() {
    // Two threads report a failed call at threshold 2: exactly one of
    // them must see `newly == true` (the quarantine-log cue fires
    // once), and the breaker must be open afterwards. A zero cooldown
    // then makes the breaker immediately probe-ready (half-open), and a
    // successful probe closes it fully — the closed → open → half-open
    // → closed cycle with the open transition under contention.
    // (`Duration::ZERO`, never `Duration::MAX`: `Instant + cooldown`
    // must not overflow.)
    model(|| {
        let breaker = Arc::new(CircuitBreaker::new());
        let other = Arc::clone(&breaker);
        let t = loom::thread::spawn(move || other.on_failure(2, Duration::ZERO));
        let mine = breaker.on_failure(2, Duration::ZERO);
        let theirs = t.join().unwrap();
        assert!(
            mine != theirs,
            "exactly one failure crosses the threshold (mine={mine} theirs={theirs})"
        );
        assert!(breaker.is_open(), "two consecutive failures at threshold 2 must open");
        assert!(breaker.allow(), "zero cooldown: probe-ready immediately");
        breaker.on_success();
        assert!(!breaker.is_open(), "successful probe re-closes the breaker");
    });
}

#[test]
fn retry_budget_stays_within_bounds_under_contention() {
    // Concurrent spends racing a deposit: whatever the interleaving,
    // the token count must stay in [0, cap] — never negative (a spend
    // observed mid-deposit), never above cap (a deposit that missed the
    // clamp).
    model(|| {
        let budget = Arc::new(RetryBudget::new(1.5));
        let spender = {
            let b = Arc::clone(&budget);
            loom::thread::spawn(move || {
                let _ = b.try_spend();
                let _ = b.try_spend();
            })
        };
        budget.deposit(1.0);
        spender.join().unwrap();
        let left = budget.available();
        assert!((0.0..=1.5).contains(&left), "budget out of bounds: {left}");
        budget.deposit(5.0);
        assert!(budget.available() <= 1.5, "deposit must clamp at the cap");
    });
}

#[test]
fn spawn_failure_rolls_back_to_inline_drain() {
    // The degraded path: a reserved executor slot whose thread spawn
    // failed must roll back, and the (now executor-less) pusher must be
    // told to drain inline so no item hangs.
    model(|| {
        let q: TaskQueue<u32> = TaskQueue::new();
        assert!(q.push_and_plan(9, 4));
        assert!(q.spawn_failed(), "no executor remains: caller must drain inline");
        assert_eq!(q.pop_now(), Some(9));
        assert_eq!(q.pop_now(), None);
    });
}
