//! Chaos suite: seeded deterministic fault injection against the real
//! coordinator + worker stack (compiled only with `--features
//! fault-injection`; tier-1 builds never see this file's cost).
//!
//! Three campaigns, serialized on a process lock because the fault
//! registry is process-wide:
//!
//! - **cluster**: ≥100 seeded coordinator+2-worker generation runs with
//!   connection drops, delays, refusals, torn/corrupted payloads and
//!   dropped heartbeats on every coordinator↔worker exchange. Every run
//!   must finish (no hangs) with a merged space byte-identical to the
//!   unfaulted single-node run — degraded local fallback is allowed,
//!   silent corruption is not.
//! - **store**: repeated restarts over one durable state dir while the
//!   job log and result store suffer torn frames, bit flips and failed
//!   fsyncs; every submission still yields the baseline result.
//! - **http**: slow reads and mid-response disconnects on the JSON
//!   front-end; a retrying client always converges and the listener
//!   survives.
//!
//! `POLYGEN_CHAOS_SEED` / `POLYGEN_CHAOS_RUNS` override the pinned seed
//! and round count (CI runs the pinned seed plus one fresh seed per
//! build).

#![cfg(feature = "fault-injection")]

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use polygen::faults::{self, FaultPlan};
use polygen::net::Policy;
use polygen::pipeline::{JobResult, JobSpec, LookupBits, PipelineError};
use polygen::service::http::HttpServer;
use polygen::service::{run_worker_agent_with, JobHandle, Service};

/// The fault registry is process-global, so campaigns must not overlap.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn seed_base() -> u64 {
    std::env::var("POLYGEN_CHAOS_SEED")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0x5EED_CAFE_F00D_D00D)
}

fn rounds(default: u64) -> u64 {
    std::env::var("POLYGEN_CHAOS_RUNS")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(default)
        .max(1)
}

/// Weyl-sequence round mixing: distinct, reproducible per-round seeds.
fn round_seed(base: u64, i: u64) -> u64 {
    base ^ i.wrapping_mul(0x9E37_79B9_97F4_A7C5)
}

fn quick_spec(func: &str) -> JobSpec {
    let mut s = JobSpec::new(func, 8);
    s.lookup = LookupBits::Fixed(4);
    s
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("polygen_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// Tight policy so faulted calls fail fast and rounds stay short.
fn tight_policy() -> Policy {
    Policy {
        call_timeout: Duration::from_secs(2),
        retries: 2,
        backoff: Duration::from_millis(10),
        breaker_threshold: 2,
        breaker_cooldown: Duration::from_millis(200),
    }
}

/// Wait for a handle with a wall-clock deadline: a round that neither
/// finishes nor fails within it is a hang, the one outcome the fault
/// layer must never produce. Returns the outcome plus the job's
/// degraded flag (read post-completion, before `wait` consumes the
/// handle).
fn wait_deadline(h: JobHandle, what: &str) -> (Result<JobResult, PipelineError>, bool) {
    let deadline = Instant::now() + Duration::from_secs(120);
    while !h.status().is_finished() {
        assert!(Instant::now() < deadline, "{what}: job hung under fault injection");
        std::thread::sleep(Duration::from_millis(10));
    }
    let degraded = h.degraded();
    (h.wait(), degraded)
}

/// The byte-identity contract: whatever the faults did, the surviving
/// result must match the unfaulted baseline exactly.
fn assert_identical(got: &JobResult, want: &JobResult, what: &str) {
    assert_eq!(got.lookup_bits, want.lookup_bits, "{what}: lookup_bits diverged");
    assert_eq!(got.implementation.k, want.implementation.k, "{what}: k diverged");
    assert_eq!(
        got.implementation.coeffs, want.implementation.coeffs,
        "{what}: coefficients diverged"
    );
    assert_eq!(got.synth, want.synth, "{what}: synthesis estimate diverged");
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("server closes after one response");
    let header_end =
        raw.windows(4).position(|w| w == b"\r\n\r\n").expect("header terminator");
    let head = String::from_utf8_lossy(&raw[..header_end]).into_owned();
    let code: u16 =
        head.split_whitespace().nth(1).and_then(|c| c.parse().ok()).expect("status code");
    (code, String::from_utf8_lossy(&raw[header_end + 4..]).into_owned())
}

#[test]
fn cluster_runs_converge_byte_identically_under_faults() {
    let _serial = lock();
    let base = seed_base();
    let n = rounds(100);

    let spec = quick_spec("recip");
    let baseline = spec.run().expect("unfaulted single-node baseline");

    // Coordinator + two real workers, joined by live heartbeat agents.
    let coord_svc = Service::builder()
        .workers(2)
        .policy(tight_policy())
        .heartbeat_timeout(Duration::from_secs(60))
        .build();
    let coord = HttpServer::spawn(coord_svc.clone(), "127.0.0.1:0").expect("bind coordinator");
    let (w1, w2) = (
        HttpServer::spawn(Service::builder().workers(1).build(), "127.0.0.1:0").unwrap(),
        HttpServer::spawn(Service::builder().workers(1).build(), "127.0.0.1:0").unwrap(),
    );
    let stop = Arc::new(AtomicBool::new(false));
    let agents = [
        run_worker_agent_with(
            coord.addr().to_string(),
            w1.addr().to_string(),
            None,
            Arc::clone(&stop),
            tight_policy(),
        ),
        run_worker_agent_with(
            coord.addr().to_string(),
            w2.addr().to_string(),
            None,
            Arc::clone(&stop),
            tight_policy(),
        ),
    ];
    // Let both agents register (unfaulted) before the storm starts.
    let setup_deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (_, list) = http(coord.addr(), "GET", "/workers", "");
        if list.matches("\"live\":true").count() == 2 {
            break;
        }
        assert!(Instant::now() < setup_deadline, "workers never registered: {list}");
        std::thread::sleep(Duration::from_millis(20));
    }

    faults::reset_injected();
    let mut degraded_rounds = 0u64;
    for i in 0..n {
        let guard = faults::arm_guard(
            FaultPlan::new(round_seed(base, i)).rate(120).only("cluster."),
        );
        let handle = coord_svc.submit(spec.clone());
        let (got, degraded) = wait_deadline(handle, &format!("cluster round {i}"));
        drop(guard);
        let got = got.unwrap_or_else(|e| panic!("cluster round {i} failed: {e}"));
        assert_identical(&got, &baseline, &format!("cluster round {i}"));
        if degraded {
            degraded_rounds += 1;
        }
    }
    assert!(
        faults::injected() > 0,
        "{n} rounds at 12% per-site rate never fired a fault — the taps are dead"
    );
    eprintln!(
        "chaos cluster: {n} rounds, seed {base:#x}, {} injections, {degraded_rounds} degraded",
        faults::injected()
    );

    // Disarmed epilogue: the stack is still healthy — a clean run agrees
    // with the baseline and the scheduler is drained but reusable.
    let (clean, _) = wait_deadline(coord_svc.submit(spec.clone()), "clean epilogue");
    assert_identical(&clean.expect("clean run succeeds"), &baseline, "clean epilogue");
    polygen::pool::global().drain();
    assert_eq!(polygen::pool::global().outstanding_jobs(), 0, "scheduler not drained");
    let reusable = quick_spec("exp2").run().expect("scheduler reusable after chaos");
    assert!(!reusable.implementation.coeffs.is_empty());

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for a in agents {
        let _ = a.join();
    }
    w1.stop();
    w2.stop();
    coord.stop();
}

#[test]
fn durable_state_survives_store_faults_across_restarts() {
    let _serial = lock();
    let base = seed_base().rotate_left(17);
    let n = rounds(100).min(30); // each round rebuilds the service
    let dir = temp_dir("store");

    let specs = [quick_spec("recip"), quick_spec("exp2")];
    let baselines: Vec<JobResult> =
        specs.iter().map(|s| s.clone().run().expect("unfaulted baseline")).collect();

    faults::reset_injected();
    for i in 0..n {
        // Aggressive rate: every append/save is a coin flip away from a
        // torn frame, a flipped bit or a failed fsync.
        let guard = faults::arm_guard(
            FaultPlan::new(round_seed(base, i)).rate(250).only("store."),
        );
        // A fresh build each round replays — and, when the previous
        // round tore the tail, quarantines and truncates — the log.
        let svc = Service::builder().workers(1).state_dir(&dir).build();
        let which = (i % 2) as usize;
        let (got, _) = wait_deadline(
            svc.submit(specs[which].clone()),
            &format!("store round {i}"),
        );
        drop(guard);
        let got = got.unwrap_or_else(|e| panic!("store round {i} failed: {e}"));
        assert_identical(&got, &baselines[which], &format!("store round {i}"));
    }
    assert!(faults::injected() > 0, "store taps never fired");

    // Disarmed: one more restart must still come up and serve both
    // specs (store hit or recompute — either way, the baseline bytes).
    let svc = Service::builder().workers(1).state_dir(&dir).build();
    for (spec, want) in specs.iter().zip(&baselines) {
        let (got, _) = wait_deadline(svc.submit(spec.clone()), "store epilogue");
        assert_identical(&got.expect("epilogue succeeds"), want, "store epilogue");
    }
    eprintln!(
        "chaos store: {n} rounds, seed {base:#x}, {} injections",
        faults::injected()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn http_front_end_survives_slow_reads_and_disconnects() {
    let _serial = lock();
    let base = seed_base().rotate_left(31);
    let n = rounds(100).min(30);

    let svc = Service::builder().workers(1).build();
    let server = HttpServer::spawn(svc.clone(), "127.0.0.1:0").expect("bind");
    let id = {
        let h = svc.submit(quick_spec("recip"));
        let id = h.id();
        wait_deadline(h, "http setup job").0.expect("setup job succeeds");
        id
    };

    // A complete 200 exchange, or None on any transport/parse trouble
    // (the injected disconnect truncates the body mid-flight).
    let fetch_ok = |path: &str| -> Option<String> {
        let mut s = TcpStream::connect(server.addr()).ok()?;
        s.set_read_timeout(Some(Duration::from_secs(10))).ok()?;
        s.write_all(
            format!("GET {path} HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n").as_bytes(),
        )
        .ok()?;
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).ok()?;
        let header_end = raw.windows(4).position(|w| w == b"\r\n\r\n")?;
        let head = String::from_utf8_lossy(&raw[..header_end]).into_owned();
        if head.split_whitespace().nth(1) != Some("200") {
            return None;
        }
        let declared: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .and_then(|v| v.trim().parse().ok())?;
        let body = &raw[header_end + 4..];
        // A torn response (injected disconnect) is shorter than its own
        // Content-Length — the client-visible signature the retry eats.
        (body.len() == declared).then(|| String::from_utf8_lossy(body).into_owned())
    };

    faults::reset_injected();
    for i in 0..n {
        let guard = faults::arm_guard(
            FaultPlan::new(round_seed(base, i)).rate(300).only("http."),
        );
        let path = format!("/jobs/{id}");
        let mut ok = false;
        for _ in 0..50 {
            if let Some(body) = fetch_ok(&path) {
                assert!(body.contains("\"status\":\"done\""), "round {i}: {body}");
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(guard);
        assert!(ok, "http round {i}: client never saw a complete response in 50 tries");
    }
    assert!(faults::injected() > 0, "http taps never fired");
    eprintln!(
        "chaos http: {n} rounds, seed {base:#x}, {} injections",
        faults::injected()
    );

    // Disarmed: the listener still serves a full job lifecycle.
    let (code, body) = http(server.addr(), "GET", &format!("/jobs/{id}/result"), "");
    assert_eq!(code, 200, "{body}");
    server.stop();
}
