//! End-to-end cluster tests: sharded multi-worker generation over real
//! TCP (byte-identical to single-node), dead-worker shard reassignment,
//! restart replay of the durable job log, the content-addressed store
//! fast path, registry eviction, the listener hardening knobs (bearer
//! auth, connection cap, per-client rate limit), the store inventory
//! route, and on-disk corruption: a rotten `.pgjr` or `jobs.log` byte
//! must be quarantined and recomputed, never panic the service.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use polygen::pipeline::{JobSpec, LookupBits};
use polygen::service::http::{HttpOptions, HttpServer};
use polygen::service::{JobStatus, Service};

fn quick_spec(func: &str) -> JobSpec {
    let mut s = JobSpec::new(func, 8);
    s.lookup = LookupBits::Fixed(4);
    s
}

/// A fresh scratch directory under the system temp dir.
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("polygen_cluster_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// One-shot HTTP/1.1 exchange returning the raw body bytes (shard sweeps
/// answer binary PGSH payloads).
fn http_bytes(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
    auth: Option<&str>,
) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let auth_line = match auth {
        Some(tok) => format!("Authorization: Bearer {tok}\r\n"),
        None => String::new(),
    };
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n{auth_line}\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("server closes after one response");
    let header_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .unwrap_or_else(|| panic!("no header terminator in {raw:?}"));
    let head = String::from_utf8_lossy(&raw[..header_end]).into_owned();
    let code: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("bad response head: {head:?}"));
    (code, raw[header_end + 4..].to_vec())
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let (code, bytes) = http_bytes(addr, method, path, body, None);
    (code, String::from_utf8_lossy(&bytes).into_owned())
}

/// Extract `"key":<integer>` from a flat JSON body.
fn json_u64(body: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat).unwrap_or_else(|| panic!("{key} missing in {body}"));
    body[at + pat.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or_else(|_| panic!("{key} not an integer in {body}"))
}

fn worker() -> HttpServer {
    let svc = Service::builder().workers(1).build();
    HttpServer::spawn(svc, "127.0.0.1:0").expect("bind worker")
}

fn register(coord: SocketAddr, worker_addr: SocketAddr) -> u64 {
    let (code, body) =
        http(coord, "POST", "/workers", &format!("{{\"addr\":\"{worker_addr}\"}}"));
    assert_eq!(code, 201, "{body}");
    json_u64(&body, "id")
}

/// A valid `POST /shards` body for a one-region probe shard; the
/// returned id reveals how many shards the worker served before it.
fn probe_shard_toml() -> String {
    "func = recip\nbits = 8\naccuracy = 1ulp\n\n[generate]\nlookup_bits = 4\n\
     search = hull\nmax_k = 30\nthreads = 1\n\n[shard]\nlo = 0\nhi = 1\n"
        .to_string()
}

/// POST a probe shard and return how many shards the worker had already
/// served (shard ids are monotonically assigned from 1).
fn shards_served_before_probe(addr: SocketAddr) -> u64 {
    let (code, body) = http(addr, "POST", "/shards", &probe_shard_toml());
    assert_eq!(code, 201, "{body}");
    let id = json_u64(&body, "id");
    let (code, _) = http(addr, "DELETE", &format!("/shards/{id}"), "");
    assert_eq!(code, 200);
    id - 1
}

#[test]
fn sharded_generation_matches_single_node() {
    let coord_svc = Service::builder().workers(2).build();
    let coord = HttpServer::spawn(coord_svc.clone(), "127.0.0.1:0").expect("bind coordinator");
    let (w1, w2) = (worker(), worker());
    register(coord.addr(), w1.addr());
    register(coord.addr(), w2.addr());

    // Both workers are listed live.
    let (code, list) = http(coord.addr(), "GET", "/workers", "");
    assert_eq!(code, 200);
    assert!(list.contains(&w1.addr().to_string()), "{list}");
    assert!(list.contains(&w2.addr().to_string()), "{list}");
    assert_eq!(list.matches("\"live\":true").count(), 2, "{list}");

    // The same spec through the cluster and single-node must agree
    // exactly (the merged space is byte-identical, so the downstream
    // DSE/synthesis sees identical inputs).
    let spec = quick_spec("recip");
    let via_cluster = coord_svc.submit(spec.clone()).wait().expect("recip 8b R=4 feasible");
    let direct = spec.run().expect("direct run feasible");
    assert_eq!(via_cluster.lookup_bits, direct.lookup_bits);
    assert_eq!(via_cluster.implementation.k, direct.implementation.k);
    assert_eq!(via_cluster.implementation.coeffs, direct.implementation.coeffs);
    assert_eq!(via_cluster.synth.delay_ns, direct.synth.delay_ns);
    assert_eq!(via_cluster.synth.area_um2, direct.synth.area_um2);

    // The work was actually distributed: each worker served one shard.
    let served = shards_served_before_probe(w1.addr()) + shards_served_before_probe(w2.addr());
    assert!(served >= 2, "expected both workers to have served shards, saw {served}");

    w1.stop();
    w2.stop();
    coord.stop();
}

#[test]
fn dead_worker_shard_is_reassigned_and_job_completes() {
    let coord_svc = Service::builder()
        .workers(1)
        .heartbeat_timeout(Duration::from_millis(500))
        .build();
    let coord = HttpServer::spawn(coord_svc.clone(), "127.0.0.1:0").expect("bind coordinator");
    let (dead, live) = (worker(), worker());
    let dead_addr = dead.addr();
    register(coord.addr(), dead_addr);
    register(coord.addr(), live.addr());
    // Kill one worker after registration: its shard POST fails and the
    // coordinator must reassign the shard to the surviving worker.
    dead.stop();

    let spec = quick_spec("log2");
    let via_cluster = coord_svc.submit(spec.clone()).wait().expect("job survives dead worker");
    let direct = spec.run().expect("direct run feasible");
    assert_eq!(via_cluster.implementation.coeffs, direct.implementation.coeffs);

    // The dead worker stays in the registry (operators can see what
    // failed) but is no longer live once its heartbeat lapses; the
    // survivor served the whole range (both shards).
    std::thread::sleep(Duration::from_millis(600));
    let (code, list) = http(coord.addr(), "GET", "/workers", "");
    assert_eq!(code, 200);
    assert!(list.contains(&dead_addr.to_string()), "dead worker should stay listed: {list}");
    assert_eq!(list.matches("\"live\":true").count(), 1, "only the survivor is live: {list}");
    assert!(
        shards_served_before_probe(live.addr()) >= 2,
        "survivor should have served the reassigned shard too"
    );

    live.stop();
    coord.stop();
}

#[test]
fn restart_replays_log_and_store_serves_resubmission() {
    let dir = temp_dir("replay");
    let spec = quick_spec("recip");
    let (id, first) = {
        let svc = Service::builder().workers(1).state_dir(&dir).build();
        let handle = svc.submit(spec.clone());
        let id = handle.id();
        let first = handle.wait().expect("recip 8b R=4 feasible");
        (id, first)
    }; // service dropped: the "restart"

    // The replayed registry still answers for the old id, over HTTP too.
    let svc2 = Service::builder().workers(1).state_dir(&dir).build();
    assert_eq!(svc2.status_of(id), Some(JobStatus::Done));
    let server = HttpServer::spawn(svc2.clone(), "127.0.0.1:0").expect("bind");
    let (code, result) = http(server.addr(), "GET", &format!("/jobs/{id}/result"), "");
    assert_eq!(code, 200, "{result}");
    for co in &first.implementation.coeffs {
        let frag = format!("{{\"a\":{},\"b\":{},\"c\":{}}}", co.a, co.b, co.c);
        assert!(result.contains(&frag), "coeff {frag} missing in replayed {result}");
    }

    // Resubmitting the same spec is a content-addressed store hit: the
    // handle is born terminal without touching the scheduler.
    let t0 = Instant::now();
    let resubmitted = svc2.submit(spec.clone());
    assert!(resubmitted.id() > id);
    assert_eq!(resubmitted.status(), JobStatus::Done, "store hit must be instantly Done");
    let hit = resubmitted.wait().expect("store hit yields the stored result");
    assert!(t0.elapsed() < Duration::from_secs(1), "store hit took {:?}", t0.elapsed());
    assert_eq!(hit.implementation.coeffs, first.implementation.coeffs);
    assert_eq!(hit.lookup_bits, first.lookup_bits);

    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn evicted_jobs_answer_404() {
    let svc = Service::builder().workers(1).max_finished(1).build();
    let server = HttpServer::spawn(svc.clone(), "127.0.0.1:0").expect("bind");
    let a = svc.submit(quick_spec("recip"));
    let b = svc.submit(quick_spec("exp2"));
    let (ida, idb) = (a.id(), b.id());
    assert!(a.wait().is_ok());
    assert!(b.wait().is_ok());

    // The next submission triggers eviction: 2 terminal jobs, cap 1 —
    // the older one goes.
    let c = svc.submit(quick_spec("log2"));
    assert_eq!(svc.status_of(ida), None, "oldest terminal job should be evicted");
    assert_eq!(svc.status_of(idb), Some(JobStatus::Done), "newest stays within the cap");
    let (code, _) = http(server.addr(), "GET", &format!("/jobs/{ida}"), "");
    assert_eq!(code, 404);
    let (code, _) = http(server.addr(), "GET", &format!("/jobs/{ida}/result"), "");
    assert_eq!(code, 404);
    let (code, _) = http(server.addr(), "GET", &format!("/jobs/{idb}"), "");
    assert_eq!(code, 200);
    assert!(c.wait().is_ok());
    server.stop();
}

#[test]
fn finished_ttl_evicts_on_submission() {
    let svc = Service::builder()
        .workers(1)
        .finished_ttl(Duration::from_millis(1))
        .build();
    let a = svc.submit(quick_spec("recip"));
    let ida = a.id();
    assert!(a.wait().is_ok());
    std::thread::sleep(Duration::from_millis(20));
    let b = svc.submit(quick_spec("exp2"));
    assert_eq!(svc.status_of(ida), None, "expired terminal job should be evicted");
    assert!(b.wait().is_ok());
}

#[test]
fn auth_token_guards_every_route() {
    let svc = Service::builder().workers(1).build();
    let opts = HttpOptions { auth_token: Some("s3cret".into()), ..HttpOptions::default() };
    let server = HttpServer::spawn_with(svc, "127.0.0.1:0", opts).expect("bind");

    let (code, body) = http_bytes(server.addr(), "GET", "/jobs", "", None);
    assert_eq!(code, 401, "{}", String::from_utf8_lossy(&body));
    let (code, _) = http_bytes(server.addr(), "GET", "/jobs", "", Some("wrong"));
    assert_eq!(code, 401);
    let (code, body) = http_bytes(server.addr(), "GET", "/jobs", "", Some("s3cret"));
    assert_eq!(code, 200);
    assert_eq!(String::from_utf8_lossy(&body), "[]");

    server.stop();
}

#[test]
fn connection_cap_answers_503() {
    let svc = Service::builder().workers(1).build();
    let opts = HttpOptions { max_conns: 1, ..HttpOptions::default() };
    let server = HttpServer::spawn_with(svc, "127.0.0.1:0", opts).expect("bind");

    // An idle connection occupies the single slot without sending a
    // request...
    let idle = TcpStream::connect(server.addr()).expect("connect idle");
    std::thread::sleep(Duration::from_millis(200));
    // ...so a concurrent request is refused at the door.
    let (code, body) = http(server.addr(), "GET", "/jobs", "");
    assert_eq!(code, 503, "{body}");
    assert!(body.contains("connection limit"), "{body}");

    // Releasing the slot restores service.
    drop(idle);
    std::thread::sleep(Duration::from_millis(200));
    let (code, _) = http(server.addr(), "GET", "/jobs", "");
    assert_eq!(code, 200);

    server.stop();
}

#[test]
fn rate_limit_answers_429_with_retry_after() {
    let svc = Service::builder().workers(1).build();
    // Sustained 1 req/s with a burst depth of 2: the first two
    // back-to-back requests pass, the third is refused at the door.
    let opts = HttpOptions { rate_limit: 1.0, rate_burst: 2.0, ..HttpOptions::default() };
    let server = HttpServer::spawn_with(svc, "127.0.0.1:0", opts).expect("bind");

    let (code, _) = http(server.addr(), "GET", "/jobs", "");
    assert_eq!(code, 200);
    let (code, _) = http(server.addr(), "GET", "/jobs", "");
    assert_eq!(code, 200);

    // Third request: raw exchange so the Retry-After header is visible.
    let mut s = TcpStream::connect(server.addr()).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(b"GET /jobs HTTP/1.1\r\nHost: test\r\nContent-Length: 0\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("server closes after one response");
    assert!(raw.starts_with("HTTP/1.1 429 "), "{raw}");
    assert!(raw.contains("Retry-After: "), "{raw}");
    assert!(raw.contains("rate limit exceeded"), "{raw}");

    // The bucket refills with time: after ~1.2 s one request fits again.
    std::thread::sleep(Duration::from_millis(1200));
    let (code, _) = http(server.addr(), "GET", "/jobs", "");
    assert_eq!(code, 200, "bucket should refill at the sustained rate");

    server.stop();
}

#[test]
fn store_inventory_route_lists_results() {
    let dir = temp_dir("inventory");
    let svc = Service::builder().workers(1).state_dir(&dir).build();
    let server = HttpServer::spawn(svc.clone(), "127.0.0.1:0").expect("bind");

    let (code, body) = http(server.addr(), "GET", "/store", "");
    assert_eq!(code, 200, "{body}");
    assert!(body.contains("\"count\":0"), "fresh store should be empty: {body}");

    svc.submit(quick_spec("recip")).wait().expect("recip 8b R=4 feasible");
    let (code, body) = http(server.addr(), "GET", "/store", "");
    assert_eq!(code, 200, "{body}");
    assert!(body.contains("\"count\":1"), "{body}");
    assert!(body.contains("\"key\":"), "{body}");
    assert!(body.contains("\"age_secs\":"), "{body}");
    server.stop();

    // A stateless service has no store to inventory.
    let svc2 = Service::builder().workers(1).build();
    let server2 = HttpServer::spawn(svc2, "127.0.0.1:0").expect("bind");
    let (code, body) = http(server2.addr(), "GET", "/store", "");
    assert_eq!(code, 404, "{body}");
    server2.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_stored_result_is_quarantined_and_recomputed() {
    let dir = temp_dir("quarantine");
    let spec = quick_spec("exp2");
    let first = {
        let svc = Service::builder().workers(1).state_dir(&dir).build();
        svc.submit(spec.clone()).wait().expect("exp2 8b R=4 feasible")
    }; // service dropped: the "restart"

    // Rot one byte of the stored artifact on disk.
    let results = dir.join("results");
    let pgjr = std::fs::read_dir(&results)
        .expect("results dir exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .find(|p| p.extension().map_or(false, |x| x == "pgjr"))
        .expect("stored result exists");
    let mut bytes = std::fs::read(&pgjr).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&pgjr, &bytes).unwrap();

    // Restart over the rotten store: the build must not panic, the bad
    // artifact is set aside, and a resubmission recomputes the same
    // result from scratch instead of serving garbage.
    let svc = Service::builder().workers(1).state_dir(&dir).build();
    let again = svc.submit(spec.clone()).wait().expect("recompute succeeds");
    assert_eq!(again.implementation.coeffs, first.implementation.coeffs);
    assert_eq!(again.lookup_bits, first.lookup_bits);
    let quarantined = std::fs::read_dir(&results)
        .unwrap()
        .filter_map(|e| e.ok())
        .any(|e| e.path().to_string_lossy().ends_with(".pgjr.quarantined"));
    assert!(quarantined, "corrupt artifact should be set aside, not deleted");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_jobs_log_byte_flip_replays_without_panic() {
    let dir = temp_dir("logflips");
    {
        let svc = Service::builder().workers(1).state_dir(&dir).build();
        svc.submit(quick_spec("recip")).wait().expect("recip 8b R=4 feasible");
    }
    let log_path = dir.join("jobs.log");
    let pristine = std::fs::read(&log_path).expect("job log exists");
    assert!(!pristine.is_empty());

    // Whatever single byte rots — length header, frame CRC, spec TOML,
    // outcome record — recovery must never panic and the service must
    // come up answering queries (possibly with fewer replayed jobs).
    for i in 0..pristine.len() {
        let mut bytes = pristine.clone();
        bytes[i] ^= 0x01;
        std::fs::write(&log_path, &bytes).unwrap();
        let svc = Service::builder().workers(1).state_dir(&dir).build();
        let _ = svc.status_of(1);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shard_protocol_round_trips_pgsh() {
    let w = worker();

    // Full-range single shard for recip 8b R=4.
    let toml = "func = recip\nbits = 8\naccuracy = 1ulp\n\n[generate]\nlookup_bits = 4\n\
                search = hull\nmax_k = 30\nthreads = 1\n\n[shard]\nlo = 0\nhi = 16\n";
    let (code, body) = http(w.addr(), "POST", "/shards", toml);
    assert_eq!(code, 201, "{body}");
    let id = json_u64(&body, "id");

    // Poll until analyzed, then sweep at the shard minimum.
    let deadline = Instant::now() + Duration::from_secs(120);
    let min_k = loop {
        let (code, st) = http(w.addr(), "GET", &format!("/shards/{id}"), "");
        assert_eq!(code, 200, "{st}");
        if st.contains("\"state\":\"analyzed\"") {
            break json_u64(&st, "min_k");
        }
        assert!(st.contains("\"state\":\"analyzing\""), "unexpected shard state: {st}");
        assert!(Instant::now() < deadline, "shard never analyzed: {st}");
        std::thread::sleep(Duration::from_millis(10));
    };
    let sweep_body = format!("k = {min_k}\n");
    let (code, bytes) =
        http_bytes(w.addr(), "POST", &format!("/shards/{id}/sweep"), &sweep_body, None);
    assert_eq!(code, 200);
    assert_eq!(&bytes[..4], b"PGSH", "sweep must answer the PGSH binary");

    // A k below the shard minimum is a 400; bogus ids are 404s.
    if min_k > 0 {
        let (code, body) = http(w.addr(), "POST", &format!("/shards/{id}/sweep"), "k = 0\n");
        assert_eq!(code, 400, "{body}");
    }
    let (code, _) = http(w.addr(), "GET", "/shards/999", "");
    assert_eq!(code, 404);
    let (code, _) = http(w.addr(), "POST", "/shards/999/sweep", "k = 1\n");
    assert_eq!(code, 404);

    // Malformed shard requests are rejected up front.
    let (code, body) = http(w.addr(), "POST", "/shards", "func = recip\nbits = 8\n");
    assert_eq!(code, 400, "{body}");

    // DELETE cancels and unregisters; a second DELETE is a 404.
    let (code, _) = http(w.addr(), "DELETE", &format!("/shards/{id}"), "");
    assert_eq!(code, 200);
    let (code, _) = http(w.addr(), "GET", &format!("/shards/{id}"), "");
    assert_eq!(code, 404);
    let (code, _) = http(w.addr(), "DELETE", &format!("/shards/{id}"), "");
    assert_eq!(code, 404);

    w.stop();
}

#[test]
fn worker_heartbeat_and_reregistration() {
    let svc = Service::builder().workers(1).build();
    let coord = HttpServer::spawn(svc, "127.0.0.1:0").expect("bind");

    let id = register(coord.addr(), "127.0.0.1:9".parse().unwrap());
    let (code, body) = http(coord.addr(), "POST", &format!("/workers/{id}/heartbeat"), "");
    assert_eq!(code, 200, "{body}");
    assert!(body.contains("\"ok\":true"), "{body}");
    // Unknown ids tell the worker to re-register.
    let (code, _) = http(coord.addr(), "POST", "/workers/999/heartbeat", "");
    assert_eq!(code, 404);

    // Re-registering the same address replaces the entry (no duplicate
    // workers after a restart).
    let id2 = register(coord.addr(), "127.0.0.1:9".parse().unwrap());
    assert_ne!(id, id2);
    let (_, list) = http(coord.addr(), "GET", "/workers", "");
    assert_eq!(list.matches("127.0.0.1:9").count(), 1, "{list}");

    coord.stop();
}
