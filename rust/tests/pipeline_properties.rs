//! Cross-module integration properties: every path through
//! generate -> DSE -> encode -> RTL-sim -> verify must hold across a grid
//! of functions, precisions, lookup heights, accuracy specs and
//! procedure variants. These are the system-level invariants DESIGN.md §6
//! commits to.

use polygen::bounds::{builtin, AccuracySpec, BoundTable};
use polygen::coordinator::cache;
use polygen::designspace::extrema::SearchStrategy;
use polygen::designspace::{generate, generate_eager, GenOptions};
use polygen::dse::{explore, Degree, DseOptions, Procedure};
use polygen::rtl::{emit_golden_hex, emit_module, DatapathSim};
use polygen::verify::{verify_exhaustive, Engine};

fn exhaustive_ok(bt: &BoundTable, im: &polygen::dse::Implementation) -> bool {
    verify_exhaustive(bt, im, &Engine::Scalar).unwrap().ok()
}

/// The headline invariant over a broad grid: whenever generation and DSE
/// succeed, the implementation verifies exhaustively, the netlist-level
/// simulator agrees with eval, and the golden vector round-trips.
#[test]
fn grid_every_design_verifies_and_simulates() {
    let mut checked = 0;
    for name in
        ["recip", "log2", "exp2", "sqrt", "tanh", "sigmoid", "gelu", "softplus"]
    {
        for bits in [8u32, 10, 12] {
            let f = builtin(name, bits).unwrap();
            let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
            for r in 3..=(bits - 3) {
                let Ok(ds) =
                    generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
                else {
                    continue;
                };
                let Some(im) = explore(&bt, &ds, &DseOptions::default()) else {
                    panic!("{name}/{bits} R={r}: space generated but DSE failed");
                };
                assert!(exhaustive_ok(&bt, &im), "{name}/{bits} R={r} violates bounds");
                let sim = DatapathSim::new(&im);
                for z in (0..(1u64 << bits)).step_by(13) {
                    assert_eq!(sim.eval(z), im.eval(z), "{name}/{bits} R={r} z={z}");
                }
                checked += 1;
            }
        }
    }
    assert!(checked >= 30, "grid too sparse: only {checked} designs checked");
}

/// The lazy-region tentpole invariant over a broad grid: whatever a
/// `RegionView` re-sweeps on demand is byte-identical to the eager
/// oracle's phase-3 output — entries, `linear_ok`, pair counts — across
/// every built-in workload, several precisions and lookup heights, and
/// the streamed metrics agree with the materialized ones.
#[test]
fn grid_lazy_views_equal_eager_oracle() {
    let mut checked = 0;
    for name in
        ["recip", "log2", "exp2", "sqrt", "tanh", "sigmoid", "gelu", "softplus"]
    {
        for bits in [8u32, 10, 12] {
            let f = builtin(name, bits).unwrap();
            let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
            for r in 3..=(bits - 3) {
                let opts = GenOptions { lookup_bits: r, ..Default::default() };
                let Ok(lazy) = generate(&bt, &opts) else { continue };
                let eager = generate_eager(&bt, &opts)
                    .expect("lazy feasible implies eager feasible");
                assert_eq!(lazy.k, eager.k, "{name}/{bits} R={r}: k");
                // Streamed metrics first — they must not materialize.
                assert_eq!(
                    lazy.num_ab_pairs(),
                    eager.num_ab_pairs(),
                    "{name}/{bits} R={r}: pair count"
                );
                assert_eq!(
                    lazy.linear_feasible(),
                    eager.linear_feasible(),
                    "{name}/{bits} R={r}: linear bit"
                );
                assert!(
                    lazy.region_views().all(|v| !v.is_materialized()),
                    "{name}/{bits} R={r}: metrics materialized a region"
                );
                // Then the byte-identical entry sweep, region by region.
                for (lv, ev) in lazy.region_views().zip(eager.region_views()) {
                    assert_eq!(
                        lv.entries(),
                        ev.entries(),
                        "{name}/{bits} R={r} region {}",
                        lv.r()
                    );
                    assert_eq!(lv.linear_ok(), ev.linear_ok(), "{name}/{bits} R={r}");
                }
                checked += 1;
            }
        }
    }
    assert!(checked >= 30, "grid too sparse: only {checked} spaces checked");
}

/// Exploring a lazy space and an eager space yields the same
/// implementation — the decision procedures are representation-blind.
#[test]
fn dse_is_representation_blind() {
    for (name, bits, r) in [("recip", 10u32, 4u32), ("exp2", 10, 5)] {
        let f = builtin(name, bits).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let opts = GenOptions { lookup_bits: r, ..Default::default() };
        let lazy = generate(&bt, &opts).unwrap();
        let eager = generate_eager(&bt, &opts).unwrap();
        let a = explore(&bt, &lazy, &DseOptions::default()).unwrap();
        let b = explore(&bt, &eager, &DseOptions::default()).unwrap();
        assert!(a.same_selection(&b), "{name}: lazy vs eager DSE diverged");
    }
}

/// Accuracy-spec variants: Faithful and Ulp(2) also produce verified
/// designs, and looser specs never need more lookup bits.
#[test]
fn accuracy_spec_variants() {
    for name in ["recip", "log2"] {
        let f = builtin(name, 10).unwrap();
        let tight = BoundTable::build(f.as_ref(), AccuracySpec::Faithful);
        let mid = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let loose = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(2));
        // Bounds nest: tight inside mid inside loose.
        for z in 0..(1usize << 10) {
            assert!(loose.l[z] <= mid.l[z] && mid.l[z] <= tight.l[z]);
            assert!(tight.u[z] <= mid.u[z] && mid.u[z] <= loose.u[z]);
        }
        let min_r = |bt: &BoundTable| -> u32 {
            polygen::designspace::min_lookup_bits(bt, &GenOptions::default(), 9)
                .expect("feasible somewhere")
        };
        let (rt, rm, rl) = (min_r(&tight), min_r(&mid), min_r(&loose));
        assert!(rl <= rm && rm <= rt, "looser spec needed more regions: {rl} {rm} {rt}");
        // And each verifies under its own spec.
        for (bt, label) in [(&tight, "faithful"), (&mid, "1ulp"), (&loose, "2ulp")] {
            let r = min_r(bt);
            let ds = generate(bt, &GenOptions { lookup_bits: r, ..Default::default() }).unwrap();
            let im = explore(bt, &ds, &DseOptions::default())
                .unwrap_or_else(|| panic!("{name} {label}: DSE failed"));
            assert!(exhaustive_ok(bt, &im), "{name} {label}");
        }
    }
}

/// Procedure and degree variants all yield verified designs; truncations
/// never exceed the input width; encodings admit all coefficients.
#[test]
fn dse_variant_matrix() {
    let f = builtin("recip", 10).unwrap();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    let ds = generate(&bt, &GenOptions { lookup_bits: 5, ..Default::default() }).unwrap();
    for procedure in [Procedure::SquareFirst, Procedure::LutFirst, Procedure::Pareto] {
        for degree in [None, Some(Degree::Quadratic)] {
            let opts = DseOptions { procedure: Some(procedure), degree, ..Default::default() };
            let Some(im) = explore(&bt, &ds, &opts) else {
                panic!("{procedure:?}/{degree:?} failed");
            };
            assert!(exhaustive_ok(&bt, &im), "{procedure:?}/{degree:?}");
            assert!(im.sq_trunc <= im.x_bits() && im.lin_trunc <= im.x_bits());
            for co in &im.coeffs {
                assert!(im.enc_a.admits(co.a) || im.degree == Degree::Linear);
                assert!(im.enc_b.admits(co.b));
                assert!(im.enc_c.admits(co.c));
            }
        }
    }
}

/// Naive and pruned strategies produce byte-identical cached spaces.
#[test]
fn strategies_agree_through_cache() {
    let f = builtin("exp2", 10).unwrap();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    let a = generate(
        &bt,
        &GenOptions { lookup_bits: 5, search: SearchStrategy::Naive, ..Default::default() },
    )
    .unwrap();
    let mut b = generate(
        &bt,
        &GenOptions { lookup_bits: 5, search: SearchStrategy::Pruned, ..Default::default() },
    )
    .unwrap();
    // dd_evals is instrumentation (naive does more work by design);
    // everything else must serialize identically.
    b.dd_evals = a.dd_evals;
    assert_eq!(cache::to_bytes(&a), cache::to_bytes(&b));
}

/// The emitted Verilog is consistent with the golden vector for every
/// function (structure check; semantic equivalence comes from DatapathSim
/// which evaluates through the same packed LUT words the case table holds).
#[test]
fn rtl_artifacts_consistent() {
    for name in ["recip", "log2", "exp2"] {
        let f = builtin(name, 8).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: 4, ..Default::default() }).unwrap();
        let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
        let v = emit_module(&im, "dut");
        assert_eq!(v.matches(": lut =").count(), 17, "{name}: 16 arms + default");
        let hex = emit_golden_hex(&im);
        assert_eq!(hex.lines().count(), 256);
        let sim = DatapathSim::new(&im);
        for (z, line) in hex.lines().enumerate() {
            let golden = i64::from_str_radix(line, 16).unwrap();
            assert_eq!(golden, sim.eval(z as u64) & ((1 << im.out_bits) - 1), "{name} z={z}");
        }
    }
}

/// Fault injection across all coefficient kinds: corruption is always
/// detected by exhaustive verification.
#[test]
fn fault_injection_matrix() {
    let f = builtin("log2", 10).unwrap();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    let ds = generate(&bt, &GenOptions { lookup_bits: 5, ..Default::default() }).unwrap();
    let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
    assert!(exhaustive_ok(&bt, &im));
    let bump = 8i64 << im.k;
    for region in [0usize, 15, 31] {
        for field in 0..3 {
            // An `a` corruption is architecturally masked in linear designs:
            // the square path is fully truncated (sq_trunc == x_bits), so
            // a*T_i(x) is identically zero. Skip — that is correct hardware
            // behaviour, not a verification gap.
            if field == 0 && im.sq_trunc >= im.x_bits() {
                continue;
            }
            let mut bad = im.clone();
            match field {
                0 => bad.coeffs[region].a += 1 << bad.enc_a.trunc.max(4),
                1 => bad.coeffs[region].b += bump.max(1 << 10),
                _ => bad.coeffs[region].c += bump,
            }
            let rep = verify_exhaustive(&bt, &bad, &Engine::Scalar).unwrap();
            assert!(
                !rep.ok(),
                "undetected corruption: region {region} field {field}"
            );
        }
    }
}

/// k returned by generation is minimal: k-1 must be infeasible for at
/// least one region (otherwise the common k would have been smaller).
#[test]
fn common_k_is_minimal() {
    for (name, r) in [("recip", 4u32), ("log2", 5), ("exp2", 4)] {
        let f = builtin(name, 10).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() }).unwrap();
        if ds.k == 0 {
            continue;
        }
        let some_region_fails = ds.analyses.iter().any(|an| {
            polygen::designspace::region::region_space_at_k(an, ds.k - 1).is_none()
        });
        assert!(some_region_fails, "{name}: k={} not minimal", ds.k);
    }
}
