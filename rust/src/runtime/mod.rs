//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them.
//!
//! The AOT bridge (see `python/compile/aot.py` and DESIGN.md): jax lowers
//! the L2 graphs to HLO **text**; this module parses the text with
//! `HloModuleProto::from_text_file`, compiles each module once on the
//! PJRT CPU client, and exposes typed entry points. Python never runs on
//! this path — the binary is self-contained once `artifacts/` exists.
//!
//! Geometry constants must match `python/compile/model.py`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::designspace::extrema::DiagExtrema;
use crate::dse::Implementation;
use crate::faults::{self, Fault};

/// Batch size of the verify graphs.
pub const CHUNK: usize = 65536;
/// Coefficient-table padding of the verify graphs (supports `R <= 11`).
pub const TABLE: usize = 2048;
/// Region sizes with a compiled extrema graph.
pub const EXTREMA_NS: [usize; 2] = [256, 1024];

/// Which lowering of the verify graph to execute.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Flavor {
    /// Pure-jnp lowering: fused XLA-CPU loops — the fast path.
    Jnp,
    /// Interpret-mode Pallas lowering: structurally the TPU kernel;
    /// bit-identical, much slower on CPU. Used for cross-checks.
    Pallas,
}

/// Sanity-check an HLO text artifact before it reaches the FFI parser.
///
/// Artifacts are machine-written ASCII, so the check is structural: the
/// bytes must be UTF-8 and name an `HloModule`. Anything else is damage
/// — the file is renamed aside (`.quarantined`) and the load fails with
/// a rebuild hint, instead of feeding garbage to the C++ HLO parser.
/// The read is routed through the `runtime.artifact` injection tap so
/// the chaos suite can prove a corrupt artifact never reaches `compile`.
fn check_artifact(path: &Path) -> Result<()> {
    let mut bytes =
        std::fs::read(path).with_context(|| format!("reading HLO text {}", path.display()))?;
    if faults::inject("runtime.artifact", &[Fault::Corrupt]).is_some() && !bytes.is_empty() {
        let at = faults::rand_below(bytes.len());
        bytes[at] ^= 0x80;
    }
    let looks_like_hlo = std::str::from_utf8(&bytes).is_ok_and(|t| t.contains("HloModule"));
    if !looks_like_hlo {
        let mut q = path.as_os_str().to_owned();
        q.push(".quarantined");
        let q = PathBuf::from(q);
        let _ = std::fs::rename(path, &q);
        bail!(
            "{} is not HLO module text; quarantined at {} — run `make artifacts` to rebuild",
            path.display(),
            q.display()
        );
    }
    Ok(())
}

struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedExe {
    fn load(client: &xla::PjRtClient, path: &Path) -> Result<LoadedExe> {
        check_artifact(path)?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedExe { exe })
    }

    fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        Ok(result.to_tuple()?)
    }
}

/// The compiled-artifact runtime. Construction compiles every artifact
/// found under the directory; individual graphs are optional so partial
/// artifact sets (e.g. `--skip-pallas`) still work.
pub struct XlaRuntime {
    verify_jnp: Option<LoadedExe>,
    verify_pallas: Option<LoadedExe>,
    extrema: Vec<(usize, LoadedExe)>,
    pub dir: PathBuf,
}

impl XlaRuntime {
    /// Load from `artifacts/` (or a custom directory).
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let opt = |name: &str| -> Result<Option<LoadedExe>> {
            let p = dir.join(name);
            if p.exists() {
                Ok(Some(LoadedExe::load(&client, &p)?))
            } else {
                Ok(None)
            }
        };
        let verify_jnp = opt("verify_jnp.hlo.txt")?;
        let verify_pallas = opt("verify_pallas.hlo.txt")?;
        let mut extrema = Vec::new();
        for n in EXTREMA_NS {
            if let Some(exe) = opt(&format!("extrema_jnp_N{n}.hlo.txt"))? {
                extrema.push((n, exe));
            }
        }
        if verify_jnp.is_none() && verify_pallas.is_none() && extrema.is_empty() {
            bail!(
                "no artifacts found in {} — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(XlaRuntime { verify_jnp, verify_pallas, extrema, dir })
    }

    pub fn has_flavor(&self, flavor: Flavor) -> bool {
        match flavor {
            Flavor::Jnp => self.verify_jnp.is_some(),
            Flavor::Pallas => self.verify_pallas.is_some(),
        }
    }

    /// Execute the verify graph on one chunk.
    ///
    /// `z`, `l`, `u` must be exactly `CHUNK` long; tables exactly `TABLE`.
    /// `params = [xbits, sq_trunc, lin_trunc, k, out_max]`.
    /// Returns `(outputs, violation count)`.
    pub fn verify_chunk(
        &self,
        flavor: Flavor,
        z: &[i64],
        tables: &CoeffTables,
        l: &[i64],
        u: &[i64],
        params: [i64; 5],
    ) -> Result<(Vec<i64>, i64)> {
        assert_eq!(z.len(), CHUNK);
        assert_eq!(l.len(), CHUNK);
        assert_eq!(u.len(), CHUNK);
        let exe = match flavor {
            Flavor::Jnp => self.verify_jnp.as_ref(),
            Flavor::Pallas => self.verify_pallas.as_ref(),
        }
        .with_context(|| format!("verify artifact for {flavor:?} not loaded"))?;
        let args = vec![
            xla::Literal::vec1(z),
            xla::Literal::vec1(&tables.a),
            xla::Literal::vec1(&tables.b),
            xla::Literal::vec1(&tables.c),
            xla::Literal::vec1(l),
            xla::Literal::vec1(u),
            xla::Literal::vec1(&params),
        ];
        let mut out = exe.run(&args)?;
        anyhow::ensure!(out.len() == 2, "verify graph returned {} outputs", out.len());
        let viol = out.pop().unwrap().to_vec::<i64>()?;
        let outs = out.pop().unwrap().to_vec::<i64>()?;
        Ok((outs, viol.iter().sum()))
    }

    /// Execute the diagonal-extrema graph for a region of exactly a
    /// compiled size. Returns `None` when no variant matches (callers fall
    /// back to the in-process Rust implementation).
    pub fn extrema(&self, l: &[i32], u: &[i32]) -> Option<DiagExtrema> {
        let n = l.len();
        let exe = self.extrema.iter().find(|&&(sz, _)| sz == n).map(|(_, e)| e)?;
        let li: Vec<i64> = l.iter().map(|&v| v as i64).collect();
        let ui: Vec<i64> = u.iter().map(|&v| v as i64).collect();
        let args = [xla::Literal::vec1(&li), xla::Literal::vec1(&ui)];
        let out = exe.run(&args).ok()?;
        if out.len() != 4 {
            return None;
        }
        let bn = out[0].to_vec::<i64>().ok()?;
        let bd = out[1].to_vec::<i64>().ok()?;
        let sn = out[2].to_vec::<i64>().ok()?;
        let sd = out[3].to_vec::<i64>().ok()?;
        let tmax = 2 * n - 3;
        let m_pairs: Vec<(i64, i64)> = bn.into_iter().zip(bd).collect();
        let s_pairs: Vec<(i64, i64)> = sn.into_iter().zip(sd).collect();
        Some(crate::designspace::extrema::diag_extrema_from_fracs(
            &m_pairs, &s_pairs, tmax,
        ))
    }
}

/// Padded coefficient tables for the verify graph.
pub struct CoeffTables {
    pub a: Vec<i64>,
    pub b: Vec<i64>,
    pub c: Vec<i64>,
}

impl CoeffTables {
    pub fn from_impl(im: &Implementation) -> CoeffTables {
        assert!(
            im.coeffs.len() <= TABLE,
            "R={} exceeds the compiled table capacity",
            im.lookup_bits
        );
        let mut a = vec![0i64; TABLE];
        let mut b = vec![0i64; TABLE];
        let mut c = vec![0i64; TABLE];
        for (i, co) in im.coeffs.iter().enumerate() {
            a[i] = co.a;
            b[i] = co.b;
            c[i] = co.c;
        }
        CoeffTables { a, b, c }
    }
}

/// Overflow guard: the XLA datapath runs in i64; reject configurations
/// whose accumulator could exceed it (none of the paper's formats do).
pub fn accumulator_fits_i64(im: &Implementation) -> bool {
    let xmax = (1i128 << im.x_bits()) - 1;
    let amax = im.coeffs.iter().map(|c| (c.a as i128).abs()).max().unwrap_or(0);
    let bmax = im.coeffs.iter().map(|c| (c.b as i128).abs()).max().unwrap_or(0);
    let cmax = im.coeffs.iter().map(|c| (c.c as i128).abs()).max().unwrap_or(0);
    let acc = amax * xmax * xmax + bmax * xmax + cmax;
    acc < (1i128 << 62)
}

#[cfg(test)]
mod tests {
    use super::*;

    const HLO: &[u8] =
        b"HloModule verify_jnp, entry_computation_layout={()->s64[]}\n\nENTRY main {\n  ROOT c = s64[] constant(1)\n}\n";

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("polygen_hlo_{}_{tag}.hlo.txt", std::process::id()))
    }

    fn quarantine_of(path: &Path) -> PathBuf {
        let mut q = path.as_os_str().to_owned();
        q.push(".quarantined");
        PathBuf::from(q)
    }

    #[test]
    fn clean_artifact_passes_and_stays() {
        let path = scratch("clean");
        std::fs::write(&path, HLO).unwrap();
        check_artifact(&path).unwrap();
        assert!(path.exists());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn every_high_bit_flip_is_caught_and_quarantined() {
        // The artifact is pure ASCII, so flipping any byte's high bit
        // yields invalid UTF-8 — the structural check must catch every
        // position and move the file aside.
        let path = scratch("byteflip");
        let q = quarantine_of(&path);
        for at in 0..HLO.len() {
            let mut bad = HLO.to_vec();
            bad[at] ^= 0x80;
            std::fs::write(&path, &bad).unwrap();
            let err = check_artifact(&path).unwrap_err().to_string();
            assert!(err.contains("quarantined"), "flip at {at}: {err}");
            assert!(!path.exists(), "flip at {at} left the bad artifact in place");
            assert!(q.exists(), "flip at {at} did not quarantine");
            std::fs::remove_file(&q).unwrap();
        }
    }

    #[test]
    fn text_without_module_header_is_quarantined() {
        let path = scratch("noheader");
        std::fs::write(&path, b"ENTRY main { ROOT c = s64[] constant(1) }\n").unwrap();
        assert!(check_artifact(&path).is_err());
        assert!(!path.exists());
        std::fs::remove_file(quarantine_of(&path)).unwrap();
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn armed_artifact_tap_quarantines() {
        use crate::faults::{arm_guard, FaultPlan};
        let _serial = crate::faults::test_serial_lock();
        let path = scratch("armed");
        std::fs::write(&path, HLO).unwrap();
        {
            let _g = arm_guard(FaultPlan::new(0xBEEF).rate(1000).only("runtime."));
            assert!(check_artifact(&path).is_err(), "armed corruption must fail the check");
        }
        std::fs::remove_file(quarantine_of(&path)).unwrap();
    }
}
