//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them.
//!
//! The AOT bridge (see `python/compile/aot.py` and DESIGN.md): jax lowers
//! the L2 graphs to HLO **text**; this module parses the text with
//! `HloModuleProto::from_text_file`, compiles each module once on the
//! PJRT CPU client, and exposes typed entry points. Python never runs on
//! this path — the binary is self-contained once `artifacts/` exists.
//!
//! Geometry constants must match `python/compile/model.py`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::designspace::extrema::DiagExtrema;
use crate::dse::Implementation;

/// Batch size of the verify graphs.
pub const CHUNK: usize = 65536;
/// Coefficient-table padding of the verify graphs (supports `R <= 11`).
pub const TABLE: usize = 2048;
/// Region sizes with a compiled extrema graph.
pub const EXTREMA_NS: [usize; 2] = [256, 1024];

/// Which lowering of the verify graph to execute.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Flavor {
    /// Pure-jnp lowering: fused XLA-CPU loops — the fast path.
    Jnp,
    /// Interpret-mode Pallas lowering: structurally the TPU kernel;
    /// bit-identical, much slower on CPU. Used for cross-checks.
    Pallas,
}

struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedExe {
    fn load(client: &xla::PjRtClient, path: &Path) -> Result<LoadedExe> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedExe { exe })
    }

    fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        Ok(result.to_tuple()?)
    }
}

/// The compiled-artifact runtime. Construction compiles every artifact
/// found under the directory; individual graphs are optional so partial
/// artifact sets (e.g. `--skip-pallas`) still work.
pub struct XlaRuntime {
    verify_jnp: Option<LoadedExe>,
    verify_pallas: Option<LoadedExe>,
    extrema: Vec<(usize, LoadedExe)>,
    pub dir: PathBuf,
}

impl XlaRuntime {
    /// Load from `artifacts/` (or a custom directory).
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let opt = |name: &str| -> Result<Option<LoadedExe>> {
            let p = dir.join(name);
            if p.exists() {
                Ok(Some(LoadedExe::load(&client, &p)?))
            } else {
                Ok(None)
            }
        };
        let verify_jnp = opt("verify_jnp.hlo.txt")?;
        let verify_pallas = opt("verify_pallas.hlo.txt")?;
        let mut extrema = Vec::new();
        for n in EXTREMA_NS {
            if let Some(exe) = opt(&format!("extrema_jnp_N{n}.hlo.txt"))? {
                extrema.push((n, exe));
            }
        }
        if verify_jnp.is_none() && verify_pallas.is_none() && extrema.is_empty() {
            bail!(
                "no artifacts found in {} — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(XlaRuntime { verify_jnp, verify_pallas, extrema, dir })
    }

    pub fn has_flavor(&self, flavor: Flavor) -> bool {
        match flavor {
            Flavor::Jnp => self.verify_jnp.is_some(),
            Flavor::Pallas => self.verify_pallas.is_some(),
        }
    }

    /// Execute the verify graph on one chunk.
    ///
    /// `z`, `l`, `u` must be exactly `CHUNK` long; tables exactly `TABLE`.
    /// `params = [xbits, sq_trunc, lin_trunc, k, out_max]`.
    /// Returns `(outputs, violation count)`.
    pub fn verify_chunk(
        &self,
        flavor: Flavor,
        z: &[i64],
        tables: &CoeffTables,
        l: &[i64],
        u: &[i64],
        params: [i64; 5],
    ) -> Result<(Vec<i64>, i64)> {
        assert_eq!(z.len(), CHUNK);
        assert_eq!(l.len(), CHUNK);
        assert_eq!(u.len(), CHUNK);
        let exe = match flavor {
            Flavor::Jnp => self.verify_jnp.as_ref(),
            Flavor::Pallas => self.verify_pallas.as_ref(),
        }
        .with_context(|| format!("verify artifact for {flavor:?} not loaded"))?;
        let args = vec![
            xla::Literal::vec1(z),
            xla::Literal::vec1(&tables.a),
            xla::Literal::vec1(&tables.b),
            xla::Literal::vec1(&tables.c),
            xla::Literal::vec1(l),
            xla::Literal::vec1(u),
            xla::Literal::vec1(&params),
        ];
        let mut out = exe.run(&args)?;
        anyhow::ensure!(out.len() == 2, "verify graph returned {} outputs", out.len());
        let viol = out.pop().unwrap().to_vec::<i64>()?;
        let outs = out.pop().unwrap().to_vec::<i64>()?;
        Ok((outs, viol.iter().sum()))
    }

    /// Execute the diagonal-extrema graph for a region of exactly a
    /// compiled size. Returns `None` when no variant matches (callers fall
    /// back to the in-process Rust implementation).
    pub fn extrema(&self, l: &[i32], u: &[i32]) -> Option<DiagExtrema> {
        let n = l.len();
        let exe = self.extrema.iter().find(|&&(sz, _)| sz == n).map(|(_, e)| e)?;
        let li: Vec<i64> = l.iter().map(|&v| v as i64).collect();
        let ui: Vec<i64> = u.iter().map(|&v| v as i64).collect();
        let args = [xla::Literal::vec1(&li), xla::Literal::vec1(&ui)];
        let out = exe.run(&args).ok()?;
        if out.len() != 4 {
            return None;
        }
        let bn = out[0].to_vec::<i64>().ok()?;
        let bd = out[1].to_vec::<i64>().ok()?;
        let sn = out[2].to_vec::<i64>().ok()?;
        let sd = out[3].to_vec::<i64>().ok()?;
        let tmax = 2 * n - 3;
        let m_pairs: Vec<(i64, i64)> = bn.into_iter().zip(bd).collect();
        let s_pairs: Vec<(i64, i64)> = sn.into_iter().zip(sd).collect();
        Some(crate::designspace::extrema::diag_extrema_from_fracs(
            &m_pairs, &s_pairs, tmax,
        ))
    }
}

/// Padded coefficient tables for the verify graph.
pub struct CoeffTables {
    pub a: Vec<i64>,
    pub b: Vec<i64>,
    pub c: Vec<i64>,
}

impl CoeffTables {
    pub fn from_impl(im: &Implementation) -> CoeffTables {
        assert!(
            im.coeffs.len() <= TABLE,
            "R={} exceeds the compiled table capacity",
            im.lookup_bits
        );
        let mut a = vec![0i64; TABLE];
        let mut b = vec![0i64; TABLE];
        let mut c = vec![0i64; TABLE];
        for (i, co) in im.coeffs.iter().enumerate() {
            a[i] = co.a;
            b[i] = co.b;
            c[i] = co.c;
        }
        CoeffTables { a, b, c }
    }
}

/// Overflow guard: the XLA datapath runs in i64; reject configurations
/// whose accumulator could exceed it (none of the paper's formats do).
pub fn accumulator_fits_i64(im: &Implementation) -> bool {
    let xmax = (1i128 << im.x_bits()) - 1;
    let amax = im.coeffs.iter().map(|c| (c.a as i128).abs()).max().unwrap_or(0);
    let bmax = im.coeffs.iter().map(|c| (c.b as i128).abs()).max().unwrap_or(0);
    let cmax = im.coeffs.iter().map(|c| (c.c as i128).abs()).max().unwrap_or(0);
    let acc = amax * xmax * xmax + bmax * xmax + cmax;
    acc < (1i128 << 62)
}
