//! Fixed-point format descriptions and bit-slicing helpers.
//!
//! The paper's notation `n.m` denotes an unsigned fixed-point format with
//! `n` integer bits and `m` fractional bits; a value `Z` is stored as the
//! integer `z = Z * 2^m`. The interpolator architecture (paper Fig. 1)
//! splits the stored integer into the top `R` lookup bits `r` and the low
//! `n+m-R` interpolation bits `x`.

use std::fmt;

/// An unsigned fixed-point format `n.m`.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct FixedFormat {
    /// Integer bits (`n`).
    pub int_bits: u32,
    /// Fractional bits (`m`).
    pub frac_bits: u32,
}

impl FixedFormat {
    pub fn new(int_bits: u32, frac_bits: u32) -> FixedFormat {
        let f = FixedFormat { int_bits, frac_bits };
        assert!(f.total_bits() <= 32, "formats beyond 32 bits are not supported");
        f
    }

    /// Purely fractional format `0.m`.
    pub fn frac(m: u32) -> FixedFormat {
        FixedFormat::new(0, m)
    }

    /// Total stored bits `n + m`.
    pub fn total_bits(&self) -> u32 {
        self.int_bits + self.frac_bits
    }

    /// Number of representable codes, `2^(n+m)`.
    pub fn num_codes(&self) -> u64 {
        1u64 << self.total_bits()
    }

    /// Largest stored integer, `2^(n+m) - 1`.
    pub fn max_code(&self) -> u64 {
        self.num_codes() - 1
    }

    /// Real value of a stored code.
    pub fn value_of(&self, code: u64) -> f64 {
        debug_assert!(code <= self.max_code());
        code as f64 / (1u64 << self.frac_bits) as f64
    }

    /// One unit in the last place as a real number.
    pub fn ulp(&self) -> f64 {
        1.0 / (1u64 << self.frac_bits) as f64
    }
}

impl fmt::Display for FixedFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}", self.int_bits, self.frac_bits)
    }
}

/// Split a stored input code into `(r, x)` for `R` lookup bits:
/// `r` = top `R` bits, `x` = low `total_bits - R` bits.
pub fn split_rx(code: u64, total_bits: u32, lookup_bits: u32) -> (u64, u64) {
    debug_assert!(lookup_bits <= total_bits);
    let xbits = total_bits - lookup_bits;
    (code >> xbits, code & ((1u64 << xbits) - 1))
}

/// Rejoin `(r, x)` into a stored code (the paper's `{r, x}` concatenation).
pub fn join_rx(r: u64, x: u64, total_bits: u32, lookup_bits: u32) -> u64 {
    let xbits = total_bits - lookup_bits;
    debug_assert!(r < (1u64 << lookup_bits) && x < (1u64 << xbits));
    (r << xbits) | x
}

/// Truncate the low `t` bits of `x` (keep the bit-slice `x[hi:t]` at its
/// original weight): `(x >> t) << t`.
pub fn trunc_low(x: u64, t: u32) -> u64 {
    (x >> t) << t
}

/// Number of bits needed to represent non-negative `v`: `ceil(log2(v+1))`.
pub fn bit_width(v: u64) -> u32 {
    64 - v.leading_zeros()
}

/// Bit width of a signed coefficient set: magnitude bits of the largest
/// absolute value, plus one sign bit if any value is negative.
pub fn signed_width(min: i64, max: i64) -> u32 {
    let mag = bit_width(min.unsigned_abs().max(max.unsigned_abs()));
    if min < 0 {
        mag + 1
    } else {
        mag
    }
}

/// Trailing zeros of `v`, with the convention that 0 has "infinite"
/// trailing zeros capped at 63 (Algorithm 1 treats 0 as maximally
/// truncatable).
pub fn trailing_zeros_capped(v: i64) -> u32 {
    if v == 0 {
        63
    } else {
        v.trailing_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_basics() {
        let f = FixedFormat::new(1, 15);
        assert_eq!(f.total_bits(), 16);
        assert_eq!(f.num_codes(), 1 << 16);
        assert_eq!(f.max_code(), (1 << 16) - 1);
        assert!((f.value_of(1 << 15) - 1.0).abs() < 1e-12);
        assert_eq!(format!("{f}"), "1.15");
        assert!((FixedFormat::frac(8).ulp() - 1.0 / 256.0).abs() < 1e-15);
    }

    #[test]
    fn split_join_roundtrip() {
        for code in [0u64, 1, 0xabcd, 0xffff] {
            let (r, x) = split_rx(code, 16, 6);
            assert_eq!(join_rx(r, x, 16, 6), code);
            assert!(r < 64);
            assert!(x < (1 << 10));
        }
        assert_eq!(split_rx(0xffff, 16, 0), (0, 0xffff));
        assert_eq!(split_rx(0xffff, 16, 16), (0xffff, 0));
    }

    #[test]
    fn trunc_and_widths() {
        assert_eq!(trunc_low(0b101101, 2), 0b101100);
        assert_eq!(trunc_low(0b101101, 0), 0b101101);
        assert_eq!(bit_width(0), 0);
        assert_eq!(bit_width(1), 1);
        assert_eq!(bit_width(255), 8);
        assert_eq!(bit_width(256), 9);
        assert_eq!(signed_width(0, 255), 8);
        assert_eq!(signed_width(-1, 255), 9);
        assert_eq!(signed_width(-256, 0), 10);
        assert_eq!(trailing_zeros_capped(0), 63);
        assert_eq!(trailing_zeros_capped(8), 3);
        assert_eq!(trailing_zeros_capped(-8), 3);
    }
}
