//! Design-space exploration — the paper's §III decision procedure,
//! decomposed into technology-pluggable pieces.
//!
//! The paper's ASIC procedure, as a [`procedure::Lexicographic`] pass
//! sequence ([`procedure::Pass`]):
//!
//! 1. **Minimize `k`** — done during generation
//!    ([`crate::designspace::generate`] returns the smallest `k` feasible
//!    across all regions).
//! 2. **Maximize square-input truncation `i`** — the square path
//!    evaluates `a * (x[m-1:i])^2`; only candidates that tolerate the
//!    induced error survive.
//! 3. **Maximize linear-input truncation `j`** — `b * x[m-1:j]`.
//! 4. **Minimize coefficient bitwidths** `a`, then `b`, then `c`, with
//!    Algorithm 1 ([`precision::algorithm1`]), pruning the dictionary
//!    after each step; finally the first surviving `(a, b, c)` triple is
//!    selected per region.
//!
//! Alternative orderings (the paper: "prioritizing LUT optimization ...
//! yielded inferior area-delay profiles") are just different pass
//! sequences, and [`procedure::ParetoCost`] drops the fixed ordering
//! entirely, ranking the truncation/width frontier by a technology's
//! [`CostModel`](crate::tech::CostModel) — the paper's "modified decision
//! procedure" for alternative hardware technologies. [`explore`] runs
//! the procedure selected by [`DseOptions`]; [`explore_with`] accepts
//! any user [`procedure::DecisionProcedure`].

pub mod precision;
pub mod procedure;

use crate::bounds::BoundTable;
use crate::designspace::region::{polynomial_valid, CEnvelope};
use crate::designspace::DesignSpace;
use crate::pool::CancelToken;
use crate::tech::{CostModel, TechKind};
use precision::{algorithm1, Encoding, IntervalSet};
use procedure::DecisionProcedure;

/// Interpolator degree (paper §II: linear suffices iff `0 in [a0, a1]` in
/// every region — "resulting in smaller and faster hardware").
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Degree {
    Linear,
    Quadratic,
}

/// Named decision-procedure variant (the serializable selector behind
/// `dse.procedure`; custom procedures go through [`explore_with`]).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Procedure {
    /// The paper's procedure: truncations first, then widths.
    SquareFirst,
    /// Ablation: widths first, then truncations.
    LutFirst,
    /// Cost-guided Pareto ranking by the technology's cost model.
    Pareto,
}

impl Procedure {
    /// Instantiate the named procedure.
    pub fn instantiate(self) -> Box<dyn DecisionProcedure> {
        match self {
            Procedure::SquareFirst => Box::new(procedure::Lexicographic::square_first()),
            Procedure::LutFirst => Box::new(procedure::Lexicographic::lut_first()),
            Procedure::Pareto => Box::new(procedure::ParetoCost::default()),
        }
    }
}

/// Exploration options.
#[derive(Clone, Copy, Debug)]
pub struct DseOptions {
    /// Forced procedure; `None` = the technology's default ordering
    /// ([`crate::tech::Technology::default_procedure`]).
    pub procedure: Option<Procedure>,
    /// Technology target: supplies the cost model (for cost-guided
    /// procedures and downstream synthesis) and the default procedure.
    pub tech: TechKind,
    /// Force a linear implementation when feasible (`a = 0` everywhere);
    /// `None` = automatic (linear if feasible).
    pub degree: Option<Degree>,
    /// Cap on enumerated `b` values per `(region, a)` during filtering; the
    /// full range is scanned when it is narrower, otherwise a strided
    /// subset (the result is then still a *valid* design, merely possibly
    /// missing the global width optimum — recorded as `sampled`).
    pub max_b_per_a: usize,
}

impl Default for DseOptions {
    fn default() -> Self {
        DseOptions { procedure: None, tech: TechKind::AsicGe, degree: None, max_b_per_a: 512 }
    }
}

/// One region's surviving candidates after truncation filtering.
#[derive(Clone, Debug, Default)]
struct RegionCands {
    /// `(a, surviving b values)`, `a` ascending by absolute value.
    cands: Vec<(i64, Vec<i64>)>,
}

impl RegionCands {
    fn is_empty(&self) -> bool {
        self.cands.iter().all(|(_, bs)| bs.is_empty())
    }
}

/// Selected coefficients for one region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Coeffs {
    pub a: i64,
    pub b: i64,
    pub c: i64,
}

/// A fully decided implementation: everything the RTL emitter, cost model
/// and runtime evaluator need.
#[derive(Clone, Debug)]
pub struct Implementation {
    pub func: String,
    pub accuracy: String,
    pub in_bits: u32,
    pub out_bits: u32,
    pub lookup_bits: u32,
    pub k: u32,
    pub degree: Degree,
    /// Square-input truncation `i`.
    pub sq_trunc: u32,
    /// Linear-input truncation `j`.
    pub lin_trunc: u32,
    pub enc_a: Encoding,
    pub enc_b: Encoding,
    pub enc_c: Encoding,
    /// Per-region selected polynomials, index = `r`.
    pub coeffs: Vec<Coeffs>,
    /// True when `b` enumeration was subsampled (widths may be
    /// conservative).
    pub sampled: bool,
}

impl Implementation {
    /// Interpolation bits per region (`x` width before truncation).
    pub fn x_bits(&self) -> u32 {
        self.in_bits - self.lookup_bits
    }

    /// Stored LUT width per region (the paper's Table II metric).
    pub fn lut_width(&self) -> u32 {
        let a = if self.degree == Degree::Linear { 0 } else { self.enc_a.width };
        a + self.enc_b.width + self.enc_c.width
    }

    /// Widths as the paper prints them: `[a, b, c] = total`.
    pub fn lut_width_label(&self) -> String {
        let a = if self.degree == Degree::Linear { 0 } else { self.enc_a.width };
        format!(
            "[{},{},{}] = {}",
            a,
            self.enc_b.width,
            self.enc_c.width,
            a + self.enc_b.width + self.enc_c.width
        )
    }

    /// True when two implementations make the same selection from a
    /// space: degree, truncations, encodings and per-region coefficients
    /// all equal. The single definition of "same design" used by the
    /// per-technology divergence report/tests — a new
    /// selection-determining field must be added here once, not at every
    /// comparison site.
    pub fn same_selection(&self, other: &Implementation) -> bool {
        self.degree == other.degree
            && self.sq_trunc == other.sq_trunc
            && self.lin_trunc == other.lin_trunc
            && self.enc_a == other.enc_a
            && self.enc_b == other.enc_b
            && self.enc_c == other.enc_c
            && self.coeffs == other.coeffs
    }

    /// Bit-accurate datapath semantics — the single definition that the
    /// RTL emitter, the behavioural simulator, the XLA kernel and the
    /// verifier must all agree with:
    /// `out = clamp(floor((a*T_i(x) + b*S_j(x) + c) / 2^k), 0, 2^q - 1)`.
    /// (The output saturation stage is standard practice and free for
    /// design-space implementations, whose bounds already confine them.)
    pub fn eval(&self, z: u64) -> i64 {
        let xbits = self.x_bits();
        let r = (z >> xbits) as usize;
        let x = z & ((1u64 << xbits) - 1);
        let co = self.coeffs[r];
        let xt = ((x >> self.sq_trunc) << self.sq_trunc) as i128;
        let xl = ((x >> self.lin_trunc) << self.lin_trunc) as i128;
        let acc = co.a as i128 * xt * xt + co.b as i128 * xl + co.c as i128;
        // Arithmetic shift = floor division by 2^k, also for negatives.
        let y = (acc >> self.k) as i64;
        y.clamp(0, (1i64 << self.out_bits) - 1)
    }
}

/// Explore the design space with the procedure and technology selected
/// by `opts` and return the selected implementation.
///
/// `bt` must be the bound table the space was generated from. The
/// default options reproduce the paper's ASIC procedure exactly
/// (`AsicGe` technology, whose default ordering is SquareFirst).
pub fn explore(bt: &BoundTable, ds: &DesignSpace, opts: &DseOptions) -> Option<Implementation> {
    explore_ctrl(bt, ds, opts, None)
}

/// [`explore`] with a cooperative [`CancelToken`] threaded into the
/// shipped procedures (checked between regions of every dictionary
/// scan, so a cancel lands within one region's worth of work even
/// minutes into a 20-bit exploration). A cancelled exploration returns
/// `None`; the caller distinguishes that from an exhausted space by
/// polling the token it passed in.
pub fn explore_ctrl(
    bt: &BoundTable,
    ds: &DesignSpace,
    opts: &DseOptions,
    cancel: Option<&CancelToken>,
) -> Option<Implementation> {
    let tech = opts.tech.technology();
    let proc_: Box<dyn DecisionProcedure> = match opts.procedure {
        Some(p) => p.instantiate(),
        None => tech.default_procedure(),
    };
    explore_with_ctrl(bt, ds, proc_.as_ref(), tech.cost_model(), opts, cancel)
}

/// [`explore`] with an explicit procedure and cost model — the plugin
/// entry point for technologies and procedures not named by
/// [`TechKind`]/[`Procedure`].
pub fn explore_with(
    bt: &BoundTable,
    ds: &DesignSpace,
    proc_: &dyn DecisionProcedure,
    cm: &dyn CostModel,
    opts: &DseOptions,
) -> Option<Implementation> {
    proc_.decide(bt, ds, cm, opts)
}

/// [`explore_with`] plus a cancel token, dispatched through
/// [`DecisionProcedure::decide_ctrl`] (procedures that don't override it
/// run to completion as before).
pub fn explore_with_ctrl(
    bt: &BoundTable,
    ds: &DesignSpace,
    proc_: &dyn DecisionProcedure,
    cm: &dyn CostModel,
    opts: &DseOptions,
    cancel: Option<&CancelToken>,
) -> Option<Implementation> {
    proc_.decide_ctrl(bt, ds, cm, opts, cancel)
}

/// Resolve the degree under `opts`: forced if requested (and feasible),
/// otherwise linear iff the space admits it.
fn resolve_degree(ds: &DesignSpace, opts: &DseOptions) -> Option<Degree> {
    let degree = match opts.degree {
        Some(d) => d,
        None => {
            if ds.linear_feasible() {
                Degree::Linear
            } else {
                Degree::Quadratic
            }
        }
    };
    if degree == Degree::Linear && !ds.linear_feasible() {
        return None;
    }
    Some(degree)
}

/// Binary-search the largest truncation parameter `p` in `[0, x_bits]`
/// such that every region retains a candidate under `(i, j) = map(p)`.
/// (Feasibility is monotone in the truncation error in all observed
/// workloads; the returned value is re-validated.)
fn max_feasible_trunc(
    bt: &BoundTable,
    ds: &DesignSpace,
    degree: Degree,
    opts: &DseOptions,
    cancel: Option<&CancelToken>,
    map: impl Fn(u32) -> (u32, u32),
) -> u32 {
    let xbits = ds.x_bits();
    let feasible = |p: u32| {
        let (i, j) = map(p);
        all_regions_survive(bt, ds, degree, i, j, opts.max_b_per_a, cancel)
    };
    // (A cancelled scan reports infeasible, which would trip the
    // untruncated-dictionary invariant — the short-circuit exempts it.)
    debug_assert!(
        cancel.is_some_and(|c| c.is_cancelled()) || feasible(0),
        "untruncated dictionary must be feasible"
    );
    let (mut lo, mut hi) = (0u32, xbits);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if feasible(mid) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

fn all_regions_survive(
    bt: &BoundTable,
    ds: &DesignSpace,
    degree: Degree,
    i: u32,
    j: u32,
    cap: usize,
    cancel: Option<&CancelToken>,
) -> bool {
    // Lazy iteration: each region's entries are swept (and memoized) as
    // the procedure reaches it, so an early infeasible region stops the
    // scan before the rest of the space is ever materialized. A fired
    // cancel token reports "infeasible" to end the enclosing search —
    // the procedure's own checkpoint then discards the bogus answer.
    ds.region_views().all(|rv| {
        if cancel.is_some_and(|c| c.is_cancelled()) {
            return false;
        }
        let sp = rv.space();
        let (l, u) = bt.region(ds.lookup_bits, sp.r);
        !filter_region(l, u, ds.k, sp, degree, i, j, cap, true).is_empty()
    })
}

fn filter_all(
    bt: &BoundTable,
    ds: &DesignSpace,
    degree: Degree,
    i: u32,
    j: u32,
    cap: usize,
    cancel: Option<&CancelToken>,
) -> Vec<RegionCands> {
    ds.region_views()
        .map(|rv| {
            // An empty candidate set makes the downstream `finish` bail
            // with `None` — the cheapest way for a cancel to propagate.
            if cancel.is_some_and(|c| c.is_cancelled()) {
                return RegionCands::default();
            }
            let sp = rv.space();
            let (l, u) = bt.region(ds.lookup_bits, sp.r);
            filter_region(l, u, ds.k, sp, degree, i, j, cap, false)
        })
        .collect()
}

/// The paper's "discard those that cannot [tolerate the truncation error]":
/// keep the `(a, b)` whose Eqn 1 `c`-interval is non-empty under `(i, j)`.
#[allow(clippy::too_many_arguments)]
fn filter_region(
    l: &[i32],
    u: &[i32],
    k: u32,
    sp: &crate::designspace::region::RegionSpace,
    degree: Degree,
    i: u32,
    j: u32,
    cap: usize,
    early_out: bool,
) -> RegionCands {
    let mut out = RegionCands::default();
    // Ascending |a| keeps the cheapest quadratic term first (selection
    // order matters: the paper "picks the first polynomial").
    let mut entries: Vec<_> = sp.entries.iter().collect();
    entries.sort_by_key(|e| (e.a.abs(), e.a));
    for e in entries {
        if degree == Degree::Linear && e.a != 0 {
            continue;
        }
        let width = (e.b_hi - e.b_lo + 1) as usize;
        let bs: Vec<i64> = if width <= cap {
            (e.b_lo..=e.b_hi).collect()
        } else {
            // Strided subsample, keeping both endpoints.
            let stride = width.div_ceil(cap);
            let mut v: Vec<i64> = (e.b_lo..=e.b_hi).step_by(stride).collect();
            if *v.last().unwrap() != e.b_hi {
                v.push(e.b_hi);
            }
            v
        };
        // §Perf: one envelope build per (a, i, j) answers every b in O(1)
        // amortized — the b values are ascending, so a cursor suffices.
        let env = CEnvelope::build(l, u, k, e.a, i, j);
        let mut cur = env.cursor();
        let surviving: Vec<i64> =
            bs.into_iter().filter(|&b| cur.interval_at(b).is_some()).collect();
        if !surviving.is_empty() {
            out.cands.push((e.a, surviving));
            if early_out {
                return out;
            }
        }
    }
    out
}

/// Algorithm 1 per coefficient (a, then b, then c) with pruning, then
/// select the first jointly-valid triple per region.
fn finish(
    bt: &BoundTable,
    ds: &DesignSpace,
    degree: Degree,
    i: u32,
    j: u32,
    mut cands: Vec<RegionCands>,
    opts: &DseOptions,
    cancel: Option<&CancelToken>,
) -> Option<Implementation> {
    let cancelled = || cancel.is_some_and(|c| c.is_cancelled());
    if cancelled() {
        return None;
    }
    let sampled = sampled_any(ds, opts);

    // --- a ---
    let a_sets: Vec<IntervalSet> = cands
        .iter()
        .map(|rc| rc.cands.iter().map(|&(a, _)| (a, a)).collect())
        .collect();
    let enc_a = algorithm1(&a_sets)?;
    for rc in &mut cands {
        rc.cands.retain(|&(a, _)| enc_a.admits(a));
        if rc.is_empty() {
            return None;
        }
    }

    // --- b ---
    let b_sets: Vec<IntervalSet> = cands
        .iter()
        .map(|rc| {
            rc.cands
                .iter()
                .flat_map(|(_, bs)| bs.iter().map(|&b| (b, b)))
                .collect()
        })
        .collect();
    let enc_b = algorithm1(&b_sets)?;
    for rc in &mut cands {
        for (_, bs) in &mut rc.cands {
            bs.retain(|&b| enc_b.admits(b));
        }
        rc.cands.retain(|(_, bs)| !bs.is_empty());
        if rc.is_empty() {
            return None;
        }
    }

    // --- c --- (interval-backed: one interval per surviving (a, b))
    let mut c_sets: Vec<IntervalSet> = Vec::with_capacity(cands.len());
    for (rc, rv) in cands.iter().zip(ds.region_views()) {
        if cancelled() {
            return None;
        }
        let (l, u) = bt.region(ds.lookup_bits, rv.r());
        let mut set: IntervalSet = Vec::new();
        for (a, bs) in &rc.cands {
            let env = CEnvelope::build(l, u, ds.k, *a, i, j);
            let mut cur = env.cursor();
            for &b in bs {
                if let Some(iv) = cur.interval_at(b) {
                    set.push(iv);
                }
            }
        }
        if set.is_empty() {
            return None;
        }
        c_sets.push(set);
    }
    let enc_c = algorithm1(&c_sets)?;

    // --- selection: first jointly-valid triple per region ---
    let mut coeffs = Vec::with_capacity(cands.len());
    for (rc, rv) in cands.iter().zip(ds.region_views()) {
        if cancelled() {
            return None;
        }
        let (l, u) = bt.region(ds.lookup_bits, rv.r());
        let mut chosen: Option<Coeffs> = None;
        'outer: for (a, bs) in &rc.cands {
            let env = CEnvelope::build(l, u, ds.k, *a, i, j);
            let mut cur = env.cursor();
            for &b in bs {
                let Some((c0, c1)) = cur.interval_at(b) else { continue };
                if let Some(c) = first_admissible_in(&enc_c, c0, c1) {
                    debug_assert!(polynomial_valid(l, u, ds.k, *a, b, c, i, j));
                    chosen = Some(Coeffs { a: *a, b, c });
                    break 'outer;
                }
            }
        }
        coeffs.push(chosen?);
    }

    Some(Implementation {
        func: ds.func.clone(),
        accuracy: ds.accuracy.clone(),
        in_bits: ds.in_bits,
        out_bits: ds.out_bits,
        lookup_bits: ds.lookup_bits,
        k: ds.k,
        degree,
        sq_trunc: i,
        lin_trunc: j,
        enc_a,
        enc_b,
        enc_c,
        coeffs,
        sampled,
    })
}

fn sampled_any(ds: &DesignSpace, opts: &DseOptions) -> bool {
    ds.region_views().any(|rv| {
        rv.entries()
            .iter()
            .any(|e| (e.b_hi - e.b_lo + 1) as usize > opts.max_b_per_a)
    })
}

/// Smallest-magnitude value in `[c0, c1]` admissible under `enc`
/// (scanning multiples of `2^trunc` from the near edge).
fn first_admissible_in(enc: &Encoding, c0: i64, c1: i64) -> Option<i64> {
    let step = 1i64 << enc.trunc;
    // First multiple of step >= c0.
    let mut v = c0.div_euclid(step) * step;
    if v < c0 {
        v += step;
    }
    while v <= c1 {
        if enc.admits(v) {
            return Some(v);
        }
        v += step;
    }
    None
}

/// Re-run selection at a different truncation pair, constrained to
/// already-fixed encodings (used by the width-first orderings).
fn reselect_at_trunc(
    bt: &BoundTable,
    ds: &DesignSpace,
    pre: &Implementation,
    i: u32,
    j: u32,
    admits: &impl Fn(&Coeffs) -> bool,
    cancel: Option<&CancelToken>,
) -> Option<Implementation> {
    let mut coeffs = Vec::with_capacity(ds.num_regions());
    for rv in ds.region_views() {
        if cancel.is_some_and(|c| c.is_cancelled()) {
            return None;
        }
        let sp = rv.space();
        let (l, u) = bt.region(ds.lookup_bits, sp.r);
        let mut chosen = None;
        'outer: for e in &sp.entries {
            if pre.degree == Degree::Linear && e.a != 0 {
                continue;
            }
            if !pre.enc_a.admits(e.a) {
                continue;
            }
            let env = CEnvelope::build(l, u, ds.k, e.a, i, j);
            let mut cur = env.cursor();
            for b in e.b_lo..=e.b_hi {
                if !pre.enc_b.admits(b) {
                    continue;
                }
                let Some((c0, c1)) = cur.interval_at(b) else { continue };
                if let Some(c) = first_admissible_in(&pre.enc_c, c0, c1) {
                    let co = Coeffs { a: e.a, b, c };
                    if admits(&co) {
                        chosen = Some(co);
                        break 'outer;
                    }
                }
            }
        }
        coeffs.push(chosen?);
    }
    Some(Implementation { sq_trunc: i, lin_trunc: j, coeffs, ..pre.clone() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};

    fn setup(name: &str, bits: u32, r: u32) -> (BoundTable, DesignSpace) {
        let f = builtin(name, bits).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
            .unwrap_or_else(|e| panic!("{name}/{bits} R={r}: {e}"));
        (bt, ds)
    }

    /// The end-to-end invariant: the selected implementation meets the
    /// bounds on EVERY input.
    fn assert_impl_valid(bt: &BoundTable, im: &Implementation) {
        for z in 0..(1u64 << bt.in_bits) {
            let out = im.eval(z);
            assert!(
                out >= bt.l[z as usize] as i64 && out <= bt.u[z as usize] as i64,
                "{} z={z}: out={out} not in [{}, {}]",
                im.func,
                bt.l[z as usize],
                bt.u[z as usize]
            );
        }
    }

    #[test]
    fn recip8_explore_and_verify_exhaustively() {
        let (bt, ds) = setup("recip", 8, 4);
        let im = explore(&bt, &ds, &DseOptions::default()).expect("DSE failed");
        assert_impl_valid(&bt, &im);
        assert_eq!(im.coeffs.len(), 16);
        // Encodings admit every selected coefficient.
        for co in &im.coeffs {
            assert!(im.enc_a.admits(co.a));
            assert!(im.enc_b.admits(co.b));
            assert!(im.enc_c.admits(co.c));
        }
    }

    #[test]
    fn all_functions_10bit_explore_and_verify() {
        for name in ["recip", "log2", "exp2", "sqrt"] {
            for r in [5u32, 6] {
                let f = builtin(name, 10).unwrap();
                let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
                let Ok(ds) =
                    generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
                else {
                    continue;
                };
                let im = explore(&bt, &ds, &DseOptions::default())
                    .unwrap_or_else(|| panic!("{name} R={r}: DSE failed"));
                assert_impl_valid(&bt, &im);
            }
        }
    }

    #[test]
    fn linear_chosen_when_feasible() {
        // With enough regions, recip 8-bit becomes linear-feasible.
        let f = builtin("recip", 8).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        for r in 4..=7u32 {
            let Ok(ds) = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
            else {
                continue;
            };
            if ds.linear_feasible() {
                let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
                assert_eq!(im.degree, Degree::Linear);
                assert!(im.coeffs.iter().all(|c| c.a == 0));
                assert_impl_valid(&bt, &im);
                return;
            }
        }
        panic!("recip 8-bit never became linear-feasible up to R=7");
    }

    #[test]
    fn forced_quadratic_also_valid() {
        let (bt, ds) = setup("recip", 8, 6);
        let im = explore(
            &bt,
            &ds,
            &DseOptions { degree: Some(Degree::Quadratic), ..Default::default() },
        )
        .expect("forced quadratic failed");
        assert_eq!(im.degree, Degree::Quadratic);
        assert_impl_valid(&bt, &im);
    }

    #[test]
    fn truncations_are_maximal() {
        let (bt, ds) = setup("log2", 10, 5);
        let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
        assert_impl_valid(&bt, &im);
        if im.degree == Degree::Quadratic && im.sq_trunc < im.x_bits() {
            // One more bit of square truncation must be infeasible.
            assert!(
                !all_regions_survive(&bt, &ds, im.degree, im.sq_trunc + 1, 0, 512, None),
                "sq_trunc {} not maximal",
                im.sq_trunc
            );
        }
    }

    #[test]
    fn lut_first_is_no_better_than_square_first() {
        // The paper found LUT-first inferior; at minimum both must verify.
        let (bt, ds) = setup("recip", 10, 5);
        let a = explore(&bt, &ds, &DseOptions::default()).unwrap();
        let b = explore(
            &bt,
            &ds,
            &DseOptions { procedure: Some(Procedure::LutFirst), ..Default::default() },
        )
        .unwrap();
        assert_impl_valid(&bt, &a);
        assert_impl_valid(&bt, &b);
        // SquareFirst should truncate at least as aggressively.
        assert!(a.sq_trunc >= b.sq_trunc || a.degree == Degree::Linear);
    }

    #[test]
    fn explicit_square_first_equals_default() {
        // Default options = AsicGe technology whose default ordering is
        // SquareFirst; forcing it must be a no-op.
        let (bt, ds) = setup("exp2", 8, 4);
        let a = explore(&bt, &ds, &DseOptions::default()).unwrap();
        let b = explore(
            &bt,
            &ds,
            &DseOptions { procedure: Some(Procedure::SquareFirst), ..Default::default() },
        )
        .unwrap();
        assert_eq!(a.coeffs, b.coeffs);
        assert_eq!((a.sq_trunc, a.lin_trunc), (b.sq_trunc, b.lin_trunc));
        assert_eq!((a.enc_a, a.enc_b, a.enc_c), (b.enc_a, b.enc_b, b.enc_c));
    }

    #[test]
    fn pareto_procedure_explores_and_verifies() {
        for tech in TechKind::ALL {
            let (bt, ds) = setup("recip", 8, 3); // naturally quadratic
            let im = explore(
                &bt,
                &ds,
                &DseOptions { procedure: Some(Procedure::Pareto), tech, ..Default::default() },
            )
            .unwrap_or_else(|| panic!("{}: pareto found nothing", tech.label()));
            assert_impl_valid(&bt, &im);
        }
    }

    #[test]
    fn fpga_technology_selects_differently_somewhere() {
        // The headline acceptance: from the SAME complete space, the
        // FPGA technology's default procedure picks a different
        // implementation than the ASIC default on at least one bundled
        // example. (On recip 8-bit R=3 the FPGA model trades one bit of
        // square truncation for a two-bit-narrower b coefficient —
        // narrow soft multipliers beat shallow tables.)
        let mut diverged = false;
        for (name, bits, r) in [("recip", 8u32, 3u32), ("recip", 10, 4), ("log2", 10, 4)] {
            let (bt, ds) = setup(name, bits, r);
            let asic = explore(&bt, &ds, &DseOptions::default()).unwrap();
            let fpga = explore(
                &bt,
                &ds,
                &DseOptions { tech: TechKind::FpgaLut6, ..Default::default() },
            )
            .unwrap();
            assert_impl_valid(&bt, &fpga);
            if !asic.same_selection(&fpga) {
                diverged = true;
            }
        }
        assert!(diverged, "FPGA technology never diverged from the ASIC selection");
    }

    #[test]
    fn eval_matches_manual_formula() {
        let (bt, ds) = setup("exp2", 8, 4);
        let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
        for z in [0u64, 1, 37, 128, 255] {
            let xbits = im.x_bits();
            let r = (z >> xbits) as usize;
            let x = z & ((1 << xbits) - 1);
            let co = im.coeffs[r];
            let xt = ((x >> im.sq_trunc) << im.sq_trunc) as i128;
            let xl = ((x >> im.lin_trunc) << im.lin_trunc) as i128;
            let want = ((co.a as i128 * xt * xt + co.b as i128 * xl + co.c as i128)
                >> im.k) as i64;
            assert_eq!(im.eval(z), want);
        }
        let _ = bt;
    }
}
