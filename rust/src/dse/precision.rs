//! Algorithm 1 of the paper: coefficient precision minimization.
//!
//! Given, per region, the set of valid integer values for one coefficient,
//! find the storage encoding `(trailing-zero truncation t, stored width P)`
//! that minimizes `P` while every region retains at least one representable
//! value. The paper runs the algorithm separately on the positive and the
//! negative values (as magnitudes) and takes the cheaper of the two; when
//! regions disagree on sign a signed encoding (one extra bit) is used.
//!
//! Value sets are represented as unions of inclusive intervals — the `c`
//! coefficient's valid set per `(a, b)` is a contiguous interval that can
//! span thousands of values, so interval arithmetic (rather than value
//! enumeration) keeps Algorithm 1 exact *and* cheap: the largest available
//! trailing-zero count in `[lo, hi]` and the minimum `bits(s) - t` over the
//! multiples of `2^t` in `[lo, hi]` are both O(1) computations.

use crate::fixedpoint::bit_width;

/// Union of inclusive integer intervals (a coefficient's valid values in
/// one region). Not necessarily sorted or disjoint.
pub type IntervalSet = Vec<(i64, i64)>;

/// Sign discipline of a coefficient encoding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sign {
    /// All stored values are `>= 0` (stored as magnitudes).
    NonNeg,
    /// All stored values are `<= 0` (stored as magnitudes; the datapath
    /// subtracts).
    NonPos,
    /// Mixed signs: one stored bit is the sign.
    Signed,
}

/// A coefficient storage encoding chosen by Algorithm 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Encoding {
    /// Low bits dropped from storage (values are multiples of `2^trunc`).
    pub trunc: u32,
    /// Stored bits, including the sign bit when `sign == Signed`.
    pub width: u32,
    pub sign: Sign,
}

impl Encoding {
    /// Magnitude bits available for the value (`width` minus sign bit).
    /// A signed zero-width encoding has no magnitude bits at all.
    pub fn mag_bits(&self) -> u32 {
        self.width.saturating_sub((self.sign == Sign::Signed) as u32)
    }

    /// Can `v` be stored under this encoding?
    pub fn admits(&self, v: i64) -> bool {
        if v == 0 {
            return true;
        }
        if self.sign == Sign::Signed && self.width == 0 {
            return false; // no magnitude bits at all
        }
        match self.sign {
            Sign::NonNeg if v < 0 => return false,
            Sign::NonPos if v > 0 => return false,
            _ => {}
        }
        let mag = v.unsigned_abs();
        mag.trailing_zeros() >= self.trunc && bit_width(mag >> self.trunc) <= self.mag_bits()
    }
}

/// Largest `t` such that some multiple of `2^t` lies in `[lo, hi]`
/// (`lo <= hi`, both `>= 0`). A set containing 0 returns 63.
fn max_tz_in_interval(lo: i64, hi: i64) -> u32 {
    debug_assert!(0 <= lo && lo <= hi);
    if lo == 0 {
        return 63;
    }
    let mut t = 62u32;
    loop {
        let step = 1i64 << t;
        // Smallest multiple of 2^t that is >= lo.
        let m = lo.div_euclid(step) * step + if lo % step == 0 { 0 } else { step };
        if m <= hi {
            return t;
        }
        t -= 1; // t = 0 always succeeds (every integer is a multiple of 1)
    }
}

/// Minimum `bits(s) - t` over multiples `s` of `2^t` in `[lo, hi]`
/// (`0 <= lo <= hi`), or `None` if there is no such multiple.
/// `bits` is monotone, so the smallest multiple realizes the minimum.
fn min_width_at_t(lo: i64, hi: i64, t: u32) -> Option<u32> {
    debug_assert!(0 <= lo && lo <= hi);
    let step = 1i64 << t;
    let m = lo.div_euclid(step) * step + if lo % step == 0 { 0 } else { step };
    if m > hi {
        return None;
    }
    Some(bit_width((m as u64) >> t))
}

/// Core of Algorithm 1 over non-negative interval sets: returns
/// `(t, P)` minimizing stored width `P`, or `None` if some region's set is
/// empty. Ties on `P` prefer larger `t` (cheaper downstream arithmetic).
fn algorithm1_unsigned(regions: &[IntervalSet]) -> Option<(u32, u32)> {
    if regions.iter().any(|s| s.is_empty()) {
        return None;
    }
    // T = min over regions of (max over the region's values of tz).
    let mut t_cap = 63u32;
    for set in regions {
        let tr = set.iter().map(|&(lo, hi)| max_tz_in_interval(lo, hi)).max().unwrap();
        t_cap = t_cap.min(tr);
    }
    let mut best: Option<(u32, u32)> = None; // (t, P)
    for t in 0..=t_cap {
        // P_t = max over regions of (min width over the region's values).
        let mut p_t = 0u32;
        let mut ok = true;
        for set in regions {
            let pr = set
                .iter()
                .filter_map(|&(lo, hi)| min_width_at_t(lo, hi, t))
                .min();
            match pr {
                Some(p) => p_t = p_t.max(p),
                None => {
                    ok = false;
                    break;
                }
            }
        }
        if ok && best.map_or(true, |(_, bp)| p_t <= bp) {
            best = Some((t, p_t));
        }
    }
    best
}

/// Restrict an interval set to its non-negative part.
fn positive_part(set: &IntervalSet) -> IntervalSet {
    set.iter()
        .filter_map(|&(lo, hi)| if hi >= 0 { Some((lo.max(0), hi)) } else { None })
        .collect()
}

/// Restrict to the non-positive part, negated into non-negative magnitudes.
fn negative_part(set: &IntervalSet) -> IntervalSet {
    set.iter()
        .filter_map(|&(lo, hi)| if lo <= 0 { Some(((-hi).max(0), -lo)) } else { None })
        .collect()
}

/// Absolute values of the whole set (for the signed branch): split at zero
/// and merge.
fn abs_part(set: &IntervalSet) -> IntervalSet {
    let mut out = positive_part(set);
    out.extend(negative_part(set));
    out
}

/// Algorithm 1 with the paper's sign handling: run on the positive and
/// negative sets, take the cheaper; fall back to a signed encoding when
/// neither single-sign branch can cover every region.
pub fn algorithm1(regions: &[IntervalSet]) -> Option<Encoding> {
    let pos: Vec<IntervalSet> = regions.iter().map(positive_part).collect();
    let neg: Vec<IntervalSet> = regions.iter().map(negative_part).collect();
    // A signed encoding is needed when the single-sign branches fail; it
    // costs one extra stored bit.
    let abs: Vec<IntervalSet> = regions.iter().map(abs_part).collect();

    let candidates = [
        algorithm1_unsigned(&pos)
            .map(|(t, p)| Encoding { trunc: t, width: p, sign: Sign::NonNeg }),
        algorithm1_unsigned(&neg)
            .map(|(t, p)| Encoding { trunc: t, width: p, sign: Sign::NonPos }),
        algorithm1_unsigned(&abs)
            .map(|(t, p)| Encoding { trunc: t, width: p + 1, sign: Sign::Signed }),
    ];
    candidates
        .into_iter()
        .flatten()
        .min_by_key(|e| (e.width, std::cmp::Reverse(e.trunc)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::for_each_seed;

    /// Brute-force reference: enumerate every (t, P) up to caps and check
    /// representability by scanning actual values.
    fn brute(regions: &[Vec<i64>]) -> Option<Encoding> {
        let mut best: Option<Encoding> = None;
        for sign in [Sign::NonNeg, Sign::NonPos, Sign::Signed] {
            for t in 0..16u32 {
                for w in 0..20u32 {
                    let e = Encoding { trunc: t, width: w, sign };
                    let ok = regions
                        .iter()
                        .all(|set| set.iter().any(|&v| e.admits(v)));
                    if ok
                        && best.map_or(true, |b| {
                            (e.width, std::cmp::Reverse(e.trunc))
                                < (b.width, std::cmp::Reverse(b.trunc))
                        })
                    {
                        best = Some(e);
                    }
                }
            }
        }
        best
    }

    fn to_intervals(sets: &[Vec<i64>]) -> Vec<IntervalSet> {
        sets.iter().map(|s| s.iter().map(|&v| (v, v)).collect()).collect()
    }

    #[test]
    fn matches_bruteforce_on_random_sets() {
        for_each_seed(80, |rng| {
            let nregions = 1 + rng.below(5) as usize;
            let sets: Vec<Vec<i64>> = (0..nregions)
                .map(|_| {
                    let n = 1 + rng.below(6) as usize;
                    (0..n).map(|_| rng.range_i64(-200, 200)).collect()
                })
                .collect();
            let got = algorithm1(&to_intervals(&sets)).expect("non-empty sets");
            let want = brute(&sets).expect("brute must find something");
            assert_eq!(
                (got.width, got.trunc),
                (want.width, want.trunc),
                "sets={sets:?} got={got:?} want={want:?}"
            );
        });
    }

    #[test]
    fn paper_style_example() {
        // Regions {12, 20}, {8}, {24}: all multiples of 4 -> t=2;
        // magnitudes >>2 are {3,5},{2},{6} -> min widths 2,2,3 -> P=3.
        let sets = vec![vec![12i64, 20], vec![8], vec![24]];
        let e = algorithm1(&to_intervals(&sets)).unwrap();
        assert_eq!(e, Encoding { trunc: 2, width: 3, sign: Sign::NonNeg });
        assert!(e.admits(8) && e.admits(24) && e.admits(20));
        assert!(!e.admits(9)); // tz too small
        assert!(!e.admits(64)); // needs 4 magnitude bits after trunc
    }

    #[test]
    fn negative_only_sets_use_negative_branch() {
        let sets: Vec<IntervalSet> = vec![vec![(-20, -12)], vec![(-8, -8)]];
        let e = algorithm1(&sets).unwrap();
        assert_eq!(e.sign, Sign::NonPos, "all-negative sets use the negative branch");
        // Width 2 suffices (-16 = 2<<3 and -8 = 1<<3 at t=3; ties on width
        // prefer the larger truncation).
        assert_eq!((e.width, e.trunc), (2, 3));
        assert!(e.admits(-16) && e.admits(-8));
        assert!(!e.admits(-12) && !e.admits(16));
    }

    #[test]
    fn mixed_signs_require_sign_bit() {
        let sets: Vec<IntervalSet> = vec![vec![(4, 4)], vec![(-4, -4)]];
        let e = algorithm1(&sets).unwrap();
        assert_eq!(e.sign, Sign::Signed);
        assert_eq!(e.trunc, 2);
        assert_eq!(e.width, 2); // 1 magnitude bit + sign
        assert!(e.admits(4) && e.admits(-4));
    }

    #[test]
    fn zero_is_free() {
        let sets: Vec<IntervalSet> = vec![vec![(0, 0)], vec![(0, 0)]];
        let e = algorithm1(&sets).unwrap();
        assert_eq!(e.width, 0);
        assert!(e.admits(0));
    }

    #[test]
    fn interval_vs_enumeration_equivalence() {
        for_each_seed(40, |rng| {
            let nregions = 1 + rng.below(4) as usize;
            let intervals: Vec<IntervalSet> = (0..nregions)
                .map(|_| {
                    let lo = rng.range_i64(-100, 80);
                    let hi = lo + rng.range_i64(0, 60);
                    vec![(lo, hi)]
                })
                .collect();
            let enumerated: Vec<IntervalSet> = intervals
                .iter()
                .map(|set| {
                    set.iter()
                        .flat_map(|&(lo, hi)| (lo..=hi).map(|v| (v, v)))
                        .collect()
                })
                .collect();
            assert_eq!(algorithm1(&intervals), algorithm1(&enumerated));
        });
    }

    #[test]
    fn interval_helpers() {
        assert_eq!(max_tz_in_interval(5, 7), 1); // 6 = 2*3
        assert_eq!(max_tz_in_interval(5, 8), 3);
        assert_eq!(max_tz_in_interval(1, 1), 0);
        assert_eq!(max_tz_in_interval(0, 0), 63);
        assert_eq!(min_width_at_t(5, 8, 3), Some(1)); // 8>>3 = 1
        assert_eq!(min_width_at_t(5, 7, 3), None);
        assert_eq!(min_width_at_t(5, 7, 0), Some(3)); // 5 -> 3 bits
    }
}
