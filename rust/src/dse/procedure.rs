//! Decision procedures over the complete design space — the paper's §III
//! exploration, decomposed into composable lexicographic passes plus a
//! cost-guided Pareto procedure.
//!
//! The pre-trait code was one monolith hardwired to the ASIC ordering.
//! Here a procedure is data: [`Lexicographic`] sequences [`Pass`]es in
//! any order ([`Lexicographic::square_first`] reproduces the paper's
//! procedure bit-for-bit, pinned by `tests/procedure_golden.rs`), and
//! [`ParetoCost`] replaces the fixed ordering with ranking by a
//! [`CostModel`] — the "modified decision procedure" the paper says is
//! all a new hardware technology needs. Custom procedures implement
//! [`DecisionProcedure`] and run through [`crate::dse::explore_with`].

use std::cmp::Ordering;

use super::{
    filter_all, finish, max_feasible_trunc, reselect_at_trunc, resolve_degree, Coeffs, Degree,
    DseOptions, Implementation,
};
use crate::bounds::BoundTable;
use crate::designspace::DesignSpace;
use crate::pool::CancelToken;
use crate::synth::synth_min_delay_with;
use crate::tech::CostModel;

/// One lexicographic optimization pass. A pass refines the current
/// truncation pair `(i, j)` and/or the selected encodings; sequencing
/// decides which objective dominates.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Pass {
    /// Minimize the evaluation-precision surplus `k`. Satisfied by
    /// construction: [`crate::designspace::generate`] returns the space
    /// at the smallest `k` feasible across all regions, so this pass is
    /// a documented no-op — it exists so procedure listings read like
    /// the paper's step sequence.
    MinimizeK,
    /// Maximize the square-input truncation `i`. Before widths are
    /// fixed this binary-searches the largest `i` every region survives;
    /// after [`Pass::MinimizeWidths`] it re-selects coefficients under
    /// the already-chosen encodings at the deepest truncation that still
    /// admits a selection.
    MaximizeSquareTrunc,
    /// Maximize the linear-input truncation `j` (same two modes).
    MaximizeLinearTrunc,
    /// Minimize coefficient storage widths `a`, then `b`, then `c` with
    /// Algorithm 1, pruning the dictionary after each step, then select
    /// the first jointly-valid triple per region.
    MinimizeWidths,
}

/// A decision procedure: consumes the complete [`DesignSpace`] (plus the
/// bound table it was generated from) and a technology's [`CostModel`],
/// returns one concrete [`Implementation`].
///
/// Lexicographic procedures ignore the cost model; [`ParetoCost`] ranks
/// by it. Implement this trait to plug in a custom exploration strategy
/// — [`crate::dse::explore_with`] is the entry point.
pub trait DecisionProcedure: Sync {
    /// Identifier for reports and logs.
    fn name(&self) -> &'static str;

    /// Explore and decide. `None` = the space admits no implementation
    /// under `opts` (e.g. a forced degree that is infeasible).
    fn decide(
        &self,
        bt: &BoundTable,
        ds: &DesignSpace,
        cm: &dyn CostModel,
        opts: &DseOptions,
    ) -> Option<Implementation>;

    /// [`DecisionProcedure::decide`] with a cooperative cancel token.
    /// The default ignores the token (a custom procedure stays correct,
    /// just uncancellable); the shipped procedures override it to poll
    /// between regions of every dictionary scan and return `None` once
    /// the token fires. Callers that pass a token must check it on a
    /// `None` result to tell cancellation from an exhausted space.
    fn decide_ctrl(
        &self,
        bt: &BoundTable,
        ds: &DesignSpace,
        cm: &dyn CostModel,
        opts: &DseOptions,
        _cancel: Option<&CancelToken>,
    ) -> Option<Implementation> {
        self.decide(bt, ds, cm, opts)
    }
}

/// A sequence of [`Pass`]es applied left to right — earlier passes take
/// lexicographic priority.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lexicographic {
    pub passes: Vec<Pass>,
    name: &'static str,
}

impl Lexicographic {
    pub fn new(passes: Vec<Pass>) -> Lexicographic {
        Lexicographic { passes, name: "lexicographic" }
    }

    /// The paper's ASIC ordering: truncations first (square, then
    /// linear), widths last.
    pub fn square_first() -> Lexicographic {
        Lexicographic {
            passes: vec![
                Pass::MinimizeK,
                Pass::MaximizeSquareTrunc,
                Pass::MaximizeLinearTrunc,
                Pass::MinimizeWidths,
            ],
            name: "square_first",
        }
    }

    /// The ablation ordering the paper found inferior on ASIC: widths
    /// minimized on the untruncated dictionary, truncation re-maximized
    /// afterwards under the fixed encodings.
    pub fn lut_first() -> Lexicographic {
        Lexicographic {
            passes: vec![Pass::MinimizeK, Pass::MinimizeWidths, Pass::MaximizeSquareTrunc],
            name: "lut_first",
        }
    }
}

/// Deepest-truncation re-selection under fixed encodings: walk the axis
/// from full truncation down, return the first depth that still admits a
/// selection (feasibility under fixed encodings need not be monotone, so
/// this is a linear descent, not a bisection).
fn constrained_max(
    bt: &BoundTable,
    ds: &DesignSpace,
    pre: &Implementation,
    square_axis: bool,
    i: u32,
    j: u32,
    cancel: Option<&CancelToken>,
) -> Implementation {
    let admits = |co: &Coeffs| {
        pre.enc_a.admits(co.a) && pre.enc_b.admits(co.b) && pre.enc_c.admits(co.c)
    };
    for p in (0..=ds.x_bits()).rev() {
        if cancel.is_some_and(|c| c.is_cancelled()) {
            break;
        }
        let (ii, jj) = if square_axis { (p, j) } else { (i, p) };
        if let Some(im) = reselect_at_trunc(bt, ds, pre, ii, jj, &admits, cancel) {
            return im;
        }
    }
    pre.clone()
}

impl DecisionProcedure for Lexicographic {
    fn name(&self) -> &'static str {
        self.name
    }

    fn decide(
        &self,
        bt: &BoundTable,
        ds: &DesignSpace,
        cm: &dyn CostModel,
        opts: &DseOptions,
    ) -> Option<Implementation> {
        self.decide_ctrl(bt, ds, cm, opts, None)
    }

    fn decide_ctrl(
        &self,
        bt: &BoundTable,
        ds: &DesignSpace,
        _cm: &dyn CostModel,
        opts: &DseOptions,
        cancel: Option<&CancelToken>,
    ) -> Option<Implementation> {
        let cancelled = || cancel.is_some_and(|c| c.is_cancelled());
        let degree = resolve_degree(ds, opts)?;
        let xbits = ds.x_bits();
        let (mut i, mut j) = (0u32, 0u32);
        let mut fixed: Option<Implementation> = None;
        for pass in &self.passes {
            if cancelled() {
                return None;
            }
            match pass {
                Pass::MinimizeK => {} // generation already minimized k
                Pass::MaximizeSquareTrunc => {
                    if let Some(pre) = fixed.take() {
                        let upd = constrained_max(bt, ds, &pre, true, i, j, cancel);
                        i = upd.sq_trunc;
                        fixed = Some(upd);
                    } else {
                        // The square path vanishes for linear designs:
                        // `a = 0` makes `i` unconstrained, so it is
                        // maximal outright.
                        i = if degree == Degree::Linear {
                            xbits
                        } else {
                            max_feasible_trunc(bt, ds, degree, opts, cancel, |p| (p, j))
                        };
                    }
                }
                Pass::MaximizeLinearTrunc => {
                    if let Some(pre) = fixed.take() {
                        let upd = constrained_max(bt, ds, &pre, false, i, j, cancel);
                        j = upd.lin_trunc;
                        fixed = Some(upd);
                    } else {
                        j = max_feasible_trunc(bt, ds, degree, opts, cancel, |p| (i, p));
                    }
                }
                Pass::MinimizeWidths => {
                    let cands = filter_all(bt, ds, degree, i, j, opts.max_b_per_a, cancel);
                    fixed = Some(finish(bt, ds, degree, i, j, cands, opts, cancel)?);
                }
            }
        }
        if cancelled() {
            return None;
        }
        match fixed {
            Some(im) => Some(im),
            // A sequence without MinimizeWidths still needs encodings to
            // emit an implementation: minimize them at the final (i, j).
            None => {
                let cands = filter_all(bt, ds, degree, i, j, opts.max_b_per_a, cancel);
                finish(bt, ds, degree, i, j, cands, opts, cancel)
            }
        }
    }
}

/// Cost-guided Pareto procedure: instead of committing to one pass
/// order, enumerate the truncation/width trade-off frontier of the
/// space, cost every candidate with the technology's model, drop
/// dominated points, and rank the survivors by area-delay product (in
/// the technology's own units).
///
/// Candidates: for quadratic designs, the **2-D `(i, j)` truncation
/// frontier** — each sampled square truncation `i` crossed with a
/// sampled descent of linear truncations `j` from the maximal feasible
/// `j` at that `i` down to zero, widths minimized at every grid point.
/// (The pre-frontier behaviour, `j` maximized per `i`, is the
/// `frontier_2d = false` ablation; its candidate set is a subset of the
/// frontier's, so the widened pool never selects a costlier
/// implementation — property-tested.) For linear designs the sweep runs
/// over `j` alone and the two shapes coincide. The width-first
/// ([`Lexicographic::lut_first`]) selection joins the pool, so the
/// procedure can trade truncation away entirely when storage is cheap —
/// which is exactly what the FPGA model does on bundled examples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParetoCost {
    /// Cap on sampled truncation depths **per axis** — never exceeded;
    /// both endpoints (full and zero truncation) are always in the
    /// sample. Values below 2 are treated as 2. The 2-D frontier costs
    /// at most `max_candidates^2` selections.
    pub max_candidates: usize,
    /// Sweep the full `(i, j)` grid (the default). `false` restores the
    /// 1-D ablation: `j` maximized per sampled `i`.
    pub frontier_2d: bool,
}

impl Default for ParetoCost {
    fn default() -> Self {
        ParetoCost { max_candidates: 6, frontier_2d: true }
    }
}

/// `max, ..., 0` downsampled to at most `cap` values (`cap >= 2`),
/// descending, both endpoints included.
fn downsample_desc(max: u32, cap: usize) -> Vec<u32> {
    let cap = cap.max(2) as u32;
    if max < cap {
        return (0..=max).rev().collect();
    }
    // ceil(max / stride) values above zero, i.e. at most cap - 1, plus 0.
    let stride = max.div_ceil(cap - 1);
    let mut vals = Vec::with_capacity(cap as usize);
    let mut v = max;
    while v > 0 {
        vals.push(v);
        v = v.saturating_sub(stride);
    }
    vals.push(0);
    vals
}

impl DecisionProcedure for ParetoCost {
    fn name(&self) -> &'static str {
        "pareto"
    }

    fn decide(
        &self,
        bt: &BoundTable,
        ds: &DesignSpace,
        cm: &dyn CostModel,
        opts: &DseOptions,
    ) -> Option<Implementation> {
        self.decide_ctrl(bt, ds, cm, opts, None)
    }

    fn decide_ctrl(
        &self,
        bt: &BoundTable,
        ds: &DesignSpace,
        cm: &dyn CostModel,
        opts: &DseOptions,
        cancel: Option<&CancelToken>,
    ) -> Option<Implementation> {
        let cancelled = || cancel.is_some_and(|c| c.is_cancelled());
        let degree = resolve_degree(ds, opts)?;
        let xbits = ds.x_bits();
        let cap = opts.max_b_per_a;
        let mut cands: Vec<Implementation> = Vec::new();
        let at = |i: u32, j: u32| -> Option<Implementation> {
            let cands = filter_all(bt, ds, degree, i, j, cap, cancel);
            finish(bt, ds, degree, i, j, cands, opts, cancel)
        };
        if degree == Degree::Quadratic {
            let i_max = max_feasible_trunc(bt, ds, degree, opts, cancel, |p| (p, 0));
            for i in downsample_desc(i_max, self.max_candidates) {
                if cancelled() {
                    return None;
                }
                let j_max = max_feasible_trunc(bt, ds, degree, opts, cancel, |p| (i, p));
                let js = if self.frontier_2d {
                    // The full frontier row at this i: j_max down to 0.
                    // Shallower j admits more (a, b) survivors, which can
                    // tighten the minimized widths — a trade only a cost
                    // model (not a fixed pass order) can arbitrate.
                    downsample_desc(j_max, self.max_candidates)
                } else {
                    vec![j_max]
                };
                for j in js {
                    cands.extend(at(i, j));
                }
            }
        } else {
            let j_max = max_feasible_trunc(bt, ds, degree, opts, cancel, |p| (xbits, p));
            for j in downsample_desc(j_max, self.max_candidates) {
                if cancelled() {
                    return None;
                }
                cands.extend(at(xbits, j));
            }
        }
        if cancelled() {
            return None;
        }
        // The width-first selection explores the opposite corner of the
        // trade space (minimal widths, whatever truncation survives).
        if let Some(wf) = Lexicographic::lut_first().decide_ctrl(bt, ds, cm, opts, cancel) {
            if wf.degree == degree {
                cands.push(wf);
            }
        }
        if cancelled() {
            return None;
        }
        let mut costed: Vec<(Implementation, crate::synth::SynthPoint)> = cands
            .into_iter()
            .map(|im| {
                let p = synth_min_delay_with(cm, &im);
                (im, p)
            })
            .collect();
        // Pareto filter on (area, delay), then rank by area-delay
        // product; ties keep the earlier (deeper-truncation) candidate.
        let mut best: Option<(usize, f64)> = None;
        for (idx, (_, p)) in costed.iter().enumerate() {
            let dominated = costed.iter().any(|(_, q)| {
                q.area_um2 <= p.area_um2
                    && q.delay_ns <= p.delay_ns
                    && (q.area_um2 < p.area_um2 || q.delay_ns < p.delay_ns)
            });
            if dominated {
                continue;
            }
            let adp = p.area_um2 * p.delay_ns;
            let improves = match best {
                None => true,
                Some((_, b)) => adp.total_cmp(&b) == Ordering::Less,
            };
            if improves {
                best = Some((idx, adp));
            }
        }
        best.map(|(idx, _)| costed.swap_remove(idx).0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};
    use crate::tech::TechKind;

    fn setup(name: &str, bits: u32, r: u32) -> (BoundTable, DesignSpace) {
        let f = builtin(name, bits).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
            .unwrap_or_else(|e| panic!("{name}/{bits} R={r}: {e}"));
        (bt, ds)
    }

    fn assert_valid(bt: &BoundTable, im: &Implementation) {
        for z in 0..(1u64 << bt.in_bits) {
            let out = im.eval(z);
            assert!(
                out >= bt.l[z as usize] as i64 && out <= bt.u[z as usize] as i64,
                "z={z}: {out} outside bounds"
            );
        }
    }

    #[test]
    fn downsample_keeps_endpoints_and_honors_cap() {
        assert_eq!(downsample_desc(3, 6), vec![3, 2, 1, 0]);
        assert_eq!(downsample_desc(0, 6), vec![0]);
        assert_eq!(downsample_desc(11, 4), vec![11, 7, 3, 0]);
        for max in 0..40u32 {
            for cap in 2..8usize {
                let v = downsample_desc(max, cap);
                assert!(v.len() <= cap, "max={max} cap={cap}: {v:?}");
                assert_eq!(*v.first().unwrap(), max);
                assert_eq!(*v.last().unwrap(), 0);
                assert!(v.windows(2).all(|w| w[0] > w[1]), "{v:?}");
            }
        }
    }

    #[test]
    fn every_procedure_yields_valid_implementations() {
        let (bt, ds) = setup("recip", 8, 3); // naturally quadratic
        let cm = TechKind::AsicGe.technology().cost_model();
        let opts = DseOptions::default();
        for proc_ in [
            &Lexicographic::square_first() as &dyn DecisionProcedure,
            &Lexicographic::lut_first(),
            &ParetoCost::default(),
        ] {
            let im = proc_
                .decide(&bt, &ds, cm, &opts)
                .unwrap_or_else(|| panic!("{} found nothing", proc_.name()));
            assert_valid(&bt, &im);
        }
    }

    #[test]
    fn custom_pass_orders_explore_and_verify() {
        // The point of the decomposition: orderings beyond the two
        // shipped ones are expressible and stay correct.
        let (bt, ds) = setup("log2", 10, 5);
        let cm = TechKind::AsicGe.technology().cost_model();
        let opts = DseOptions::default();
        for passes in [
            vec![Pass::MaximizeLinearTrunc, Pass::MaximizeSquareTrunc, Pass::MinimizeWidths],
            vec![Pass::MaximizeSquareTrunc, Pass::MinimizeWidths, Pass::MaximizeLinearTrunc],
            vec![Pass::MinimizeK], // implicit width minimization at (0, 0)
        ] {
            let im = Lexicographic::new(passes.clone())
                .decide(&bt, &ds, cm, &opts)
                .unwrap_or_else(|| panic!("{passes:?} found nothing"));
            assert_valid(&bt, &im);
        }
    }

    #[test]
    fn two_d_frontier_never_selects_costlier_than_one_d() {
        // Satellite property (ROADMAP PR-3 item): the 2-D (i, j) grid's
        // candidate pool is a superset of the old per-i-max-j pool
        // (downsample_desc always includes its max endpoint), and the
        // winner is the ADP-minimum over undominated candidates — so
        // widening the pool can never select a costlier implementation,
        // under ANY shipped cost model.
        for (name, bits, r) in [("recip", 8u32, 3u32), ("recip", 10, 4), ("log2", 10, 4)] {
            let (bt, ds) = setup(name, bits, r);
            let opts = DseOptions::default();
            for tech in TechKind::ALL {
                let cm = tech.technology().cost_model();
                let one_d = ParetoCost { frontier_2d: false, ..Default::default() }
                    .decide(&bt, &ds, cm, &opts);
                let two_d = ParetoCost::default().decide(&bt, &ds, cm, &opts);
                let (Some(one_d), Some(two_d)) = (one_d, two_d) else {
                    panic!("{name}/{bits} R={r} {}: pareto found nothing", tech.label());
                };
                assert_valid(&bt, &two_d);
                let p1 = synth_min_delay_with(cm, &one_d);
                let p2 = synth_min_delay_with(cm, &two_d);
                let (adp1, adp2) = (p1.area_um2 * p1.delay_ns, p2.area_um2 * p2.delay_ns);
                assert!(
                    adp2 <= adp1 * (1.0 + 1e-12),
                    "{name}/{bits} R={r} {}: 2-D frontier regressed ADP {adp1} -> {adp2}",
                    tech.label()
                );
            }
        }
    }

    #[test]
    fn pareto_never_returns_a_dominated_candidate() {
        let (bt, ds) = setup("recip", 10, 4); // quadratic
        for tech in TechKind::ALL {
            let cm = tech.technology().cost_model();
            let im = ParetoCost::default()
                .decide(&bt, &ds, cm, &DseOptions::default())
                .expect("pareto found nothing");
            assert_valid(&bt, &im);
            // The winner must not be beaten on both axes by the plain
            // square-first selection under the same model.
            let sq = Lexicographic::square_first()
                .decide(&bt, &ds, cm, &DseOptions::default())
                .unwrap();
            let pw = synth_min_delay_with(cm, &im);
            let ps = synth_min_delay_with(cm, &sq);
            assert!(
                !(ps.area_um2 < pw.area_um2 && ps.delay_ns < pw.delay_ns),
                "{}: pareto winner dominated by square-first",
                tech.label()
            );
        }
    }
}
