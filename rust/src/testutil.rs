//! Deterministic pseudo-random helpers for property-based tests.
//!
//! `proptest` is not available offline, so invariant tests use this small
//! splitmix64-based generator: seeded, reproducible, shrink-free. Failures
//! print the seed so a case can be replayed by pinning it.

/// Splitmix64 PRNG — tiny, fast, and good enough for test-case generation.
#[derive(Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Random bound slices that are guaranteed feasible by construction
/// (perturb an exact quadratic, widen by a random slack) — shared by the
/// region / DSE / envelope equivalence property tests.
pub fn quadratic_bounds(rng: &mut Rng, n: usize) -> (Vec<i32>, Vec<i32>) {
    quadratic_bounds_with(rng, n, 3, 50, 4)
}

/// [`quadratic_bounds`] with explicit caps on the quadratic coefficient,
/// linear coefficient and slack magnitudes.
pub fn quadratic_bounds_with(
    rng: &mut Rng,
    n: usize,
    a_mag: i64,
    b_mag: i64,
    slack_max: i64,
) -> (Vec<i32>, Vec<i32>) {
    let a = rng.range_i64(-a_mag, a_mag);
    let b = rng.range_i64(-b_mag, b_mag);
    let c = rng.range_i64(0, 100);
    let slack = rng.range_i64(1, slack_max);
    let mut l = Vec::new();
    let mut u = Vec::new();
    for x in 0..n as i64 {
        let v = a * x * x + b * x + c;
        l.push((v - slack) as i32);
        u.push((v + slack) as i32);
    }
    (l, u)
}

/// Random unstructured bound slices (frequently infeasible for any
/// quadratic) — exercises the infeasible / `KExhausted` paths the
/// feasible-by-construction generator cannot reach.
pub fn zigzag_bounds(rng: &mut Rng, n: usize) -> (Vec<i32>, Vec<i32>) {
    let l: Vec<i32> = (0..n).map(|_| rng.range_i64(-40, 40) as i32).collect();
    let u: Vec<i32> = l.iter().map(|&v| v + rng.range_i64(0, 6) as i32).collect();
    (l, u)
}

/// Run `f` across `cases` seeds; on panic, report which seed failed.
///
/// `POLYGEN_PROP_SEEDS` caps the seed count from the environment — the
/// miri CI job sets it low (interpreted execution is ~2 orders of
/// magnitude slower than native) without thinning native coverage.
pub fn for_each_seed(cases: u64, f: impl Fn(&mut Rng)) {
    let cases = std::env::var("POLYGEN_PROP_SEEDS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .map_or(cases, |n| cases.min(n.max(1)));
    for seed in 0..cases {
        let mut rng = Rng::new(0xc0ffee ^ seed.wrapping_mul(0x9e3779b97f4a7c15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property failed at seed {seed}: {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(42);
        for _ in 0..1000 {
            let v = r.range_i64(-5, 9);
            assert!((-5..=9).contains(&v));
            let u = r.below(17);
            assert!(u < 17);
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }
}
