//! Minimal configuration system: a TOML-subset parser (sections,
//! `key = value`, comments) plus typed accessors and CLI-style overrides.
//!
//! No third-party crates are available offline, so this is hand-rolled;
//! it supports exactly what `polygen` job files need — see
//! `examples/configs/` for samples.

use std::collections::BTreeMap;
use std::path::Path;

/// Parsed configuration: `section.key -> string value` (top-level keys
/// live under the empty section).
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected `key = value`", ln + 1));
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
        Config::parse(&text)
    }

    /// Apply a `key=value` override (CLI `--set`).
    pub fn set(&mut self, kv: &str) -> Result<(), String> {
        let (k, v) = kv.split_once('=').ok_or("override must be key=value")?;
        self.values.insert(k.trim().to_string(), v.trim().to_string());
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u32(&self, key: &str) -> Result<Option<u32>, String> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| format!("{key}: {e}")))
            .transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>, String> {
        self.get(key)
            .map(|v| match v {
                "true" | "yes" | "1" => Ok(true),
                "false" | "no" | "0" => Ok(false),
                other => Err(format!("{key}: not a bool: {other}")),
            })
            .transpose()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_overrides() {
        let mut c = Config::parse(
            "# job file\nfunc = recip\nbits = 16\n[generate]\nlookup_bits = 8 # LUB\nsearch = \"pruned\"\n",
        )
        .unwrap();
        assert_eq!(c.get("func"), Some("recip"));
        assert_eq!(c.get_u32("bits").unwrap(), Some(16));
        assert_eq!(c.get("generate.lookup_bits"), Some("8"));
        assert_eq!(c.get("generate.search"), Some("pruned"));
        c.set("generate.lookup_bits=9").unwrap();
        assert_eq!(c.get_u32("generate.lookup_bits").unwrap(), Some(9));
        assert_eq!(c.get_or("missing", "x"), "x");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("not a kv line").is_err());
        let c = Config::parse("flag = maybe").unwrap();
        assert!(c.get_bool("flag").is_err());
    }
}
