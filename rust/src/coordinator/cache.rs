//! Design-space disk cache.
//!
//! Generating a space is the expensive step (exponential in precision),
//! and downstream exploration is tuned per hardware target — the paper's
//! core argument for generating the *complete* space once. This cache
//! makes that concrete: `.pgds` files store the full region dictionaries
//! in a small versioned little-endian binary format (hand-rolled; no
//! serde offline). Loads are verified against a whole-file CRC-32
//! trailer; a damaged file is quarantined aside (`.quarantined`) and the
//! space regenerates — never a silently wrong dictionary.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::designspace::extrema::SearchStrategy;
use crate::designspace::region::{AbEntry, RegionSpace};
use crate::designspace::{DesignSpace, GenOptions};
use crate::faults::{self, Fault};
use crate::obs::metrics;
use crate::service::store::crc32;

const CACHE_HITS: metrics::Counter = metrics::counter("cache.hits");
const CACHE_MISSES: metrics::Counter = metrics::counter("cache.misses");
const CACHE_QUARANTINED: metrics::Counter = metrics::counter("cache.quarantined");

const MAGIC: &[u8; 4] = b"PGDS";
/// v4 stores the generation degree after `k` (the degree-1 linear slice
/// is a distinct space from the quadratic one). v3 added the whole-file
/// CRC-32 trailer (the `.pgjr` idiom), so *any* flipped bit fails closed
/// instead of decoding into a wrong dictionary. Older clean files decode
/// as `Stale` — a plain miss that regenerates — so upgrades are
/// self-healing.
const VERSION: u32 = 4;

fn w_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_str(out: &mut Vec<u8>, s: &str) {
    w_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err("truncated cache file".into());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64, String> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn string(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|e| e.to_string())
    }
}

/// Serialize a design space (region dictionaries + metadata; the real
/// analyses are recomputable and not stored). Materializes every lazy
/// region — the `.pgds` format is the full dictionary by design, so a
/// load never needs the analyses back.
pub fn to_bytes(ds: &DesignSpace) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    w_u32(&mut out, VERSION);
    w_str(&mut out, &ds.func);
    w_str(&mut out, &ds.accuracy);
    w_u32(&mut out, ds.in_bits);
    w_u32(&mut out, ds.out_bits);
    w_u32(&mut out, ds.lookup_bits);
    w_u32(&mut out, ds.k);
    w_u32(&mut out, ds.degree);
    w_u64(&mut out, ds.dd_evals);
    w_u32(&mut out, ds.num_regions() as u32);
    for rv in ds.region_views() {
        let sp = rv.space();
        w_u64(&mut out, sp.r);
        w_u32(&mut out, sp.linear_ok as u32);
        w_u32(&mut out, sp.entries.len() as u32);
        for e in &sp.entries {
            w_i64(&mut out, e.a);
            w_i64(&mut out, e.b_lo);
            w_i64(&mut out, e.b_hi);
        }
    }
    let crc = crc32(&out);
    w_u32(&mut out, crc);
    out
}

/// Why a buffer did or did not decode, for [`load_checked`]'s verdict.
enum Decoded {
    Ok(DesignSpace),
    /// CRC-valid file in a different format version: not damage, just a
    /// stale or foreign writer — treated as a miss and regenerated over.
    Stale(u32),
    Corrupt(String),
}

fn decode(buf: &[u8]) -> Decoded {
    // The trailer covers everything before it and is checked first, so
    // any flipped bit or lost tail fails closed.
    if buf.len() < 12 {
        return Decoded::Corrupt("truncated cache file".into());
    }
    let (payload, tail) = buf.split_at(buf.len() - 4);
    let crc = u32::from_le_bytes(tail.try_into().unwrap());
    if crc32(payload) != crc {
        return Decoded::Corrupt("cache CRC mismatch".into());
    }
    let mut r = Reader { buf: payload, pos: 0 };
    match r.take(4) {
        Ok(m) if m == MAGIC => {}
        _ => return Decoded::Corrupt("not a .pgds file".into()),
    }
    match r.u32() {
        Ok(v) if v == VERSION => {}
        Ok(v) => return Decoded::Stale(v),
        Err(e) => return Decoded::Corrupt(e),
    }
    match decode_body(&mut r) {
        Ok(ds) if r.pos == payload.len() => Decoded::Ok(ds),
        Ok(_) => Decoded::Corrupt("trailing bytes in cache file".into()),
        Err(e) => Decoded::Corrupt(e),
    }
}

/// Deserialize; `analyses` comes back empty (recompute when needed).
pub fn from_bytes(buf: &[u8]) -> Result<DesignSpace, String> {
    match decode(buf) {
        Decoded::Ok(ds) => Ok(ds),
        Decoded::Stale(v) => Err(format!("cache version {v}, expected {VERSION}")),
        Decoded::Corrupt(e) => Err(e),
    }
}

fn decode_body(r: &mut Reader) -> Result<DesignSpace, String> {
    let func = r.string()?;
    let accuracy = r.string()?;
    let in_bits = r.u32()?;
    let out_bits = r.u32()?;
    let lookup_bits = r.u32()?;
    let k = r.u32()?;
    let degree = r.u32()?;
    if degree != 1 && degree != 2 {
        return Err(format!("cache degree {degree} out of range"));
    }
    let dd_evals = r.u64()?;
    let nregions = r.u32()? as usize;
    let mut regions = Vec::with_capacity(nregions);
    for _ in 0..nregions {
        let rr = r.u64()?;
        let linear_ok = r.u32()? != 0;
        let nent = r.u32()? as usize;
        let mut entries = Vec::with_capacity(nent);
        for _ in 0..nent {
            entries.push(AbEntry { a: r.i64()?, b_lo: r.i64()?, b_hi: r.i64()? });
        }
        regions.push(RegionSpace { r: rr, k, entries, linear_ok });
    }
    // Cache hits come back fully materialized (analyses are recomputable
    // and deliberately not stored); every lazy-view query answers from
    // the pre-filled cells.
    Ok(DesignSpace::from_materialized(
        func,
        accuracy,
        in_bits,
        out_bits,
        lookup_bits,
        k,
        degree,
        regions,
        Vec::new(),
        dd_evals,
    ))
}

/// Canonical cache path for a workload at specific generation options.
/// Every result-affecting [`GenOptions`] field is part of the key:
/// `lookup_bits` shapes the space, `search` changes the stored `dd_evals`
/// instrumentation, `max_k` bounds which spaces exist at all, and
/// `degree` selects the linear slice. The default degree 2 adds no
/// suffix, so pre-degree-knob cache keys are unchanged. `threads` is
/// deliberately excluded — worker count never changes the result
/// (`designspace::tests::threads_do_not_change_result`).
pub fn cache_path(dir: &Path, func: &str, acc: &str, in_bits: u32, opts: &GenOptions) -> PathBuf {
    let strategy = match opts.search {
        SearchStrategy::Naive => "naive",
        SearchStrategy::Pruned => "pruned",
        SearchStrategy::Hull => "hull",
    };
    let deg = if opts.degree == 1 { "_deg1" } else { "" };
    dir.join(format!(
        "{func}_{acc}_{in_bits}b_R{}_{strategy}_k{}{deg}.pgds",
        opts.lookup_bits, opts.max_k
    ))
}

/// Save atomically (write a per-process temp file, then rename): batch
/// workers share one cache directory, and a reader must never observe a
/// half-written `.pgds`.
// lint: fault-ok(write-side damage is load-side damage by the time anyone
// reads it, and the load path below injects + catches exactly that; the
// tmp+rename dance keeps torn writes invisible)
pub fn save(ds: &DesignSpace, path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    // lint: sync-ok(const-init static counter in never-modeled code)
    static SAVE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = SAVE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".tmp{}.{seq}", std::process::id()));
    let tmp = PathBuf::from(tmp);
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&to_bytes(ds))?;
    }
    std::fs::rename(&tmp, path)
}

/// What [`load_checked`] found at a cache path.
#[derive(Debug)]
pub enum CacheLoad {
    /// A CRC-valid, current-version space.
    Hit(DesignSpace),
    /// No file, an unreadable file, or a clean file in another format
    /// version — regenerate (the save overwrites it).
    Miss,
    /// The file failed its integrity check and was renamed aside to the
    /// returned path; regenerate and inspect the quarantined bytes.
    Quarantined(PathBuf),
}

/// Load with the full verdict. The read is routed through the
/// `cache.load` injection tap (bit flips and truncation — the two
/// disk-rot shapes the CRC trailer must catch), so the chaos suite can
/// prove a damaged cache is quarantined, never decoded.
pub fn load_checked(path: &Path) -> CacheLoad {
    let mut buf = match std::fs::read(path) {
        Ok(b) => b,
        Err(_) => {
            CACHE_MISSES.inc();
            return CacheLoad::Miss;
        }
    };
    match faults::inject("cache.load", &[Fault::Corrupt, Fault::Truncate]) {
        Some(Fault::Corrupt) if !buf.is_empty() => {
            let at = faults::rand_below(buf.len());
            buf[at] ^= 0x01;
        }
        Some(Fault::Truncate) => {
            let cut = 1 + faults::rand_below(buf.len().min(16));
            let keep = buf.len().saturating_sub(cut);
            buf.truncate(keep);
        }
        _ => {}
    }
    match decode(&buf) {
        Decoded::Ok(ds) => {
            CACHE_HITS.inc();
            CacheLoad::Hit(ds)
        }
        Decoded::Stale(_) => {
            CACHE_MISSES.inc();
            CacheLoad::Miss
        }
        Decoded::Corrupt(why) => {
            CACHE_QUARANTINED.inc();
            let mut q = path.as_os_str().to_owned();
            q.push(".quarantined");
            let q = PathBuf::from(q);
            if std::fs::rename(path, &q).is_err() {
                let _ = std::fs::remove_file(path);
            }
            eprintln!(
                "polygen: design-space cache {} failed its integrity check ({why}); \
                 quarantined at {} (will regenerate)",
                path.display(),
                q.display()
            );
            CacheLoad::Quarantined(q)
        }
    }
}

/// Compatibility wrapper: any non-hit is an `Err` (callers regenerate).
pub fn load(path: &Path) -> Result<DesignSpace, String> {
    match load_checked(path) {
        CacheLoad::Hit(ds) => Ok(ds),
        CacheLoad::Miss => Err(format!("{}: cache miss", path.display())),
        CacheLoad::Quarantined(q) => Err(format!("cache quarantined at {}", q.display())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};

    #[test]
    fn roundtrip_preserves_everything_needed() {
        let f = builtin("log2", 10).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: 5, ..Default::default() }).unwrap();
        let back = from_bytes(&to_bytes(&ds)).unwrap();
        assert_eq!(back.func, ds.func);
        assert_eq!(back.k, ds.k);
        assert_eq!(back.degree, ds.degree);
        assert_eq!(back.lookup_bits, ds.lookup_bits);
        assert_eq!(back.num_regions(), ds.num_regions());
        for (a, b) in ds.region_views().zip(back.region_views()) {
            assert_eq!(a.entries(), b.entries());
            assert_eq!(a.linear_ok(), b.linear_ok());
        }
        // A cached space must drive the DSE identically.
        let im1 = crate::dse::explore(&bt, &ds, &Default::default()).unwrap();
        let im2 = crate::dse::explore(&bt, &back, &Default::default()).unwrap();
        assert_eq!(im1.coeffs, im2.coeffs);
    }

    #[test]
    fn cache_key_covers_all_gen_options() {
        // Regression: the key once hashed only `lookup_bits`, so switching
        // strategy (or `max_k`) could return a stale space with the other
        // option's instrumentation.
        let dir = Path::new("/tmp/pgds");
        let base = GenOptions { lookup_bits: 5, ..Default::default() };
        let naive = GenOptions { search: SearchStrategy::Naive, ..base };
        let low_k = GenOptions { max_k: 12, ..base };
        let threaded = GenOptions { threads: 8, ..base };
        let linear = GenOptions { degree: 1, ..base };
        let p = |o: &GenOptions| cache_path(dir, "recip", "1ulp", 10, o);
        assert_ne!(p(&base), p(&naive), "search strategy must be in the key");
        assert_ne!(p(&base), p(&low_k), "max_k must be in the key");
        assert_ne!(p(&naive), p(&low_k));
        assert_ne!(p(&base), p(&linear), "degree must be in the key");
        assert_eq!(p(&base), p(&threaded), "threads never changes the result");
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_bytes(b"nope").is_err());
        assert!(from_bytes(b"PGDS\x09\x00\x00\x00").is_err());
        let f = builtin("exp2", 8).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: 4, ..Default::default() }).unwrap();
        let mut bytes = to_bytes(&ds);
        bytes.push(0); // trailing byte shifts the CRC window
        assert!(from_bytes(&bytes).is_err());
    }

    fn small_space() -> DesignSpace {
        let f = builtin("exp2", 8).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        generate(&bt, &GenOptions { lookup_bits: 4, ..Default::default() }).unwrap()
    }

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("pgds_test_{}_{tag}.pgds", std::process::id()))
    }

    #[test]
    fn every_single_byte_flip_is_caught_and_quarantined() {
        let ds = small_space();
        let path = scratch("byteflip");
        save(&ds, &path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let quarantined = {
            let mut q = path.as_os_str().to_owned();
            q.push(".quarantined");
            PathBuf::from(q)
        };
        for at in 0..clean.len() {
            let mut bad = clean.clone();
            bad[at] ^= 0x01;
            std::fs::write(&path, &bad).unwrap();
            match load_checked(&path) {
                CacheLoad::Quarantined(q) => assert_eq!(q, quarantined, "flip at byte {at}"),
                other => panic!("flip at byte {at} not quarantined: {other:?}"),
            }
            assert!(!path.exists(), "flip at byte {at} left the bad file in place");
            std::fs::remove_file(&quarantined).unwrap();
        }
        std::fs::write(&path, &clean).unwrap();
        match load_checked(&path) {
            CacheLoad::Hit(back) => assert_eq!(back.num_regions(), ds.num_regions()),
            other => panic!("clean file did not load: {other:?}"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stale_version_is_a_miss_not_damage() {
        // A CRC-valid file from another format version is a plain miss:
        // left in place for regeneration to overwrite, never quarantined.
        let ds = small_space();
        let mut bytes = to_bytes(&ds);
        bytes[4..8].copy_from_slice(&2u32.to_le_bytes());
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let path = scratch("stale");
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(load_checked(&path), CacheLoad::Miss));
        assert!(path.exists(), "stale file must stay for the save to overwrite");
        std::fs::remove_file(&path).unwrap();
        // Missing file is also a miss, not damage.
        assert!(matches!(load_checked(&path), CacheLoad::Miss));
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn armed_load_tap_quarantines() {
        use crate::faults::{arm_guard, injected, FaultPlan};
        let _serial = crate::faults::test_serial_lock();
        let ds = small_space();
        let path = scratch("armed");
        save(&ds, &path).unwrap();
        let before = injected();
        {
            let _g = arm_guard(FaultPlan::new(0xCAFE).rate(1000).only("cache."));
            // Corrupt or Truncate, either way the CRC fails closed.
            assert!(matches!(load_checked(&path), CacheLoad::Quarantined(_)));
        }
        assert!(injected() > before, "the tap must have fired");
        let mut q = path.as_os_str().to_owned();
        q.push(".quarantined");
        std::fs::remove_file(PathBuf::from(q)).unwrap();
    }
}
