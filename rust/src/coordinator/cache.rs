//! Design-space disk cache.
//!
//! Generating a space is the expensive step (exponential in precision),
//! and downstream exploration is tuned per hardware target — the paper's
//! core argument for generating the *complete* space once. This cache
//! makes that concrete: `.pgds` files store the full region dictionaries
//! in a small versioned little-endian binary format (hand-rolled; no
//! serde offline).

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::designspace::extrema::SearchStrategy;
use crate::designspace::region::{AbEntry, RegionSpace};
use crate::designspace::{DesignSpace, GenOptions};

const MAGIC: &[u8; 4] = b"PGDS";
const VERSION: u32 = 2;

fn w_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_str(out: &mut Vec<u8>, s: &str) {
    w_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err("truncated cache file".into());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64, String> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn string(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|e| e.to_string())
    }
}

/// Serialize a design space (region dictionaries + metadata; the real
/// analyses are recomputable and not stored). Materializes every lazy
/// region — the `.pgds` format is the full dictionary by design, so a
/// load never needs the analyses back.
pub fn to_bytes(ds: &DesignSpace) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    w_u32(&mut out, VERSION);
    w_str(&mut out, &ds.func);
    w_str(&mut out, &ds.accuracy);
    w_u32(&mut out, ds.in_bits);
    w_u32(&mut out, ds.out_bits);
    w_u32(&mut out, ds.lookup_bits);
    w_u32(&mut out, ds.k);
    w_u64(&mut out, ds.dd_evals);
    w_u32(&mut out, ds.num_regions() as u32);
    for rv in ds.region_views() {
        let sp = rv.space();
        w_u64(&mut out, sp.r);
        w_u32(&mut out, sp.linear_ok as u32);
        w_u32(&mut out, sp.entries.len() as u32);
        for e in &sp.entries {
            w_i64(&mut out, e.a);
            w_i64(&mut out, e.b_lo);
            w_i64(&mut out, e.b_hi);
        }
    }
    out
}

/// Deserialize; `analyses` comes back empty (recompute when needed).
pub fn from_bytes(buf: &[u8]) -> Result<DesignSpace, String> {
    let mut r = Reader { buf, pos: 0 };
    if r.take(4)? != MAGIC {
        return Err("not a .pgds file".into());
    }
    let ver = r.u32()?;
    if ver != VERSION {
        return Err(format!("cache version {ver}, expected {VERSION}"));
    }
    let func = r.string()?;
    let accuracy = r.string()?;
    let in_bits = r.u32()?;
    let out_bits = r.u32()?;
    let lookup_bits = r.u32()?;
    let k = r.u32()?;
    let dd_evals = r.u64()?;
    let nregions = r.u32()? as usize;
    let mut regions = Vec::with_capacity(nregions);
    for _ in 0..nregions {
        let rr = r.u64()?;
        let linear_ok = r.u32()? != 0;
        let nent = r.u32()? as usize;
        let mut entries = Vec::with_capacity(nent);
        for _ in 0..nent {
            entries.push(AbEntry { a: r.i64()?, b_lo: r.i64()?, b_hi: r.i64()? });
        }
        regions.push(RegionSpace { r: rr, k, entries, linear_ok });
    }
    if r.pos != buf.len() {
        return Err("trailing bytes in cache file".into());
    }
    // Cache hits come back fully materialized (analyses are recomputable
    // and deliberately not stored); every lazy-view query answers from
    // the pre-filled cells.
    Ok(DesignSpace::from_materialized(
        func,
        accuracy,
        in_bits,
        out_bits,
        lookup_bits,
        k,
        regions,
        Vec::new(),
        dd_evals,
    ))
}

/// Canonical cache path for a workload at specific generation options.
/// Every result-affecting [`GenOptions`] field is part of the key:
/// `lookup_bits` shapes the space, `search` changes the stored `dd_evals`
/// instrumentation, and `max_k` bounds which spaces exist at all.
/// `threads` is deliberately excluded — worker count never changes the
/// result (`designspace::tests::threads_do_not_change_result`).
pub fn cache_path(dir: &Path, func: &str, acc: &str, in_bits: u32, opts: &GenOptions) -> PathBuf {
    let strategy = match opts.search {
        SearchStrategy::Naive => "naive",
        SearchStrategy::Pruned => "pruned",
        SearchStrategy::Hull => "hull",
    };
    dir.join(format!(
        "{func}_{acc}_{in_bits}b_R{}_{strategy}_k{}.pgds",
        opts.lookup_bits, opts.max_k
    ))
}

/// Save atomically (write a per-process temp file, then rename): batch
/// workers share one cache directory, and a reader must never observe a
/// half-written `.pgds`.
pub fn save(ds: &DesignSpace, path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    static SAVE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = SAVE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".tmp{}.{seq}", std::process::id()));
    let tmp = PathBuf::from(tmp);
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&to_bytes(ds))?;
    }
    std::fs::rename(&tmp, path)
}

pub fn load(path: &Path) -> Result<DesignSpace, String> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| format!("{}: {e}", path.display()))?
        .read_to_end(&mut buf)
        .map_err(|e| e.to_string())?;
    from_bytes(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};

    #[test]
    fn roundtrip_preserves_everything_needed() {
        let f = builtin("log2", 10).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: 5, ..Default::default() }).unwrap();
        let back = from_bytes(&to_bytes(&ds)).unwrap();
        assert_eq!(back.func, ds.func);
        assert_eq!(back.k, ds.k);
        assert_eq!(back.lookup_bits, ds.lookup_bits);
        assert_eq!(back.num_regions(), ds.num_regions());
        for (a, b) in ds.region_views().zip(back.region_views()) {
            assert_eq!(a.entries(), b.entries());
            assert_eq!(a.linear_ok(), b.linear_ok());
        }
        // A cached space must drive the DSE identically.
        let im1 = crate::dse::explore(&bt, &ds, &Default::default()).unwrap();
        let im2 = crate::dse::explore(&bt, &back, &Default::default()).unwrap();
        assert_eq!(im1.coeffs, im2.coeffs);
    }

    #[test]
    fn cache_key_covers_all_gen_options() {
        // Regression: the key once hashed only `lookup_bits`, so switching
        // strategy (or `max_k`) could return a stale space with the other
        // option's instrumentation.
        let dir = Path::new("/tmp/pgds");
        let base = GenOptions { lookup_bits: 5, ..Default::default() };
        let naive = GenOptions { search: SearchStrategy::Naive, ..base };
        let low_k = GenOptions { max_k: 12, ..base };
        let threaded = GenOptions { threads: 8, ..base };
        let p = |o: &GenOptions| cache_path(dir, "recip", "1ulp", 10, o);
        assert_ne!(p(&base), p(&naive), "search strategy must be in the key");
        assert_ne!(p(&base), p(&low_k), "max_k must be in the key");
        assert_ne!(p(&naive), p(&low_k));
        assert_eq!(p(&base), p(&threaded), "threads never changes the result");
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_bytes(b"nope").is_err());
        assert!(from_bytes(b"PGDS\x09\x00\x00\x00").is_err());
        let f = builtin("exp2", 8).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: 4, ..Default::default() }).unwrap();
        let mut bytes = to_bytes(&ds);
        bytes.push(0); // trailing byte
        assert!(from_bytes(&bytes).is_err());
    }
}
