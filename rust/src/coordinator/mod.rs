//! Workload orchestration: prepared workloads, cached generation, and
//! multi-`R` sweeps across worker threads.
//!
//! This is the "coordinator" layer of the three-layer architecture: it
//! owns job configuration ([`config`]), persistent design-space caching
//! ([`cache`]), and the parallel sweeps (the paper's "parallelism" item)
//! that the report generators and the CLI drive.

pub mod cache;
pub mod config;

use std::path::Path;
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicUsize, Ordering};

use crate::bounds::{builtin, AccuracySpec, BoundTable, TargetFunction};
use crate::designspace::{generate_ticks, DesignSpace, GenError, GenOptions};
use crate::pool::{CancelToken, Progress};
use crate::dse::{explore_ctrl, DseOptions, Implementation};
use crate::synth::{synth_min_delay_with, SynthPoint};

/// A prepared workload: the function and its bound table.
pub struct Workload {
    pub func: Box<dyn TargetFunction>,
    pub bt: BoundTable,
    pub accuracy: AccuracySpec,
}

impl Workload {
    /// Prepare a built-in function at the paper's precision conventions.
    pub fn prepare(name: &str, bits: u32, acc: AccuracySpec) -> Option<Workload> {
        let func = builtin(name, bits)?;
        let bt = BoundTable::build(func.as_ref(), acc);
        Some(Workload { func, bt, accuracy: acc })
    }
}

/// One point of a lookup-bit sweep.
pub struct SweepPoint {
    pub lookup_bits: u32,
    /// Generation wall-clock.
    pub gen_time: Duration,
    /// Generation outcome.
    pub space: Result<DesignSpace, GenError>,
    /// DSE result (when generation succeeded).
    pub implementation: Option<Implementation>,
    /// Min-delay synthesis point (when DSE succeeded).
    pub synth: Option<SynthPoint>,
}

impl SweepPoint {
    pub fn area_delay(&self) -> Option<f64> {
        self.synth.map(|p| p.area_delay())
    }
}

/// Generate + explore + cost one `R` value.
pub fn run_point(w: &Workload, r: u32, gen: &GenOptions, dse: &DseOptions) -> SweepPoint {
    run_point_cached(w, r, gen, dse, None)
}

/// [`run_point`] with an optional design-space disk cache.
pub fn run_point_cached(
    w: &Workload,
    r: u32,
    gen: &GenOptions,
    dse: &DseOptions,
    cache: Option<&Path>,
) -> SweepPoint {
    run_point_inner(w, r, gen, dse, cache, None, None)
}

/// One sweep point with an optional cancel token threaded into its
/// generation (the token is checked between region sweeps); a point
/// cancelled mid-generation records `Err(GenError::Cancelled)` as its
/// space and skips exploration.
fn run_point_inner(
    w: &Workload,
    r: u32,
    gen: &GenOptions,
    dse: &DseOptions,
    cache: Option<&Path>,
    cancel: Option<&CancelToken>,
    sub: Option<&Progress>,
) -> SweepPoint {
    let opts = GenOptions { lookup_bits: r, ..*gen };
    let t0 = Instant::now();
    let space = match cache {
        Some(dir) => generate_cached_ctrl(w, r, &opts, dir, cancel, sub),
        None => generate_ticks(&w.bt, &opts, cancel, sub),
    };
    let gen_time = t0.elapsed();
    // A cancel that lands between generation and exploration also stops
    // the point: exploration re-sweeps regions, which can dwarf the
    // analysis phases on small-R points.
    let space = match space {
        Ok(_) if cancel.is_some_and(|c| c.is_cancelled()) => Err(GenError::Cancelled),
        other => other,
    };
    let implementation =
        space.as_ref().ok().and_then(|ds| explore_ctrl(&w.bt, ds, dse, cancel));
    // Cost under the technology the exploration targeted, so sweeps and
    // auto-LUB selection optimize the same model the procedure used.
    let cm = dse.tech.technology().cost_model();
    let synth = implementation.as_ref().map(|im| synth_min_delay_with(cm, im));
    SweepPoint { lookup_bits: r, gen_time, space, implementation, synth }
}

/// Sweep `R` across `r_values`, distributing points over `threads`
/// workers (each point runs single-threaded generation).
pub fn sweep_lub(
    w: &Workload,
    r_values: &[u32],
    gen: &GenOptions,
    dse: &DseOptions,
    threads: usize,
) -> Vec<SweepPoint> {
    sweep_lub_cached(w, r_values, gen, dse, threads, None)
}

/// [`sweep_lub`] with an optional shared disk cache: hit points parse a
/// `.pgds` file instead of regenerating (their `gen_time` then measures
/// the parse — much smaller, as a cached sweep should report).
///
/// Points are scheduled on the process-wide pool ([`crate::pool`]):
/// point cost falls steeply with `R` (low-`R` regions are exponentially
/// larger), so workers steal points from a shared cursor instead of the
/// static chunks an earlier revision used — and when this sweep runs
/// inside a batch, idle batch workers are donated to it automatically.
pub fn sweep_lub_cached(
    w: &Workload,
    r_values: &[u32],
    gen: &GenOptions,
    dse: &DseOptions,
    threads: usize,
    cache: Option<&Path>,
) -> Vec<SweepPoint> {
    crate::pool::run_indexed(r_values.len(), threads, |i| {
        run_point_cached(w, r_values[i], gen, dse, cache)
    })
}

/// [`sweep_lub_cached`] with cooperative cancellation and two-level
/// progress — the sweep [`crate::service`] jobs run. The token is
/// checked before each point *and* between each point's region sweeps;
/// a cancelled point carries `Err(GenError::Cancelled)` as its space.
/// `progress` counts completed points. `sub` counts analyzed regions
/// summed across the whole sweep (one window of `Σ 2^R`, opened here
/// once): concurrent points only ever *add* to it, so — unlike the
/// per-generation reset-style counter — interleaving stays monotone,
/// and the long first points of a 16-bit sweep are visibly advancing.
#[allow(clippy::too_many_arguments)]
pub fn sweep_lub_ctrl(
    w: &Workload,
    r_values: &[u32],
    gen: &GenOptions,
    dse: &DseOptions,
    threads: usize,
    cache: Option<&Path>,
    cancel: &CancelToken,
    progress: Option<&Progress>,
    sub: Option<&Progress>,
) -> Vec<SweepPoint> {
    if let Some(p) = progress {
        p.begin(r_values.len());
    }
    if let Some(s) = sub {
        s.begin(r_values.iter().map(|&r| 1usize << r).sum());
    }
    crate::pool::run_indexed(r_values.len(), threads, |i| {
        if cancel.is_cancelled() {
            return SweepPoint {
                lookup_bits: r_values[i],
                gen_time: Duration::ZERO,
                space: Err(GenError::Cancelled),
                implementation: None,
                synth: None,
            };
        }
        let point = run_point_inner(w, r_values[i], gen, dse, cache, Some(cancel), sub);
        if let Some(p) = progress {
            p.tick();
        }
        point
    })
}

/// The best point of a sweep by area-delay product (the paper's Table I
/// LUB selection rule).
pub fn best_by_adp(points: &[SweepPoint]) -> Option<&SweepPoint> {
    best_by_objective(points, LubObjective::AreaDelay)
}

/// Objective for automatic lookup-bit selection.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LubObjective {
    Area,
    Delay,
    AreaDelay,
}

/// A point's cost under an objective; `None` for unsynthesized points and
/// for non-finite cost-model outputs (a NaN/inf point must never win —
/// or panic — a selection).
fn objective_key(p: &SweepPoint, objective: LubObjective) -> Option<f64> {
    p.synth
        .filter(|sp| sp.delay_ns.is_finite() && sp.area_um2.is_finite())
        .map(|sp| match objective {
            LubObjective::Area => sp.area_um2,
            LubObjective::Delay => sp.delay_ns,
            LubObjective::AreaDelay => sp.area_delay(),
        })
}

/// The sweep point minimizing `objective`, NaN-safe (`f64::total_cmp`,
/// with non-finite keys excluded up front).
pub fn best_by_objective(points: &[SweepPoint], objective: LubObjective) -> Option<&SweepPoint> {
    points
        .iter()
        .filter_map(|p| objective_key(p, objective).map(|k| (p, k)))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(p, _)| p)
}

/// The paper's stated future work — "a decision procedure to choose the
/// optimal number of lookup bits" — realized: sweep the default `R` range
/// and select by the requested hardware objective. Returns the chosen
/// point (with its implementation) or `None` if nothing is feasible.
pub fn auto_lub(
    w: &Workload,
    objective: LubObjective,
    gen: &GenOptions,
    dse: &DseOptions,
    threads: usize,
) -> Option<SweepPoint> {
    let mut pts = sweep_lub(w, &default_r_range(w.bt.in_bits), gen, dse, threads);
    let best = pts
        .iter()
        .enumerate()
        .filter_map(|(i, p)| objective_key(p, objective).map(|k| (i, k)))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(i, _)| i)?;
    Some(pts.swap_remove(best))
}

/// Generate with a disk cache under `dir` (hit = parse + return). The
/// cache key covers every result-affecting [`GenOptions`] field, so
/// switching options never returns a stale space.
pub fn generate_cached(
    w: &Workload,
    r: u32,
    gen: &GenOptions,
    dir: &Path,
) -> Result<DesignSpace, GenError> {
    generate_cached_ctrl(w, r, gen, dir, None, None)
}

/// [`generate_cached`] with cooperative cancellation/progress threaded
/// into the miss path (both the analysis phases and the pre-save
/// materialization sweep — the dominant cost at 16+ bits — honor the
/// token). Cache hits are a parse and never cancel. `ticks` advances
/// against a window the **caller** opened (never re-opened here, so one
/// window can span several generations): a miss ticks per analyzed
/// region, a hit credits all `2^R` regions at once.
pub fn generate_cached_ctrl(
    w: &Workload,
    r: u32,
    gen: &GenOptions,
    dir: &Path,
    cancel: Option<&CancelToken>,
    ticks: Option<&Progress>,
) -> Result<DesignSpace, GenError> {
    generate_cached_rec(w, r, gen, dir, cancel, ticks, None)
}

/// [`generate_cached_ctrl`] with an optional recovery counter: a
/// quarantined `.pgds` (integrity-check failure, renamed aside and
/// regenerated over) bumps it, so a service job can report how many
/// recoveries it absorbed next to its `degraded` flag
/// ([`crate::pipeline::JobCtrl::recovered`]).
pub(crate) fn generate_cached_rec(
    w: &Workload,
    r: u32,
    gen: &GenOptions,
    dir: &Path,
    cancel: Option<&CancelToken>,
    ticks: Option<&Progress>,
    recovered: Option<&AtomicUsize>,
) -> Result<DesignSpace, GenError> {
    let opts = GenOptions { lookup_bits: r, ..*gen };
    let path = cache::cache_path(dir, &w.bt.func, &w.bt.accuracy, w.bt.in_bits, &opts);
    match cache::load_checked(&path) {
        cache::CacheLoad::Hit(ds)
            if ds.in_bits == w.bt.in_bits && ds.out_bits == w.bt.out_bits =>
        {
            if let Some(p) = ticks {
                p.add(1usize << r);
            }
            return Ok(ds);
        }
        cache::CacheLoad::Quarantined(_) => {
            if let Some(n) = recovered {
                n.fetch_add(1, Ordering::Relaxed);
            }
        }
        // A dimension-mismatched hit, a plain miss, or a stale version:
        // regenerate (the save below overwrites the entry).
        _ => {}
    }
    let ds = generate_ticks(&w.bt, &opts, cancel, ticks)?;
    // The `.pgds` format stores the full dictionaries, so a miss pays
    // materialization here either way — do it through the scheduler
    // (parallel phase 3) rather than letting `cache::save`'s serializer
    // sweep every region sequentially.
    if !ds.materialize_ctrl(opts.threads, cancel) {
        return Err(GenError::Cancelled);
    }
    let _ = cache::save(&ds, &path); // best-effort
    Ok(ds)
}

/// Default `R` sweep range for a precision: keep regions at most 2^10
/// points (generation stays interactive) and at least 4 points.
pub fn default_r_range(in_bits: u32) -> Vec<u32> {
    let lo = in_bits.saturating_sub(10).max(2);
    let hi = in_bits.saturating_sub(2).min(11);
    (lo..=hi).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_parallel_equals_serial() {
        let w = Workload::prepare("recip", 10, AccuracySpec::Ulp(1)).unwrap();
        let rs = [4u32, 5, 6, 7];
        let gen = GenOptions::default();
        let dse = DseOptions::default();
        let a = sweep_lub(&w, &rs, &gen, &dse, 1);
        let b = sweep_lub(&w, &rs, &gen, &dse, 4);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.lookup_bits, y.lookup_bits);
            assert_eq!(x.space.is_ok(), y.space.is_ok());
            match (&x.implementation, &y.implementation) {
                (Some(ix), Some(iy)) => assert_eq!(ix.coeffs, iy.coeffs),
                (None, None) => {}
                _ => panic!("parallel/serial divergence at R={}", x.lookup_bits),
            }
        }
    }

    #[test]
    fn best_by_adp_picks_minimum() {
        let w = Workload::prepare("log2", 10, AccuracySpec::Ulp(1)).unwrap();
        let pts = sweep_lub(
            &w,
            &default_r_range(10),
            &GenOptions::default(),
            &DseOptions::default(),
            2,
        );
        let best = best_by_adp(&pts).expect("some R must work");
        for p in &pts {
            if let Some(adp) = p.area_delay() {
                assert!(best.area_delay().unwrap() <= adp + 1e-12);
            }
        }
    }

    #[test]
    fn auto_lub_objectives_pick_feasible_optima() {
        let w = Workload::prepare("log2", 10, AccuracySpec::Ulp(1)).unwrap();
        let gen = GenOptions::default();
        let dse = DseOptions::default();
        let area = auto_lub(&w, LubObjective::Area, &gen, &dse, 2).unwrap();
        let delay = auto_lub(&w, LubObjective::Delay, &gen, &dse, 2).unwrap();
        let adp = auto_lub(&w, LubObjective::AreaDelay, &gen, &dse, 2).unwrap();
        // Each winner must be at least as good as the others on its own
        // metric.
        assert!(area.synth.unwrap().area_um2 <= adp.synth.unwrap().area_um2 + 1e-9);
        assert!(delay.synth.unwrap().delay_ns <= area.synth.unwrap().delay_ns + 1e-9);
        // And the implementations verify (spot).
        for p in [&area, &delay, &adp] {
            let im = p.implementation.as_ref().unwrap();
            for z in (0..(1u64 << 10)).step_by(17) {
                let y = im.eval(z);
                assert!(y >= w.bt.l[z as usize] as i64 && y <= w.bt.u[z as usize] as i64);
            }
        }
    }

    fn synthetic_point(r: u32, synth: Option<SynthPoint>) -> SweepPoint {
        SweepPoint {
            lookup_bits: r,
            gen_time: Duration::ZERO,
            space: Err(GenError::InfeasibleRegion { r: 0 }),
            implementation: None,
            synth,
        }
    }

    /// Regression: selection once used `partial_cmp(..).unwrap()`, which
    /// panics the moment a cost model emits NaN. A NaN point must be
    /// skipped, not crowned or fatal.
    #[test]
    fn best_by_adp_survives_nan_and_none_points() {
        let pts = vec![
            synthetic_point(4, None),
            synthetic_point(5, Some(SynthPoint { delay_ns: f64::NAN, area_um2: 1.0 })),
            synthetic_point(6, Some(SynthPoint { delay_ns: 2.0, area_um2: 3.0 })),
            synthetic_point(7, Some(SynthPoint { delay_ns: 1.0, area_um2: 100.0 })),
        ];
        let best = best_by_adp(&pts).expect("a finite point exists");
        assert_eq!(best.lookup_bits, 6);
        for obj in [LubObjective::Area, LubObjective::Delay, LubObjective::AreaDelay] {
            let b = best_by_objective(&pts, obj).unwrap();
            assert!(b.area_delay().unwrap().is_finite(), "{obj:?} picked a NaN point");
        }
        // All-NaN and all-None sweeps select nothing instead of panicking.
        let nan_only =
            vec![synthetic_point(4, Some(SynthPoint { delay_ns: f64::NAN, area_um2: f64::NAN }))];
        assert!(best_by_adp(&nan_only).is_none());
        assert!(best_by_adp(&[synthetic_point(4, None)]).is_none());
    }

    /// Regression: the disk cache once keyed only on `lookup_bits`, so
    /// switching the search strategy returned the other strategy's stale
    /// space (visible through `dd_evals`).
    #[test]
    fn generate_cached_distinguishes_gen_options() {
        use crate::designspace::extrema::SearchStrategy;
        let w = Workload::prepare("recip", 8, AccuracySpec::Ulp(1)).unwrap();
        let dir = std::env::temp_dir().join("polygen_cache_opts_test");
        let _ = std::fs::remove_dir_all(&dir);
        let naive = GenOptions { search: SearchStrategy::Naive, ..Default::default() };
        let pruned = GenOptions { search: SearchStrategy::Pruned, ..Default::default() };
        let a = generate_cached(&w, 4, &naive, &dir).unwrap();
        let b = generate_cached(&w, 4, &pruned, &dir).unwrap();
        assert!(
            b.dd_evals < a.dd_evals,
            "pruned run served the cached naive space: {} vs {}",
            b.dd_evals,
            a.dd_evals
        );
        // And each variant now hits its own cache entry.
        assert_eq!(generate_cached(&w, 4, &naive, &dir).unwrap().dd_evals, a.dd_evals);
        assert_eq!(generate_cached(&w, 4, &pruned, &dir).unwrap().dd_evals, b.dd_evals);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cache_roundtrip_through_generate_cached() {
        let w = Workload::prepare("exp2", 8, AccuracySpec::Ulp(1)).unwrap();
        let dir = std::env::temp_dir().join("polygen_cache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let gen = GenOptions::default();
        let a = generate_cached(&w, 4, &gen, &dir).unwrap();
        let b = generate_cached(&w, 4, &gen, &dir).unwrap(); // cache hit
        assert_eq!(a.k, b.k);
        for (x, y) in a.region_views().zip(b.region_views()) {
            assert_eq!(x.entries(), y.entries());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
