//! The crate-wide synchronization shim (DESIGN.md §Static analysis).
//!
//! Every concurrency primitive the scheduler and service protocols use
//! is imported from here, never from `std::sync` directly — that is the
//! rule `cargo xtask lint` (the `sync-imports` pass) enforces. Normally
//! the re-exports below *are* `std::sync`, so this module costs
//! nothing; under `--cfg loom` they swap to [`loom`]'s model-checked
//! mirrors, and the `tests/loom` suite explores every interleaving of
//! the real locking protocol — the same source lines that ship, not a
//! hand-written model.
//!
//! Run the models locally with
//! `RUSTFLAGS="--cfg loom" cargo test --release --test loom`.
//!
//! Two rules keep the swap sound:
//!
//! - **No `std::sync` primitives outside this module.** A single raw
//!   `Mutex` in a modeled protocol is invisible to loom's exploration,
//!   which silently un-checks the model. Const-initialized `static`s in
//!   never-modeled code are the one sanctioned exception (loom's
//!   constructors are not `const`); they carry a
//!   `// lint: sync-ok(reason)` waiver.
//! - **No `.unwrap()` on lock results.** Lock poisoning is a byproduct
//!   of a task panic, which the scheduler and service already catch and
//!   forward; unwrapping the poison would turn one recovered panic into
//!   a cascade. Use [`plock`] / [`cwait`], which recover the guard.
//!
//! `Arc`, `mpsc`, and `std::thread` are not primitives the lint bans —
//! but modeled protocols still take `Arc` and thread spawns from here so
//! loom can track clone counts and joins.

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, RwLock};

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};

/// Lock `m`, recovering the guard from a poisoned lock. Poisoning here
/// only ever means "a task panicked while holding the guard"; both the
/// scheduler and the service catch that panic and forward it to the
/// submitter, so the shared state a survivor observes is already
/// consistent — propagating the poison would fail healthy threads for
/// a failure that was handled.
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`Condvar::wait`] with the same poison recovery as [`plock`].
pub fn cwait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Thread spawning for modeled protocols: loom's scheduler must own
/// every thread a model creates, so modeled code spawns through here.
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::JoinHandle;

    #[cfg(loom)]
    pub use loom::thread::JoinHandle;

    /// Spawn a named thread; `None` = resource exhaustion (the callers
    /// all degrade — fewer pool workers, inline execution — rather than
    /// propagate). Loom has no named builder and cannot fail to spawn.
    #[cfg(not(loom))]
    pub fn spawn_named<F>(name: String, f: F) -> Option<JoinHandle<()>>
    where
        F: FnOnce() + Send + 'static,
    {
        std::thread::Builder::new().name(name).spawn(f).ok()
    }

    #[cfg(loom)]
    pub fn spawn_named<F>(_name: String, f: F) -> Option<JoinHandle<()>>
    where
        F: FnOnce() + Send + 'static,
    {
        Some(loom::thread::spawn(f))
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn plock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let clone = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = clone.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "the mutex must actually be poisoned");
        assert_eq!(*plock(&m), 7, "plock must hand back the guard anyway");
        *plock(&m) = 8;
        assert_eq!(*plock(&m), 8);
    }

    #[test]
    fn cwait_wakes_like_condvar_wait() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let clone = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*clone;
            *plock(m) = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut done = plock(m);
        while !*done {
            done = cwait(cv, done);
        }
        t.join().unwrap();
    }
}
