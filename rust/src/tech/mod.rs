//! Pluggable technology targets — the paper's closing claim made into an
//! API.
//!
//! The paper ends on: *"Targeting alternative hardware technologies
//! simply requires a modified decision procedure to explore the space."*
//! This module is that claim as a contract. Two traits carve the
//! technology axis out of the exploration/costing layer:
//!
//! - [`CostModel`] — the area/delay primitives (coefficient LUT,
//!   multiplier, squarer, multi-operand accumulate) plus the
//!   technology's unit system and delay-target sizing behaviour. The
//!   whole-datapath composition lives in [`crate::synth::model`]
//!   (`breakdown_with`, `synth_at_with`, ...), parameterized over this
//!   trait.
//! - [`Technology`] — bundles a cost model with the technology's default
//!   decision-procedure ordering
//!   ([`DecisionProcedure`](crate::dse::procedure::DecisionProcedure))
//!   and its default lookup-bit selection objective. Adding a backend =
//!   implementing these two traits; nothing else in the system changes.
//!
//! Three technologies ship ([`TechKind`] names them for configs/CLI):
//!
//! | kind | cost model | default procedure |
//! |---|---|---|
//! | `asic-ge` | the calibrated TSMC-7nm-like gate model ([`crate::synth::components`]) | the paper's SquareFirst ordering (bit-identical to the pre-trait selections) |
//! | `fpga-lut6` | LUT6/carry-chain costs (soft multipliers dominate, short tables are nearly free) | cost-guided Pareto ([`crate::dse::procedure::ParetoCost`]) |
//! | `low-power` | activity-weighted gates ("area" = switched capacitance) | cost-guided Pareto |
//!
//! The trio demonstrably disagrees: on bundled examples the FPGA model
//! trades square-input truncation for narrower `b` coefficients (narrow
//! soft multipliers beat shallow tables), selecting a different
//! implementation than `asic-ge` from the *same* complete design space —
//! see `report tech` and `examples/tech_compare.rs`.

mod asic;
mod fpga;
mod lowpower;

pub use asic::AsicGe;
pub use fpga::FpgaLut6;
pub use lowpower::LowPower;

use crate::coordinator::LubObjective;
use crate::dse::procedure::DecisionProcedure;
use crate::synth::components::Cost;

/// Area/delay primitives of one hardware technology.
///
/// Areas and delays are in *technology units* (gate equivalents and FO4
/// delays for `asic-ge`, LUT6s and logic levels for `fpga-lut6`, switched
/// capacitance for `low-power`); [`CostModel::delay_unit_ns`] and
/// [`CostModel::area_unit_um2`] convert to report units. Within one
/// technology the units are consistent, so Pareto comparisons and the
/// area-delay objectives need no conversion.
pub trait CostModel: Sync {
    /// Technology identifier for reports.
    fn name(&self) -> &'static str;
    /// The coefficient table: `2^r_bits` words of `width` bits.
    fn lut(&self, r_bits: u32, width: u32) -> Cost;
    /// Dedicated squarer of input width `w`.
    fn squarer(&self, w: u32) -> Cost;
    /// Signed multiplier `w1 x w2`.
    fn multiplier(&self, w1: u32, w2: u32) -> Cost;
    /// Carry-save reduction of `n` operands of width `w` plus final CPA.
    fn multi_operand_add(&self, n: u32, w: u32) -> Cost;
    /// Nanoseconds per delay unit.
    fn delay_unit_ns(&self) -> f64;
    /// µm²-equivalents per area unit (1.0 = report areas in native units).
    fn area_unit_um2(&self) -> f64;
    /// Human-readable area unit for report tables.
    fn area_unit(&self) -> &'static str;
    /// Multiplier on summed component area (wiring/misc overhead).
    fn wiring_overhead(&self) -> f64 {
        1.10
    }
    /// Area multiplier for synthesizing at delay target `d_target_ns`
    /// when the minimum obtainable delay is `d_min_ns` (gate upsizing on
    /// ASIC, near-flat retiming cost on FPGA).
    fn sizing_multiplier(&self, d_min_ns: f64, d_target_ns: f64) -> f64;
}

/// A hardware technology: a cost model plus the decision-procedure
/// ordering and selection objective tuned to it.
pub trait Technology: Sync {
    /// Identifier used by configs, the CLI and reports.
    fn name(&self) -> &'static str;
    /// The technology's area/delay primitives.
    fn cost_model(&self) -> &dyn CostModel;
    /// The decision procedure this technology explores the space with
    /// when the user does not force one (`dse.procedure = auto`).
    fn default_procedure(&self) -> Box<dyn DecisionProcedure>;
    /// The lookup-bit sweep objective this technology optimizes by
    /// default. Consumed by the CLI's `--lub auto` when no
    /// `--objective` is given and by job files whose
    /// `lookup_bits = auto` names no explicit objective; the
    /// library-level
    /// [`LookupBits::Auto`](crate::pipeline::LookupBits) always
    /// carries the resolved objective.
    fn default_objective(&self) -> LubObjective {
        LubObjective::AreaDelay
    }
}

/// The shipped technologies, as a serializable name (configs, `--tech`).
/// Custom [`Technology`] impls bypass this enum via
/// [`crate::dse::explore_with`] and
/// [`crate::synth::synth_min_delay_with`].
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum TechKind {
    /// The calibrated gate-equivalent ASIC model (the original target).
    #[default]
    AsicGe,
    /// LUT6/carry-chain FPGA fabric.
    FpgaLut6,
    /// Activity-weighted low-power ASIC.
    LowPower,
}

static ASIC_GE: AsicGe = AsicGe;
static FPGA_LUT6: FpgaLut6 = FpgaLut6;
static LOW_POWER: LowPower = LowPower;

impl TechKind {
    pub const ALL: [TechKind; 3] = [TechKind::AsicGe, TechKind::FpgaLut6, TechKind::LowPower];

    /// The technology singleton behind this kind.
    pub fn technology(self) -> &'static dyn Technology {
        match self {
            TechKind::AsicGe => &ASIC_GE,
            TechKind::FpgaLut6 => &FPGA_LUT6,
            TechKind::LowPower => &LOW_POWER,
        }
    }

    /// Config/CLI label (`asic-ge`, `fpga-lut6`, `low-power`).
    pub fn label(self) -> &'static str {
        match self {
            TechKind::AsicGe => "asic-ge",
            TechKind::FpgaLut6 => "fpga-lut6",
            TechKind::LowPower => "low-power",
        }
    }

    /// Parse a config/CLI label; underscores are accepted for dashes.
    pub fn parse(s: &str) -> Option<TechKind> {
        match s.replace('_', "-").as_str() {
            "asic-ge" | "asic" => Some(TechKind::AsicGe),
            "fpga-lut6" | "fpga" => Some(TechKind::FpgaLut6),
            "low-power" | "lowpower" => Some(TechKind::LowPower),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for t in TechKind::ALL {
            assert_eq!(TechKind::parse(t.label()), Some(t));
            assert_eq!(t.technology().name(), t.label());
            assert_eq!(t.technology().cost_model().name(), t.label());
        }
        assert_eq!(TechKind::parse("fpga_lut6"), Some(TechKind::FpgaLut6));
        assert_eq!(TechKind::parse("asic"), Some(TechKind::AsicGe));
        assert_eq!(TechKind::parse("tpu"), None);
    }

    #[test]
    fn cost_models_are_monotone_in_width() {
        for t in TechKind::ALL {
            let cm = t.technology().cost_model();
            for w in 2..24u32 {
                assert!(
                    cm.multiplier(w + 1, w).area_ge > cm.multiplier(w, w - 1).area_ge,
                    "{}: multiplier not monotone at {w}",
                    cm.name()
                );
                assert!(cm.squarer(w + 1).area_ge > cm.squarer(w).area_ge);
                assert!(cm.lut(6, w + 1).area_ge > cm.lut(6, w).area_ge);
            }
            assert!(cm.delay_unit_ns() > 0.0);
            assert!(cm.area_unit_um2() > 0.0);
        }
    }

    #[test]
    fn fpga_tables_are_cheap_multipliers_expensive() {
        // The divergence driver: relative to a 12x12 soft multiplier, a
        // 64-entry table is far cheaper on the FPGA model than the gate
        // model — so the FPGA procedure should spend table bits to buy
        // narrower multipliers.
        let asic = TechKind::AsicGe.technology().cost_model();
        let fpga = TechKind::FpgaLut6.technology().cost_model();
        let ratio =
            |cm: &dyn CostModel| cm.lut(6, 20).area_ge / cm.multiplier(12, 12).area_ge;
        assert!(
            ratio(fpga) < 0.5 * ratio(asic),
            "FPGA table/multiplier cost ratio should be far below ASIC: {} vs {}",
            ratio(fpga),
            ratio(asic)
        );
    }

    #[test]
    fn sizing_curves_behave() {
        for t in TechKind::ALL {
            let cm = t.technology().cost_model();
            let relaxed = cm.sizing_multiplier(0.2, 0.4);
            let tight = cm.sizing_multiplier(0.2, 0.2);
            assert!(relaxed >= 1.0 && tight >= relaxed);
        }
    }
}
