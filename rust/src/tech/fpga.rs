//! LUT6/carry-chain FPGA technology.
//!
//! Cost structure differs from the gate model in exactly the way that
//! flips the decision procedure's preferences: a coefficient table of
//! `R <= 6` lookup bits costs about one LUT6 *per stored bit* (a 6-input
//! LUT holds 64 entries natively), while multipliers are soft —
//! partial-product LUTs plus carry chains, with area close to the full
//! `w1 * w2` bit product. Narrow multipliers therefore beat shallow
//! tables, and the cost-guided default procedure spends table width to
//! buy narrower `b` coefficients (see `report tech`: on `recip` 8-bit at
//! `R = 3` it selects `(i, widths)` the ASIC ordering rejects).
//!
//! Units: area in LUT6 equivalents, delay in logic levels
//! (~0.45 ns per level including routing).

use super::{CostModel, Technology};
use crate::dse::procedure::{DecisionProcedure, ParetoCost};
use crate::synth::components::Cost;

/// LUT6/carry-chain fabric model.
pub struct FpgaLut6;

fn log2f(v: u32) -> f64 {
    (v.max(2) as f64).log2()
}

/// Carry-chain ripple adder: ~w/2 LUT6s (two bits per LUT + chain), one
/// level plus the chain propagation.
fn cc_adder(w: u32) -> Cost {
    if w == 0 {
        return Cost::zero();
    }
    Cost { area_ge: 0.5 * w as f64, delay_fo4: 0.6 + 0.045 * w as f64 }
}

impl CostModel for FpgaLut6 {
    fn name(&self) -> &'static str {
        "fpga-lut6"
    }

    fn lut(&self, r_bits: u32, width: u32) -> Cost {
        if width == 0 || r_bits == 0 {
            return Cost::zero();
        }
        // One LUT6 per output bit per 64-entry block; F7/F8-style muxes
        // combine blocks above R = 6.
        let blocks = (1u64 << r_bits.saturating_sub(6)) as f64;
        let mux = 0.5 * width as f64 * (blocks - 1.0);
        Cost {
            area_ge: width as f64 * blocks + mux,
            delay_fo4: 1.0 + 0.5 * r_bits.saturating_sub(6) as f64 + 0.15 * log2f(width),
        }
    }

    fn squarer(&self, w: u32) -> Cost {
        if w == 0 {
            return Cost::zero();
        }
        // Folding + the constant operand halve the array twice over.
        let pp = 0.22 * w as f64 * w as f64;
        let ca = cc_adder(2 * w);
        Cost {
            area_ge: pp + w as f64 + ca.area_ge,
            delay_fo4: 1.0 + 0.8 * log2f(w) + ca.delay_fo4,
        }
    }

    fn multiplier(&self, w1: u32, w2: u32) -> Cost {
        if w1 == 0 || w2 == 0 {
            return Cost::zero();
        }
        // Soft multiplier: partial-product LUTs plus carry-chain
        // compressor rows — the dominant FPGA cost.
        let pp = 0.8 * w1 as f64 * w2 as f64;
        let ca = cc_adder(w1 + w2);
        Cost {
            area_ge: pp + 0.5 * (w1 + w2) as f64 + ca.area_ge,
            delay_fo4: 1.0 + 1.1 * log2f(w1) + ca.delay_fo4,
        }
    }

    fn multi_operand_add(&self, n: u32, w: u32) -> Cost {
        if n <= 1 {
            return Cost::zero();
        }
        // Ternary carry-chain adders absorb one extra operand per level.
        let ca = cc_adder(w);
        Cost {
            area_ge: n.saturating_sub(2) as f64 * 0.7 * w as f64 + ca.area_ge,
            delay_fo4: 0.8 * n.saturating_sub(2) as f64 + ca.delay_fo4,
        }
    }

    fn delay_unit_ns(&self) -> f64 {
        0.45 // one logic level + routing
    }

    fn area_unit_um2(&self) -> f64 {
        1.0 // report areas in native LUT6 units
    }

    fn area_unit(&self) -> &'static str {
        "LUT6"
    }

    fn wiring_overhead(&self) -> f64 {
        1.0 // routing is already in the per-level delay
    }

    fn sizing_multiplier(&self, d_min_ns: f64, d_target_ns: f64) -> f64 {
        // No continuous gate sizing on an FPGA: tightening the target
        // costs only mild retiming/duplication.
        assert!(d_target_ns > 0.0 && d_min_ns > 0.0);
        let e = (d_min_ns / d_target_ns).min(1.0);
        1.0 + 0.15 * e * e
    }
}

impl Technology for FpgaLut6 {
    fn name(&self) -> &'static str {
        "fpga-lut6"
    }

    fn cost_model(&self) -> &dyn CostModel {
        self
    }

    /// Fixed orderings encode the ASIC trade-off; the FPGA fabric needs
    /// the cost model itself to arbitrate tables against soft
    /// multipliers, so its default is the cost-guided Pareto procedure.
    fn default_procedure(&self) -> Box<dyn DecisionProcedure> {
        Box::new(ParetoCost::default())
    }
}
