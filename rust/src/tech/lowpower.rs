//! Activity-weighted low-power technology.
//!
//! Same structural models as [`AsicGe`](super::AsicGe) — the gates are
//! the gates — but "area" is weighted by per-component switching
//! activity, so it tracks switched capacitance (dynamic energy) rather
//! than silicon. Arithmetic arrays toggle on most cycles; the
//! coefficient table is quiet (one word changes per region switch). The
//! cost-guided default procedure therefore leans harder on truncation
//! (shrinking the toggling arrays) and tolerates wider, cold storage.

use super::{AsicGe, CostModel, Technology};
use crate::coordinator::LubObjective;
use crate::dse::procedure::{DecisionProcedure, ParetoCost};
use crate::synth::components::Cost;

/// Switching-activity weights relative to a free-running adder.
const ACT_LUT: f64 = 0.15;
const ACT_SQ: f64 = 0.50;
const ACT_MUL: f64 = 0.60;
const ACT_ADD: f64 = 0.35;

/// Activity-weighted gate model: areas are energy proxies, delays are
/// the [`AsicGe`] delays.
pub struct LowPower;

fn weigh(c: Cost, act: f64) -> Cost {
    Cost { area_ge: c.area_ge * act, delay_fo4: c.delay_fo4 }
}

impl CostModel for LowPower {
    fn name(&self) -> &'static str {
        "low-power"
    }

    fn lut(&self, r_bits: u32, width: u32) -> Cost {
        weigh(AsicGe.lut(r_bits, width), ACT_LUT)
    }

    fn squarer(&self, w: u32) -> Cost {
        weigh(AsicGe.squarer(w), ACT_SQ)
    }

    fn multiplier(&self, w1: u32, w2: u32) -> Cost {
        weigh(AsicGe.multiplier(w1, w2), ACT_MUL)
    }

    fn multi_operand_add(&self, n: u32, w: u32) -> Cost {
        weigh(AsicGe.multi_operand_add(n, w), ACT_ADD)
    }

    fn delay_unit_ns(&self) -> f64 {
        AsicGe.delay_unit_ns()
    }

    fn area_unit_um2(&self) -> f64 {
        AsicGe.area_unit_um2()
    }

    fn area_unit(&self) -> &'static str {
        "sw-um2" // switched-capacitance-weighted µm²
    }

    fn sizing_multiplier(&self, d_min_ns: f64, d_target_ns: f64) -> f64 {
        AsicGe.sizing_multiplier(d_min_ns, d_target_ns)
    }
}

impl Technology for LowPower {
    fn name(&self) -> &'static str {
        "low-power"
    }

    fn cost_model(&self) -> &dyn CostModel {
        self
    }

    fn default_procedure(&self) -> Box<dyn DecisionProcedure> {
        Box::new(ParetoCost::default())
    }

    /// Energy is the scarce resource: sweep lookup bits for minimum
    /// (activity-weighted) area rather than area-delay. Takes effect on
    /// `--tech low-power --lub auto` and on job files with
    /// `lookup_bits = auto` (an explicit `--objective` /
    /// `auto:<objective>` overrides).
    fn default_objective(&self) -> LubObjective {
        LubObjective::Area
    }
}
