//! The ASIC gate-equivalent technology: the original calibrated TSMC
//! 7 nm-like model, now behind the [`Technology`] traits.
//!
//! [`AsicGe`]'s cost model *is* [`crate::synth::components`] — every
//! method delegates to the free functions there — and its default
//! decision procedure is the paper's SquareFirst ordering, so exploring
//! and costing through the trait layer reproduces the pre-trait
//! selections bit-for-bit (pinned by `tests/procedure_golden.rs`).

use super::{CostModel, Technology};
use crate::dse::procedure::{DecisionProcedure, Lexicographic};
use crate::synth::components::{
    self, lut, multi_operand_add, multiplier, squarer, Cost, FO4_NS, GE_UM2,
};

/// Design Compiler / TSMC 7 nm substitute: areas in gate equivalents,
/// delays in FO4 units (DESIGN.md §3).
pub struct AsicGe;

impl CostModel for AsicGe {
    fn name(&self) -> &'static str {
        "asic-ge"
    }

    fn lut(&self, r_bits: u32, width: u32) -> Cost {
        lut(r_bits, width)
    }

    fn squarer(&self, w: u32) -> Cost {
        squarer(w)
    }

    fn multiplier(&self, w1: u32, w2: u32) -> Cost {
        multiplier(w1, w2)
    }

    fn multi_operand_add(&self, n: u32, w: u32) -> Cost {
        multi_operand_add(n, w)
    }

    fn delay_unit_ns(&self) -> f64 {
        FO4_NS
    }

    fn area_unit_um2(&self) -> f64 {
        GE_UM2
    }

    fn area_unit(&self) -> &'static str {
        "um2"
    }

    fn sizing_multiplier(&self, d_min_ns: f64, d_target_ns: f64) -> f64 {
        components::sizing_multiplier(d_min_ns, d_target_ns)
    }
}

impl Technology for AsicGe {
    fn name(&self) -> &'static str {
        "asic-ge"
    }

    fn cost_model(&self) -> &dyn CostModel {
        self
    }

    /// The paper's ASIC-tuned ordering: the square path is critical, so
    /// truncations are maximized before widths are minimized.
    fn default_procedure(&self) -> Box<dyn DecisionProcedure> {
        Box::new(Lexicographic::square_first())
    }
}
