//! The one public entry point: a typed, staged pipeline from a target
//! function to verified hardware.
//!
//! The paper's pitch is that the *complete* design space plus a modified
//! decision procedure is all you need to retarget new hardware
//! technologies. This module packages that claim as an API instead of a
//! pile of free functions: a [`Pipeline`] builder whose stages produce
//! inspectable artifacts —
//!
//! ```text
//! Pipeline ──prepare()──▶ Prepared ──generate()──▶ Spaced
//!     ──explore()──▶ Explored ──synthesize()──▶ Synthesized
//!     ──verify()──▶ Verified ──emit_rtl()──▶ RtlEmitted
//! ```
//!
//! — so callers can stop at any layer (inspect the [`DesignSpace`], grab
//! the [`Implementation`], cost it) or run end-to-end with
//! [`Pipeline::run`]. Every fallible stage returns
//! `Result<_, PipelineError>`: failures carry their cause (the offending
//! region, the exhausted sweep, the first counterexample input) instead
//! of a bare `None`.
//!
//! # End to end
//!
//! ```
//! use polygen::pipeline::Pipeline;
//!
//! let verified = Pipeline::function("recip")
//!     .bits(8)
//!     .lub(4)
//!     .run()
//!     .expect("recip 8-bit at R=4 is feasible");
//! assert!(verified.report.ok());
//! assert_eq!(verified.space.num_regions(), 16);
//! ```
//!
//! # Stop at any stage
//!
//! ```
//! use polygen::pipeline::Pipeline;
//!
//! let spaced = Pipeline::function("exp2")
//!     .bits(8)
//!     .lub(4)
//!     .prepare()
//!     .unwrap()
//!     .generate()
//!     .unwrap();
//! // The complete space is an artifact, not an intermediate.
//! assert!(spaced.space.num_ab_pairs() > 0);
//! let explored = spaced.explore().unwrap();
//! assert_eq!(explored.implementation.coeffs.len(), 16);
//! ```
//!
//! # Automatic lookup-bit selection
//!
//! The paper's stated future work — "a decision procedure to choose the
//! optimal number of lookup bits" — is a builder knob:
//!
//! ```no_run
//! use polygen::pipeline::{LookupBits, LubObjective, Pipeline};
//!
//! let v = Pipeline::function("log2")
//!     .bits(16)
//!     .lookup_bits(LookupBits::Auto(LubObjective::AreaDelay))
//!     .threads(8)
//!     .run()
//!     .unwrap();
//! println!("chose R = {}", v.implementation.lookup_bits);
//! ```
//!
//! # Batch execution
//!
//! Many jobs, worker threads, one shared disk cache — see [`JobSpec`] and
//! [`Batch`] in [`job`].

pub mod error;
pub mod job;

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use crate::sync::Arc;

use crate::coordinator::{
    best_by_objective, default_r_range, generate_cached_rec, sweep_lub_cached, sweep_lub_ctrl,
    Workload,
};
use crate::designspace::generate_ctrl;
use crate::obs::trace::Tracer;
use crate::pool::{CancelToken, Progress};
use crate::rtl;
use crate::verify::verify_exhaustive;

pub use error::PipelineError;
pub use job::{parse_accuracy, Batch, JobResult, JobSpec};

/// Gracefully drain the process-wide scheduler: blocks until every
/// outstanding generation/sweep/batch job has completed, leaving the
/// persistent workers parked and reusable. Call at pipeline shutdown
/// (the CLI does after each `batch` run) when you need the guarantee
/// that no scheduler work is still in flight — e.g. before tearing down
/// resources that in-flight jobs might touch.
pub fn shutdown() {
    crate::pool::global().drain();
}

// Re-exports: everything a pipeline caller needs, so `main.rs`, the
// examples and the benches compile against `polygen::pipeline` alone.
pub use crate::bounds::{
    builtin, AccuracySpec, BoundTable, CustomF64, Gelu, Sigmoid, Softplus, Tanh, TargetFunction,
};
pub use crate::coordinator::config::Config;
pub use crate::coordinator::{LubObjective, SweepPoint};
pub use crate::designspace::extrema::SearchStrategy;
pub use crate::designspace::{DesignSpace, GenError, GenOptions};
pub use crate::dse::procedure::{DecisionProcedure, Lexicographic, ParetoCost, Pass};
pub use crate::dse::{Degree, DseOptions, Implementation, Procedure};
pub use crate::rtl::{emit_golden_hex, emit_module, emit_testbench, DatapathSim};
pub use crate::runtime::{Flavor, XlaRuntime};
pub use crate::synth::{
    breakdown, breakdown_with, synth_at, synth_at_with, synth_min_delay_with, Breakdown,
    SynthPoint,
};
pub use crate::tech::{CostModel, TechKind, Technology};
pub use crate::verify::{verify_exhaustive as verify_implementation, Engine, VerifyReport};

/// Which pipeline stage a controlled run is currently in — the phase a
/// [`crate::service`] job reports from [`JobCtrl::phase`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Phase {
    Prepare,
    Generate,
    Explore,
    Synthesize,
    Verify,
}

impl Phase {
    /// Lowercase wire/report label (`"generate"`, ...).
    pub fn label(self) -> &'static str {
        match self {
            Phase::Prepare => "prepare",
            Phase::Generate => "generate",
            Phase::Explore => "explore",
            Phase::Synthesize => "synthesize",
            Phase::Verify => "verify",
        }
    }

    fn from_u8(v: u8) -> Phase {
        match v {
            1 => Phase::Generate,
            2 => Phase::Explore,
            3 => Phase::Synthesize,
            4 => Phase::Verify,
            _ => Phase::Prepare,
        }
    }
}

/// A pluggable generation backend for the fixed-`R` generate phase.
/// The one production implementation lives in `service::cluster`: it
/// shards the region range across registered workers. Returning `None`
/// means "not applicable here" (e.g. no live workers) and falls the
/// pipeline back to local generation; `Some(result)` is authoritative.
pub(crate) trait Generator: Send + Sync {
    fn generate(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
    ) -> Option<Result<DesignSpace, GenError>>;
}

/// [`Settings`]-storable wrapper for an optional [`Generator`]:
/// `Settings` derives `Clone + Debug`, and trait objects have neither.
#[derive(Clone, Default)]
pub(crate) struct GenHook(Option<Arc<dyn Generator>>);

impl std::fmt::Debug for GenHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.0.is_some() { "GenHook(installed)" } else { "GenHook(none)" })
    }
}

/// [`JobCtrl`]-storable wrapper for an optional span [`Tracer`], the
/// [`GenHook`] shape again: `JobCtrl` derives `Debug`, and the tracer's
/// internals are noise there.
#[derive(Clone, Default)]
pub(crate) struct TraceHook(Option<Arc<Tracer>>);

impl std::fmt::Debug for TraceHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.0.is_some() { "TraceHook(installed)" } else { "TraceHook(none)" })
    }
}

/// Shared control block for one controlled pipeline run: a cooperative
/// [`CancelToken`], a [`Progress`] counter, and the current [`Phase`].
///
/// Attach one with [`Pipeline::control`] (or run a [`JobSpec`] through
/// [`crate::service::Service`], which does it for you), keep a clone of
/// the `Arc`, and you can observe and cancel the run from any thread:
///
/// - **Cancellation points.** The token is checked at every phase
///   boundary, before each region's analysis sweep inside generation,
///   between the points of an auto-LUB sweep, and between the region
///   materialization sweeps of a cache-miss — so a cancel lands within
///   one region's worth of work per executor. A cancelled run returns
///   [`PipelineError::Cancelled`]; the process-wide scheduler fully
///   drains its tasks (cancellation is cooperative, never a kill), so
///   the pool stays reusable.
/// - **Progress.** During [`Phase::Generate`] the counter holds
///   `(regions analyzed, regions total)` for a fixed-`R` job and
///   `(sweep points done, points total)` for an auto-LUB job. Auto-LUB
///   jobs additionally expose a second level through [`JobCtrl::sub`]:
///   `(regions analyzed, regions total)` summed across the whole sweep,
///   so the long first points of a 16-bit sweep are visibly advancing.
#[derive(Debug, Default)]
pub struct JobCtrl {
    cancel: CancelToken,
    progress: Progress,
    sub: Progress,
    phase: AtomicU8,
    degraded: AtomicBool,
    recovered: AtomicUsize,
    trace: TraceHook,
}

impl JobCtrl {
    pub fn new() -> JobCtrl {
        JobCtrl::default()
    }

    /// Request cooperative cancellation (idempotent, never blocks).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// The phase the run last entered.
    pub fn phase(&self) -> Phase {
        Phase::from_u8(self.phase.load(Ordering::Relaxed))
    }

    /// `(done, total)` within the current phase's counted unit.
    pub fn progress(&self) -> (usize, usize) {
        self.progress.get()
    }

    /// Second-level `(done, total)` progress, when the run reports one:
    /// for an auto-LUB job's generate phase this counts regions analyzed
    /// across the whole sweep underneath the per-point top level. `None`
    /// until a phase opens a sub-window.
    pub fn sub(&self) -> Option<(usize, usize)> {
        let (done, total) = self.sub.get();
        if total == 0 {
            None
        } else {
            Some((done, total))
        }
    }

    /// The underlying token, for threading into lower layers.
    pub fn token(&self) -> &CancelToken {
        &self.cancel
    }

    /// True once any part of the run fell back from its intended
    /// distributed path to local compute (all cluster workers dead or
    /// quarantined). Sticky for the lifetime of the run; the service
    /// surfaces it in job status.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Mark the run degraded (idempotent).
    pub fn mark_degraded(&self) {
        self.degraded.store(true, Ordering::Relaxed);
    }

    /// The raw flag, for threading into the cluster driver.
    pub(crate) fn degraded_flag(&self) -> &AtomicBool {
        &self.degraded
    }

    /// Build a control block with span tracing enabled: every phase
    /// transition (and, on cluster runs, each shard's dispatch) records
    /// a span, exportable as Chrome `trace_events` JSON through
    /// [`crate::obs::trace`]. The default [`JobCtrl::new`] carries no
    /// tracer and records nothing.
    pub fn traced() -> JobCtrl {
        JobCtrl { trace: TraceHook(Some(Arc::new(Tracer::new()))), ..JobCtrl::default() }
    }

    /// The attached span tracer, when this block was built with
    /// [`JobCtrl::traced`].
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.trace.0.as_ref()
    }

    /// Close the still-open phase span, if any (idempotent). The service
    /// calls this when the job settles so the last phase's duration is
    /// final instead of "up to now" at every export.
    pub fn finish_trace(&self) {
        if let Some(t) = self.trace.0.as_deref() {
            t.finish();
        }
    }

    /// Per-phase wall-clock totals in microseconds, in first-entered
    /// order. `None` without a tracer or before any phase ran.
    pub fn timings(&self) -> Option<Vec<(String, u64)>> {
        let t = self.trace.0.as_deref()?;
        let v = t.timings();
        if v.is_empty() {
            None
        } else {
            Some(v)
        }
    }

    /// Count one integrity-check recovery against this run: a damaged
    /// `.pgjr` or `.pgds` that was quarantined aside and regenerated
    /// over. Sticky, like `degraded`; the service surfaces the count in
    /// job status so "healed by recomputing" is visible, not silent.
    pub fn mark_recovered(&self) {
        self.recovered.fetch_add(1, Ordering::Relaxed);
    }

    /// How many quarantine recoveries this run absorbed.
    pub fn recovered(&self) -> usize {
        self.recovered.load(Ordering::Relaxed)
    }

    /// The raw counter, for threading into the cache layer.
    pub(crate) fn recovered_counter(&self) -> &AtomicUsize {
        &self.recovered
    }

    fn set_phase(&self, p: Phase) {
        self.phase.store(p as u8, Ordering::Relaxed);
        if let Some(t) = self.trace.0.as_deref() {
            t.enter_phase(p.label());
        }
    }
}

/// How the pipeline chooses the lookup-bit count `R`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LookupBits {
    /// Generate at exactly this `R`.
    Fixed(u32),
    /// Sweep the default `R` range and select the point optimizing the
    /// given hardware objective (the paper's future-work decision
    /// procedure, realized by [`crate::coordinator::sweep_lub`]).
    Auto(LubObjective),
}

/// Shared stage configuration, fixed when the builder is consumed.
#[derive(Clone, Debug)]
struct Settings {
    bits: u32,
    accuracy: AccuracySpec,
    lookup: LookupBits,
    /// Generation degree (the [`GenOptions::degree`] knob): 2 enumerates
    /// the full quadratic space, 1 generates only the linear slice.
    /// Distinct from `degree` below, which picks the interpolator *within*
    /// whatever space was generated.
    gen_degree: u32,
    degree: Option<Degree>,
    /// Forced procedure; `None` = the technology's default ordering.
    procedure: Option<Procedure>,
    /// Technology target: cost model + default procedure/objective.
    tech: TechKind,
    search: SearchStrategy,
    max_k: u32,
    threads: usize,
    max_b_per_a: usize,
    cache_dir: Option<PathBuf>,
    testbench: bool,
    sweep_range: Option<Vec<u32>>,
    /// Cancellation/progress control block for this run (service jobs).
    ctrl: Option<Arc<JobCtrl>>,
    /// Optional generation backend override (the service's cluster).
    generator: GenHook,
}

impl Default for Settings {
    fn default() -> Self {
        let gen = GenOptions::default();
        let dse = DseOptions::default();
        Settings {
            bits: 10,
            accuracy: AccuracySpec::Ulp(1),
            lookup: LookupBits::Fixed(gen.lookup_bits),
            gen_degree: gen.degree,
            degree: dse.degree,
            procedure: dse.procedure,
            tech: dse.tech,
            search: gen.search,
            max_k: gen.max_k,
            threads: gen.threads,
            max_b_per_a: dse.max_b_per_a,
            cache_dir: None,
            testbench: false,
            sweep_range: None,
            ctrl: None,
            generator: GenHook::default(),
        }
    }
}

impl Settings {
    fn gen_opts(&self, lookup_bits: u32) -> GenOptions {
        GenOptions {
            lookup_bits,
            search: self.search,
            max_k: self.max_k,
            threads: self.threads,
            degree: self.gen_degree,
        }
    }

    /// Options for one point of a sweep: `sweep_lub` already spreads
    /// points across the scheduler, so per-point generation stays
    /// single-threaded. The process-wide pool would bound real
    /// parallelism either way; pinning the inner thread count keeps each
    /// point's `gen_time` a clean single-thread measurement.
    fn sweep_gen_opts(&self) -> GenOptions {
        GenOptions { threads: 1, ..self.gen_opts(0) }
    }

    fn dse_opts(&self) -> DseOptions {
        DseOptions {
            procedure: self.procedure,
            tech: self.tech,
            degree: self.degree,
            max_b_per_a: self.max_b_per_a,
        }
    }

    /// The cost model every costing stage uses.
    fn cost_model(&self) -> &'static dyn CostModel {
        self.tech.technology().cost_model()
    }

    /// Phase-boundary cancellation point: fail with
    /// [`PipelineError::Cancelled`] if the run's control block was
    /// cancelled, otherwise record that `next` begins. No-op without a
    /// control block.
    fn checkpoint(&self, next: Phase) -> Result<(), PipelineError> {
        if let Some(c) = &self.ctrl {
            if c.is_cancelled() {
                return Err(PipelineError::Cancelled);
            }
            c.set_phase(next);
        }
        Ok(())
    }

    fn cancel_token(&self) -> Option<&CancelToken> {
        self.ctrl.as_deref().map(JobCtrl::token)
    }

    fn progress_counter(&self) -> Option<&Progress> {
        self.ctrl.as_deref().map(|c| &c.progress)
    }

    fn sub_counter(&self) -> Option<&Progress> {
        self.ctrl.as_deref().map(|c| &c.sub)
    }

    fn recovered_counter(&self) -> Option<&AtomicUsize> {
        self.ctrl.as_deref().map(JobCtrl::recovered_counter)
    }
}

enum Source {
    Builtin(String),
    Custom(Box<dyn TargetFunction>),
}

/// The staged builder. Construct with [`Pipeline::function`] (a built-in
/// workload) or [`Pipeline::custom`] (bring your own
/// [`TargetFunction`]), configure, then either [`Pipeline::run`]
/// end-to-end or step through the stages starting at
/// [`Pipeline::prepare`].
pub struct Pipeline {
    source: Source,
    settings: Settings,
}

impl Pipeline {
    /// Target a built-in function (`recip`, `log2`, `exp2`, `sqrt`).
    /// Name resolution is deferred to [`Pipeline::prepare`], which
    /// returns [`PipelineError::UnknownFunction`] for anything else.
    pub fn function(name: &str) -> Pipeline {
        Pipeline { source: Source::Builtin(name.to_string()), settings: Settings::default() }
    }

    /// Target a custom function. Its own `in_bits` wins over
    /// [`Pipeline::bits`].
    pub fn custom(f: Box<dyn TargetFunction>) -> Pipeline {
        Pipeline { source: Source::Custom(f), settings: Settings::default() }
    }

    /// Stored input precision for built-in functions (default 10).
    pub fn bits(mut self, bits: u32) -> Self {
        self.settings.bits = bits;
        self
    }

    /// Accuracy specification (default 1 ULP).
    pub fn accuracy(mut self, acc: AccuracySpec) -> Self {
        self.settings.accuracy = acc;
        self
    }

    /// Lookup-bit policy: [`LookupBits::Fixed`] or [`LookupBits::Auto`].
    pub fn lookup_bits(mut self, lookup: LookupBits) -> Self {
        self.settings.lookup = lookup;
        self
    }

    /// Shorthand for `lookup_bits(LookupBits::Fixed(r))`.
    pub fn lub(self, r: u32) -> Self {
        self.lookup_bits(LookupBits::Fixed(r))
    }

    /// Shorthand for `lookup_bits(LookupBits::Auto(objective))`.
    pub fn auto_lub(self, objective: LubObjective) -> Self {
        self.lookup_bits(LookupBits::Auto(objective))
    }

    /// Force the interpolator degree (default: linear iff feasible).
    pub fn degree(mut self, degree: Degree) -> Self {
        self.settings.degree = Some(degree);
        self
    }

    /// Polynomial degree of the *generated* space (default 2): 2 is the
    /// paper's complete quadratic space, 1 generates only the linear
    /// `b·x + c` slice at its own minimal `k` (see
    /// [`GenOptions::degree`]). Panics on any other value when the
    /// pipeline generates.
    pub fn gen_degree(mut self, degree: u32) -> Self {
        self.settings.gen_degree = degree;
        self
    }

    /// Force a decision-procedure variant (default: the technology's own
    /// ordering — the paper's SquareFirst for [`TechKind::AsicGe`]).
    pub fn procedure(mut self, procedure: Procedure) -> Self {
        self.settings.procedure = Some(procedure);
        self
    }

    /// Technology target (default [`TechKind::AsicGe`]): selects the
    /// cost model behind every costing stage and, unless
    /// [`Pipeline::procedure`] forces one, the decision procedure.
    pub fn technology(mut self, tech: TechKind) -> Self {
        self.settings.tech = tech;
        self
    }

    /// Eqn 10 search implementation: the §Perf hull engine (the
    /// default), Claim II.1-pruned, or naive — all value-identical.
    pub fn search(mut self, search: SearchStrategy) -> Self {
        self.settings.search = search;
        self
    }

    /// Give up if no common `k <= max_k` exists (default 30).
    pub fn max_k(mut self, max_k: u32) -> Self {
        self.settings.max_k = max_k;
        self
    }

    /// Worker threads for generation and sweeps (default 1).
    pub fn threads(mut self, threads: usize) -> Self {
        self.settings.threads = threads.max(1);
        self
    }

    /// Cap on enumerated `b` values per `(region, a)` (default 512).
    pub fn max_b_per_a(mut self, cap: usize) -> Self {
        self.settings.max_b_per_a = cap;
        self
    }

    /// Cache generated spaces under this directory (`.pgds` files); see
    /// [`crate::coordinator::cache`]. The key covers every
    /// result-affecting [`GenOptions`] field. Custom functions are never
    /// disk-cached: their name does not determine their content, so a
    /// stale space could silently shadow an edited closure.
    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.settings.cache_dir = Some(dir.into());
        self
    }

    /// Also emit a self-checking testbench + golden vector from
    /// [`Explored::emit_rtl`] (default false).
    pub fn testbench(mut self, tb: bool) -> Self {
        self.settings.testbench = tb;
        self
    }

    /// Override the `R` values swept by [`LookupBits::Auto`] and
    /// [`Pipeline::sweep`] (default: [`default_r_range`]).
    pub fn sweep_range(mut self, r_values: Vec<u32>) -> Self {
        self.settings.sweep_range = Some(r_values);
        self
    }

    /// Attach a [`JobCtrl`]: the run becomes cancellable (checked at
    /// phase boundaries and between region sweeps) and reports its
    /// phase/progress through the shared block. [`crate::service`]
    /// attaches one to every submitted job.
    pub fn control(mut self, ctrl: Arc<JobCtrl>) -> Self {
        self.settings.ctrl = Some(ctrl);
        self
    }

    /// Install a generation backend override. Consulted by the fixed-`R`
    /// generate stage for built-in workloads only (a custom function's
    /// name cannot be resolved by a remote worker); a `None` from the
    /// hook falls back to the local path.
    pub(crate) fn generator(mut self, g: Arc<dyn Generator>) -> Self {
        self.settings.generator = GenHook(Some(g));
        self
    }

    /// Stage 1: resolve the function and build its bound table.
    pub fn prepare(self) -> Result<Prepared, PipelineError> {
        let Pipeline { source, settings } = self;
        settings.checkpoint(Phase::Prepare)?;
        let (workload, cacheable) = match source {
            Source::Builtin(name) => (
                Workload::prepare(&name, settings.bits, settings.accuracy)
                    .ok_or(PipelineError::UnknownFunction(name))?,
                true,
            ),
            Source::Custom(f) => {
                let bt = BoundTable::build(f.as_ref(), settings.accuracy);
                // Not disk-cacheable: the cache key is the function name,
                // which only determines the content for built-ins.
                (Workload { func: f, bt, accuracy: settings.accuracy }, false)
            }
        };
        Ok(Prepared { settings, workload, cacheable })
    }

    /// Run every stage (scalar verification) and return the final
    /// artifact bundle.
    pub fn run(self) -> Result<Verified, PipelineError> {
        self.prepare()?.generate()?.explore()?.synthesize().verify()
    }

    /// Sweep the lookup-bit range without committing to one point:
    /// the exploratory flavor of [`LookupBits::Auto`]. Used by the
    /// Fig. 3 / Table I report generators.
    pub fn sweep(self) -> Result<Swept, PipelineError> {
        let prepared = self.prepare()?;
        let Prepared { settings, workload, cacheable } = prepared;
        let rs = settings
            .sweep_range
            .clone()
            .unwrap_or_else(|| default_r_range(workload.bt.in_bits));
        let cache = if cacheable { settings.cache_dir.as_deref() } else { None };
        let points = sweep_lub_cached(
            &workload,
            &rs,
            &settings.sweep_gen_opts(),
            &settings.dse_opts(),
            settings.threads,
            cache,
        );
        Ok(Swept { settings, workload, points })
    }
}

/// Stage-1 artifact: the resolved [`Workload`] (function + bound table).
pub struct Prepared {
    settings: Settings,
    pub workload: Workload,
    /// Built-ins may use the disk cache (name determines content).
    cacheable: bool,
}

impl Prepared {
    /// Smallest `R` with a feasible complete space (paper §I: "the
    /// minimum number of regions required"), probing `0..=r_max`.
    pub fn min_lookup_bits(&self, r_max: u32) -> Option<u32> {
        crate::designspace::min_lookup_bits(&self.workload.bt, &self.settings.gen_opts(0), r_max)
    }

    /// [`Prepared::min_lookup_bits`] with evidence: on failure the error
    /// distinguishes "needs more lookup bits" (an infeasible region at
    /// the largest probed `R`) from "needs a larger `max_k`" (the
    /// `k`-search was the binding constraint).
    pub fn min_lookup_bits_report(&self, r_max: u32) -> Result<u32, PipelineError> {
        crate::designspace::min_lookup_bits_report(
            &self.workload.bt,
            &self.settings.gen_opts(0),
            r_max,
        )
        .map_err(|(lookup_bits, source)| PipelineError::Generation { lookup_bits, source })
    }

    /// Stage 2: generate the complete design space. Under
    /// [`LookupBits::Auto`] this sweeps the `R` range, selects the best
    /// point by the objective, and carries that point's implementation
    /// forward so [`Spaced::explore`] does not repeat the work.
    pub fn generate(self) -> Result<Spaced, PipelineError> {
        let Prepared { settings, workload, cacheable } = self;
        settings.checkpoint(Phase::Generate)?;
        let cache = if cacheable { settings.cache_dir.as_deref() } else { None };
        match settings.lookup {
            LookupBits::Fixed(r) => {
                let opts = settings.gen_opts(r);
                let t0 = Instant::now();
                // One region-count window for whichever backend runs:
                // the cluster hook and the cache probe tick/add against
                // it without re-opening it.
                if let Some(p) = settings.progress_counter() {
                    p.begin(1usize << r);
                }
                let hook = if cacheable { settings.generator.0.as_deref() } else { None };
                let remote = hook.and_then(|g| {
                    g.generate(
                        &workload.bt,
                        &opts,
                        settings.cancel_token(),
                        settings.progress_counter(),
                    )
                });
                let space = match remote {
                    Some(result) => result,
                    None => match cache {
                        Some(dir) => generate_cached_rec(
                            &workload,
                            r,
                            &opts,
                            dir,
                            settings.cancel_token(),
                            settings.progress_counter(),
                            settings.recovered_counter(),
                        ),
                        None => generate_ctrl(
                            &workload.bt,
                            &opts,
                            settings.cancel_token(),
                            settings.progress_counter(),
                        ),
                    },
                };
                let gen_time = t0.elapsed();
                let space = space.map_err(|source| match source {
                    GenError::Cancelled => PipelineError::Cancelled,
                    source => PipelineError::Generation { lookup_bits: r, source },
                })?;
                Ok(Spaced { settings, workload, space, gen_time, preselected: None })
            }
            LookupBits::Auto(objective) => {
                let rs = settings
                    .sweep_range
                    .clone()
                    .unwrap_or_else(|| default_r_range(workload.bt.in_bits));
                let mut points = match settings.cancel_token() {
                    Some(token) => sweep_lub_ctrl(
                        &workload,
                        &rs,
                        &settings.sweep_gen_opts(),
                        &settings.dse_opts(),
                        settings.threads,
                        cache,
                        token,
                        settings.progress_counter(),
                        settings.sub_counter(),
                    ),
                    None => sweep_lub_cached(
                        &workload,
                        &rs,
                        &settings.sweep_gen_opts(),
                        &settings.dse_opts(),
                        settings.threads,
                        cache,
                    ),
                };
                if settings.ctrl.as_deref().is_some_and(JobCtrl::is_cancelled) {
                    return Err(PipelineError::Cancelled);
                }
                let best = best_by_objective(&points, objective)
                    .map(|b| b.lookup_bits)
                    .and_then(|r| points.iter().position(|p| p.lookup_bits == r));
                let Some(idx) = best else {
                    let last = points.iter().rev().find_map(|p| p.space.as_ref().err().cloned());
                    return Err(PipelineError::SweepExhausted {
                        func: workload.bt.func.clone(),
                        tried: rs,
                        last,
                    });
                };
                let chosen = points.swap_remove(idx);
                let space = chosen.space.expect("selected sweep point lost its space");
                Ok(Spaced {
                    settings,
                    workload,
                    space,
                    gen_time: chosen.gen_time,
                    preselected: chosen.implementation,
                })
            }
        }
    }
}

/// Stage-2 artifact: the complete [`DesignSpace`] (plus its workload).
pub struct Spaced {
    settings: Settings,
    pub workload: Workload,
    pub space: DesignSpace,
    /// Generation wall-clock. Generation is lazy (§Scaling): this covers
    /// the analysis phases and the common-`k` search; per-region entries
    /// are swept on first touch by the exploration stage. The
    /// paper-comparable full-materialization runtime is what
    /// `report::{claim_ii1,scaling}` and the `gen_engine` bench measure
    /// (they time the eager oracle).
    pub gen_time: Duration,
    /// Implementation already selected by an auto-LUB sweep.
    preselected: Option<Implementation>,
}

impl Spaced {
    /// Stage 3: run the decision procedure over the complete space.
    pub fn explore(self) -> Result<Explored, PipelineError> {
        let Spaced { settings, workload, space, gen_time, preselected } = self;
        settings.checkpoint(Phase::Explore)?;
        let implementation = match preselected {
            Some(im) => im,
            None => {
                let im = crate::dse::explore_ctrl(
                    &workload.bt,
                    &space,
                    &settings.dse_opts(),
                    settings.cancel_token(),
                );
                // A cancelled procedure bails out with `None`; report it
                // as a cancellation, not an exhausted space.
                if settings.ctrl.as_deref().is_some_and(JobCtrl::is_cancelled) {
                    return Err(PipelineError::Cancelled);
                }
                im.ok_or_else(|| PipelineError::DseExhausted {
                    func: workload.bt.func.clone(),
                    lookup_bits: space.lookup_bits,
                    degree: settings.degree,
                })?
            }
        };
        Ok(Explored { settings, workload, space, gen_time, implementation })
    }
}

/// Stage-3 artifact: one concrete [`Implementation`].
pub struct Explored {
    settings: Settings,
    pub workload: Workload,
    pub space: DesignSpace,
    pub gen_time: Duration,
    pub implementation: Implementation,
}

impl Explored {
    /// Stage 4: cost the datapath at its minimum obtainable delay, under
    /// the pipeline's technology cost model. Infallible, so it only
    /// records the phase transition; a pending cancel lands at the next
    /// fallible boundary ([`Synthesized::verify`]).
    pub fn synthesize(self) -> Synthesized {
        if let Some(c) = &self.settings.ctrl {
            c.set_phase(Phase::Synthesize);
        }
        let synth = synth_min_delay_with(self.settings.cost_model(), &self.implementation);
        let Explored { settings, workload, space, gen_time, implementation } = self;
        Synthesized { settings, workload, space, gen_time, implementation, synth }
    }

    /// Emit Verilog (module, optional testbench + golden vector, and the
    /// behavioural reference for `recip`) without synthesizing first.
    pub fn emit_rtl(&self, dir: impl AsRef<Path>) -> Result<RtlEmitted, PipelineError> {
        emit_rtl_files(&self.implementation, &self.settings, dir.as_ref())
    }
}

/// Stage-4 artifact: the implementation plus its min-delay [`SynthPoint`].
pub struct Synthesized {
    settings: Settings,
    pub workload: Workload,
    pub space: DesignSpace,
    pub gen_time: Duration,
    pub implementation: Implementation,
    pub synth: SynthPoint,
}

impl Synthesized {
    /// Stage 5: exhaustive scalar verification (the trust anchor). A
    /// clean sweep yields [`Verified`]; any violation is a
    /// [`PipelineError::VerifyFailed`] carrying the first counterexample.
    pub fn verify(self) -> Result<Verified, PipelineError> {
        self.settings.checkpoint(Phase::Verify)?;
        let report = verify_exhaustive(&self.workload.bt, &self.implementation, &Engine::Scalar)
            .map_err(|e| PipelineError::Engine(e.to_string()))?;
        self.finish(report)
    }

    /// Stage 5 through a compiled XLA engine (jnp or Pallas flavor).
    pub fn verify_with(self, rt: &XlaRuntime, flavor: Flavor) -> Result<Verified, PipelineError> {
        self.settings.checkpoint(Phase::Verify)?;
        let engine = Engine::Xla { rt, flavor };
        let report = verify_exhaustive(&self.workload.bt, &self.implementation, &engine)
            .map_err(|e| PipelineError::Engine(e.to_string()))?;
        self.finish(report)
    }

    fn finish(self, report: VerifyReport) -> Result<Verified, PipelineError> {
        if !report.ok() {
            return Err(PipelineError::VerifyFailed {
                counterexample: report
                    .first_violation
                    .expect("violations recorded without a first input"),
                report,
            });
        }
        let Synthesized { settings, workload, space, gen_time, implementation, synth } = self;
        Ok(Verified { settings, workload, space, gen_time, implementation, synth, report })
    }

    /// See [`Explored::emit_rtl`].
    pub fn emit_rtl(&self, dir: impl AsRef<Path>) -> Result<RtlEmitted, PipelineError> {
        emit_rtl_files(&self.implementation, &self.settings, dir.as_ref())
    }
}

/// Stage-5 artifact: everything, plus the clean [`VerifyReport`].
pub struct Verified {
    settings: Settings,
    pub workload: Workload,
    pub space: DesignSpace,
    pub gen_time: Duration,
    pub implementation: Implementation,
    pub synth: SynthPoint,
    pub report: VerifyReport,
}

impl Verified {
    /// Cross-check a strided input sample through a second engine flavor
    /// (`Ok(true)` = bit-identical with [`Implementation::eval`]).
    pub fn cross_check(
        &self,
        rt: &XlaRuntime,
        flavor: Flavor,
        stride: u64,
    ) -> Result<bool, PipelineError> {
        crate::verify::cross_check_sample(&self.workload.bt, &self.implementation, rt, flavor, stride)
            .map_err(|e| PipelineError::Engine(e.to_string()))
    }

    /// The paper's HECTOR-style behavioural check for `recip`: the output
    /// must sit between the round-toward-zero and round-toward-+inf
    /// references. A no-op for other functions.
    pub fn check_behavioural_bracket(&self) -> Result<(), PipelineError> {
        if self.implementation.func != "recip" {
            return Ok(());
        }
        rtl::behavioral::recip_between_roundings(&self.implementation)
            .map_err(|(z, y, lo, hi)| PipelineError::BracketFailed { z, y, lo, hi })
    }

    /// Final stage: write the Verilog artifacts.
    pub fn emit_rtl(&self, dir: impl AsRef<Path>) -> Result<RtlEmitted, PipelineError> {
        emit_rtl_files(&self.implementation, &self.settings, dir.as_ref())
    }
}

/// Terminal artifact of [`Verified::emit_rtl`]: the module name and every
/// file written.
#[derive(Clone, Debug)]
pub struct RtlEmitted {
    pub module: String,
    pub files: Vec<PathBuf>,
}

fn emit_rtl_files(
    im: &Implementation,
    settings: &Settings,
    dir: &Path,
) -> Result<RtlEmitted, PipelineError> {
    let io_err = |path: &Path, source: std::io::Error| PipelineError::Io {
        path: path.to_path_buf(),
        source,
    };
    std::fs::create_dir_all(dir).map_err(|e| io_err(dir, e))?;
    let module = format!("{}_{}b_r{}", im.func, im.in_bits, im.lookup_bits);
    let mut files = Vec::new();
    let mut write = |path: PathBuf, text: String| -> Result<(), PipelineError> {
        std::fs::write(&path, text).map_err(|e| io_err(&path, e))?;
        files.push(path);
        Ok(())
    };
    write(dir.join(format!("{module}.v")), rtl::emit_module(im, &module))?;
    if settings.testbench {
        write(dir.join(format!("{module}_tb.v")), rtl::emit_testbench(im, &module))?;
        write(dir.join(format!("{module}_golden.hex")), rtl::emit_golden_hex(im))?;
    }
    if im.func == "recip" {
        write(
            dir.join("recip_behavioral.v"),
            rtl::behavioral::emit_recip_behavioral(im.in_bits, im.out_bits),
        )?;
    }
    Ok(RtlEmitted { module, files })
}

/// Artifact of [`Pipeline::sweep`]: every point of a lookup-bit sweep.
pub struct Swept {
    #[allow(dead_code)]
    settings: Settings,
    pub workload: Workload,
    pub points: Vec<SweepPoint>,
}

impl Swept {
    /// The best synthesizable point under `objective` (NaN-safe; `None`
    /// when nothing in the range was feasible).
    pub fn best(&self, objective: LubObjective) -> Option<&SweepPoint> {
        best_by_objective(&self.points, objective)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staged_and_end_to_end_agree() {
        let staged = Pipeline::function("recip")
            .bits(8)
            .lub(4)
            .prepare()
            .unwrap()
            .generate()
            .unwrap()
            .explore()
            .unwrap()
            .synthesize()
            .verify()
            .unwrap();
        let direct = Pipeline::function("recip").bits(8).lub(4).run().unwrap();
        assert_eq!(staged.implementation.coeffs, direct.implementation.coeffs);
        assert_eq!(staged.synth, direct.synth);
        assert!(staged.report.ok());
    }

    #[test]
    fn unknown_function_is_structured() {
        match Pipeline::function("tan").bits(8).prepare() {
            Err(PipelineError::UnknownFunction(name)) => assert_eq!(name, "tan"),
            other => panic!("expected UnknownFunction, got {:?}", other.err()),
        }
    }

    #[test]
    fn infeasible_generation_names_the_region() {
        let err = Pipeline::function("recip")
            .bits(8)
            .lub(0)
            .prepare()
            .unwrap()
            .generate()
            .unwrap_err();
        match err {
            PipelineError::Generation { lookup_bits: 0, source } => match source {
                GenError::InfeasibleRegion { .. } | GenError::KExhausted { .. } => {}
                GenError::Cancelled => panic!("no cancel token in play"),
            },
            other => panic!("expected Generation, got {other:?}"),
        }
    }

    #[test]
    fn verify_failure_carries_counterexample() {
        let mut explored = Pipeline::function("exp2")
            .bits(8)
            .lub(4)
            .prepare()
            .unwrap()
            .generate()
            .unwrap()
            .explore()
            .unwrap();
        let k = explored.implementation.k;
        explored.implementation.coeffs[7].c += 64 << k;
        match explored.synthesize().verify() {
            Err(PipelineError::VerifyFailed { counterexample, report }) => {
                assert!(report.violations > 0);
                assert_eq!(counterexample >> 4, 7, "counterexample not in region 7");
            }
            other => panic!("expected VerifyFailed, got {:?}", other.err().map(|e| e.to_string())),
        }
    }

    #[test]
    fn controlled_run_reports_phases_and_cancels() {
        // An unfired control block is invisible to the result, records
        // the final phase, and counts every region of the fixed-R
        // generation.
        let ctrl = Arc::new(JobCtrl::new());
        let v = Pipeline::function("recip")
            .bits(8)
            .lub(4)
            .control(Arc::clone(&ctrl))
            .run()
            .unwrap();
        assert!(v.report.ok());
        assert_eq!(ctrl.phase(), Phase::Verify);
        assert_eq!(ctrl.progress(), (16, 16), "R=4 has 16 regions");
        let plain = Pipeline::function("recip").bits(8).lub(4).run().unwrap();
        assert_eq!(v.implementation.coeffs, plain.implementation.coeffs);

        // A pre-fired block cancels at the first phase boundary.
        let ctrl = Arc::new(JobCtrl::new());
        ctrl.cancel();
        match Pipeline::function("recip").bits(8).lub(4).control(ctrl).run() {
            Err(PipelineError::Cancelled) => {}
            other => panic!("expected Cancelled, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn gen_degree_flows_through_and_verifies() {
        // Spelling out the default degree changes nothing.
        let quad = Pipeline::function("recip").bits(8).lub(4).run().unwrap();
        let explicit = Pipeline::function("recip").bits(8).lub(4).gen_degree(2).run().unwrap();
        assert_eq!(quad.implementation.coeffs, explicit.implementation.coeffs);
        assert_eq!(explicit.space.degree, 2);

        // The linear slice of an activation workload: find a feasible R,
        // run end to end, and check the space really is the a = 0 slice.
        let r = (0..=8u32)
            .find(|&r| {
                Pipeline::function("tanh")
                    .bits(8)
                    .lub(r)
                    .gen_degree(1)
                    .prepare()
                    .unwrap()
                    .generate()
                    .is_ok()
            })
            .expect("tanh 8-bit degree-1 must be feasible at some R");
        let lin = Pipeline::function("tanh").bits(8).lub(r).gen_degree(1).run().unwrap();
        assert!(lin.report.ok());
        assert_eq!(lin.space.degree, 1);
        assert!(lin.space.linear_feasible());
        assert!(lin.implementation.coeffs.iter().all(|c| c.a == 0));
    }

    #[test]
    fn auto_lub_picks_a_feasible_point() {
        let v = Pipeline::function("log2")
            .bits(10)
            .auto_lub(LubObjective::AreaDelay)
            .threads(2)
            .run()
            .unwrap();
        assert!(v.report.ok());
        let range = default_r_range(10);
        assert!(range.contains(&v.implementation.lookup_bits));
    }

    #[test]
    fn technology_threads_through_the_pipeline() {
        // Same flow, different technology target: the FPGA pipeline must
        // verify end to end and cost in its own units (logic levels are
        // far slower than 7nm FO4s).
        let asic = Pipeline::function("recip").bits(8).lub(3).run().unwrap();
        let fpga = Pipeline::function("recip")
            .bits(8)
            .lub(3)
            .technology(TechKind::FpgaLut6)
            .run()
            .unwrap();
        assert!(fpga.report.ok());
        assert!(fpga.synth.delay_ns > asic.synth.delay_ns);
        // Forcing the ASIC procedure on the FPGA tech still verifies.
        let forced = Pipeline::function("recip")
            .bits(8)
            .lub(3)
            .technology(TechKind::FpgaLut6)
            .procedure(Procedure::SquareFirst)
            .run()
            .unwrap();
        assert!(forced.report.ok());
    }

    #[test]
    fn custom_function_flows_through() {
        let f = CustomF64 {
            name: "half_x".into(),
            in_bits: 8,
            out_bits: 8,
            f: |x: f64| 0.5 * x,
            margin: 1e-9,
        };
        let v = Pipeline::custom(Box::new(f)).lub(3).run().unwrap();
        assert!(v.report.ok());
        assert_eq!(v.implementation.func, "half_x");
    }

    #[test]
    fn sweep_exposes_every_point() {
        let swept = Pipeline::function("exp2").bits(8).threads(2).sweep().unwrap();
        assert_eq!(swept.points.len(), default_r_range(8).len());
        let best = swept.best(LubObjective::Area).expect("some R feasible");
        assert!(best.synth.is_some());
    }
}
