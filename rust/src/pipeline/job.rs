//! Serializable pipeline jobs and batch execution.
//!
//! A [`JobSpec`] is the declarative form of a [`Pipeline`](super::Pipeline)
//! configuration: it round-trips through the TOML-subset config layer
//! ([`crate::coordinator::config::Config`]), so job files can be checked
//! in, generated, and shipped to workers. [`Batch`] executes many specs
//! across worker threads, all reusing one coordinator disk cache — the
//! scale/batching story for serving many scenarios.
//!
//! ```no_run
//! use polygen::pipeline::{Batch, JobSpec};
//!
//! let specs: Vec<JobSpec> = ["recip", "log2", "exp2"]
//!     .iter()
//!     .map(|f| JobSpec::new(f, 16))
//!     .collect();
//! for (spec, result) in specs.iter().zip(Batch::run(&specs, 3)) {
//!     match result {
//!         Ok(job) => println!("{}: R={} ok", spec.label(), job.lookup_bits),
//!         Err(e) => println!("{}: {e}", spec.label()),
//!     }
//! }
//! ```

use std::path::{Path, PathBuf};

use crate::sync::Arc;

use crate::coordinator::config::Config;

use super::{
    AccuracySpec, Degree, Implementation, JobCtrl, LookupBits, LubObjective, Pipeline,
    PipelineError, Procedure, SearchStrategy, Settings, SynthPoint, TechKind, VerifyReport,
};

/// One pipeline job, serializable to/from a TOML job file.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub func: String,
    pub bits: u32,
    pub accuracy: AccuracySpec,
    pub lookup: LookupBits,
    /// Generation degree (`generate.degree`, default 2): 2 generates the
    /// complete quadratic space, 1 only the linear `b·x + c` slice.
    /// Distinct from `degree` below, which picks the interpolator within
    /// the generated space.
    pub gen_degree: u32,
    pub degree: Option<Degree>,
    /// Forced procedure; `None` (`procedure = auto`) = the technology's
    /// default ordering.
    pub procedure: Option<Procedure>,
    /// Technology target (`tech = "asic-ge" | "fpga-lut6" | "low-power"`).
    pub tech: TechKind,
    pub search: SearchStrategy,
    pub max_k: u32,
    /// Concurrency budget for the job's generation/sweep phases. Under a
    /// [`Batch`] or a [`crate::service::Service`] this is a **floor**,
    /// not a cap: the executor raises it to its own budget so idle
    /// workers can be donated to this job's inner phases (thread counts
    /// never change results, only scheduling). Set
    /// [`JobSpec::threads_strict`] to make it a hard cap instead, or run
    /// the spec standalone ([`JobSpec::run`]) to pin an exact count.
    pub threads: usize,
    /// Opt out of budget donation: when true, `threads` is a hard cap on
    /// the job's inner concurrency even inside a batch/service whose
    /// budget is larger (`generate.threads_strict = true` in job files,
    /// `--threads-strict` on the CLI). For deployments that need strict
    /// per-job thread isolation — e.g. to keep one job's latency
    /// profile independent of its neighbours.
    pub threads_strict: bool,
    pub max_b_per_a: usize,
    /// Exhaustively verify the selected implementation (default true).
    pub verify: bool,
    /// When set, emit Verilog artifacts into this directory.
    pub rtl_out: Option<PathBuf>,
}

impl JobSpec {
    /// A job with the pipeline's defaults for everything but the target.
    pub fn new(func: &str, bits: u32) -> JobSpec {
        let s = Settings::default();
        JobSpec {
            func: func.to_string(),
            bits,
            accuracy: s.accuracy,
            lookup: s.lookup,
            gen_degree: s.gen_degree,
            degree: s.degree,
            procedure: s.procedure,
            tech: s.tech,
            search: s.search,
            max_k: s.max_k,
            threads: s.threads,
            threads_strict: false,
            max_b_per_a: s.max_b_per_a,
            verify: true,
            rtl_out: None,
        }
    }

    /// Short identifier for logs and result files, e.g. `recip_16b_R8`.
    pub fn label(&self) -> String {
        match self.lookup {
            LookupBits::Fixed(r) => format!("{}_{}b_R{r}", self.func, self.bits),
            LookupBits::Auto(_) => format!("{}_{}b_Rauto", self.func, self.bits),
        }
    }

    /// The imperative form of this spec.
    pub fn to_pipeline(&self) -> Pipeline {
        let mut p = Pipeline::function(&self.func)
            .bits(self.bits)
            .accuracy(self.accuracy)
            .lookup_bits(self.lookup)
            .gen_degree(self.gen_degree)
            .technology(self.tech)
            .search(self.search)
            .max_k(self.max_k)
            .threads(self.threads)
            .max_b_per_a(self.max_b_per_a);
        if let Some(pr) = self.procedure {
            p = p.procedure(pr);
        }
        if let Some(d) = self.degree {
            p = p.degree(d);
        }
        p
    }

    /// Execute the job (no disk cache).
    pub fn run(&self) -> Result<JobResult, PipelineError> {
        self.run_with(None)
    }

    /// Execute the job, generating through a shared disk cache.
    pub fn run_with(&self, cache: Option<&Path>) -> Result<JobResult, PipelineError> {
        self.run_controlled(cache, None)
    }

    /// [`JobSpec::run_with`] under a [`JobCtrl`]: the run becomes
    /// cancellable and reports phase/progress — how
    /// [`crate::service::Service`] executes every job.
    pub fn run_controlled(
        &self,
        cache: Option<&Path>,
        ctrl: Option<Arc<JobCtrl>>,
    ) -> Result<JobResult, PipelineError> {
        self.run_serviced(cache, ctrl, None)
    }

    /// [`JobSpec::run_controlled`] plus an optional generation backend
    /// override — the service layer passes its cluster here so fixed-`R`
    /// generation can be sharded across registered workers.
    pub(crate) fn run_serviced(
        &self,
        cache: Option<&Path>,
        ctrl: Option<Arc<JobCtrl>>,
        generator: Option<Arc<dyn crate::pipeline::Generator>>,
    ) -> Result<JobResult, PipelineError> {
        let mut p = self.to_pipeline();
        if let Some(dir) = cache {
            p = p.cache_dir(dir);
        }
        if let Some(c) = ctrl {
            p = p.control(c);
        }
        if let Some(g) = generator {
            p = p.generator(g);
        }
        let synthesized = p.prepare()?.generate()?.explore()?.synthesize();
        if self.verify {
            let v = synthesized.verify()?;
            let rtl = match &self.rtl_out {
                Some(dir) => v.emit_rtl(dir)?.files,
                None => Vec::new(),
            };
            Ok(JobResult::assemble(v.implementation, v.synth, Some(v.report), rtl))
        } else {
            let rtl = match &self.rtl_out {
                Some(dir) => synthesized.emit_rtl(dir)?.files,
                None => Vec::new(),
            };
            Ok(JobResult::assemble(synthesized.implementation, synthesized.synth, None, rtl))
        }
    }

    /// The spec as an executor with concurrency budget `budget` runs it:
    /// `threads` is a donation **floor** raised to the budget, unless
    /// [`JobSpec::threads_strict`] opts the job out (then it is a cap).
    pub(crate) fn donated(&self, budget: usize) -> JobSpec {
        let mut s = self.clone();
        if !s.threads_strict {
            s.threads = s.threads.max(budget);
        }
        s
    }

    /// Parse a job file's text (the TOML subset [`Config`] accepts).
    pub fn from_toml(text: &str) -> Result<JobSpec, PipelineError> {
        let cfg = Config::parse(text).map_err(PipelineError::Spec)?;
        JobSpec::from_config(&cfg)
    }

    /// Build a spec from a parsed [`Config`] (missing keys take the
    /// pipeline defaults; unknown values are [`PipelineError::Spec`]).
    pub fn from_config(cfg: &Config) -> Result<JobSpec, PipelineError> {
        let spec_err = PipelineError::Spec;
        let mut s = JobSpec::new(cfg.get_or("func", "recip"), 10);
        s.bits = cfg.get_u32("bits").map_err(spec_err)?.unwrap_or(10);
        if let Some(v) = cfg.get("accuracy") {
            s.accuracy = parse_accuracy(v)?;
        }
        if let Some(v) = cfg.get("tech") {
            s.tech = TechKind::parse(v)
                .ok_or_else(|| spec_err(format!("tech: {v} (asic-ge|fpga-lut6|low-power)")))?;
        }
        if let Some(v) = cfg.get("generate.lookup_bits") {
            // Tech-aware: a plain `auto` resolves to the technology's own
            // default objective (`tech` is parsed above), so low-power
            // job files sweep for minimum area without spelling it out.
            s.lookup = parse_lookup(v, s.tech)?;
        }
        if let Some(v) = cfg.get("generate.search") {
            s.search = match v {
                "hull" => SearchStrategy::Hull,
                "pruned" => SearchStrategy::Pruned,
                "naive" => SearchStrategy::Naive,
                other => return Err(spec_err(format!("generate.search: {other}"))),
            };
        }
        if let Some(v) = cfg.get_u32("generate.degree").map_err(spec_err)? {
            if v != 1 && v != 2 {
                return Err(spec_err(format!("generate.degree: {v} (use 1 or 2)")));
            }
            s.gen_degree = v;
        }
        if let Some(v) = cfg.get_u32("generate.max_k").map_err(spec_err)? {
            s.max_k = v;
        }
        if let Some(v) = cfg.get_u32("generate.threads").map_err(spec_err)? {
            s.threads = v as usize;
        }
        if let Some(v) = cfg.get_bool("generate.threads_strict").map_err(spec_err)? {
            s.threads_strict = v;
        }
        if let Some(v) = cfg.get("dse.procedure") {
            s.procedure = match v {
                "auto" => None,
                "square_first" => Some(Procedure::SquareFirst),
                "lut_first" => Some(Procedure::LutFirst),
                "pareto" => Some(Procedure::Pareto),
                other => return Err(spec_err(format!("dse.procedure: {other}"))),
            };
        }
        if let Some(v) = cfg.get("dse.degree") {
            s.degree = match v {
                "auto" => None,
                "linear" => Some(Degree::Linear),
                "quadratic" => Some(Degree::Quadratic),
                other => return Err(spec_err(format!("dse.degree: {other}"))),
            };
        }
        if let Some(v) = cfg.get_u32("dse.max_b_per_a").map_err(spec_err)? {
            s.max_b_per_a = v as usize;
        }
        if let Some(v) = cfg.get_bool("job.verify").map_err(spec_err)? {
            s.verify = v;
        }
        if let Some(v) = cfg.get("job.rtl_out") {
            s.rtl_out = Some(PathBuf::from(v));
        }
        Ok(s)
    }

    /// Serialize to job-file text; `JobSpec::from_toml(&spec.to_toml())`
    /// reconstructs the spec exactly.
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("func = {}\n", self.func));
        out.push_str(&format!("bits = {}\n", self.bits));
        out.push_str(&format!("accuracy = {}\n", self.accuracy.label()));
        out.push_str(&format!("tech = {}\n\n", self.tech.label()));
        out.push_str("[generate]\n");
        out.push_str(&format!("lookup_bits = {}\n", lookup_label(self.lookup, self.tech)));
        out.push_str(&format!(
            "search = {}\n",
            match self.search {
                SearchStrategy::Hull => "hull",
                SearchStrategy::Pruned => "pruned",
                SearchStrategy::Naive => "naive",
            }
        ));
        // Only a non-default degree is spelled out, so pre-degree job
        // files and the service store's canonical keys are unchanged.
        if self.gen_degree != 2 {
            out.push_str(&format!("degree = {}\n", self.gen_degree));
        }
        out.push_str(&format!("max_k = {}\n", self.max_k));
        out.push_str(&format!("threads = {}\n", self.threads));
        out.push_str(&format!("threads_strict = {}\n\n", self.threads_strict));
        out.push_str("[dse]\n");
        out.push_str(&format!(
            "procedure = {}\n",
            match self.procedure {
                None => "auto",
                Some(Procedure::SquareFirst) => "square_first",
                Some(Procedure::LutFirst) => "lut_first",
                Some(Procedure::Pareto) => "pareto",
            }
        ));
        out.push_str(&format!(
            "degree = {}\n",
            match self.degree {
                None => "auto",
                Some(Degree::Linear) => "linear",
                Some(Degree::Quadratic) => "quadratic",
            }
        ));
        out.push_str(&format!("max_b_per_a = {}\n\n", self.max_b_per_a));
        out.push_str("[job]\n");
        out.push_str(&format!("verify = {}\n", self.verify));
        if let Some(dir) = &self.rtl_out {
            out.push_str(&format!("rtl_out = {}\n", dir.display()));
        }
        out
    }
}

/// Parse an accuracy label (`faithful`, `1ulp`, `2ulp`, ...) — the
/// single grammar shared by job files and the CLI's `--accuracy` flag.
pub fn parse_accuracy(s: &str) -> Result<AccuracySpec, PipelineError> {
    if s == "faithful" {
        return Ok(AccuracySpec::Faithful);
    }
    s.trim_end_matches("ulp")
        .parse()
        .map(AccuracySpec::Ulp)
        .map_err(|_| PipelineError::Spec(format!("accuracy: {s}")))
}

/// Parse a `lookup_bits` value. A plain `auto` consults the technology's
/// [`default_objective`](crate::tech::Technology::default_objective) —
/// the same rule the CLI's `--lub auto` applies — so job files no longer
/// hardcode area-delay; `auto:<objective>` forces one explicitly.
fn parse_lookup(s: &str, tech: TechKind) -> Result<LookupBits, PipelineError> {
    match s {
        "auto" => Ok(LookupBits::Auto(tech.technology().default_objective())),
        "auto:area_delay" => Ok(LookupBits::Auto(LubObjective::AreaDelay)),
        "auto:area" => Ok(LookupBits::Auto(LubObjective::Area)),
        "auto:delay" => Ok(LookupBits::Auto(LubObjective::Delay)),
        fixed => fixed
            .parse()
            .map(LookupBits::Fixed)
            .map_err(|_| PipelineError::Spec(format!("generate.lookup_bits: {fixed}"))),
    }
}

/// Inverse of [`parse_lookup`] under the same technology: the
/// technology's own default objective prints as the idiomatic `auto`,
/// anything else spells the objective out, so every `(tech, lookup)`
/// combination round-trips exactly.
fn lookup_label(lookup: LookupBits, tech: TechKind) -> String {
    match lookup {
        LookupBits::Fixed(r) => r.to_string(),
        LookupBits::Auto(obj) if obj == tech.technology().default_objective() => "auto".into(),
        LookupBits::Auto(LubObjective::AreaDelay) => "auto:area_delay".into(),
        LookupBits::Auto(LubObjective::Area) => "auto:area".into(),
        LookupBits::Auto(LubObjective::Delay) => "auto:delay".into(),
    }
}

/// What one executed job produced (everything `Send`, so batches can
/// collect results across workers).
#[derive(Clone, Debug)]
pub struct JobResult {
    pub func: String,
    pub bits: u32,
    /// The `R` actually used (the sweep's choice under auto selection).
    pub lookup_bits: u32,
    pub implementation: Implementation,
    pub synth: SynthPoint,
    /// Present when the spec asked for verification (always clean —
    /// violations surface as [`PipelineError::VerifyFailed`]).
    pub verify: Option<VerifyReport>,
    /// Verilog files written, when the spec named an output directory.
    pub rtl: Vec<PathBuf>,
}

impl JobResult {
    fn assemble(
        implementation: Implementation,
        synth: SynthPoint,
        verify: Option<VerifyReport>,
        rtl: Vec<PathBuf>,
    ) -> JobResult {
        JobResult {
            func: implementation.func.clone(),
            bits: implementation.in_bits,
            lookup_bits: implementation.lookup_bits,
            implementation,
            synth,
            verify,
            rtl,
        }
    }
}

/// Blocking multi-job execution: submit-all + wait-all over a private
/// [`crate::service::Service`].
///
/// `Batch` is now a thin shim — the async, handle-based service is the
/// real execution layer, and this type preserves the original blocking
/// contract on top of it: `results[i]` corresponds to `specs[i]`, a
/// failing job fails only its own slot, and results are byte-identical
/// to running each spec alone (scheduling never changes results,
/// property-tested). Callers that want to poll progress or cancel
/// individual jobs should use [`crate::service::Service`] directly.
///
/// `threads` is the batch's **concurrency budget**, and it flows
/// dynamically: each job's inner generation/sweep work is raised to the
/// same budget (a donation *floor* — see [`JobSpec::threads`]; jobs with
/// [`JobSpec::threads_strict`] keep their own cap) and posted to the
/// process-wide scheduler, so when a small job finishes early its
/// worker is donated to a sibling's inner work instead of idling. Real
/// parallelism stays bounded by the persistent pool size regardless of
/// nesting. [`shutdown`](super::shutdown) drains the scheduler after
/// batches when a completion barrier is needed.
#[derive(Clone, Debug, Default)]
pub struct Batch {
    threads: usize,
    cache_dir: Option<PathBuf>,
}

impl Batch {
    pub fn new() -> Batch {
        Batch { threads: 1, cache_dir: None }
    }

    /// Concurrency budget (default 1 = sequential).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Share one design-space disk cache across all jobs.
    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// One-call form: `Batch::run(&specs, threads)`.
    pub fn run(specs: &[JobSpec], threads: usize) -> Vec<Result<JobResult, PipelineError>> {
        Batch::new().threads(threads).execute(specs)
    }

    /// Execute every spec; `results[i]` corresponds to `specs[i]`. A
    /// failing job fails its own slot only.
    pub fn execute(&self, specs: &[JobSpec]) -> Vec<Result<JobResult, PipelineError>> {
        let mut svc = crate::service::Service::builder().workers(self.threads);
        if let Some(dir) = &self.cache_dir {
            svc = svc.cache_dir(dir);
        }
        let svc = svc.build();
        // Submit everything up front (the service's executors pull jobs
        // as capacity frees — budget donation happens in submit), then
        // wait in spec order. Handle extraction keeps each job's owned
        // `Result` so the shim's signature matches the pre-service
        // `Batch` exactly.
        let handles: Vec<_> = specs.iter().map(|s| svc.submit(s.clone())).collect();
        handles.into_iter().map(|h| h.wait()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_roundtrip_defaults() {
        let spec = JobSpec::new("recip", 16);
        let back = JobSpec::from_toml(&spec.to_toml()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn toml_roundtrip_every_nondefault_field() {
        let spec = JobSpec {
            func: "log2".into(),
            bits: 12,
            accuracy: AccuracySpec::Faithful,
            lookup: LookupBits::Auto(LubObjective::Delay),
            gen_degree: 1,
            degree: Some(Degree::Quadratic),
            procedure: Some(Procedure::LutFirst),
            tech: TechKind::FpgaLut6,
            search: SearchStrategy::Naive,
            max_k: 24,
            threads: 4,
            threads_strict: true,
            max_b_per_a: 128,
            verify: false,
            rtl_out: Some(PathBuf::from("out/rtl")),
        };
        let text = spec.to_toml();
        let back = JobSpec::from_toml(&text).unwrap();
        assert_eq!(spec, back, "round-trip through:\n{text}");
    }

    #[test]
    fn gen_degree_roundtrips_and_default_stays_implicit() {
        // The default degree never appears in [generate] — pre-degree job
        // files and the service store's canonical keys are unchanged.
        let spec = JobSpec::new("tanh", 12);
        assert_eq!(spec.gen_degree, 2);
        let text = spec.to_toml();
        let cfg = Config::parse(&text).unwrap();
        assert!(cfg.get("generate.degree").is_none(), "default degree leaked into:\n{text}");
        // A linear-slice job spells it out and round-trips.
        let mut spec = spec;
        spec.gen_degree = 1;
        let text = spec.to_toml();
        assert!(text.contains("degree = 1\n"), "{text}");
        assert_eq!(JobSpec::from_toml(&text).unwrap(), spec);
        // Hand-written form parses too.
        let parsed = JobSpec::from_toml("func = tanh\n[generate]\ndegree = 1\n").unwrap();
        assert_eq!(parsed.gen_degree, 1);
    }

    #[test]
    fn tech_and_procedure_labels_roundtrip() {
        for tech in TechKind::ALL {
            for procedure in [
                None,
                Some(Procedure::SquareFirst),
                Some(Procedure::LutFirst),
                Some(Procedure::Pareto),
            ] {
                let mut spec = JobSpec::new("recip", 10);
                spec.tech = tech;
                spec.procedure = procedure;
                let back = JobSpec::from_toml(&spec.to_toml()).unwrap();
                assert_eq!(back.tech, tech);
                assert_eq!(back.procedure, procedure);
            }
        }
    }

    #[test]
    fn auto_objective_labels_roundtrip() {
        // Every (tech, objective) combination round-trips — including
        // objectives that differ from the technology's default.
        for tech in TechKind::ALL {
            for obj in [LubObjective::Area, LubObjective::Delay, LubObjective::AreaDelay] {
                let lb = LookupBits::Auto(obj);
                assert_eq!(parse_lookup(&lookup_label(lb, tech), tech).unwrap(), lb);
            }
            assert_eq!(parse_lookup("7", tech).unwrap(), LookupBits::Fixed(7));
        }
    }

    #[test]
    fn plain_auto_resolves_to_technology_default_objective() {
        // The ROADMAP open item from PR 3: `lookup_bits = auto` job files
        // must consult Technology::default_objective instead of
        // hardcoding area-delay. low-power's default is Area.
        let text = "tech = low-power\n[generate]\nlookup_bits = auto\n";
        let spec = JobSpec::from_toml(text).unwrap();
        assert_eq!(spec.lookup, LookupBits::Auto(LubObjective::Area));
        // ... asic-ge keeps the historical area-delay meaning.
        let spec = JobSpec::from_toml("[generate]\nlookup_bits = auto\n").unwrap();
        assert_eq!(spec.lookup, LookupBits::Auto(LubObjective::AreaDelay));
        // And the round-trip prints the default back as plain `auto`.
        let mut s = JobSpec::new("recip", 10);
        s.tech = TechKind::LowPower;
        s.lookup = LookupBits::Auto(LubObjective::Area);
        assert!(s.to_toml().contains("lookup_bits = auto\n"), "{}", s.to_toml());
        assert_eq!(JobSpec::from_toml(&s.to_toml()).unwrap(), s);
        // A non-default objective under the same tech stays explicit.
        s.lookup = LookupBits::Auto(LubObjective::Delay);
        assert!(s.to_toml().contains("lookup_bits = auto:delay\n"));
        assert_eq!(JobSpec::from_toml(&s.to_toml()).unwrap(), s);
    }

    #[test]
    fn threads_strict_roundtrips_and_caps_donation() {
        // ROADMAP PR-4 item: per-job `threads` is a donation floor by
        // default; `threads_strict = true` turns it into a hard cap.
        let mut spec = JobSpec::new("recip", 10);
        spec.threads = 2;
        assert_eq!(spec.donated(8).threads, 8, "default: floor raised to the budget");
        spec.threads_strict = true;
        assert_eq!(spec.donated(8).threads, 2, "strict: the job keeps its own cap");
        assert_eq!(spec.donated(1).threads, 2, "strict never lowers the cap either");

        // TOML round-trip, both through to_toml and from a hand-written
        // job file.
        let back = JobSpec::from_toml(&spec.to_toml()).unwrap();
        assert_eq!(back, spec);
        let text = "func = recip\n[generate]\nthreads = 2\nthreads_strict = true\n";
        assert!(JobSpec::from_toml(text).unwrap().threads_strict);
        let text = "func = recip\n[generate]\nthreads = 2\n";
        assert!(!JobSpec::from_toml(text).unwrap().threads_strict, "default is false");
        match JobSpec::from_toml("[generate]\nthreads_strict = sometimes\n") {
            Err(PipelineError::Spec(_)) => {}
            other => panic!("bad bool must be a Spec error, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn bad_values_are_spec_errors() {
        for text in [
            "bits = twelve\n",
            "accuracy = tight\n",
            "tech = tpu\n",
            "[generate]\nlookup_bits = many\n",
            "[generate]\nsearch = exhaustive\n",
            "[generate]\ndegree = 3\n",
            "[generate]\ndegree = linear\n",
            "[dse]\ndegree = cubic\n",
            "[dse]\nprocedure = random\n",
            "[job]\nverify = maybe\n",
        ] {
            match JobSpec::from_toml(text) {
                Err(PipelineError::Spec(_)) => {}
                other => panic!("{text:?}: expected Spec error, got {:?}", other.err()),
            }
        }
    }

    #[test]
    fn batch_isolates_failures_and_preserves_order() {
        let specs = vec![
            JobSpec::new("recip", 8),
            JobSpec::new("tan", 8), // unknown function
            JobSpec::new("exp2", 8),
        ];
        let results = Batch::run(&specs, 2);
        assert_eq!(results.len(), 3);
        let ok = results[0].as_ref().expect("recip should succeed");
        assert_eq!(ok.func, "recip");
        assert!(ok.verify.as_ref().unwrap().ok());
        match &results[1] {
            Err(PipelineError::UnknownFunction(f)) => assert_eq!(f, "tan"),
            other => panic!("expected UnknownFunction, got ok={}", other.is_ok()),
        }
        assert_eq!(results[2].as_ref().unwrap().func, "exp2");
    }

    #[test]
    fn batch_parallel_equals_sequential() {
        let specs = vec![JobSpec::new("recip", 8), JobSpec::new("log2", 8)];
        let seq = Batch::run(&specs, 1);
        let par = Batch::run(&specs, 2);
        for (a, b) in seq.iter().zip(&par) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.implementation.coeffs, b.implementation.coeffs);
            assert_eq!(a.lookup_bits, b.lookup_bits);
        }
    }

    #[test]
    fn batch_nested_parallelism_does_not_change_results() {
        // Jobs demanding 16 inner threads under a 2-thread batch budget:
        // inner work is posted to the global scheduler (no static clamp
        // anymore) and results still match the sequential run — thread
        // counts and scheduling never change results.
        let mut specs = vec![JobSpec::new("recip", 8), JobSpec::new("exp2", 8)];
        for s in &mut specs {
            s.threads = 16;
        }
        let scheduled = Batch::run(&specs, 2);
        let seq: Vec<_> = specs.iter().map(|s| s.run()).collect();
        for (a, b) in scheduled.iter().zip(&seq) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.implementation.coeffs, b.implementation.coeffs);
        }
    }

    #[test]
    fn drained_batch_leaves_global_pool_reusable() {
        // The shutdown contract: after a batch completes and the
        // scheduler drains, the persistent workers are parked — and a
        // second batch (and a bare run_indexed) reuse them with
        // identical results.
        let specs = vec![JobSpec::new("recip", 8), JobSpec::new("log2", 8)];
        let first = Batch::run(&specs, 2);
        super::super::shutdown();
        let again = Batch::run(&specs, 2);
        for (a, b) in first.iter().zip(&again) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.implementation.coeffs, b.implementation.coeffs);
            assert_eq!(a.lookup_bits, b.lookup_bits);
        }
        super::super::shutdown(); // idempotent on an idle pool
        let direct = crate::pool::run_indexed(16, 4, |i| i * i);
        assert_eq!(direct, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn batch_runs_per_technology_jobs() {
        // One function, three technologies, one batch: every job
        // verifies, and the FPGA job costs in its own (slower) units.
        let specs: Vec<JobSpec> = TechKind::ALL
            .iter()
            .map(|&t| {
                let mut s = JobSpec::new("recip", 8);
                s.lookup = LookupBits::Fixed(3);
                s.tech = t;
                s
            })
            .collect();
        let results = Batch::run(&specs, 3);
        let ok: Vec<&JobResult> =
            results.iter().map(|r| r.as_ref().expect("job failed")).collect();
        for j in &ok {
            assert!(j.verify.as_ref().unwrap().ok());
        }
        assert!(ok[1].synth.delay_ns > ok[0].synth.delay_ns, "FPGA must be slower");
    }

    #[test]
    fn job_with_rtl_out_writes_files() {
        let dir = std::env::temp_dir().join(format!("polygen_job_rtl_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = JobSpec::new("recip", 8);
        spec.lookup = LookupBits::Fixed(4);
        spec.rtl_out = Some(dir.clone());
        let res = spec.run().unwrap();
        assert!(!res.rtl.is_empty());
        for f in &res.rtl {
            assert!(f.exists(), "{} missing", f.display());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
