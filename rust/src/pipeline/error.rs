//! Structured pipeline failures.
//!
//! Every fallible stage of [`crate::pipeline`] returns
//! `Result<_, PipelineError>`; the old `Option<Implementation>`-style
//! returns along the generate → explore → synth → verify path swallowed
//! *why* a flow failed. This enum carries the cause: the offending region
//! for infeasible generation, the exhausted sweep range for automatic
//! lookup-bit selection, the DSE configuration that found no design, and
//! the first counterexample input for a verification mismatch.

use std::path::PathBuf;

use crate::designspace::GenError;
use crate::dse::Degree;
use crate::verify::VerifyReport;

/// Why a pipeline run failed, with the failing stage's evidence attached.
#[derive(Debug)]
pub enum PipelineError {
    /// `Pipeline::function` named something [`crate::bounds::builtin`]
    /// does not know.
    UnknownFunction(String),
    /// Design-space generation failed at a fixed `R`; `source` names the
    /// offending region (Eqn 9/10 infeasibility or `k` exhaustion).
    Generation { lookup_bits: u32, source: GenError },
    /// Automatic lookup-bit selection swept `tried` and found no point
    /// with a synthesizable implementation. `last` is the generation
    /// error at the largest attempted `R`, when generation itself failed.
    SweepExhausted { func: String, tried: Vec<u32>, last: Option<GenError> },
    /// The space generated but the decision procedure found no design
    /// under the requested constraints (forced degree, `b` cap, ...).
    DseExhausted { func: String, lookup_bits: u32, degree: Option<Degree> },
    /// Exhaustive verification found bound violations; `counterexample`
    /// is the smallest violating input code.
    VerifyFailed { counterexample: u64, report: VerifyReport },
    /// The behavioural RTZ/R+inf reference bracket failed (recip only):
    /// output `y` at input `z` fell outside `[lo, hi]`.
    BracketFailed { z: u64, y: i64, lo: i64, hi: i64 },
    /// A PJRT/XLA engine error (artifact loading, graph execution).
    Engine(String),
    /// Filesystem failure while emitting artifacts.
    Io { path: PathBuf, source: std::io::Error },
    /// A malformed [`crate::pipeline::JobSpec`] (bad TOML key or value).
    Spec(String),
    /// The run's [`crate::pipeline::JobCtrl`] was cancelled: the
    /// pipeline stopped cooperatively at a phase boundary or between
    /// region sweeps. Not a property of the workload — resubmitting the
    /// same spec can succeed.
    Cancelled,
    /// A panic escaped a pipeline stage; carries the payload's message.
    /// Produced by [`crate::service::Service`] executors, which convert
    /// panics into failed jobs instead of dying.
    Panic(String),
    /// The job ran *degraded* — the cluster had workers registered but
    /// none reachable (dead or quarantined), so the coordinator fell
    /// back to local compute — and then failed anyway; `source` is the
    /// underlying failure. Jobs that degrade but succeed surface the
    /// flag through their status instead of an error.
    Degraded { source: Box<PipelineError> },
    /// A persisted artifact (a `.pgjr` result file or the tail of
    /// `jobs.log`) failed its integrity check and was renamed aside;
    /// `path` is where the quarantined copy lives. Resubmitting the
    /// same spec recomputes the result.
    Quarantined { path: PathBuf },
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::UnknownFunction(name) => write!(f, "unknown function {name}"),
            PipelineError::Generation { lookup_bits, source } => {
                write!(f, "generation failed at R={lookup_bits}: {source}")
            }
            PipelineError::SweepExhausted { func, tried, last } => {
                write!(f, "no feasible lookup-bit count for {func} in {tried:?}")?;
                if let Some(e) = last {
                    write!(f, " (last error: {e})")?;
                }
                Ok(())
            }
            PipelineError::DseExhausted { func, lookup_bits, degree } => write!(
                f,
                "decision procedure found no design for {func} at R={lookup_bits}\
                 {}",
                match degree {
                    Some(Degree::Linear) => " (forced linear)",
                    Some(Degree::Quadratic) => " (forced quadratic)",
                    None => "",
                }
            ),
            PipelineError::VerifyFailed { counterexample, report } => write!(
                f,
                "verification FAILED: {} of {} inputs violate bounds \
                 (first counterexample z={counterexample}, worst excess {})",
                report.violations, report.total, report.worst_excess
            ),
            PipelineError::BracketFailed { z, y, lo, hi } => write!(
                f,
                "behavioural bracket failed at z={z}: {y} not in [{lo},{hi}]"
            ),
            PipelineError::Engine(msg) => write!(f, "verification engine: {msg}"),
            PipelineError::Io { path, source } => {
                write!(f, "{}: {source}", path.display())
            }
            PipelineError::Spec(msg) => write!(f, "job spec: {msg}"),
            PipelineError::Cancelled => write!(f, "job cancelled"),
            PipelineError::Panic(msg) => write!(f, "job panicked: {msg}"),
            PipelineError::Degraded { source } => {
                write!(f, "degraded (cluster fell back to local compute): {source}")
            }
            PipelineError::Quarantined { path } => {
                write!(
                    f,
                    "stored artifact failed its integrity check and was quarantined at {}; \
                     resubmit to recompute",
                    path.display()
                )
            }
        }
    }
}

impl std::error::Error for PipelineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PipelineError::Generation { source, .. } => Some(source),
            PipelineError::Io { source, .. } => Some(source),
            PipelineError::Degraded { source } => Some(source.as_ref()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_cause() {
        let e = PipelineError::Generation {
            lookup_bits: 3,
            source: GenError::InfeasibleRegion { r: 7 },
        };
        let s = e.to_string();
        assert!(s.contains("R=3"), "{s}");
        assert!(s.contains("region 7"), "{s}");

        let e = PipelineError::UnknownFunction("tan".into());
        assert_eq!(e.to_string(), "unknown function tan");

        let e = PipelineError::VerifyFailed {
            counterexample: 42,
            report: VerifyReport {
                total: 1024,
                violations: 3,
                first_violation: Some(42),
                worst_excess: 9,
            },
        };
        let s = e.to_string();
        assert!(s.contains("z=42") && s.contains("3 of 1024"), "{s}");
    }

    #[test]
    fn degraded_and_quarantined_carry_their_evidence() {
        use std::error::Error as _;
        let inner = PipelineError::Generation {
            lookup_bits: 4,
            source: GenError::InfeasibleRegion { r: 2 },
        };
        let e = PipelineError::Degraded { source: Box::new(inner) };
        let s = e.to_string();
        assert!(s.contains("degraded") && s.contains("region 2"), "{s}");
        assert!(e.source().unwrap().to_string().contains("R=4"));

        let e = PipelineError::Quarantined { path: PathBuf::from("/state/results/ab.pgjr") };
        let s = e.to_string();
        assert!(s.contains("quarantined") && s.contains("ab.pgjr"), "{s}");
    }

    #[test]
    fn generation_error_exposes_source() {
        use std::error::Error as _;
        let e = PipelineError::Generation {
            lookup_bits: 2,
            source: GenError::KExhausted { r: 1, max_k: 30 },
        };
        assert!(e.source().unwrap().to_string().contains("k <= 30"));
    }
}
