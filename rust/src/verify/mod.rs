//! Exhaustive design verification — the HECTOR substitute (DESIGN.md §3).
//!
//! The paper formally verifies generated RTL with Synopsys HECTOR; the
//! input spaces here are at most 2^24 codes, so *exhaustive simulation* of
//! the bit-accurate datapath against the bound tables is a stronger check
//! and is what we run: every input code, not a property proof over an
//! abstraction. Two engines:
//!
//! - [`Engine::Scalar`]: pure-Rust evaluation of
//!   [`Implementation::eval`] — the trust anchor;
//! - [`Engine::Xla`]: the AOT-compiled verify graph, chunked through PJRT
//!   (~the hot path; bit-identical by construction and cross-checked by
//!   `tests/runtime_integration.rs`).

use anyhow::Result;

use crate::bounds::BoundTable;
use crate::dse::Implementation;
use crate::runtime::{accumulator_fits_i64, CoeffTables, Flavor, XlaRuntime, CHUNK};

/// Which verification engine to run.
pub enum Engine<'rt> {
    Scalar,
    Xla { rt: &'rt XlaRuntime, flavor: Flavor },
}

/// Outcome of an exhaustive verification sweep.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VerifyReport {
    /// Inputs checked (always the full space).
    pub total: u64,
    pub violations: u64,
    /// Smallest violating input code, if any.
    pub first_violation: Option<u64>,
    /// Worst signed distance outside the bounds (0 when clean).
    pub worst_excess: i64,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.violations == 0
    }
}

/// Exhaustively verify `im` against `bt` over all `2^in_bits` inputs.
pub fn verify_exhaustive(
    bt: &BoundTable,
    im: &Implementation,
    engine: &Engine<'_>,
) -> Result<VerifyReport> {
    assert_eq!(bt.in_bits, im.in_bits, "bound table / implementation mismatch");
    match engine {
        Engine::Scalar => Ok(verify_scalar(bt, im)),
        Engine::Xla { rt, flavor } => verify_xla(bt, im, rt, *flavor),
    }
}

fn verify_scalar(bt: &BoundTable, im: &Implementation) -> VerifyReport {
    let total = 1u64 << bt.in_bits;
    let mut violations = 0u64;
    let mut first = None;
    let mut worst = 0i64;
    for z in 0..total {
        let out = im.eval(z);
        let (lo, hi) = (bt.l[z as usize] as i64, bt.u[z as usize] as i64);
        if out < lo || out > hi {
            violations += 1;
            if first.is_none() {
                first = Some(z);
            }
            let excess = if out < lo { lo - out } else { out - hi };
            worst = worst.max(excess);
        }
    }
    VerifyReport { total, violations, first_violation: first, worst_excess: worst }
}

fn verify_xla(
    bt: &BoundTable,
    im: &Implementation,
    rt: &XlaRuntime,
    flavor: Flavor,
) -> Result<VerifyReport> {
    anyhow::ensure!(accumulator_fits_i64(im), "accumulator would overflow the i64 datapath");
    let total = 1u64 << bt.in_bits;
    let tables = CoeffTables::from_impl(im);
    let params = [
        im.x_bits() as i64,
        im.sq_trunc as i64,
        im.lin_trunc as i64,
        im.k as i64,
        (1i64 << im.out_bits) - 1,
    ];
    let mut violations = 0u64;
    let mut first = None;
    let mut worst = 0i64;

    let mut z_buf = vec![0i64; CHUNK];
    let mut l_buf = vec![0i64; CHUNK];
    let mut u_buf = vec![0i64; CHUNK];
    let mut base = 0u64;
    while base < total {
        let n = ((total - base) as usize).min(CHUNK);
        for i in 0..CHUNK {
            if i < n {
                let z = base + i as u64;
                z_buf[i] = z as i64;
                l_buf[i] = bt.l[z as usize] as i64;
                u_buf[i] = bt.u[z as usize] as i64;
            } else {
                // Padding lanes: input 0 with permissive bounds.
                z_buf[i] = 0;
                l_buf[i] = i64::MIN / 4;
                u_buf[i] = i64::MAX / 4;
            }
        }
        let (outs, viol) = rt.verify_chunk(flavor, &z_buf, &tables, &l_buf, &u_buf, params)?;
        if viol > 0 {
            violations += viol as u64;
            // Localize within the chunk (cheap: only on failure).
            for i in 0..n {
                let out = outs[i];
                if out < l_buf[i] || out > u_buf[i] {
                    let z = base + i as u64;
                    if first.is_none() {
                        first = Some(z);
                    }
                    let excess =
                        if out < l_buf[i] { l_buf[i] - out } else { out - u_buf[i] };
                    worst = worst.max(excess);
                }
            }
        }
        base += n as u64;
    }
    Ok(VerifyReport { total, violations, first_violation: first, worst_excess: worst })
}

/// Cross-check the two engines on a strided sample of inputs (used by
/// integration tests and `polygen verify --cross-check`).
pub fn cross_check_sample(
    bt: &BoundTable,
    im: &Implementation,
    rt: &XlaRuntime,
    flavor: Flavor,
    stride: u64,
) -> Result<bool> {
    let tables = CoeffTables::from_impl(im);
    let params = [
        im.x_bits() as i64,
        im.sq_trunc as i64,
        im.lin_trunc as i64,
        im.k as i64,
        (1i64 << im.out_bits) - 1,
    ];
    let total = 1u64 << bt.in_bits;
    let mut z_buf = vec![0i64; CHUNK];
    let l_buf = vec![i64::MIN / 4; CHUNK];
    let u_buf = vec![i64::MAX / 4; CHUNK];
    let picks: Vec<u64> = (0..total).step_by(stride.max(1) as usize).collect();
    for (i, &z) in picks.iter().enumerate() {
        z_buf[i % CHUNK] = z as i64;
        if (i + 1) % CHUNK == 0 || i + 1 == picks.len() {
            let (outs, _) = rt.verify_chunk(flavor, &z_buf, &tables, &l_buf, &u_buf, params)?;
            let filled = (i % CHUNK) + 1;
            for (slot, &out) in outs.iter().enumerate().take(filled) {
                let zz = z_buf[slot] as u64;
                if out != im.eval(zz) {
                    return Ok(false);
                }
            }
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec};
    use crate::designspace::{generate, GenOptions};
    use crate::dse::{explore, DseOptions};

    #[test]
    fn scalar_verify_clean_design() {
        let f = builtin("recip", 10).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: 5, ..Default::default() }).unwrap();
        let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
        let rep = verify_exhaustive(&bt, &im, &Engine::Scalar).unwrap();
        assert!(rep.ok(), "{rep:?}");
        assert_eq!(rep.total, 1 << 10);
    }

    #[test]
    fn scalar_verify_catches_corruption() {
        let f = builtin("exp2", 8).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: 4, ..Default::default() }).unwrap();
        let mut im = explore(&bt, &ds, &DseOptions::default()).unwrap();
        // Fault injection: corrupt one region's c.
        im.coeffs[7].c += 64 << im.k;
        let rep = verify_exhaustive(&bt, &im, &Engine::Scalar).unwrap();
        assert!(!rep.ok());
        assert!(rep.first_violation.is_some());
        let z = rep.first_violation.unwrap();
        assert_eq!(z >> im.x_bits(), 7, "violation not localized to region 7");
        assert!(rep.worst_excess > 0);
    }
}
