//! Dependency-free HTTP/JSON front-end over a [`Service`] — the
//! `polygen serve` wire protocol.
//!
//! Built on `std::net::TcpListener` alone (no async runtime, no HTTP or
//! JSON crates are available offline): one accept loop, one short-lived
//! handler thread per connection, one request per connection
//! (`Connection: close`). That is deliberately modest — the point of
//! this layer is the *protocol*, which every future scaling PR (remote
//! workers, rate limiting, sharding) keeps while replacing the
//! transport.
//!
//! # Endpoints
//!
//! | Method & path          | Body                        | Replies |
//! |------------------------|-----------------------------|---------|
//! | `POST /jobs`           | job file (TOML) or JSON     | `201` status object |
//! | `GET /jobs`            | —                           | `200` array of status objects |
//! | `GET /jobs/:id`        | —                           | `200` status object, `404` |
//! | `GET /jobs/:id/result` | —                           | `200` result, `202` still queued/running, `409` cancelled, `422` failed, `404` |
//! | `GET /jobs/:id/trace`  | —                           | `200` Chrome trace JSON, `404` (job unknown or not traced) |
//! | `DELETE /jobs/:id`     | —                           | `200` post-cancel status, `404` |
//! | `GET /metrics`         | —                           | `200` Prometheus text exposition |
//!
//! A status object is
//! `{"id":3,"label":"recip_16b_R8","status":"running","phase":"generate",`
//! `"progress":{"done":37,"total":64}}` (phase/progress only while
//! running, plus a second-level `"sub"` counter when the job reports
//! one; `"error"` when failed). `POST` accepts the exact job-file
//! TOML the CLI's `batch` takes, or the same keys as JSON — nested
//! (`{"generate":{"lookup_bits":"auto"}}`) or dotted
//! (`{"generate.lookup_bits":"auto"}`).
//!
//! # Cluster endpoints
//!
//! The same listener doubles as the cluster wire surface (see
//! `service::cluster` for the protocol):
//!
//! | Method & path                 | Role        | Replies |
//! |-------------------------------|-------------|---------|
//! | `POST /workers`               | coordinator | `201 {"id":n}` — register a worker (`{"addr":"host:port"}`) |
//! | `GET /workers`                | coordinator | `200` array of `{"id","addr","live"}` |
//! | `POST /workers/:id/heartbeat` | coordinator | `200`, `404` (worker must re-register) |
//! | `POST /shards`                | worker      | `201 {"id":n}` — start analyzing a shard (TOML body) |
//! | `GET /shards/:id`             | worker      | `200` shard state, `404` |
//! | `POST /shards/:id/sweep`      | worker      | `200` binary (PGSH) region entries, `400`, `409`, `404` |
//! | `DELETE /shards/:id`          | worker      | `200`, `404` |
//!
//! # Hardening
//!
//! [`HttpOptions`] adds an optional bearer token (every request must
//! carry `Authorization: Bearer <token>`; failures get `401`) and a cap
//! on concurrent in-flight connections (excess gets `503` immediately).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::thread::JoinHandle;
use std::time::Duration;

use super::store::crc32;
use super::{JobEntry, JobStatus, Service};
use crate::faults::{self, Fault};
use crate::net::TokenBucket;
use crate::obs::metrics;
use crate::pipeline::{JobResult, PipelineError};
use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::sync::{plock, Arc, Mutex};

/// Listener-level hardening knobs for [`serve_with`] /
/// [`HttpServer::spawn_with`].
#[derive(Clone, Debug, Default)]
pub struct HttpOptions {
    /// When set, every request must carry `Authorization: Bearer
    /// <token>`; anything else is refused with `401`.
    pub auth_token: Option<String>,
    /// Cap on concurrently-served connections; excess connections are
    /// answered `503` without touching the service. `0` = unlimited.
    pub max_conns: usize,
    /// Per-client (peer IP) sustained request rate in requests/second;
    /// excess connections are answered `429` with a `Retry-After`
    /// header. `0.0` = unlimited.
    pub rate_limit: f64,
    /// Burst allowance on top of [`HttpOptions::rate_limit`] (token
    /// bucket depth). Values below 1 are raised to 1 when a limit is
    /// set, so the first request always passes.
    pub rate_burst: f64,
}

/// Serve `service` on `listener` until the process exits (the blocking
/// entry point `polygen serve` uses). Use [`HttpServer::spawn`] for an
/// in-process server you can stop (tests, examples).
pub fn serve(service: Service, listener: TcpListener) {
    serve_with(service, listener, HttpOptions::default());
}

/// [`serve`] with hardening options.
pub fn serve_with(service: Service, listener: TcpListener, opts: HttpOptions) {
    serve_until(service, listener, opts, None);
}

fn serve_until(
    service: Service,
    listener: TcpListener,
    opts: HttpOptions,
    stop: Option<Arc<AtomicBool>>,
) {
    let opts = Arc::new(opts);
    let active = Arc::new(AtomicUsize::new(0));
    let buckets: Arc<Mutex<HashMap<IpAddr, TokenBucket>>> = Arc::new(Mutex::new(HashMap::new()));
    for conn in listener.incoming() {
        if stop.as_ref().is_some_and(|s| s.load(Ordering::Relaxed)) {
            return;
        }
        let Ok(mut stream) = conn else { continue };
        let svc = service.clone();
        let opts = Arc::clone(&opts);
        let active = Arc::clone(&active);
        let buckets = Arc::clone(&buckets);
        // One thread per connection: connections are short (one request)
        // and job execution happens on the service's executors, so the
        // handler threads only parse and format.
        std::thread::spawn(move || {
            // Claim a slot before parsing anything: an idle client that
            // never sends its request still occupies a connection.
            let claimed = active.fetch_add(1, Ordering::SeqCst) + 1;
            if opts.max_conns != 0 && claimed > opts.max_conns {
                let _ = respond(
                    &mut stream,
                    503,
                    &obj([("error", json_str("connection limit reached"))]),
                );
            } else if let Some(retry_after) = over_rate_limit(&mut stream, &opts, &buckets) {
                let _ = respond_rate_limited(&mut stream, retry_after);
            } else {
                let _ = handle_connection(stream, &svc, &opts);
            }
            active.fetch_sub(1, Ordering::SeqCst);
        });
    }
}

/// Spend one token from the connecting peer's bucket; `Some(secs)` =
/// the peer is over its budget and should retry after that long.
/// Checked before the request is even read, so a flooding client costs
/// one accept and one small write, never a parse or a registry lock.
fn over_rate_limit(
    stream: &mut TcpStream,
    opts: &HttpOptions,
    buckets: &Mutex<HashMap<IpAddr, TokenBucket>>,
) -> Option<u64> {
    if opts.rate_limit <= 0.0 {
        return None;
    }
    let peer = stream.peer_addr().ok()?.ip();
    let mut map = plock(buckets);
    // Bound the table: buckets that have refilled to full are
    // indistinguishable from fresh ones, so they can be dropped.
    if map.len() > 1024 {
        map.retain(|_, b| !b.is_full());
    }
    let bucket = map
        .entry(peer)
        .or_insert_with(|| TokenBucket::new(opts.rate_limit, opts.rate_burst.max(1.0)));
    bucket.try_take().err()
}

/// An HTTP front-end running on its own thread. Dropping it does *not*
/// stop the loop (threads are detached on drop); call
/// [`HttpServer::stop`] for a clean shutdown.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `service` on a background thread.
    pub fn spawn(service: Service, addr: &str) -> std::io::Result<HttpServer> {
        HttpServer::spawn_with(service, addr, HttpOptions::default())
    }

    /// [`HttpServer::spawn`] with hardening options.
    pub fn spawn_with(
        service: Service,
        addr: &str,
        opts: HttpOptions,
    ) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("polygen-http".into())
            .spawn(move || serve_until(service, listener, opts, Some(flag)))?;
        Ok(HttpServer { addr, stop, thread: Some(thread) })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept loop. In-flight handler
    /// threads finish their single request on their own.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept with a throwaway connection.
        // lint: fault-ok(self-connect to our own listener; not a remote boundary)
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(
    mut stream: TcpStream,
    svc: &Service,
    opts: &HttpOptions,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    // Injection tap: a slow client dribbling its request in.
    if faults::inject("http.read", &[Fault::Delay]).is_some() {
        faults::small_delay();
    }
    let (method, path, auth, body) = match read_request(&mut stream) {
        Ok(req) => req,
        Err(e) => return respond(&mut stream, 400, &obj([("error", json_str(&e))])),
    };
    if let Some(token) = &opts.auth_token {
        if auth.as_deref() != Some(&format!("Bearer {token}")) {
            return respond(&mut stream, 401, &obj([("error", json_str("unauthorized"))]));
        }
    }
    let segs: Vec<&str> = path.trim_matches('/').split('/').filter(|s| !s.is_empty()).collect();
    match route(svc, &method, &segs, &body) {
        (code, Payload::Json(body)) => respond(&mut stream, code, &body),
        (code, Payload::Bytes(body)) => respond_bytes(&mut stream, code, &body),
        (code, Payload::Text(body)) => respond_text(&mut stream, code, &body),
    }
}

/// A response body: JSON (everything), raw bytes (shard sweeps, whose
/// entry lists would be pathological as JSON — see `service::cluster`),
/// or plain text (the Prometheus exposition format on `/metrics`).
enum Payload {
    Json(String),
    Bytes(Vec<u8>),
    Text(String),
}

fn route(svc: &Service, method: &str, segs: &[&str], body: &str) -> (u16, Payload) {
    // Cluster surface first: worker registry and shard execution.
    match (method, segs) {
        ("GET", ["metrics"]) => {
            return (200, Payload::Text(metrics::render_prometheus()));
        }
        ("POST", ["workers"]) => {
            let Some(addr) = super::cluster::json_field(body, "addr") else {
                return json(400, obj([("error", json_str("missing \"addr\""))]));
            };
            let id = svc.cluster().register(addr);
            return json(201, obj([("id", id.to_string())]));
        }
        ("GET", ["workers"]) => {
            let items: Vec<String> = svc
                .cluster()
                .workers()
                .into_iter()
                .map(|w| {
                    obj([
                        ("id", w.id.to_string()),
                        ("addr", json_str(&w.addr)),
                        ("live", w.live.to_string()),
                        ("state", json_str(w.state)),
                    ])
                })
                .collect();
            return json(200, format!("[{}]", items.join(",")));
        }
        ("POST", ["workers", id, "heartbeat"]) => {
            return match parse_id(id).map(|id| svc.cluster().heartbeat(id)) {
                Some(true) => json(200, obj([("ok", "true".into())])),
                _ => json(404, obj([("error", json_str("no such worker"))])),
            };
        }
        ("POST", ["shards"]) => {
            return match svc.shards().start(body) {
                // `body_crc` echoes what this worker actually received;
                // the coordinator compares it against what it sent, so a
                // spec corrupted in flight is re-dispatched instead of
                // silently analyzed wrong.
                Ok(id) => json(
                    201,
                    obj([
                        ("id", id.to_string()),
                        ("body_crc", crc32(body.as_bytes()).to_string()),
                    ]),
                ),
                Err(e) => json(400, obj([("error", json_str(&e))])),
            };
        }
        ("GET", ["shards", id]) => {
            return match parse_id(id).and_then(|id| svc.shards().status_json(id)) {
                Some(body) => json(200, body),
                None => json(404, obj([("error", json_str("no such shard"))])),
            };
        }
        ("POST", ["shards", id, "sweep"]) => {
            let Some(id) = parse_id(id) else {
                return json(404, obj([("error", json_str("no such shard"))]));
            };
            return match svc.shards().sweep(id, body) {
                Ok(bytes) => (200, Payload::Bytes(bytes)),
                Err((code, e)) => json(code, obj([("error", json_str(&e))])),
            };
        }
        ("DELETE", ["shards", id]) => {
            return match parse_id(id).map(|id| svc.shards().cancel(id)) {
                Some(true) => json(200, obj([("ok", "true".into())])),
                _ => json(404, obj([("error", json_str("no such shard"))])),
            };
        }
        ("GET", ["store"]) => {
            return match svc.store_inventory() {
                Some(entries) => {
                    let total: u64 = entries.iter().map(|e| e.bytes).sum();
                    let items: Vec<String> = entries
                        .iter()
                        .map(|e| {
                            obj([
                                ("key", json_str(&e.key)),
                                ("bytes", e.bytes.to_string()),
                                ("age_secs", e.age_secs.to_string()),
                            ])
                        })
                        .collect();
                    json(
                        200,
                        obj([
                            ("count", entries.len().to_string()),
                            ("bytes", total.to_string()),
                            // Aggregate duplicated under one key so
                            // clients scrape a single object instead of
                            // re-summing the entry list.
                            (
                                "summary",
                                obj([
                                    ("entries", entries.len().to_string()),
                                    ("total_bytes", total.to_string()),
                                ]),
                            ),
                            ("entries", format!("[{}]", items.join(","))),
                        ]),
                    )
                }
                None => {
                    json(404, obj([("error", json_str("no result store (start with --state)"))]))
                }
            };
        }
        _ => {}
    }
    let (code, body) = route_jobs(svc, method, segs, body);
    json(code, body)
}

fn json(code: u16, body: String) -> (u16, Payload) {
    (code, Payload::Json(body))
}

fn route_jobs(svc: &Service, method: &str, segs: &[&str], body: &str) -> (u16, String) {
    match (method, segs) {
        ("POST", ["jobs"]) => {
            let text = body.trim();
            let toml = if text.starts_with('{') {
                match json_to_job_toml(text) {
                    Ok(t) => t,
                    Err(e) => return (400, obj([("error", json_str(&format!("json: {e}")))])),
                }
            } else {
                text.to_string()
            };
            match svc.submit_toml(&toml) {
                Ok(handle) => {
                    let id = handle.id();
                    // The registry keeps the entry; the handle is not
                    // needed (results are served by id).
                    drop(handle);
                    let entry = svc.entry(id).expect("just submitted");
                    (201, status_json(&entry))
                }
                Err(e) => (400, obj([("error", json_str(&e.to_string()))])),
            }
        }
        ("GET", ["jobs"]) => {
            let items: Vec<String> =
                svc.entries().iter().map(status_json).collect();
            (200, format!("[{}]", items.join(",")))
        }
        ("GET", ["jobs", id]) => match parse_id(id).and_then(|id| svc.entry(id)) {
            Some(entry) => (200, status_json(&entry)),
            None => not_found(),
        },
        ("GET", ["jobs", id, "result"]) => match parse_id(id).and_then(|id| svc.entry(id)) {
            Some(entry) => result_response(&entry),
            None => not_found(),
        },
        ("GET", ["jobs", id, "trace"]) => match parse_id(id).and_then(|id| svc.entry(id)) {
            Some(entry) => match entry.tracer() {
                Some(t) => (200, t.export_chrome()),
                None => (404, obj([("error", json_str("job not traced (serve with --trace)"))])),
            },
            None => not_found(),
        },
        ("DELETE", ["jobs", id]) => match parse_id(id).and_then(|id| svc.entry(id)) {
            Some(entry) => {
                entry.cancel();
                (200, status_json(&entry))
            }
            None => not_found(),
        },
        ("GET" | "POST" | "DELETE", _) => not_found(),
        _ => (405, obj([("error", json_str("method not allowed"))])),
    }
}

fn not_found() -> (u16, String) {
    (404, obj([("error", json_str("no such job"))]))
}

fn parse_id(s: &str) -> Option<u64> {
    s.parse().ok()
}

/// `GET /jobs/:id/result`: the terminal outcome, or a 202 with the
/// status object while the job is still queued/running.
fn result_response(entry: &Arc<JobEntry>) -> (u16, String) {
    match entry.status() {
        JobStatus::Done => {
            let body = entry
                .with_outcome(|o| match o {
                    Some(Ok(res)) => result_json(entry.id(), res),
                    // Outcome taken by a local JobHandle, or a pre-crash
                    // job replayed from the log whose result predates
                    // the content-addressed store: the status is still
                    // truthful, the payload is just gone.
                    _ => obj([
                        ("id", entry.id().to_string()),
                        ("status", json_str("done")),
                        ("error", json_str("result not retained")),
                    ]),
                })
                .unwrap_or_default();
            (200, body)
        }
        JobStatus::Failed { error } => (
            422,
            obj([
                ("id", entry.id().to_string()),
                ("status", json_str("failed")),
                ("error", json_str(&error)),
            ]),
        ),
        JobStatus::Cancelled => (
            409,
            obj([("id", entry.id().to_string()), ("status", json_str("cancelled"))]),
        ),
        JobStatus::Queued | JobStatus::Running { .. } => (202, status_json(entry)),
    }
}

// ---------------------------------------------------------------------
// Wire formats
// ---------------------------------------------------------------------

fn status_json(entry: &Arc<JobEntry>) -> String {
    let mut fields: Vec<(&str, String)> = vec![
        ("id", entry.id().to_string()),
        ("label", json_str(&entry.spec().label())),
    ];
    let status = entry.status();
    fields.push(("status", json_str(status.label())));
    match &status {
        JobStatus::Running { phase, done, total, sub } => {
            fields.push(("phase", json_str(phase.label())));
            fields.push(("progress", format!("{{\"done\":{done},\"total\":{total}}}")));
            if let Some((sd, st)) = sub {
                fields.push(("sub", format!("{{\"done\":{sd},\"total\":{st}}}")));
            }
        }
        JobStatus::Failed { error } => fields.push(("error", json_str(error))),
        _ => {}
    }
    // A job that completed only because the coordinator fell back to
    // local compute is still correct, but the operator should know the
    // cluster wasn't. (Absent entirely when the job never degraded.)
    if entry.is_degraded() {
        fields.push(("degraded", "true".into()));
    }
    // Same contract for recovery: how many corrupt on-disk artifacts
    // (.pgjr results, .pgds caches) this job survived by recomputing.
    let recovered = entry.recovered();
    if recovered > 0 {
        fields.push(("recovered", recovered.to_string()));
    }
    // Per-phase wall time, present once a traced job has closed at
    // least one phase span.
    if let Some(timings) = entry.timings() {
        let items: Vec<String> =
            timings.iter().map(|(name, us)| format!("\"{name}\":{us}")).collect();
        fields.push(("timings", format!("{{{}}}", items.join(","))));
    }
    obj(fields)
}

fn result_json(id: u64, res: &JobResult) -> String {
    let im = &res.implementation;
    let coeffs: Vec<String> = im
        .coeffs
        .iter()
        .map(|c| format!("{{\"a\":{},\"b\":{},\"c\":{}}}", c.a, c.b, c.c))
        .collect();
    let result = obj([
        ("func", json_str(&res.func)),
        ("bits", res.bits.to_string()),
        ("lookup_bits", res.lookup_bits.to_string()),
        ("k", im.k.to_string()),
        ("degree", json_str(&format!("{:?}", im.degree).to_lowercase())),
        ("sq_trunc", im.sq_trunc.to_string()),
        ("lin_trunc", im.lin_trunc.to_string()),
        ("lut_width", json_str(&im.lut_width_label())),
        ("delay_ns", fmt_f64(res.synth.delay_ns)),
        ("area", fmt_f64(res.synth.area_um2)),
        (
            "verified",
            res.verify.as_ref().map(|v| v.total.to_string()).unwrap_or_else(|| "null".into()),
        ),
        ("coeffs", format!("[{}]", coeffs.join(","))),
    ]);
    obj([("id", id.to_string()), ("status", json_str("done")), ("result", result)])
}

/// JSON-safe float rendering (the error enums never reach here with
/// NaN/inf, but a cost model could).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

pub(crate) fn obj<'a>(fields: impl IntoIterator<Item = (&'a str, String)>) -> String {
    let body: Vec<String> =
        fields.into_iter().map(|(k, v)| format!("\"{k}\":{v}")).collect();
    format!("{{{}}}", body.join(","))
}

pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

// ---------------------------------------------------------------------
// JSON job specs
// ---------------------------------------------------------------------

/// Convert a JSON job object into the TOML job-file text
/// [`crate::pipeline::JobSpec::from_toml`] parses. Supports one level of
/// nesting (`{"generate":{...}}`) and dotted keys; values may be
/// strings, numbers, or booleans.
fn json_to_job_toml(text: &str) -> Result<String, String> {
    let mut p = JsonParser { b: text.as_bytes(), i: 0 };
    let mut pairs: Vec<(String, String)> = Vec::new();
    p.object("", &mut pairs, 0)?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    // TOML needs top-level keys before any [section] header.
    let mut out = String::new();
    for (k, v) in pairs.iter().filter(|(k, _)| !k.contains('.')) {
        out.push_str(&format!("{k} = {v}\n"));
    }
    let mut section = String::new();
    for (k, v) in pairs.iter().filter(|(k, _)| k.contains('.')) {
        let (sec, key) = k.split_once('.').expect("filtered on '.'");
        if key.contains('.') {
            return Err(format!("{k}: at most one level of nesting"));
        }
        if sec != section {
            out.push_str(&format!("[{sec}]\n"));
            section = sec.to_string();
        }
        out.push_str(&format!("{key} = {v}\n"));
    }
    Ok(out)
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl JsonParser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.ws();
        self.b.get(self.i).copied()
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        // Collected as bytes and decoded once: pushing `byte as char`
        // would widen each UTF-8 continuation byte into its own Latin-1
        // code point and mangle any non-ASCII value.
        let mut out: Vec<u8> = Vec::new();
        loop {
            let Some(&c) = self.b.get(self.i) else {
                return Err("unterminated string".into());
            };
            self.i += 1;
            match c {
                b'"' => {
                    return String::from_utf8(out).map_err(|_| "invalid utf-8".to_string())
                }
                b'\\' => {
                    let Some(&e) = self.b.get(self.i) else {
                        return Err("unterminated escape".into());
                    };
                    self.i += 1;
                    match e {
                        b'"' | b'\\' | b'/' => out.push(e),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        other => {
                            return Err(format!("unsupported escape \\{}", other as char))
                        }
                    }
                }
                c => out.push(c),
            }
        }
    }

    /// A scalar value rendered as job-file TOML text (strings lose their
    /// quotes — the config layer strips them anyway and never contains
    /// commas or braces in valid values).
    fn scalar(&mut self) -> Result<String, String> {
        match self.peek() {
            Some(b'"') => {
                let s = self.string()?;
                if s.contains('\n') || s.contains('#') {
                    return Err(format!("value {s:?} not representable in a job file"));
                }
                Ok(s)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let start = self.i;
                while self
                    .b
                    .get(self.i)
                    .is_some_and(|&c| c.is_ascii_digit() || b"+-.eE".contains(&c))
                {
                    self.i += 1;
                }
                Ok(std::str::from_utf8(&self.b[start..self.i]).unwrap().to_string())
            }
            Some(b't') | Some(b'f') => {
                for word in ["true", "false"] {
                    if self.b[self.i..].starts_with(word.as_bytes()) {
                        self.i += word.len();
                        return Ok(word.to_string());
                    }
                }
                Err(format!("bad literal at byte {}", self.i))
            }
            _ => Err(format!("unsupported value at byte {}", self.i)),
        }
    }

    fn object(
        &mut self,
        prefix: &str,
        out: &mut Vec<(String, String)>,
        depth: usize,
    ) -> Result<(), String> {
        if depth > 1 {
            return Err("at most one level of nesting".into());
        }
        self.eat(b'{')?;
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            let key = self.string()?;
            let key = if prefix.is_empty() { key } else { format!("{prefix}.{key}") };
            self.eat(b':')?;
            if self.peek() == Some(b'{') {
                self.object(&key, out, depth + 1)?;
            } else {
                let v = self.scalar()?;
                out.push((key, v));
            }
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Minimal HTTP/1.1
// ---------------------------------------------------------------------

type Request = (String, String, Option<String>, String);

// lint: fault-ok(the http.read delay tap fires in handle_connection
// right before this reader runs on the same stream)
fn read_request(stream: &mut TcpStream) -> Result<Request, String> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| e.to_string())?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_string();
    let path = parts.next().ok_or("request line without path")?.to_string();
    let mut content_length = 0usize;
    let mut auth: Option<String> = None;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).map_err(|e| e.to_string())?;
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().map_err(|_| "bad content-length")?;
            } else if k.trim().eq_ignore_ascii_case("authorization") {
                auth = Some(v.trim().to_string());
            }
        }
    }
    if content_length > 1 << 20 {
        return Err("body too large".into());
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| e.to_string())?;
    String::from_utf8(body).map(|b| (method, path, auth, b)).map_err(|e| e.to_string())
}

fn reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

// lint: fault-ok(the http.respond disconnect tap fires in write_body on
// the payload; the head write shares the stream and failure path)
fn respond(stream: &mut TcpStream, code: u16, body: &str) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        reason(code),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    write_body(stream, body.as_bytes())
}

// lint: fault-ok(the http.respond disconnect tap fires in write_body on
// the payload; the head write shares the stream and failure path)
fn respond_bytes(stream: &mut TcpStream, code: u16, body: &[u8]) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: application/octet-stream\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        reason(code),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    write_body(stream, body)
}

// lint: fault-ok(the http.respond disconnect tap fires in write_body on
// the payload; the head write shares the stream and failure path)
fn respond_text(stream: &mut TcpStream, code: u16, body: &str) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        reason(code),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    write_body(stream, body.as_bytes())
}

/// `429 Too Many Requests` with the `Retry-After` hint a well-behaved
/// client backs off by.
// lint: fault-ok(load-shed fast path that bypasses route dispatch;
// disconnect faults are exercised on the normal path via write_body)
fn respond_rate_limited(stream: &mut TcpStream, retry_after_secs: u64) -> std::io::Result<()> {
    let body = obj([("error", json_str("rate limit exceeded"))]);
    let head = format!(
        "HTTP/1.1 429 {}\r\nContent-Type: application/json\r\n\
         Retry-After: {retry_after_secs}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(429),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Write a response body after its head — with an injection tap that
/// hangs up halfway through (the declared `Content-Length` then never
/// arrives, which clients must treat as a failed call, not a short
/// success).
fn write_body(stream: &mut TcpStream, body: &[u8]) -> std::io::Result<()> {
    if faults::inject("http.respond", &[Fault::Disconnect]).is_some() {
        stream.write_all(&body[..body.len() / 2])?;
        stream.flush()?;
        return stream.shutdown(std::net::Shutdown::Both);
    }
    stream.write_all(body)?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_specs_become_job_files() {
        let toml = json_to_job_toml(
            r#"{"func":"recip","bits":16,"generate":{"lookup_bits":"auto","threads":4},
                "dse":{"procedure":"pareto"},"job":{"verify":false}}"#,
        )
        .unwrap();
        let spec = crate::pipeline::JobSpec::from_toml(&toml).unwrap();
        assert_eq!(spec.func, "recip");
        assert_eq!(spec.bits, 16);
        assert_eq!(spec.threads, 4);
        assert!(!spec.verify);
        assert_eq!(spec.procedure, Some(crate::pipeline::Procedure::Pareto));

        // Dotted keys are the flat spelling of the same thing.
        let toml = json_to_job_toml(r#"{"func":"log2","generate.lookup_bits":"5"}"#).unwrap();
        let spec = crate::pipeline::JobSpec::from_toml(&toml).unwrap();
        assert_eq!(spec.lookup, crate::pipeline::LookupBits::Fixed(5));

        // Structural errors are reported, not mangled.
        assert!(json_to_job_toml("{\"a\":{\"b\":{\"c\":1}}}").is_err());
        assert!(json_to_job_toml("{\"a\":[1,2]}").is_err());
        assert!(json_to_job_toml("{\"a\":1} trailing").is_err());
        assert!(json_to_job_toml("not json").is_err());
    }

    #[test]
    fn json_escaping_round_trips_control_chars() {
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_str("plain"), "\"plain\"");
        let esc = json_str("\u{1}");
        assert_eq!(esc, "\"\\u0001\"");
    }

    #[test]
    fn empty_json_object_is_a_valid_default_spec() {
        let toml = json_to_job_toml("{}").unwrap();
        let spec = crate::pipeline::JobSpec::from_toml(&toml).unwrap();
        assert_eq!(spec.func, "recip"); // all defaults
    }
}
