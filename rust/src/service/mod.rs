//! `polygen::service` — the async, handle-based job execution layer.
//!
//! The paper's value proposition ("give us an accuracy spec, get the
//! complete design space and competitive hardware") is the shape of a
//! request/response service, and the blocking APIs
//! ([`Pipeline::run`](crate::pipeline::Pipeline::run),
//! [`Batch`](crate::pipeline::Batch)) cannot serve it: a caller that
//! wants ten concurrent jobs,
//! live progress, or the ability to abandon one has to own a thread per
//! job. A [`Service`] fixes that:
//!
//! - [`Service::submit`] accepts a [`JobSpec`] and **returns
//!   immediately** with a [`JobHandle`];
//! - the handle exposes [`JobHandle::status`] (queued / running with
//!   phase + region progress / done / failed with the structured
//!   [`PipelineError`] / cancelled), blocking [`JobHandle::wait`],
//!   non-blocking [`JobHandle::try_result`], and cooperative
//!   [`JobHandle::cancel`] — checked at pipeline phase boundaries and
//!   between region sweeps (see [`JobCtrl`]);
//! - jobs run on a small pool of **executor threads** owned by the
//!   service (spawned lazily up to the service's worker budget); each
//!   executor drives one pipeline at a time, and the pipeline's inner
//!   generation/sweep parallelism is posted to the process-wide
//!   scheduler ([`crate::pool::global`]) exactly as before — the
//!   service is an orchestration layer, not a second thread pool for
//!   region work;
//! - every submitted spec's `threads` is raised to the service budget
//!   (donation floor) unless the spec sets
//!   [`threads_strict`](JobSpec::threads_strict);
//! - a shared disk cache ([`ServiceBuilder::cache_dir`]) backs all
//!   jobs, so repeated specs parse a `.pgds` instead of regenerating.
//!
//! [`crate::pipeline::Batch`] is now a thin blocking shim over this
//! module (submit-all + wait-all), and [`http`] serves the same
//! registry over a dependency-free HTTP/JSON front-end (`polygen serve`).
//!
//! ```no_run
//! use polygen::pipeline::JobSpec;
//! use polygen::service::{JobStatus, Service};
//!
//! let svc = Service::builder().workers(4).build();
//! let mut spec = JobSpec::new("recip", 16);
//! let handle = svc.submit(spec.clone());
//! spec.func = "log2".into();
//! let other = svc.submit(spec); // both jobs now run concurrently
//! while !handle.status().is_finished() {
//!     if let JobStatus::Running { phase, done, total, .. } = handle.status() {
//!         eprintln!("recip: {} {done}/{total}", phase.label());
//!     }
//!     std::thread::sleep(std::time::Duration::from_millis(100));
//! }
//! other.cancel(); // changed our mind about log2
//! let result = handle.wait().expect("recip 16-bit is feasible");
//! println!("R = {}", result.lookup_bits);
//! ```
//!
//! # Lifecycle
//!
//! A job moves `Queued → Running → (Done | Failed | Cancelled)`; the
//! transitions are monotone and every terminal state is sticky. The
//! service keeps finished entries in its registry so late `GET`s (and
//! late [`JobHandle`] reads) still see them. Long-lived deployments can
//! bound the registry with [`ServiceBuilder::finished_ttl`] /
//! [`ServiceBuilder::max_finished`]: terminal entries past the TTL or
//! beyond the count cap are evicted (oldest first) on each submission,
//! after which their ids answer 404 over HTTP; outstanding
//! [`JobHandle`]s are unaffected (they own their entry).
//!
//! # Durability and clustering
//!
//! [`ServiceBuilder::state_dir`] makes the registry survive restarts: an
//! append-only, checksummed job log (`jobs.log`, replayed on startup)
//! plus a content-addressed result store keyed by the result-affecting
//! spec text — a resubmitted spec completes at submit time as a store
//! hit without touching the scheduler. The `cluster` module adds
//! region-sharded multi-worker generation over the same HTTP surface
//! (`polygen serve --worker --coordinator <url>`); see DESIGN.md
//! §Cluster.
//!
//! Dropping the last [`Service`] clone *closes* the service: executors
//! finish the queued backlog and exit. Outstanding [`JobHandle`]s stay
//! valid — their jobs complete (or were already finished) because the
//! backlog is drained, never abandoned. Cancellation is cooperative
//! everywhere: the process-wide scheduler fully retires a cancelled
//! job's tasks (each one observes the token and returns early), so the
//! pool is left drained-but-reusable, never poisoned.

pub(crate) mod cluster;
#[doc(hidden)]
pub mod exec;
pub mod http;
pub(crate) mod store;

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::net::Policy;
use crate::obs::metrics;
use crate::pipeline::{Generator, JobCtrl, JobResult, JobSpec, Phase, PipelineError};
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{cwait, plock, thread, Arc, Condvar, Mutex};

use cluster::Cluster;
use exec::TaskQueue;
pub use cluster::{run_worker_agent, run_worker_agent_with, WorkerView};
use store::{JobLog, LoadOutcome, LogOutcome, ResultStore};
pub use store::StoreEntry;

const SUBMITTED: metrics::Counter = metrics::counter("service.submitted");
const DONE: metrics::Counter = metrics::counter("service.done");
const FAILED: metrics::Counter = metrics::counter("service.failed");
const CANCELLED: metrics::Counter = metrics::counter("service.cancelled");
const STORE_SUBMIT_HITS: metrics::Counter = metrics::counter("service.store_submit_hits");
const REGISTRY_SIZE: metrics::Gauge = metrics::gauge("service.registry_size");
const JOB_MS: metrics::Histogram = metrics::histogram("service.job_ms");

/// Observable job state. `Failed` carries the error's rendered message;
/// the owned structured [`PipelineError`] is delivered once, by
/// [`JobHandle::wait`] / [`JobHandle::try_result`].
#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    /// Accepted, waiting for an executor.
    Queued,
    /// An executor is driving the pipeline; `phase` is the stage it last
    /// entered and `done`/`total` count the phase's work unit (regions
    /// analyzed for fixed-`R` generation, sweep points for auto-LUB).
    /// For auto-LUB jobs `sub` is the second level: regions analyzed
    /// across the whole sweep, so a 16-bit sweep's long first points are
    /// visible while `done` still reads 0. `None` when the job has a
    /// single progress level.
    Running { phase: Phase, done: usize, total: usize, sub: Option<(usize, usize)> },
    Done,
    Failed { error: String },
    Cancelled,
}

impl JobStatus {
    /// Lowercase wire label (`"queued"`, `"running"`, ...).
    pub fn label(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running { .. } => "running",
            JobStatus::Done => "done",
            JobStatus::Failed { .. } => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }

    /// Terminal? (`done` / `failed` / `cancelled`)
    pub fn is_finished(&self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed { .. } | JobStatus::Cancelled)
    }
}

/// Terminal label kept after the owned outcome may have been taken.
#[derive(Clone, Debug)]
enum FinLabel {
    Done,
    Failed(String),
    Cancelled,
}

enum EntryState {
    Queued,
    Running,
    Finished {
        label: FinLabel,
        /// The owned result/error; `None` once a consuming handle
        /// accessor extracted it — or from the start for entries
        /// replayed out of the job log without a stored result. The
        /// HTTP layer only ever peeks.
        outcome: Option<Result<JobResult, PipelineError>>,
        /// When the entry went terminal (eviction clock).
        at: Instant,
    },
}

/// One registered job: spec, control block, and its state machine.
pub(crate) struct JobEntry {
    id: u64,
    spec: JobSpec,
    ctrl: Arc<JobCtrl>,
    state: Mutex<EntryState>,
    cv: Condvar,
}

impl JobEntry {
    pub(crate) fn id(&self) -> u64 {
        self.id
    }

    pub(crate) fn spec(&self) -> &JobSpec {
        &self.spec
    }

    pub(crate) fn status(&self) -> JobStatus {
        let st = plock(&self.state);
        match &*st {
            // A cancel on a still-queued job is reported immediately —
            // the executor that eventually pops it only confirms.
            EntryState::Queued if self.ctrl.is_cancelled() => JobStatus::Cancelled,
            EntryState::Queued => JobStatus::Queued,
            EntryState::Running => {
                let (done, total) = self.ctrl.progress();
                JobStatus::Running { phase: self.ctrl.phase(), done, total, sub: self.ctrl.sub() }
            }
            EntryState::Finished { label, .. } => match label {
                FinLabel::Done => JobStatus::Done,
                FinLabel::Failed(e) => JobStatus::Failed { error: e.clone() },
                FinLabel::Cancelled => JobStatus::Cancelled,
            },
        }
    }

    pub(crate) fn cancel(&self) {
        self.ctrl.cancel();
    }

    /// Did this job's cluster path degrade to local compute?
    pub(crate) fn is_degraded(&self) -> bool {
        self.ctrl.is_degraded()
    }

    /// Quarantine recoveries this job absorbed (damaged `.pgjr`/`.pgds`
    /// healed by recomputing) — surfaced next to `degraded` in status.
    pub(crate) fn recovered(&self) -> usize {
        self.ctrl.recovered()
    }

    /// Per-phase wall-clock totals (µs), when the job was traced.
    pub(crate) fn timings(&self) -> Option<Vec<(String, u64)>> {
        self.ctrl.timings()
    }

    /// The job's span tracer, when the service runs with tracing.
    pub(crate) fn tracer(&self) -> Option<&Arc<crate::obs::trace::Tracer>> {
        self.ctrl.tracer()
    }

    /// Block until the entry reaches a terminal state (does not consume
    /// the outcome).
    fn wait_finished(&self) {
        let mut st = plock(&self.state);
        while !matches!(*st, EntryState::Finished { .. }) {
            st = cwait(&self.cv, st);
        }
    }

    /// Read-only view of the outcome; `None` until terminal. The closure
    /// sees `None` only in the (single-extraction) case where a handle
    /// already took the owned value.
    pub(crate) fn with_outcome<R>(
        &self,
        f: impl FnOnce(Option<&Result<JobResult, PipelineError>>) -> R,
    ) -> Option<R> {
        let st = plock(&self.state);
        match &*st {
            EntryState::Finished { outcome, .. } => Some(f(outcome.as_ref())),
            _ => None,
        }
    }

    /// Take the owned outcome (blocks until terminal). Guarded by the
    /// consuming handle accessors: each entry has exactly one handle and
    /// both accessors take `self`, so this runs at most once.
    fn take_outcome(&self) -> Result<JobResult, PipelineError> {
        let mut st = plock(&self.state);
        loop {
            match &mut *st {
                EntryState::Finished { outcome, .. } => {
                    return outcome
                        .take()
                        .expect("outcome taken twice despite consuming accessors");
                }
                _ => st = cwait(&self.cv, st),
            }
        }
    }

    fn finish(&self, label: FinLabel, outcome: Result<JobResult, PipelineError>) {
        let mut st = plock(&self.state);
        *st = EntryState::Finished { label, outcome: Some(outcome), at: Instant::now() };
        drop(st);
        self.cv.notify_all();
    }

    /// Time since the entry went terminal (`None` while live).
    fn finished_elapsed(&self) -> Option<Duration> {
        match &*plock(&self.state) {
            EntryState::Finished { at, .. } => Some(at.elapsed()),
            _ => None,
        }
    }
}

/// Owner's view of one submitted job. Not `Clone`: single ownership is
/// what lets [`JobHandle::wait`] hand back the *owned* structured
/// [`PipelineError`] / [`JobResult`] exactly once (the [`Service`]
/// registry keeps shared read access for everyone else).
pub struct JobHandle {
    entry: Arc<JobEntry>,
}

impl JobHandle {
    /// Service-unique job id (the HTTP API's `:id`).
    pub fn id(&self) -> u64 {
        self.entry.id
    }

    /// The spec as the service runs it (after donation — see
    /// [`JobSpec::threads`]).
    pub fn spec(&self) -> &JobSpec {
        &self.entry.spec
    }

    /// Current status snapshot (cheap; safe to poll).
    pub fn status(&self) -> JobStatus {
        self.entry.status()
    }

    /// Request cooperative cancellation. Returns immediately; the job
    /// observes the request at its next checkpoint (phase boundary /
    /// between region sweeps) and settles to [`JobStatus::Cancelled`].
    /// A job that already finished is unaffected.
    pub fn cancel(&self) {
        self.entry.cancel();
    }

    /// `true` once the job's cluster path has fallen back to local
    /// compute (all workers stale/quarantined, or a shard failed
    /// mid-sweep). The result — if any — is still byte-identical to a
    /// healthy run; this flag only reports that the *cluster* wasn't.
    /// Also surfaced as `"degraded":true` in the HTTP status object.
    pub fn degraded(&self) -> bool {
        self.entry.is_degraded()
    }

    /// How many quarantine recoveries this job absorbed: damaged
    /// durable artifacts (`.pgjr` result, `.pgds` space) that failed
    /// their integrity check, were renamed aside, and were regenerated
    /// over. Also surfaced as `"recovered":N` in the HTTP status object.
    pub fn recovered(&self) -> usize {
        self.entry.recovered()
    }

    /// Block until the job finishes and take its outcome. A cancelled
    /// job yields `Err(`[`PipelineError::Cancelled`]`)`.
    pub fn wait(self) -> Result<JobResult, PipelineError> {
        self.entry.take_outcome()
    }

    /// Non-blocking [`JobHandle::wait`]: the outcome if the job already
    /// finished, otherwise the handle back (`Err` = keep polling).
    /// Deliberately checks the entry's *settled* state, not the status
    /// label: a cancelled-but-still-queued job reports
    /// [`JobStatus::Cancelled`] immediately, while its outcome settles
    /// only when an executor retires it — `try_result` must not block on
    /// that window.
    pub fn try_result(self) -> Result<Result<JobResult, PipelineError>, JobHandle> {
        if self.entry.with_outcome(|_| ()).is_some() {
            Ok(self.entry.take_outcome())
        } else {
            Err(self)
        }
    }
}

struct Inner {
    workers: usize,
    cache_dir: Option<PathBuf>,
    max_finished: usize,
    finished_ttl: Option<Duration>,
    /// Attach a span tracer to every submitted job
    /// ([`ServiceBuilder::tracing`]).
    tracing: bool,
    next_id: AtomicU64,
    /// The executor pool's work queue and park/close protocol — the
    /// loom-modeled half of the service (see [`exec::TaskQueue`]).
    exec: TaskQueue<Arc<JobEntry>>,
    jobs: Mutex<BTreeMap<u64, Arc<JobEntry>>>,
    /// Durability (present iff [`ServiceBuilder::state_dir`] was set).
    log: Option<JobLog>,
    store: Option<ResultStore>,
    /// Cluster registries: every service can coordinate workers and
    /// serve shards; both stay empty until the HTTP surface is used.
    cluster: Arc<Cluster>,
    shards: Arc<cluster::ShardServer>,
}

impl Inner {
    fn close(&self) {
        self.exec.close();
    }
}

/// Closes the service when the last public [`Service`] clone drops.
/// Executor threads hold only `Arc<Inner>`, so they never keep the
/// gate — and therefore the service — alive.
struct Gate {
    inner: Arc<Inner>,
}

impl Drop for Gate {
    fn drop(&mut self) {
        self.inner.close();
    }
}

/// Builder for [`Service`].
pub struct ServiceBuilder {
    workers: usize,
    cache_dir: Option<PathBuf>,
    state_dir: Option<PathBuf>,
    max_finished: usize,
    finished_ttl: Option<Duration>,
    heartbeat_timeout: Duration,
    auth_token: Option<String>,
    policy: Policy,
    store_max_bytes: Option<u64>,
    store_ttl: Option<Duration>,
    tracing: bool,
}

impl ServiceBuilder {
    /// Maximum concurrently *running* jobs, and the donation budget every
    /// non-strict spec's `threads` is raised to (default: machine
    /// parallelism). Executors are spawned lazily, so an idle service
    /// owns no threads.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Shared design-space disk cache for every job (see
    /// [`crate::coordinator::cache`]).
    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// Durable state directory: `dir/jobs.log` (append-only, checksummed
    /// job log, replayed by [`ServiceBuilder::build`] so `GET /jobs/:id`
    /// survives restarts) and `dir/results/` (content-addressed result
    /// store; a resubmitted spec completes at submit time as a store
    /// hit).
    pub fn state_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.state_dir = Some(dir.into());
        self
    }

    /// Keep at most `n` terminal jobs in the registry; older ones (by
    /// id, i.e. submission order) are evicted on each submission and
    /// their ids answer 404 afterwards. Default: unbounded.
    pub fn max_finished(mut self, n: usize) -> Self {
        self.max_finished = n;
        self
    }

    /// Evict terminal jobs `ttl` after they finish (checked on each
    /// submission). Default: never.
    pub fn finished_ttl(mut self, ttl: Duration) -> Self {
        self.finished_ttl = Some(ttl);
        self
    }

    /// How stale a cluster worker's heartbeat may be before the
    /// coordinator reassigns its shards (default 10s).
    pub fn heartbeat_timeout(mut self, timeout: Duration) -> Self {
        self.heartbeat_timeout = timeout;
        self
    }

    /// Bearer token this service presents on its *outgoing* cluster
    /// calls (shard dispatch to workers). The counterpart of
    /// [`http::HttpOptions::auth_token`], which guards the incoming
    /// side; start every node with the same `--auth-token` to close the
    /// cluster to outsiders.
    pub fn auth_token(mut self, token: impl Into<String>) -> Self {
        self.auth_token = Some(token.into());
        self
    }

    /// The full failure-handling policy for this service's outgoing
    /// cluster calls (deadline, retries, breaker). See
    /// [`crate::net::Policy`].
    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Per-attempt deadline for outgoing cluster calls (default 10s).
    pub fn call_timeout(mut self, timeout: Duration) -> Self {
        self.policy.call_timeout = timeout;
        self
    }

    /// Extra attempts after a failed cluster call (default 2).
    pub fn retries(mut self, retries: u32) -> Self {
        self.policy.retries = retries;
        self
    }

    /// Consecutive failed calls before a worker is quarantined behind
    /// its circuit breaker (default 3).
    pub fn breaker_threshold(mut self, threshold: u32) -> Self {
        self.policy.breaker_threshold = threshold;
        self
    }

    /// Byte budget for the content-addressed result store; oldest
    /// results are evicted past it. Default: unbounded.
    pub fn store_max_bytes(mut self, bytes: u64) -> Self {
        self.store_max_bytes = Some(bytes);
        self
    }

    /// Age limit for stored results (enforced after each save).
    /// Default: forever.
    pub fn store_ttl(mut self, ttl: Duration) -> Self {
        self.store_ttl = Some(ttl);
        self
    }

    /// Attach a span tracer ([`crate::obs::trace`]) to every submitted
    /// job: phase transitions (and cluster shard dispatches) record
    /// spans, exportable as per-job `timings` in status, `GET
    /// /jobs/:id/trace`, and `polygen trace`. Off by default — an
    /// untraced job allocates nothing and records nothing.
    pub fn tracing(mut self, on: bool) -> Self {
        self.tracing = on;
        self
    }

    pub fn build(self) -> Service {
        let (log, store, replayed, max_id) = match &self.state_dir {
            None => (None, None, Vec::new(), 0),
            Some(dir) => {
                let log_path = dir.join("jobs.log");
                // `recover`, not `replay`: a corrupt tail is copied
                // aside and truncated so this process's appends are not
                // shadowed behind a bad frame forever.
                let replayed = JobLog::recover(&log_path);
                let max_id = replayed.iter().map(|r| r.id).max().unwrap_or(0);
                (
                    JobLog::open(&log_path).ok(),
                    Some(ResultStore::with_bounds(
                        &dir.join("results"),
                        self.store_max_bytes,
                        self.store_ttl,
                    )),
                    replayed,
                    max_id,
                )
            }
        };
        let cluster = Arc::new(Cluster::new(self.heartbeat_timeout));
        cluster.set_auth(self.auth_token);
        cluster.set_policy(self.policy);
        let inner = Arc::new(Inner {
            workers: self.workers,
            cache_dir: self.cache_dir,
            max_finished: self.max_finished,
            finished_ttl: self.finished_ttl,
            tracing: self.tracing,
            next_id: AtomicU64::new(max_id),
            exec: TaskQueue::new(),
            jobs: Mutex::new(BTreeMap::new()),
            log,
            store,
            cluster,
            shards: Arc::new(cluster::ShardServer::default()),
        });
        // Replay the log into the registry: every job the previous
        // process accepted is queryable again. Done jobs reload their
        // result from the store (absence degrades to a label-only
        // entry); jobs interrupted mid-run report a structured failure
        // rather than a forever-Running lie.
        {
            let mut jobs = plock(&inner.jobs);
            for r in replayed {
                let label = match &r.outcome {
                    Some(LogOutcome::Done) => FinLabel::Done,
                    Some(LogOutcome::Failed(e)) => FinLabel::Failed(e.clone()),
                    Some(LogOutcome::Cancelled) => FinLabel::Cancelled,
                    None => FinLabel::Failed("interrupted by service restart".into()),
                };
                let mut quarantined = false;
                let outcome = match (&r.outcome, &r.store_key, &inner.store) {
                    (Some(LogOutcome::Done), Some(key), Some(st)) => match st.load_checked(key) {
                        LoadOutcome::Hit(res) => Some(Ok(res)),
                        // Absent file: label-only entry, as before.
                        LoadOutcome::Miss => None,
                        // A corrupt artifact was renamed aside: the
                        // entry stays Done (that's what history says)
                        // but its payload is the structured quarantine
                        // error, so a result fetch explains itself.
                        LoadOutcome::Quarantined(path) => {
                            quarantined = true;
                            Some(Err(PipelineError::Quarantined { path }))
                        }
                    },
                    _ => None,
                };
                let ctrl = Arc::new(JobCtrl::new());
                if quarantined {
                    // Latched so `"recovered"` in status JSON records
                    // that this entry's artifact was healed-by-removal.
                    ctrl.mark_recovered();
                }
                let entry = Arc::new(JobEntry {
                    id: r.id,
                    spec: r.spec,
                    ctrl,
                    state: Mutex::new(EntryState::Finished {
                        label,
                        outcome,
                        at: Instant::now(),
                    }),
                    cv: Condvar::new(),
                });
                jobs.insert(r.id, entry);
            }
            REGISTRY_SIZE.set(jobs.len() as u64);
        }
        Service { gate: Arc::new(Gate { inner: Arc::clone(&inner) }), inner }
    }
}

/// The job service: a registry + executor pool over the process-wide
/// scheduler and the shared disk cache. Cheap to clone (all clones share
/// one registry); see the [module docs](self) for the full lifecycle.
#[derive(Clone)]
pub struct Service {
    inner: Arc<Inner>,
    /// Present in every public clone; executors do not hold it.
    gate: Arc<Gate>,
}

impl Service {
    /// A service with default settings (machine-parallel workers, no
    /// disk cache).
    pub fn new() -> Service {
        Service::builder().build()
    }

    pub fn builder() -> ServiceBuilder {
        ServiceBuilder {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            cache_dir: None,
            state_dir: None,
            max_finished: usize::MAX,
            finished_ttl: None,
            heartbeat_timeout: cluster::DEFAULT_HEARTBEAT_TIMEOUT,
            auth_token: None,
            policy: Policy::default(),
            store_max_bytes: None,
            store_ttl: None,
            tracing: false,
        }
    }

    /// The concurrent-job budget this service was built with.
    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Register `spec` and return immediately with its handle. The job
    /// starts as soon as an executor is free; specs without
    /// [`JobSpec::threads_strict`] get their inner budget raised to the
    /// service's worker budget (donation floor).
    pub fn submit(&self, spec: JobSpec) -> JobHandle {
        self.evict_finished();
        SUBMITTED.inc();
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let spec = spec.donated(self.inner.workers);

        // Content-addressed store hit: a spec whose result-affecting
        // text is already stored completes *now* — the handle is born
        // terminal and the scheduler is never touched.
        let mut store_recovered = false;
        if let Some(store) = &self.inner.store {
            if let Some(key) = store::store_key(&spec) {
                // `load_checked`: a corrupt file is quarantined aside
                // here and the submission falls through to a real run,
                // whose save then repopulates the key — self-healing.
                match store.load_checked(&key) {
                    LoadOutcome::Hit(res) => {
                        STORE_SUBMIT_HITS.inc();
                        DONE.inc();
                        let entry = Arc::new(JobEntry {
                            id,
                            spec,
                            ctrl: Arc::new(JobCtrl::new()),
                            state: Mutex::new(EntryState::Finished {
                                label: FinLabel::Done,
                                outcome: Some(Ok(res)),
                                at: Instant::now(),
                            }),
                            cv: Condvar::new(),
                        });
                        if let Some(log) = &self.inner.log {
                            log.append_submit(id, &entry.spec);
                            log.append_finish(id, &LogOutcome::Done, Some(&key));
                        }
                        let mut jobs = plock(&self.inner.jobs);
                        jobs.insert(id, Arc::clone(&entry));
                        REGISTRY_SIZE.set(jobs.len() as u64);
                        return JobHandle { entry };
                    }
                    LoadOutcome::Quarantined(_) => store_recovered = true,
                    LoadOutcome::Miss => {}
                }
            }
        }

        let ctrl = if self.inner.tracing {
            Arc::new(JobCtrl::traced())
        } else {
            Arc::new(JobCtrl::new())
        };
        if store_recovered {
            // The fresh run below regenerates over the quarantined
            // artifact; latch that into the job's `recovered` count.
            ctrl.mark_recovered();
        }
        let entry = Arc::new(JobEntry {
            id,
            spec,
            ctrl,
            state: Mutex::new(EntryState::Queued),
            cv: Condvar::new(),
        });
        if let Some(log) = &self.inner.log {
            log.append_submit(id, &entry.spec);
        }
        {
            let mut jobs = plock(&self.inner.jobs);
            jobs.insert(id, Arc::clone(&entry));
            REGISTRY_SIZE.set(jobs.len() as u64);
        }
        // The queue decides whether a new executor is warranted (backlog
        // exceeds parked executors, pool under budget — see
        // `TaskQueue::push_and_plan`); a `true` return reserves the slot.
        if self.inner.exec.push_and_plan(Arc::clone(&entry), self.inner.workers) {
            let inner = Arc::clone(&self.inner);
            let spawned =
                thread::spawn_named(format!("polygen-svc-{id}"), move || executor_loop(inner));
            if spawned.is_none() && self.inner.exec.spawn_failed() {
                // Resource exhaustion with no executor alive: degrade to
                // running the backlog inline so the handle can never hang.
                drain_queue_inline(&self.inner);
            }
        }
        JobHandle { entry }
    }

    /// Parse a TOML job file (the [`JobSpec::from_toml`] grammar) and
    /// submit it — the HTTP `POST /jobs` entry point.
    pub fn submit_toml(&self, text: &str) -> Result<JobHandle, PipelineError> {
        Ok(self.submit(JobSpec::from_toml(text)?))
    }

    /// Status of a job by id (`None` = unknown id).
    pub fn status_of(&self, id: u64) -> Option<JobStatus> {
        self.entry(id).map(|e| e.status())
    }

    /// Cancel a job by id; `false` = unknown id. Idempotent.
    pub fn cancel(&self, id: u64) -> bool {
        match self.entry(id) {
            Some(e) => {
                e.cancel();
                true
            }
            None => false,
        }
    }

    /// Snapshot of every registered job, id-ascending (submission order).
    pub fn jobs(&self) -> Vec<(u64, String, JobStatus)> {
        plock(&self.inner.jobs).values().map(|e| (e.id, e.spec.label(), e.status())).collect()
    }

    /// Block until every job submitted so far is terminal. (Jobs
    /// submitted concurrently with the call may be missed — this is a
    /// test/shutdown barrier, not a fence.)
    pub fn drain(&self) {
        let entries: Vec<Arc<JobEntry>> = plock(&self.inner.jobs).values().cloned().collect();
        for e in entries {
            e.wait_finished();
        }
    }

    /// Drop terminal registry entries past the TTL / count cap (oldest
    /// ids first). Handles keep their `Arc`, so an evicted job's owner
    /// can still read its outcome; only id-based lookups 404.
    fn evict_finished(&self) {
        let cap = self.inner.max_finished;
        let ttl = self.inner.finished_ttl;
        if cap == usize::MAX && ttl.is_none() {
            return;
        }
        let mut jobs = plock(&self.inner.jobs);
        if let Some(ttl) = ttl {
            let expired: Vec<u64> = jobs
                .iter()
                .filter(|(_, e)| e.finished_elapsed().is_some_and(|el| el > ttl))
                .map(|(&id, _)| id)
                .collect();
            for id in expired {
                jobs.remove(&id);
            }
        }
        if cap < usize::MAX {
            let mut finished: Vec<u64> = jobs
                .iter()
                .filter(|(_, e)| e.finished_elapsed().is_some())
                .map(|(&id, _)| id)
                .collect();
            if finished.len() > cap {
                finished.truncate(finished.len() - cap);
                for id in finished {
                    jobs.remove(&id);
                }
            }
        }
        REGISTRY_SIZE.set(jobs.len() as u64);
    }

    /// The coordinator-side cluster registry (worker registration,
    /// heartbeats, distributed generate) — the HTTP layer's access path.
    pub(crate) fn cluster(&self) -> &Arc<Cluster> {
        &self.inner.cluster
    }

    /// The worker-side shard registry — the HTTP layer's access path.
    pub(crate) fn shards(&self) -> &Arc<cluster::ShardServer> {
        &self.inner.shards
    }

    /// Inventory of the content-addressed result store (the `GET
    /// /store` payload); `None` when the service has no state dir.
    pub fn store_inventory(&self) -> Option<Vec<StoreEntry>> {
        self.inner.store.as_ref().map(|s| s.inventory())
    }

    pub(crate) fn entry(&self, id: u64) -> Option<Arc<JobEntry>> {
        plock(&self.inner.jobs).get(&id).cloned()
    }

    /// Every registered entry, id-ascending, cloned out under one lock
    /// acquisition (the HTTP listing's access path).
    pub(crate) fn entries(&self) -> Vec<Arc<JobEntry>> {
        plock(&self.inner.jobs).values().cloned().collect()
    }
}

impl Default for Service {
    fn default() -> Self {
        Service::new()
    }
}

fn executor_loop(inner: Arc<Inner>) {
    while let Some(e) = inner.exec.pop_or_exit() {
        run_job(&inner, &e);
    }
}

/// Spawn-failure fallback: run whatever is queued on the calling thread.
fn drain_queue_inline(inner: &Inner) {
    while let Some(e) = inner.exec.pop_now() {
        run_job(inner, &e);
    }
}

fn run_job(inner: &Inner, entry: &Arc<JobEntry>) {
    {
        let mut st = plock(&entry.state);
        if entry.ctrl.is_cancelled() {
            // Cancelled while queued: settle without touching the
            // pipeline at all.
            drop(st);
            if let Some(log) = &inner.log {
                log.append_finish(entry.id, &LogOutcome::Cancelled, None);
            }
            CANCELLED.inc();
            entry.finish(FinLabel::Cancelled, Err(PipelineError::Cancelled));
            return;
        }
        *st = EntryState::Running;
    }
    let t0 = Instant::now();
    let cache = inner.cache_dir.as_deref();
    let ctrl = Arc::clone(&entry.ctrl);
    // Fixed-R generation consults the cluster first: with live workers
    // registered the region range is sharded across them (merging
    // byte-identically); with none the hook declines and the local
    // engine runs exactly as before.
    let generator: Arc<dyn Generator> = Arc::new(cluster::ClusterGenerator {
        cluster: Arc::clone(&inner.cluster),
        ctrl: Some(Arc::clone(&entry.ctrl)),
    });
    // A panicking stage must fail the job, not kill the executor (the
    // scheduler already forwards task panics to the submitting thread —
    // which is us). AssertUnwindSafe: the pipeline owns all its state
    // and nothing of ours is observable after the catch.
    let run = catch_unwind(AssertUnwindSafe(|| {
        entry.spec.run_serviced(cache, Some(ctrl), Some(generator))
    }));
    let (label, outcome) = match run {
        // A cancel that races the run's completion still wins — even on
        // paths with no checkpoint after their last phase (fixed-R with
        // verify = false): the owner asked the job to stop, so it must
        // not observe success.
        Ok(Ok(_)) if entry.ctrl.is_cancelled() => {
            (FinLabel::Cancelled, Err(PipelineError::Cancelled))
        }
        Ok(Ok(result)) => (FinLabel::Done, Ok(result)),
        Ok(Err(PipelineError::Cancelled)) => {
            (FinLabel::Cancelled, Err(PipelineError::Cancelled))
        }
        // A failure after the cluster degraded to local compute gets
        // the degradation attached: the caller should know the error
        // happened *under* a broken cluster, not a healthy one.
        Ok(Err(e)) if entry.ctrl.is_degraded() => (
            FinLabel::Failed(format!("degraded: {e}")),
            Err(PipelineError::Degraded { source: Box::new(e) }),
        ),
        Ok(Err(e)) => (FinLabel::Failed(e.to_string()), Err(e)),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "job panicked".into());
            (FinLabel::Failed(format!("panic: {msg}")), Err(PipelineError::Panic(msg)))
        }
    };
    // Durability: persist the result (content-addressed), then the
    // terminal log record, then publish — so any state a restarted
    // service replays is backed by what is already on disk.
    let store_key = match (&outcome, &inner.store) {
        (Ok(res), Some(store)) => match store::store_key(&entry.spec) {
            Some(key) => {
                store.save(&key, res);
                Some(key)
            }
            None => None,
        },
        _ => None,
    };
    if let Some(log) = &inner.log {
        let logged = match &label {
            FinLabel::Done => LogOutcome::Done,
            FinLabel::Failed(e) => LogOutcome::Failed(e.clone()),
            FinLabel::Cancelled => LogOutcome::Cancelled,
        };
        log.append_finish(entry.id, &logged, store_key.as_deref());
    }
    match &label {
        FinLabel::Done => DONE.inc(),
        FinLabel::Failed(_) => FAILED.inc(),
        FinLabel::Cancelled => CANCELLED.inc(),
    }
    JOB_MS.observe(t0.elapsed().as_millis() as u64);
    // Close the open phase span before publishing: every later export
    // of this job's trace sees final durations.
    entry.ctrl.finish_trace();
    entry.finish(label, outcome);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::LookupBits;

    fn quick_spec(func: &str) -> JobSpec {
        let mut s = JobSpec::new(func, 8);
        s.lookup = LookupBits::Fixed(4);
        s
    }

    #[test]
    fn submit_wait_matches_direct_run() {
        let svc = Service::builder().workers(2).build();
        let spec = quick_spec("recip");
        let handle = svc.submit(spec.clone());
        assert_eq!(handle.spec().func, "recip");
        let via_service = handle.wait().expect("recip 8b R=4 feasible");
        let direct = spec.run().expect("direct run feasible");
        assert_eq!(via_service.implementation.coeffs, direct.implementation.coeffs);
        assert_eq!(via_service.lookup_bits, direct.lookup_bits);
    }

    #[test]
    fn statuses_progress_to_done_and_failures_are_structured() {
        let svc = Service::builder().workers(1).build();
        let ok = svc.submit(quick_spec("recip"));
        let bad = svc.submit(quick_spec("tan")); // unknown function
        assert!(matches!(
            ok.status(),
            JobStatus::Queued | JobStatus::Running { .. } | JobStatus::Done
        ));
        let result = ok.wait();
        assert!(result.is_ok());
        svc.drain();
        match bad.status() {
            JobStatus::Failed { error } => assert!(error.contains("tan"), "{error}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        match bad.wait() {
            Err(PipelineError::UnknownFunction(f)) => assert_eq!(f, "tan"),
            other => panic!("expected owned UnknownFunction, ok={}", other.is_ok()),
        }
    }

    #[test]
    fn try_result_round_trips_the_handle() {
        let svc = Service::builder().workers(1).build();
        let mut handle = svc.submit(quick_spec("exp2"));
        let result = loop {
            match handle.try_result() {
                Ok(r) => break r,
                Err(h) => {
                    handle = h;
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            }
        };
        assert_eq!(result.unwrap().func, "exp2");
    }

    #[test]
    fn queued_job_cancel_settles_cancelled() {
        // One executor, so the second submission sits queued behind the
        // first; cancelling it must settle Cancelled whether the
        // executor reached it or not.
        let svc = Service::builder().workers(1).build();
        let first = svc.submit(quick_spec("recip"));
        let second = svc.submit(quick_spec("log2"));
        second.cancel();
        assert!(first.wait().is_ok());
        match second.wait() {
            Err(PipelineError::Cancelled) => {}
            other => panic!("expected Cancelled, ok={}", other.is_ok()),
        }
        assert_eq!(svc.status_of(2), Some(JobStatus::Cancelled));
    }

    #[test]
    fn service_registry_answers_by_id() {
        let svc = Service::builder().workers(2).build();
        let a = svc.submit(quick_spec("recip"));
        let b = svc.submit(quick_spec("exp2"));
        let (ida, idb) = (a.id(), b.id());
        assert_ne!(ida, idb);
        svc.drain();
        assert_eq!(svc.status_of(ida), Some(JobStatus::Done));
        assert_eq!(svc.status_of(idb), Some(JobStatus::Done));
        assert_eq!(svc.status_of(999), None);
        assert!(!svc.cancel(999));
        let jobs = svc.jobs();
        assert_eq!(jobs.len(), 2);
        assert!(jobs.iter().all(|(_, _, s)| *s == JobStatus::Done));
    }

    #[test]
    fn interrupted_log_records_replay_as_failed() {
        // A submit record with no finish record is what a crash leaves
        // behind; the replayed entry must settle as a structured failure
        // (never a forever-Running lie) and the id counter must resume
        // past it.
        let dir = std::env::temp_dir()
            .join(format!("polygen_svc_interrupt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let log = JobLog::open(&dir.join("jobs.log")).expect("open log");
            log.append_submit(7, &quick_spec("recip"));
        }
        let svc = Service::builder().workers(1).state_dir(&dir).build();
        match svc.status_of(7) {
            Some(JobStatus::Failed { error }) => {
                assert!(error.contains("interrupted"), "{error}")
            }
            other => panic!("expected interrupted Failed, got {other:?}"),
        }
        let handle = svc.submit(quick_spec("recip"));
        assert!(handle.id() > 7, "id counter must resume past replayed ids");
        assert!(handle.wait().is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn submit_toml_drives_the_pipeline() {
        let svc = Service::builder().workers(1).build();
        let handle = svc
            .submit_toml("func = recip\nbits = 8\n[generate]\nlookup_bits = 4\n")
            .expect("valid job file");
        assert_eq!(handle.wait().unwrap().lookup_bits, 4);
        match svc.submit_toml("func = recip\nbits = many\n") {
            Err(PipelineError::Spec(_)) => {}
            other => panic!("expected Spec error, ok={}", other.is_ok()),
        }
    }
}
