//! The service's executor queue, extracted so the close protocol is one
//! self-contained, loom-modelable unit (DESIGN.md §Static analysis).
//!
//! A [`TaskQueue`] is the park/close half of the service's executor
//! pool: submissions push work and decide whether a new executor thread
//! is warranted; executors pop work or park; closing the queue (what
//! the service's `Gate` does when the last public clone drops) lets
//! parked executors drain the backlog and exit instead of re-parking.
//! The invariant the loom models check: after `close`, every item
//! pushed *before* the close is still popped by someone — the backlog
//! is drained, never abandoned.
//!
//! This type is `pub` only so the `tests/loom` suite can drive it; it
//! is not part of the crate's supported API surface.

use std::collections::VecDeque;

use crate::obs::metrics;
use crate::sync::{cwait, plock, Condvar, Mutex};

// Process-global last-write-wins gauges; with several queues alive
// (tests) they track whichever moved last, which is exactly the
// production shape (one service, one queue).
const QUEUE_DEPTH: metrics::Gauge = metrics::gauge("exec.queue_depth");
const EXECUTORS: metrics::Gauge = metrics::gauge("exec.executors");

struct QueueState<T> {
    queue: VecDeque<T>,
    /// Executor threads alive (decremented when one exits).
    spawned: usize,
    /// Executors parked waiting for work.
    idle: usize,
    /// Set once by [`TaskQueue::close`]: executors drain the backlog,
    /// then exit instead of parking.
    closed: bool,
}

/// A close-aware MPMC work queue with executor-pool accounting.
pub struct TaskQueue<T> {
    state: Mutex<QueueState<T>>,
    work_cv: Condvar,
}

impl<T> Default for TaskQueue<T> {
    fn default() -> Self {
        TaskQueue::new()
    }
}

impl<T> TaskQueue<T> {
    pub fn new() -> TaskQueue<T> {
        TaskQueue {
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                spawned: 0,
                idle: 0,
                closed: false,
            }),
            work_cv: Condvar::new(),
        }
    }

    /// Push `item` and decide whether the caller should spawn a new
    /// executor: `true` whenever the backlog exceeds the parked
    /// executors and the pool is under `cap` — a burst of submissions
    /// to a warm pool must ramp to `cap`-way concurrency, not serialize
    /// on whichever executor happens to be idle. A `true` return
    /// *reserves* the spawn slot (the `spawned` count is already
    /// incremented); the caller must either actually spawn an executor
    /// that will run a pop loop, or report [`TaskQueue::spawn_failed`].
    pub fn push_and_plan(&self, item: T, cap: usize) -> bool {
        let mut st = plock(&self.state);
        st.queue.push_back(item);
        QUEUE_DEPTH.set(st.queue.len() as u64);
        let plan = st.idle < st.queue.len() && st.spawned < cap;
        if plan {
            st.spawned += 1;
            EXECUTORS.set(st.spawned as u64);
        }
        drop(st);
        self.work_cv.notify_one();
        plan
    }

    /// Roll back a reserved spawn slot after a failed thread spawn.
    /// Returns `true` when no executor remains alive — the caller must
    /// then drain the queue inline ([`TaskQueue::pop_now`]) so no
    /// pushed item can hang forever.
    pub fn spawn_failed(&self) -> bool {
        let mut st = plock(&self.state);
        st.spawned -= 1;
        EXECUTORS.set(st.spawned as u64);
        st.spawned == 0
    }

    /// The executor loop's blocking pop: an item to run, or `None` when
    /// the queue is closed *and* the backlog is fully drained — at
    /// which point this executor's `spawned` slot is already released
    /// and it must exit.
    pub fn pop_or_exit(&self) -> Option<T> {
        let mut st = plock(&self.state);
        loop {
            if let Some(item) = st.queue.pop_front() {
                QUEUE_DEPTH.set(st.queue.len() as u64);
                return Some(item);
            }
            if st.closed {
                st.spawned -= 1;
                EXECUTORS.set(st.spawned as u64);
                return None;
            }
            st.idle += 1;
            st = cwait(&self.work_cv, st);
            st.idle -= 1;
        }
    }

    /// Non-blocking pop (the inline-drain fallback when no executor
    /// could be spawned).
    pub fn pop_now(&self) -> Option<T> {
        let mut st = plock(&self.state);
        let item = st.queue.pop_front();
        QUEUE_DEPTH.set(st.queue.len() as u64);
        item
    }

    /// Close the queue: parked executors wake, drain the backlog, and
    /// exit. Items may still be pushed afterwards; they are only
    /// guaranteed to run if the pusher handles the no-executor case
    /// (the service never pushes after its gate dropped — the gate *is*
    /// the last clone).
    pub fn close(&self) {
        let mut st = plock(&self.state);
        st.closed = true;
        drop(st);
        self.work_cv.notify_all();
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::sync::Arc;

    #[test]
    fn push_plans_spawns_up_to_cap() {
        let q: TaskQueue<u32> = TaskQueue::new();
        assert!(q.push_and_plan(1, 2), "first push must plan an executor");
        assert!(q.push_and_plan(2, 2), "backlog of 2 > idle 0, under cap");
        assert!(!q.push_and_plan(3, 2), "at cap: no third executor");
        // Failed spawns roll back; the last rollback demands inline drain.
        assert!(!q.spawn_failed(), "one executor slot still reserved");
        assert!(q.spawn_failed(), "no executors left: caller must drain inline");
        assert_eq!(q.pop_now(), Some(1));
        assert_eq!(q.pop_now(), Some(2));
        assert_eq!(q.pop_now(), Some(3));
        assert_eq!(q.pop_now(), None);
    }

    #[test]
    fn close_drains_backlog_then_exits_executors() {
        let q: Arc<TaskQueue<u32>> = Arc::new(TaskQueue::new());
        assert!(q.push_and_plan(10, 4));
        assert!(q.push_and_plan(20, 4));
        q.close();
        let worker = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(v) = q.pop_or_exit() {
                    seen.push(v);
                }
                seen
            })
        };
        let seen = worker.join().unwrap();
        assert_eq!(seen, vec![10, 20], "backlog drained before exit");
    }

    #[test]
    fn close_wakes_a_parked_executor() {
        let q: Arc<TaskQueue<u32>> = Arc::new(TaskQueue::new());
        assert!(q.push_and_plan(1, 1));
        let worker = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut n = 0;
                while q.pop_or_exit().is_some() {
                    n += 1;
                }
                n
            })
        };
        // Eventually the worker pops the item and parks; close must wake
        // it so it exits rather than parking forever.
        q.close();
        assert_eq!(worker.join().unwrap(), 1);
    }
}
