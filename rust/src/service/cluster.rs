//! `polygen::cluster` — region-sharded multi-worker generation.
//!
//! The unit of parallelism is the region: per-region analyses are
//! independent, and the common `k` is an associative max over the
//! per-region minima — so one job's region range `0..2^R` splits into
//! contiguous shards that different *processes* can analyze. This
//! module is both halves of that protocol:
//!
//! - **Coordinator side** ([`Cluster`]): a heartbeat-tracked worker
//!   registry (`POST /workers`, `POST /workers/:id/heartbeat`) and the
//!   distributed generate driver, which assigns shards round-robin,
//!   polls them, reassigns a dead worker's shard (heartbeat timeout or
//!   connection failure) to a live worker — or analyzes it locally when
//!   none is left — and merges the returned per-region entry lists. The
//!   merged space is **byte-identical to single-node generation**: the
//!   pure shard algebra lives in [`crate::designspace`]
//!   ([`analyze_shard`]/[`sweep_shard`]/[`merge_shard_spaces`]) and is
//!   property-tested there across shard counts and boundaries.
//! - **Worker side** ([`ShardServer`]): an async shard state machine
//!   behind `POST /shards` (spec TOML + `[shard] lo/hi` → analyze in a
//!   background thread), `GET /shards/:id` (flat JSON status carrying
//!   `min_k`/`dd_evals` or the structured [`GenError`]),
//!   `POST /shards/:id/sweep` (sweep at the cluster-wide common `k`,
//!   returning the region entries as a versioned `PGSH` binary — the
//!   JSON layer has no arrays, and entry lists are big), and
//!   `DELETE /shards/:id` (cooperative cancel + drop). Plus
//!   [`run_worker_agent`]: the register/heartbeat/re-register loop
//!   `polygen serve --worker --coordinator <url>` runs.
//!
//! The wire protocol is two-phase because the common `k` is global:
//! every shard must finish analyzing before any shard can sweep. Shard
//! requests reuse the job-file TOML grammar; binary payloads reuse the
//! PGDS length-prefixed idiom. See DESIGN.md §Cluster.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use crate::bounds::{builtin, BoundTable};
use crate::designspace::region::RegionSpace;
use crate::designspace::{
    analyze_shard, merge_shard_spaces, shard_ranges, sweep_shard, DesignSpace, GenError,
    GenOptions, ShardAnalysis,
};
use crate::faults::{self, Fault};
use crate::net::{CircuitBreaker, Policy, RetryBudget};
use crate::obs::metrics;
use crate::obs::trace::{Tracer, TID_SHARDS};
use crate::pipeline::{Config, JobSpec, LookupBits, SearchStrategy};
use crate::pool::{CancelToken, Progress};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{cwait, plock, thread, Arc, Condvar, Mutex};

use super::http::{json_str, obj};
use super::store::crc32;

/// How often a worker pings its coordinator, and the staleness bound
/// after which the coordinator treats it as dead and reassigns its
/// shard. Tests shrink the timeout through [`Cluster::new`].
pub const HEARTBEAT_INTERVAL: Duration = Duration::from_secs(2);
pub const DEFAULT_HEARTBEAT_TIMEOUT: Duration = Duration::from_secs(10);

/// Coordinator → worker poll cadence while a shard analyzes.
const POLL_INTERVAL: Duration = Duration::from_millis(25);

const SHARDS_DISPATCHED: metrics::Counter = metrics::counter("cluster.shards_dispatched");
const SHARDS_REASSIGNED: metrics::Counter = metrics::counter("cluster.shards_reassigned");
const HEARTBEAT_MISSES: metrics::Counter = metrics::counter("cluster.heartbeat_misses");
const WIRE_CRC_FAILURES: metrics::Counter = metrics::counter("cluster.wire_crc_failures");
const DEGRADED: metrics::Counter = metrics::counter("cluster.degraded");
const STRIKES: metrics::Counter = metrics::counter("cluster.strikes");

// ---------------------------------------------------------------------
// Minimal HTTP client (the other half of service::http's server).

/// Strip an `http://` scheme and trailing slash: the registry stores
/// plain `host:port` but accepts URL spellings.
pub(crate) fn normalize_addr(addr: &str) -> String {
    addr.trim().trim_start_matches("http://").trim_end_matches('/').to_string()
}

/// One `Connection: close` HTTP/1.1 exchange with a per-call deadline
/// covering connect, write, and read. Returns `(status, body)`;
/// transport-level failures are `Err` (the coordinator's dead-worker
/// signal). Carries the `cluster.call*` fault-injection sites — every
/// coordinator↔worker exchange funnels through here.
pub(crate) fn http_call_to(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    auth: Option<&str>,
    timeout: Duration,
) -> Result<(u16, Vec<u8>), String> {
    let addr = normalize_addr(addr);
    match faults::inject("cluster.call", &[Fault::Drop, Fault::Delay, Fault::Refuse]) {
        Some(Fault::Drop) => return Err(format!("{addr}: injected connection drop")),
        Some(Fault::Refuse) => {
            return Ok((503, br#"{"error":"injected refusal"}"#.to_vec()));
        }
        Some(Fault::Delay) => faults::small_delay(),
        _ => {}
    }
    // Outbound tampering happens on a copy: the caller's buffer is its
    // record of what it *meant* to send (e.g. for body_crc checks).
    let mut sent: Vec<u8>;
    let mut torn = false;
    // The declared Content-Length is always the intended body's: a torn
    // send promises more bytes than it delivers.
    let declared_len = body.len();
    let body: &[u8] = match faults::inject("cluster.call.send", &[Fault::Corrupt, Fault::Truncate])
    {
        Some(Fault::Corrupt) if !body.is_empty() => {
            sent = body.to_vec();
            let at = faults::rand_below(sent.len());
            sent[at] ^= 0x01;
            &sent
        }
        Some(Fault::Truncate) if !body.is_empty() => {
            // Send a prefix, then close the write half: the peer sees a
            // torn request (EOF before Content-Length), not a stall.
            sent = body.to_vec();
            let keep = faults::rand_below(sent.len());
            sent.truncate(keep);
            torn = true;
            &sent
        }
        _ => body,
    };
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| format!("{addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("{addr}: unresolvable"))?;
    let mut stream =
        TcpStream::connect_timeout(&sock, timeout).map_err(|e| format!("{addr}: {e}"))?;
    stream.set_read_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    stream.set_write_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    let auth_line = match auth {
        Some(tok) => format!("Authorization: Bearer {tok}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {declared_len}\r\n\
         {auth_line}Connection: close\r\n\r\n"
    );
    stream.write_all(head.as_bytes()).map_err(|e| e.to_string())?;
    stream.write_all(body).map_err(|e| e.to_string())?;
    stream.flush().map_err(|e| e.to_string())?;
    if torn {
        let _ = stream.shutdown(std::net::Shutdown::Write);
    }

    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| e.to_string())?;
    let code: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| format!("bad status line {line:?}"))?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).map_err(|e| e.to_string())?;
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = Some(v.trim().parse().map_err(|_| "bad content-length")?);
            }
        }
    }
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            reader.read_exact(&mut body).map_err(|e| e.to_string())?;
        }
        None => {
            reader.read_to_end(&mut body).map_err(|e| e.to_string())?;
        }
    }
    match faults::inject("cluster.call.recv", &[Fault::Corrupt, Fault::Truncate]) {
        Some(Fault::Corrupt) if !body.is_empty() => {
            let at = faults::rand_below(body.len());
            body[at] ^= 0x01;
        }
        Some(Fault::Truncate) if !body.is_empty() => {
            let keep = faults::rand_below(body.len());
            body.truncate(keep);
        }
        _ => {}
    }
    Ok((code, body))
}

/// Extract `"key":<number>` from a flat JSON object (the coordinator
/// reads only scalar fields off the wire).
pub(crate) fn json_u64(body: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat)? + pat.len();
    let rest = &body[at..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract `"key":"value"` from a flat JSON object (values here are
/// labels — never escaped).
pub(crate) fn json_field<'a>(body: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":\"");
    let at = body.find(&pat)? + pat.len();
    let rest = &body[at..];
    Some(&rest[..rest.find('"')?])
}

// ---------------------------------------------------------------------
// Shard request wire format (TOML, reusing the job-file grammar).

fn search_label(s: SearchStrategy) -> &'static str {
    match s {
        SearchStrategy::Hull => "hull",
        SearchStrategy::Pruned => "pruned",
        SearchStrategy::Naive => "naive",
    }
}

/// The `POST /shards` body: the generation-affecting spec fields plus
/// the `[shard]` range.
fn shard_request(bt: &BoundTable, opts: &GenOptions, lo: u64, hi: u64) -> String {
    // The default degree stays implicit (the `to_toml` idiom), so
    // degree-2 request bodies are byte-identical to the pre-degree wire.
    let degree = if opts.degree != 2 {
        format!("degree = {}\n", opts.degree)
    } else {
        String::new()
    };
    format!(
        "func = {}\nbits = {}\naccuracy = {}\n\n[generate]\nlookup_bits = {}\n\
         {degree}search = {}\nmax_k = {}\nthreads = {}\n\n[shard]\nlo = {lo}\nhi = {hi}\n",
        bt.func,
        bt.in_bits,
        bt.accuracy,
        opts.lookup_bits,
        search_label(opts.search),
        opts.max_k,
        opts.threads,
    )
}

/// Parse a shard request back into `(bound table, options, lo, hi)`.
fn parse_shard_request(text: &str) -> Result<(BoundTable, GenOptions, u64, u64), String> {
    let cfg = Config::parse(text)?;
    let spec = JobSpec::from_config(&cfg).map_err(|e| e.to_string())?;
    let LookupBits::Fixed(lookup_bits) = spec.lookup else {
        return Err("shard requests must pin lookup_bits".into());
    };
    let lo = cfg.get_u32("shard.lo")?.ok_or("missing shard.lo")? as u64;
    let hi = cfg.get_u32("shard.hi")?.ok_or("missing shard.hi")? as u64;
    let f = builtin(&spec.func, spec.bits)
        .ok_or_else(|| format!("unknown function {}", spec.func))?;
    let bt = BoundTable::build(f.as_ref(), spec.accuracy);
    let opts = GenOptions {
        lookup_bits,
        search: spec.search,
        max_k: spec.max_k,
        threads: spec.threads,
        degree: spec.gen_degree,
    };
    if !(lo < hi && hi <= (1u64 << lookup_bits)) {
        return Err(format!("shard {lo}..{hi} out of range for R={lookup_bits}"));
    }
    Ok((bt, opts, lo, hi))
}

// ---------------------------------------------------------------------
// PGSH: the swept-shard binary (entry lists are too big for the flat
// JSON layer; same length-prefixed little-endian idiom as PGDS).

const PGSH_MAGIC: &[u8; 4] = b"PGSH";
// v2 appends a CRC-32 of everything before it. The entries feed the
// byte-identical merge, so a bit flipped in transit must be *detected*
// (→ reassign/local re-analysis), never silently merged.
const PGSH_VERSION: u32 = 2;

fn encode_pgsh(lo: u64, hi: u64, k: u32, dd_evals: u64, regions: &[RegionSpace]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(PGSH_MAGIC);
    out.extend_from_slice(&PGSH_VERSION.to_le_bytes());
    out.extend_from_slice(&lo.to_le_bytes());
    out.extend_from_slice(&hi.to_le_bytes());
    out.extend_from_slice(&k.to_le_bytes());
    out.extend_from_slice(&dd_evals.to_le_bytes());
    for sp in regions {
        out.extend_from_slice(&sp.r.to_le_bytes());
        out.extend_from_slice(&u32::from(sp.linear_ok).to_le_bytes());
        out.extend_from_slice(&(sp.entries.len() as u32).to_le_bytes());
        for e in &sp.entries {
            out.extend_from_slice(&e.a.to_le_bytes());
            out.extend_from_slice(&e.b_lo.to_le_bytes());
            out.extend_from_slice(&e.b_hi.to_le_bytes());
        }
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

struct Pgsh {
    lo: u64,
    hi: u64,
    k: u32,
    dd_evals: u64,
    regions: Vec<RegionSpace>,
}

fn decode_pgsh(bytes: &[u8]) -> Option<Pgsh> {
    use crate::designspace::region::AbEntry;
    fn take<'a>(b: &mut &'a [u8], n: usize) -> Option<&'a [u8]> {
        if b.len() < n {
            return None;
        }
        let (head, tail) = b.split_at(n);
        *b = tail;
        Some(head)
    }
    fn r_u32(b: &mut &[u8]) -> Option<u32> {
        take(b, 4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }
    fn r_u64(b: &mut &[u8]) -> Option<u64> {
        take(b, 8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }
    fn r_i64(b: &mut &[u8]) -> Option<i64> {
        take(b, 8).map(|s| i64::from_le_bytes(s.try_into().unwrap()))
    }
    // Verify the whole-payload checksum before trusting any field.
    if bytes.len() < 4 {
        return None;
    }
    let (payload, trailer) = bytes.split_at(bytes.len() - 4);
    if crc32(payload) != u32::from_le_bytes(trailer.try_into().unwrap()) {
        return None;
    }
    let mut b = payload;
    if take(&mut b, 4)? != PGSH_MAGIC || r_u32(&mut b)? != PGSH_VERSION {
        return None;
    }
    let lo = r_u64(&mut b)?;
    let hi = r_u64(&mut b)?;
    let k = r_u32(&mut b)?;
    let dd_evals = r_u64(&mut b)?;
    if hi <= lo {
        return None;
    }
    let mut regions = Vec::with_capacity((hi - lo) as usize);
    for _ in lo..hi {
        let r = r_u64(&mut b)?;
        let linear_ok = match r_u32(&mut b)? {
            0 => false,
            1 => true,
            _ => return None,
        };
        let nent = r_u32(&mut b)? as usize;
        let mut entries = Vec::with_capacity(nent);
        for _ in 0..nent {
            let a = r_i64(&mut b)?;
            let b_lo = r_i64(&mut b)?;
            let b_hi = r_i64(&mut b)?;
            entries.push(AbEntry { a, b_lo, b_hi });
        }
        regions.push(RegionSpace { r, k, entries, linear_ok });
    }
    if !b.is_empty() {
        return None;
    }
    Some(Pgsh { lo, hi, k, dd_evals, regions })
}

// ---------------------------------------------------------------------
// Worker side: the shard state machine.

enum ShardState {
    Analyzing,
    Analyzed(ShardAnalysis),
    Failed(GenError),
    /// The analysis thread panicked. Reported distinctly (not as a
    /// [`GenError`]) so the coordinator reassigns the shard instead of
    /// failing the job — and so the shard can never park in `Analyzing`
    /// forever.
    Panicked,
}

/// Checksum over a shard status' load-bearing fields. The coordinator
/// recomputes it from the fields it parsed off the wire; a mismatch
/// (bit flip, truncation) makes the response unintelligible, which is a
/// reassign — never a silently-wrong `min_k` in the merged space.
fn status_check(id: u64, state: &str, a: u64, b: u64, c: u64) -> u32 {
    crc32(format!("{id}/{state}/{a}/{b}/{c}").as_bytes())
}

struct ShardEntry {
    cancel: CancelToken,
    state: Mutex<ShardState>,
    cv: Condvar,
    /// Generation degree the shard was analyzed at; the sweep must
    /// enumerate the same slice.
    degree: u32,
}

/// The worker-side shard registry every service carries (any `polygen
/// serve` instance can take shard work; it only does when a coordinator
/// sends some).
#[derive(Default)]
pub(crate) struct ShardServer {
    next_id: AtomicU64,
    shards: Mutex<BTreeMap<u64, Arc<ShardEntry>>>,
}

impl ShardServer {
    /// `POST /shards`: parse, spawn the analysis, return the shard id.
    pub fn start(&self, body: &str) -> Result<u64, String> {
        let (bt, opts, lo, hi) = parse_shard_request(body)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let entry = Arc::new(ShardEntry {
            cancel: CancelToken::new(),
            state: Mutex::new(ShardState::Analyzing),
            cv: Condvar::new(),
            degree: opts.degree,
        });
        plock(&self.shards).insert(id, Arc::clone(&entry));
        let worker = Arc::clone(&entry);
        let spawned = thread::spawn_named(format!("polygen-shard-{id}"), move || {
            let result = catch_unwind(AssertUnwindSafe(|| {
                analyze_shard(&bt, &opts, lo, hi, Some(&worker.cancel))
            }));
            let mut st = plock(&worker.state);
            *st = match result {
                Ok(Ok(sa)) => ShardState::Analyzed(sa),
                Ok(Err(e)) => ShardState::Failed(e),
                Err(_) => ShardState::Panicked,
            };
            drop(st);
            worker.cv.notify_all();
        })
        .is_some();
        if !spawned {
            // Thread exhaustion: analyze inline rather than leaving the
            // shard parked in Analyzing forever.
            let result = catch_unwind(AssertUnwindSafe(|| {
                analyze_shard(&bt, &opts, lo, hi, Some(&entry.cancel))
            }));
            let mut st = plock(&entry.state);
            *st = match result {
                Ok(Ok(sa)) => ShardState::Analyzed(sa),
                Ok(Err(e)) => ShardState::Failed(e),
                Err(_) => ShardState::Panicked,
            };
        }
        Ok(id)
    }

    /// `GET /shards/:id`: flat-scalar status JSON.
    pub fn status_json(&self, id: u64) -> Option<String> {
        let entry = plock(&self.shards).get(&id).cloned()?;
        let st = plock(&entry.state);
        let body = match &*st {
            ShardState::Analyzing => obj([
                ("id", id.to_string()),
                ("state", json_str("analyzing")),
                ("check", status_check(id, "analyzing", 0, 0, 0).to_string()),
            ]),
            ShardState::Analyzed(sa) => obj([
                ("id", id.to_string()),
                ("state", json_str("analyzed")),
                ("min_k", sa.min_k.to_string()),
                ("dd_evals", sa.dd_evals.to_string()),
                (
                    "check",
                    status_check(id, "analyzed", sa.min_k as u64, sa.dd_evals, 0).to_string(),
                ),
            ]),
            ShardState::Failed(e) => {
                let mut fields = vec![("id", id.to_string()), ("state", json_str("failed"))];
                let (region, max_k, code) = match e {
                    GenError::InfeasibleRegion { r } => {
                        fields.push(("kind", json_str("infeasible")));
                        fields.push(("region", r.to_string()));
                        (*r, 0, 1)
                    }
                    GenError::KExhausted { r, max_k } => {
                        fields.push(("kind", json_str("k_exhausted")));
                        fields.push(("region", r.to_string()));
                        fields.push(("max_k", max_k.to_string()));
                        (*r, *max_k as u64, 2)
                    }
                    GenError::Cancelled => {
                        fields.push(("kind", json_str("cancelled")));
                        (0, 0, 3)
                    }
                };
                fields.push(("check", status_check(id, "failed", region, max_k, code).to_string()));
                obj(fields)
            }
            ShardState::Panicked => obj([
                ("id", id.to_string()),
                ("state", json_str("panicked")),
                ("check", status_check(id, "panicked", 0, 0, 0).to_string()),
            ]),
        };
        Some(body)
    }

    /// `POST /shards/:id/sweep` (body `k = <common k>`): block until the
    /// analysis lands, then sweep and encode. Errors are
    /// `(status, json)` pairs ready for the HTTP layer.
    pub fn sweep(&self, id: u64, body: &str) -> Result<Vec<u8>, (u16, String)> {
        let bad = |m: &str| (400u16, obj([("error", json_str(m))]));
        let k = Config::parse(body)
            .and_then(|c| c.get_u32("k")?.ok_or_else(|| "missing k".into()))
            .map_err(|e| bad(&e))?;
        let entry = plock(&self.shards)
            .get(&id)
            .cloned()
            .ok_or((404, obj([("error", json_str("no such shard"))])))?;
        let mut st = plock(&entry.state);
        loop {
            match &*st {
                ShardState::Analyzing => st = cwait(&entry.cv, st),
                ShardState::Failed(_) => {
                    return Err((409, obj([("error", json_str("shard failed"))])))
                }
                ShardState::Panicked => {
                    return Err((409, obj([("error", json_str("shard panicked"))])))
                }
                ShardState::Analyzed(sa) => {
                    if k < sa.min_k {
                        return Err(bad(&format!("k={k} below shard minimum {}", sa.min_k)));
                    }
                    let regions = sweep_shard(sa, k, entry.degree);
                    return Ok(encode_pgsh(sa.lo, sa.hi, k, sa.dd_evals, &regions));
                }
            }
        }
    }

    /// `DELETE /shards/:id`: cooperative cancel + unregister.
    pub fn cancel(&self, id: u64) -> bool {
        match plock(&self.shards).remove(&id) {
            Some(e) => {
                e.cancel.cancel();
                true
            }
            None => false,
        }
    }
}

// ---------------------------------------------------------------------
// Coordinator side.

struct WorkerInfo {
    addr: String,
    last_seen: Instant,
}

/// A registered worker as the `GET /workers` listing reports it.
#[derive(Clone, Debug)]
pub struct WorkerView {
    pub id: u64,
    pub addr: String,
    /// Eligible for shard work right now (fresh heartbeat, breaker not
    /// blocking).
    pub live: bool,
    /// `"live"`, `"stale"` (heartbeat timed out), or `"quarantined"`
    /// (circuit breaker open after consecutive call failures).
    pub state: &'static str,
}

/// The coordinator's worker registry + distributed generate driver.
///
/// Failure handling (see DESIGN.md §Fault model): every call to a
/// worker runs under the cluster [`Policy`] (per-attempt deadline,
/// bounded retries, shared [`RetryBudget`]), and each worker carries a
/// [`CircuitBreaker`] — after `breaker_threshold` consecutive failed
/// calls (or unintelligible responses) the worker is *quarantined*: it
/// stays registered and listed, but receives no shards until a
/// post-cooldown probe succeeds. A heartbeat-stale worker is likewise
/// skipped but no longer deleted from the registry.
pub(crate) struct Cluster {
    next_id: AtomicU64,
    workers: Mutex<BTreeMap<u64, WorkerInfo>>,
    timeout: Duration,
    auth: Mutex<Option<String>>,
    policy: Mutex<Policy>,
    budget: RetryBudget,
    breakers: Mutex<BTreeMap<u64, Arc<CircuitBreaker>>>,
}

impl Cluster {
    pub fn new(timeout: Duration) -> Cluster {
        Cluster {
            next_id: AtomicU64::new(0),
            workers: Mutex::new(BTreeMap::new()),
            timeout,
            auth: Mutex::new(None),
            policy: Mutex::new(Policy::default()),
            budget: RetryBudget::new(10.0),
            breakers: Mutex::new(BTreeMap::new()),
        }
    }

    /// Token forwarded on coordinator → worker calls (the cluster shares
    /// one `--auth-token`).
    pub fn set_auth(&self, token: Option<String>) {
        *plock(&self.auth) = token;
    }

    fn auth(&self) -> Option<String> {
        plock(&self.auth).clone()
    }

    /// Install the call policy (`--call-timeout` / `--retries` /
    /// `--breaker-threshold`).
    pub fn set_policy(&self, policy: Policy) {
        *plock(&self.policy) = policy;
    }

    fn policy(&self) -> Policy {
        plock(&self.policy).clone()
    }

    fn breaker(&self, id: u64) -> Arc<CircuitBreaker> {
        Arc::clone(plock(&self.breakers).entry(id).or_default())
    }

    fn breaker_allows(&self, id: u64) -> bool {
        plock(&self.breakers).get(&id).map_or(true, |b| b.allow())
    }

    /// Record a protocol-level failure (non-200, unintelligible or
    /// checksum-failing response) against `id`'s breaker. Transport
    /// failures are recorded by [`Cluster::call`] itself.
    pub fn note_failure(&self, id: u64) {
        STRIKES.inc();
        let policy = self.policy();
        let b = self.breaker(id);
        if b.on_failure(policy.breaker_threshold, policy.breaker_cooldown) {
            let addr = self.addr_of(id).unwrap_or_default();
            eprintln!(
                "polygen: worker {id} ({addr}) quarantined after \
                 {} consecutive call failures",
                policy.breaker_threshold
            );
        }
    }

    /// One policy-governed call to worker `id`: per-attempt deadline,
    /// bounded budgeted retries, breaker consulted and updated. The
    /// single funnel for every coordinator → worker exchange that
    /// matters (best-effort shard releases go around it).
    fn call(
        &self,
        id: u64,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> Result<(u16, Vec<u8>), String> {
        let Some(addr) = self.addr_of(id) else {
            return Err(format!("worker {id} not registered"));
        };
        let auth = self.auth();
        let policy = self.policy();
        let breaker = self.breaker(id);
        let was_open = breaker.is_open();
        let r = policy.run(Some(&self.budget), Some(&breaker), |timeout| {
            http_call_to(&addr, method, path, body, auth.as_deref(), timeout)
        });
        if r.is_err() && !was_open && breaker.is_open() {
            eprintln!(
                "polygen: worker {id} ({addr}) quarantined after \
                 {} consecutive call failures",
                policy.breaker_threshold
            );
        }
        r
    }

    /// `POST /workers`: register (or re-register) a worker by address.
    /// Re-registering an address replaces the old entry (so a restarted
    /// worker does not appear twice) and resets its breaker — a
    /// re-registration is positive evidence the worker is back.
    pub fn register(&self, addr: &str) -> u64 {
        let addr = normalize_addr(addr);
        let mut ws = plock(&self.workers);
        let replaced: Vec<u64> =
            ws.iter().filter(|(_, w)| w.addr == addr).map(|(&id, _)| id).collect();
        ws.retain(|_, w| w.addr != addr);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        ws.insert(id, WorkerInfo { addr, last_seen: Instant::now() });
        drop(ws);
        let mut breakers = plock(&self.breakers);
        for old in replaced {
            breakers.remove(&old);
        }
        breakers.remove(&id);
        id
    }

    /// `POST /workers/:id/heartbeat` → `false` = unknown id (the worker
    /// should re-register; the coordinator may have restarted).
    pub fn heartbeat(&self, id: u64) -> bool {
        match plock(&self.workers).get_mut(&id) {
            Some(w) => {
                w.last_seen = Instant::now();
                true
            }
            None => false,
        }
    }

    /// Registered workers, id-ascending, with their availability state.
    pub fn workers(&self) -> Vec<WorkerView> {
        let views: Vec<(u64, String, bool)> = {
            let ws = plock(&self.workers);
            ws.iter()
                .map(|(&id, w)| (id, w.addr.clone(), w.last_seen.elapsed() < self.timeout))
                .collect()
        };
        views
            .into_iter()
            .map(|(id, addr, fresh)| {
                let allows = self.breaker_allows(id);
                let state = if !fresh {
                    "stale"
                } else if !allows {
                    "quarantined"
                } else {
                    "live"
                };
                WorkerView { id, addr, live: fresh && allows, state }
            })
            .collect()
    }

    fn live(&self) -> Vec<(u64, String)> {
        self.workers().into_iter().filter(|w| w.live).map(|w| (w.id, w.addr)).collect()
    }

    /// Any worker at all in the registry? (Distinguishes "never had a
    /// cluster" from "had one and lost it" — only the latter is a
    /// degradation worth flagging.)
    fn any_registered(&self) -> bool {
        !plock(&self.workers).is_empty()
    }

    /// Distributed generation: shard `0..2^R` over the live workers,
    /// merge byte-identically to single-node. `None` = no live workers
    /// (caller falls back to the local engine); `ticks` counts analyzed
    /// regions (no `begin` — the caller owns the progress window).
    /// `degraded` (when given) is set — once, with a log line — the
    /// first time any part of the job silently falls from remote to
    /// local compute while workers are still registered.
    pub fn generate(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
        degraded: Option<&AtomicBool>,
        tracer: Option<&Tracer>,
    ) -> Option<Result<DesignSpace, GenError>> {
        let live = self.live();
        if live.is_empty() {
            if self.any_registered() {
                // Workers exist but none is reachable: the caller will
                // compute locally, which is correct but not what the
                // operator deployed workers for — say so.
                mark_degraded(
                    degraded,
                    "all registered workers are stale or quarantined; computing locally",
                );
            }
            return None;
        }
        let nregions = 1u64 << opts.lookup_bits;
        let ranges = shard_ranges(nregions, live.len());
        Some(self.drive(bt, opts, &ranges, cancel, ticks, degraded, tracer))
    }

    #[allow(clippy::too_many_arguments)]
    fn drive(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        ranges: &[(u64, u64)],
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
        degraded: Option<&AtomicBool>,
        tracer: Option<&Tracer>,
    ) -> Result<DesignSpace, GenError> {
        let auth = self.auth();
        let auth = auth.as_deref();
        // Per-shard child span: lane `TID_SHARDS + i`, so each shard gets
        // its own row under the job's phase lane in chrome://tracing.
        let span = |i: usize, op: &str, start: Instant| {
            if let Some(t) = tracer {
                t.record(format!("shard {i} {op}"), "shard", TID_SHARDS + i as u64, start, Instant::now());
            }
        };

        // Assign round-robin; a worker that fails the initial POST
        // advances its breaker and the shard moves on. `opened[i]` is
        // shard `i`'s span start: (re)set at assignment, closed when the
        // analysis settles.
        let mut rr = 0usize;
        let mut opened: Vec<Instant> = Vec::with_capacity(ranges.len());
        let mut slots: Vec<Slot> = ranges
            .iter()
            .map(|&(lo, hi)| {
                opened.push(Instant::now());
                let slot = self.assign(bt, opts, lo, hi, &mut rr, cancel, ticks, degraded);
                if !matches!(slot, Slot::Remote(..)) {
                    // Local fallback (or failure) settles inside assign.
                    span(opened.len() - 1, "analyze", opened[opened.len() - 1]);
                }
                slot
            })
            .collect();

        // Poll until every slot settles, reassigning slots whose worker
        // died mid-analysis (call failures past the retry policy,
        // heartbeat timeout, or an unintelligible/corrupt response).
        loop {
            if cancel.is_some_and(|c| c.is_cancelled()) {
                self.release(&slot_remotes(&slots), auth);
                return Err(GenError::Cancelled);
            }
            let mut pending = false;
            for (i, &(lo, hi)) in ranges.iter().enumerate() {
                let Slot::Remote(worker, remote) = slots[i] else { continue };
                let mut reassign =
                    |slots: &mut Vec<Slot>, opened: &mut Vec<Instant>, pending: &mut bool| {
                        // Best-effort: free the orphaned remote shard.
                        self.release(&[(worker, remote)], auth);
                        SHARDS_REASSIGNED.inc();
                        opened[i] = Instant::now();
                        slots[i] = self.assign(bt, opts, lo, hi, &mut rr, cancel, ticks, degraded);
                        if matches!(slots[i], Slot::Remote(..)) {
                            *pending = true;
                        } else {
                            span(i, "analyze", opened[i]);
                        }
                    };
                if !self.is_live(worker) {
                    HEARTBEAT_MISSES.inc();
                    reassign(&mut slots, &mut opened, &mut pending);
                    continue;
                }
                match self.call(worker, "GET", &format!("/shards/{remote}"), b"") {
                    Ok((200, body)) => {
                        let body = String::from_utf8_lossy(&body).into_owned();
                        let poll = verified_status(&body, remote);
                        if poll.is_none() {
                            // Unintelligible or checksum-failing status.
                            WIRE_CRC_FAILURES.inc();
                        }
                        match poll {
                            Some(ShardPoll::Analyzing) => pending = true,
                            Some(ShardPoll::Analyzed { min_k, dd_evals }) => {
                                if let Some(p) = ticks {
                                    p.add((hi - lo) as usize);
                                }
                                span(i, "analyze", opened[i]);
                                slots[i] = Slot::RemoteDone(worker, remote, min_k, dd_evals);
                            }
                            Some(ShardPoll::Failed(e)) => {
                                slots[i] = Slot::Failed(e);
                            }
                            Some(ShardPoll::Panicked) | None => {
                                // The worker's analysis thread died, or
                                // the response failed its checksum:
                                // either way this worker can't be
                                // trusted with the shard — count the
                                // strike and reassign.
                                self.note_failure(worker);
                                reassign(&mut slots, &mut opened, &mut pending);
                            }
                        }
                    }
                    // Non-200 (including a worker that restarted and
                    // forgot the shard): protocol-level strike.
                    Ok(_) => {
                        self.note_failure(worker);
                        reassign(&mut slots, &mut opened, &mut pending);
                    }
                    // Transport failure past the retry policy (the call
                    // already advanced the breaker): reassign.
                    Err(_) => {
                        reassign(&mut slots, &mut opened, &mut pending);
                    }
                }
            }
            if !pending {
                break;
            }
            std::thread::sleep(POLL_INTERVAL);
        }

        // Merge phase 1: the error of the failed shard with the smallest
        // `lo` (= lowest slot index) reproduces the single-node ascending
        // loop; otherwise the common k is the max of the shard minima.
        for slot in &slots {
            if let Slot::Failed(e) = slot {
                self.release(&slot_remotes(&slots), auth);
                return Err(e.clone());
            }
        }
        let k = slots
            .iter()
            .map(|s| match s {
                Slot::RemoteDone(_, _, min_k, _) => *min_k,
                Slot::Local(sa) => sa.min_k,
                Slot::Remote(..) | Slot::Failed(_) => 0,
            })
            .max()
            .unwrap_or(0);

        // Merge phase 2: sweep every shard at the common k, in region
        // order; a worker dying here re-analyzes its shard locally
        // (byte-identical by the shard property tests).
        let mut regions: Vec<RegionSpace> = Vec::with_capacity(1usize << opts.lookup_bits);
        let mut dd_evals = 0u64;
        for (i, &(lo, hi)) in ranges.iter().enumerate() {
            let sweep_start = Instant::now();
            match &slots[i] {
                Slot::Local(sa) => {
                    dd_evals += sa.dd_evals;
                    regions.extend(sweep_shard(sa, k, opts.degree));
                    span(i, "sweep", sweep_start);
                }
                Slot::RemoteDone(worker, remote, _, dd) => {
                    let body = format!("k = {k}\n");
                    let swept = match self.call(
                        *worker,
                        "POST",
                        &format!("/shards/{remote}/sweep"),
                        body.as_bytes(),
                    ) {
                        // decode_pgsh verifies the payload CRC: a bit
                        // flipped in transit is a miss here, never a
                        // silently-wrong entry in the merged space.
                        Ok((200, bytes)) => match decode_pgsh(&bytes) {
                            Some(p) if p.lo == lo && p.hi == hi && p.k == k => Some(p.regions),
                            Some(_) => None,
                            None => {
                                WIRE_CRC_FAILURES.inc();
                                None
                            }
                        },
                        _ => None,
                    };
                    match swept {
                        Some(sw) => {
                            dd_evals += dd;
                            regions.extend(sw);
                            self.release(&[(*worker, *remote)], auth);
                            span(i, "sweep", sweep_start);
                        }
                        None => {
                            // The worker died or garbled its sweep
                            // between analyze and here: re-analyze this
                            // shard locally (byte-identical by the shard
                            // property tests) and flag the degradation.
                            self.note_failure(*worker);
                            mark_degraded(
                                degraded,
                                "a worker failed mid-sweep; re-analyzing its shard locally",
                            );
                            match analyze_shard(bt, opts, lo, hi, cancel) {
                                Ok(sa) => {
                                    dd_evals += sa.dd_evals;
                                    regions.extend(sweep_shard(&sa, k, opts.degree));
                                    span(i, "sweep", sweep_start);
                                }
                                Err(e) => {
                                    self.release(&slot_remotes(&slots), auth);
                                    return Err(e);
                                }
                            }
                        }
                    }
                }
                Slot::Remote(..) | Slot::Failed(_) => unreachable!("settled above"),
            }
        }
        Ok(merge_shard_spaces(bt, opts, k, regions, dd_evals))
    }

    fn is_live(&self, id: u64) -> bool {
        plock(&self.workers).get(&id).is_some_and(|w| w.last_seen.elapsed() < self.timeout)
    }

    fn addr_of(&self, id: u64) -> Option<String> {
        plock(&self.workers).get(&id).map(|w| w.addr.clone())
    }

    /// POST one shard to the next live worker (round-robin via `*rr`),
    /// striking workers whose POST fails (past the retry policy) or
    /// whose response fails its `body_crc` echo; when no live worker
    /// remains, analyze in-process.
    #[allow(clippy::too_many_arguments)]
    fn assign(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        lo: u64,
        hi: u64,
        rr: &mut usize,
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
        degraded: Option<&AtomicBool>,
    ) -> Slot {
        let body = shard_request(bt, opts, lo, hi);
        loop {
            let live = self.live();
            if live.is_empty() {
                if self.any_registered() {
                    mark_degraded(
                        degraded,
                        "no live worker left for a shard; analyzing it locally",
                    );
                }
                match analyze_shard(bt, opts, lo, hi, cancel) {
                    Ok(sa) => {
                        if let Some(p) = ticks {
                            p.add((hi - lo) as usize);
                        }
                        return Slot::Local(sa);
                    }
                    Err(e) => return Slot::Failed(e),
                }
            }
            let (worker, _addr) = live[*rr % live.len()].clone();
            *rr += 1;
            match self.call(worker, "POST", "/shards", body.as_bytes()) {
                Ok((201, resp)) => {
                    let resp = String::from_utf8_lossy(&resp).into_owned();
                    // The worker echoes a CRC of the request body it
                    // received: a mismatch means the shard request was
                    // corrupted in transit and the remote shard is
                    // computing the wrong range — don't trust it.
                    let echo_ok = json_u64(&resp, "body_crc")
                        .is_some_and(|c| c == crc32(body.as_bytes()) as u64);
                    match json_u64(&resp, "id") {
                        Some(remote) if echo_ok => {
                            SHARDS_DISPATCHED.inc();
                            return Slot::Remote(worker, remote);
                        }
                        Some(remote) => {
                            WIRE_CRC_FAILURES.inc();
                            self.release(&[(worker, remote)], self.auth().as_deref());
                            self.note_failure(worker);
                        }
                        None => self.note_failure(worker),
                    }
                }
                Ok(_) => self.note_failure(worker),
                // Transport failure: call() already advanced the breaker.
                Err(_) => {}
            }
        }
    }

    /// Best-effort shard cleanup: single attempt, short deadline, no
    /// retries, breaker untouched (failing to free a shard on a dead
    /// worker is not evidence about the worker's next call).
    fn release(&self, remotes: &[(u64, u64)], auth: Option<&str>) {
        let timeout = self.policy().call_timeout;
        for &(worker, remote) in remotes {
            if let Some(addr) = self.addr_of(worker) {
                let _ =
                    http_call_to(&addr, "DELETE", &format!("/shards/{remote}"), b"", auth, timeout);
            }
        }
    }
}

/// Set the degraded flag, logging the reason the first time only.
fn mark_degraded(flag: Option<&AtomicBool>, why: &str) {
    if let Some(f) = flag {
        if !f.swap(true, Ordering::Relaxed) {
            DEGRADED.inc();
            eprintln!("polygen: cluster degraded: {why}");
        }
    }
}

/// A verified shard-status poll. `None` = the response failed its
/// checksum or was missing fields — unintelligible, reassign.
enum ShardPoll {
    Analyzing,
    Analyzed { min_k: u32, dd_evals: u64 },
    Failed(GenError),
    Panicked,
}

fn verified_status(body: &str, expect_id: u64) -> Option<ShardPoll> {
    let id = json_u64(body, "id")?;
    let state = json_field(body, "state")?;
    let check = json_u64(body, "check")? as u32;
    if id != expect_id {
        return None;
    }
    match state {
        "analyzing" => {
            (check == status_check(id, "analyzing", 0, 0, 0)).then_some(ShardPoll::Analyzing)
        }
        "analyzed" => {
            let min_k = json_u64(body, "min_k")?;
            let dd_evals = json_u64(body, "dd_evals")?;
            (check == status_check(id, "analyzed", min_k, dd_evals, 0)).then_some(
                ShardPoll::Analyzed { min_k: u32::try_from(min_k).ok()?, dd_evals },
            )
        }
        "failed" => {
            let (e, region, max_k, code) = match json_field(body, "kind")? {
                "infeasible" => {
                    let r = json_u64(body, "region")?;
                    (GenError::InfeasibleRegion { r }, r, 0, 1)
                }
                "k_exhausted" => {
                    let r = json_u64(body, "region")?;
                    let max_k = json_u64(body, "max_k")?;
                    (
                        GenError::KExhausted { r, max_k: u32::try_from(max_k).ok()? },
                        r,
                        max_k,
                        2,
                    )
                }
                "cancelled" => (GenError::Cancelled, 0, 0, 3),
                _ => return None,
            };
            (check == status_check(id, "failed", region, max_k, code))
                .then_some(ShardPoll::Failed(e))
        }
        "panicked" => {
            (check == status_check(id, "panicked", 0, 0, 0)).then_some(ShardPoll::Panicked)
        }
        _ => None,
    }
}

/// One shard's lifecycle during a distributed generate.
enum Slot {
    /// Assigned to `(worker id, remote shard id)`, awaiting analysis.
    Remote(u64, u64),
    /// Analyzed remotely: `(worker id, remote id, min_k, dd_evals)`.
    RemoteDone(u64, u64, u32, u64),
    /// Fallback: analyzed in-process.
    Local(ShardAnalysis),
    /// Failed with the single-node-identical error.
    Failed(GenError),
}

/// Every `(worker, remote shard)` pair still held remotely — the set to
/// release on an error path.
fn slot_remotes(slots: &[Slot]) -> Vec<(u64, u64)> {
    slots
        .iter()
        .filter_map(|s| match s {
            Slot::Remote(w, r) | Slot::RemoteDone(w, r, _, _) => Some((*w, *r)),
            Slot::Local(_) | Slot::Failed(_) => None,
        })
        .collect()
}

// ---------------------------------------------------------------------
// Worker agent: the register/heartbeat loop `--worker` mode runs.

/// Keep this process registered with `coordinator` as a worker reachable
/// at `my_addr`, re-registering whenever the coordinator restarts or the
/// link drops. Runs until `stop` flips. This is the background loop
/// `polygen serve --worker` pairs with its shard-serving listener;
/// re-exported as `polygen::service::run_worker_agent`.
pub fn run_worker_agent(
    coordinator: String,
    my_addr: String,
    auth: Option<String>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    run_worker_agent_with(coordinator, my_addr, auth, stop, Policy::default())
}

///// [`run_worker_agent`] with an explicit call [`Policy`]: register and
/// heartbeat calls get the policy's per-attempt deadline and bounded
/// retries (no breaker — there is exactly one coordinator, and the loop
/// itself is the recovery mechanism).
pub fn run_worker_agent_with(
    coordinator: String,
    my_addr: String,
    auth: Option<String>,
    stop: Arc<AtomicBool>,
    policy: Policy,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("polygen-worker-agent".into())
        .spawn(move || {
            let auth = auth.as_deref();
            let mut id: Option<u64> = None;
            while !stop.load(Ordering::Relaxed) {
                match id {
                    None => {
                        let body = obj([("addr", json_str(&my_addr))]);
                        let reg = policy.run(None, None, |timeout| {
                            http_call_to(
                                &coordinator,
                                "POST",
                                "/workers",
                                body.as_bytes(),
                                auth,
                                timeout,
                            )
                        });
                        if let Ok((200 | 201, resp)) = reg {
                            let resp = String::from_utf8_lossy(&resp).into_owned();
                            id = json_u64(&resp, "id");
                        }
                    }
                    Some(wid) => {
                        // A dropped heartbeat round (injected or real)
                        // just lets the coordinator see us as stale
                        // until the next beat lands.
                        let skip =
                            faults::inject("cluster.heartbeat", &[Fault::Drop]).is_some();
                        if !skip {
                            let beat = policy.run(None, None, |timeout| {
                                http_call_to(
                                    &coordinator,
                                    "POST",
                                    &format!("/workers/{wid}/heartbeat"),
                                    b"",
                                    auth,
                                    timeout,
                                )
                            });
                            if !matches!(beat, Ok((200, _))) {
                                // Coordinator restarted or evicted us:
                                // re-register on the next pass.
                                HEARTBEAT_MISSES.inc();
                                id = None;
                            }
                        }
                    }
                }
                // Sleep in short steps so `stop` is honored promptly.
                for _ in 0..20 {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    std::thread::sleep(HEARTBEAT_INTERVAL / 20);
                }
            }
        })
        .expect("spawn polygen-worker-agent")
}

/// [`crate::pipeline::Generator`] adapter: routes a pipeline's fixed-R
/// generation phase through the cluster when live workers exist,
/// falling back to local generation (by returning `None`) otherwise.
/// Carries the job's [`crate::pipeline::JobCtrl`] so cluster-level
/// degradation (local fallback while workers are registered) is visible
/// in the job's status.
pub(crate) struct ClusterGenerator {
    pub cluster: Arc<Cluster>,
    pub ctrl: Option<Arc<crate::pipeline::JobCtrl>>,
}

impl crate::pipeline::Generator for ClusterGenerator {
    fn generate(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
    ) -> Option<Result<DesignSpace, GenError>> {
        let flag = self.ctrl.as_deref().map(|c| c.degraded_flag());
        let tracer = self.ctrl.as_deref().and_then(|c| c.tracer()).map(Arc::as_ref);
        self.cluster.generate(bt, opts, cancel, ticks, flag, tracer)
    }
}
