//! `polygen::cluster` — region-sharded multi-worker generation.
//!
//! The unit of parallelism is the region: per-region analyses are
//! independent, and the common `k` is an associative max over the
//! per-region minima — so one job's region range `0..2^R` splits into
//! contiguous shards that different *processes* can analyze. This
//! module is both halves of that protocol:
//!
//! - **Coordinator side** ([`Cluster`]): a heartbeat-tracked worker
//!   registry (`POST /workers`, `POST /workers/:id/heartbeat`) and the
//!   distributed generate driver, which assigns shards round-robin,
//!   polls them, reassigns a dead worker's shard (heartbeat timeout or
//!   connection failure) to a live worker — or analyzes it locally when
//!   none is left — and merges the returned per-region entry lists. The
//!   merged space is **byte-identical to single-node generation**: the
//!   pure shard algebra lives in [`crate::designspace`]
//!   ([`analyze_shard`]/[`sweep_shard`]/[`merge_shard_spaces`]) and is
//!   property-tested there across shard counts and boundaries.
//! - **Worker side** ([`ShardServer`]): an async shard state machine
//!   behind `POST /shards` (spec TOML + `[shard] lo/hi` → analyze in a
//!   background thread), `GET /shards/:id` (flat JSON status carrying
//!   `min_k`/`dd_evals` or the structured [`GenError`]),
//!   `POST /shards/:id/sweep` (sweep at the cluster-wide common `k`,
//!   returning the region entries as a versioned `PGSH` binary — the
//!   JSON layer has no arrays, and entry lists are big), and
//!   `DELETE /shards/:id` (cooperative cancel + drop). Plus
//!   [`run_worker_agent`]: the register/heartbeat/re-register loop
//!   `polygen serve --worker --coordinator <url>` runs.
//!
//! The wire protocol is two-phase because the common `k` is global:
//! every shard must finish analyzing before any shard can sweep. Shard
//! requests reuse the job-file TOML grammar; binary payloads reuse the
//! PGDS length-prefixed idiom. See DESIGN.md §Cluster.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicU64;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::bounds::{builtin, BoundTable};
use crate::designspace::region::RegionSpace;
use crate::designspace::{
    analyze_shard, merge_shard_spaces, shard_ranges, sweep_shard, DesignSpace, GenError,
    GenOptions, ShardAnalysis,
};
use crate::pipeline::{Config, JobSpec, LookupBits, SearchStrategy};
use crate::pool::{CancelToken, Progress};

use super::http::{json_str, obj};

/// How often a worker pings its coordinator, and the staleness bound
/// after which the coordinator treats it as dead and reassigns its
/// shard. Tests shrink the timeout through [`Cluster::new`].
pub const HEARTBEAT_INTERVAL: Duration = Duration::from_secs(2);
pub const DEFAULT_HEARTBEAT_TIMEOUT: Duration = Duration::from_secs(10);

/// Coordinator → worker poll cadence while a shard analyzes.
const POLL_INTERVAL: Duration = Duration::from_millis(25);

// ---------------------------------------------------------------------
// Minimal HTTP client (the other half of service::http's server).

/// Strip an `http://` scheme and trailing slash: the registry stores
/// plain `host:port` but accepts URL spellings.
pub(crate) fn normalize_addr(addr: &str) -> String {
    addr.trim().trim_start_matches("http://").trim_end_matches('/').to_string()
}

/// One `Connection: close` HTTP/1.1 exchange. Returns `(status, body)`;
/// transport-level failures are `Err` (the coordinator's dead-worker
/// signal).
pub(crate) fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    auth: Option<&str>,
) -> Result<(u16, Vec<u8>), String> {
    let addr = normalize_addr(addr);
    let mut stream = TcpStream::connect(&addr).map_err(|e| format!("{addr}: {e}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30))).map_err(|e| e.to_string())?;
    stream.set_write_timeout(Some(Duration::from_secs(30))).map_err(|e| e.to_string())?;
    let auth_line = match auth {
        Some(tok) => format!("Authorization: Bearer {tok}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         {auth_line}Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes()).map_err(|e| e.to_string())?;
    stream.write_all(body).map_err(|e| e.to_string())?;
    stream.flush().map_err(|e| e.to_string())?;

    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| e.to_string())?;
    let code: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| format!("bad status line {line:?}"))?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).map_err(|e| e.to_string())?;
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = Some(v.trim().parse().map_err(|_| "bad content-length")?);
            }
        }
    }
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            reader.read_exact(&mut body).map_err(|e| e.to_string())?;
        }
        None => {
            reader.read_to_end(&mut body).map_err(|e| e.to_string())?;
        }
    }
    Ok((code, body))
}

/// Extract `"key":<number>` from a flat JSON object (the coordinator
/// reads only scalar fields off the wire).
pub(crate) fn json_u64(body: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat)? + pat.len();
    let rest = &body[at..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract `"key":"value"` from a flat JSON object (values here are
/// labels — never escaped).
pub(crate) fn json_field<'a>(body: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":\"");
    let at = body.find(&pat)? + pat.len();
    let rest = &body[at..];
    Some(&rest[..rest.find('"')?])
}

// ---------------------------------------------------------------------
// Shard request wire format (TOML, reusing the job-file grammar).

fn search_label(s: SearchStrategy) -> &'static str {
    match s {
        SearchStrategy::Hull => "hull",
        SearchStrategy::Pruned => "pruned",
        SearchStrategy::Naive => "naive",
    }
}

/// The `POST /shards` body: the generation-affecting spec fields plus
/// the `[shard]` range.
fn shard_request(bt: &BoundTable, opts: &GenOptions, lo: u64, hi: u64) -> String {
    format!(
        "func = {}\nbits = {}\naccuracy = {}\n\n[generate]\nlookup_bits = {}\n\
         search = {}\nmax_k = {}\nthreads = {}\n\n[shard]\nlo = {lo}\nhi = {hi}\n",
        bt.func,
        bt.in_bits,
        bt.accuracy,
        opts.lookup_bits,
        search_label(opts.search),
        opts.max_k,
        opts.threads,
    )
}

/// Parse a shard request back into `(bound table, options, lo, hi)`.
fn parse_shard_request(text: &str) -> Result<(BoundTable, GenOptions, u64, u64), String> {
    let cfg = Config::parse(text)?;
    let spec = JobSpec::from_config(&cfg).map_err(|e| e.to_string())?;
    let LookupBits::Fixed(lookup_bits) = spec.lookup else {
        return Err("shard requests must pin lookup_bits".into());
    };
    let lo = cfg.get_u32("shard.lo")?.ok_or("missing shard.lo")? as u64;
    let hi = cfg.get_u32("shard.hi")?.ok_or("missing shard.hi")? as u64;
    let f = builtin(&spec.func, spec.bits)
        .ok_or_else(|| format!("unknown function {}", spec.func))?;
    let bt = BoundTable::build(f.as_ref(), spec.accuracy);
    let opts = GenOptions {
        lookup_bits,
        search: spec.search,
        max_k: spec.max_k,
        threads: spec.threads,
    };
    if !(lo < hi && hi <= (1u64 << lookup_bits)) {
        return Err(format!("shard {lo}..{hi} out of range for R={lookup_bits}"));
    }
    Ok((bt, opts, lo, hi))
}

// ---------------------------------------------------------------------
// PGSH: the swept-shard binary (entry lists are too big for the flat
// JSON layer; same length-prefixed little-endian idiom as PGDS).

const PGSH_MAGIC: &[u8; 4] = b"PGSH";
const PGSH_VERSION: u32 = 1;

fn encode_pgsh(lo: u64, hi: u64, k: u32, dd_evals: u64, regions: &[RegionSpace]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(PGSH_MAGIC);
    out.extend_from_slice(&PGSH_VERSION.to_le_bytes());
    out.extend_from_slice(&lo.to_le_bytes());
    out.extend_from_slice(&hi.to_le_bytes());
    out.extend_from_slice(&k.to_le_bytes());
    out.extend_from_slice(&dd_evals.to_le_bytes());
    for sp in regions {
        out.extend_from_slice(&sp.r.to_le_bytes());
        out.extend_from_slice(&u32::from(sp.linear_ok).to_le_bytes());
        out.extend_from_slice(&(sp.entries.len() as u32).to_le_bytes());
        for e in &sp.entries {
            out.extend_from_slice(&e.a.to_le_bytes());
            out.extend_from_slice(&e.b_lo.to_le_bytes());
            out.extend_from_slice(&e.b_hi.to_le_bytes());
        }
    }
    out
}

struct Pgsh {
    lo: u64,
    hi: u64,
    k: u32,
    dd_evals: u64,
    regions: Vec<RegionSpace>,
}

fn decode_pgsh(bytes: &[u8]) -> Option<Pgsh> {
    use crate::designspace::region::AbEntry;
    fn take<'a>(b: &mut &'a [u8], n: usize) -> Option<&'a [u8]> {
        if b.len() < n {
            return None;
        }
        let (head, tail) = b.split_at(n);
        *b = tail;
        Some(head)
    }
    fn r_u32(b: &mut &[u8]) -> Option<u32> {
        take(b, 4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }
    fn r_u64(b: &mut &[u8]) -> Option<u64> {
        take(b, 8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }
    fn r_i64(b: &mut &[u8]) -> Option<i64> {
        take(b, 8).map(|s| i64::from_le_bytes(s.try_into().unwrap()))
    }
    let mut b = bytes;
    if take(&mut b, 4)? != PGSH_MAGIC || r_u32(&mut b)? != PGSH_VERSION {
        return None;
    }
    let lo = r_u64(&mut b)?;
    let hi = r_u64(&mut b)?;
    let k = r_u32(&mut b)?;
    let dd_evals = r_u64(&mut b)?;
    if hi <= lo {
        return None;
    }
    let mut regions = Vec::with_capacity((hi - lo) as usize);
    for _ in lo..hi {
        let r = r_u64(&mut b)?;
        let linear_ok = match r_u32(&mut b)? {
            0 => false,
            1 => true,
            _ => return None,
        };
        let nent = r_u32(&mut b)? as usize;
        let mut entries = Vec::with_capacity(nent);
        for _ in 0..nent {
            let a = r_i64(&mut b)?;
            let b_lo = r_i64(&mut b)?;
            let b_hi = r_i64(&mut b)?;
            entries.push(AbEntry { a, b_lo, b_hi });
        }
        regions.push(RegionSpace { r, k, entries, linear_ok });
    }
    if !b.is_empty() {
        return None;
    }
    Some(Pgsh { lo, hi, k, dd_evals, regions })
}

// ---------------------------------------------------------------------
// Worker side: the shard state machine.

enum ShardState {
    Analyzing,
    Analyzed(ShardAnalysis),
    Failed(GenError),
}

struct ShardEntry {
    cancel: CancelToken,
    state: Mutex<ShardState>,
    cv: Condvar,
}

/// The worker-side shard registry every service carries (any `polygen
/// serve` instance can take shard work; it only does when a coordinator
/// sends some).
#[derive(Default)]
pub(crate) struct ShardServer {
    next_id: AtomicU64,
    shards: Mutex<BTreeMap<u64, Arc<ShardEntry>>>,
}

impl ShardServer {
    /// `POST /shards`: parse, spawn the analysis, return the shard id.
    pub fn start(&self, body: &str) -> Result<u64, String> {
        let (bt, opts, lo, hi) = parse_shard_request(body)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let entry = Arc::new(ShardEntry {
            cancel: CancelToken::new(),
            state: Mutex::new(ShardState::Analyzing),
            cv: Condvar::new(),
        });
        self.shards.lock().unwrap().insert(id, Arc::clone(&entry));
        let worker = Arc::clone(&entry);
        let spawned = std::thread::Builder::new()
            .name(format!("polygen-shard-{id}"))
            .spawn(move || {
                let result = analyze_shard(&bt, &opts, lo, hi, Some(&worker.cancel));
                let mut st = worker.state.lock().unwrap();
                *st = match result {
                    Ok(sa) => ShardState::Analyzed(sa),
                    Err(e) => ShardState::Failed(e),
                };
                drop(st);
                worker.cv.notify_all();
            })
            .is_ok();
        if !spawned {
            // Thread exhaustion: analyze inline rather than leaving the
            // shard parked in Analyzing forever.
            let result = analyze_shard(&bt, &opts, lo, hi, Some(&entry.cancel));
            let mut st = entry.state.lock().unwrap();
            *st = match result {
                Ok(sa) => ShardState::Analyzed(sa),
                Err(e) => ShardState::Failed(e),
            };
        }
        Ok(id)
    }

    /// `GET /shards/:id`: flat-scalar status JSON.
    pub fn status_json(&self, id: u64) -> Option<String> {
        let entry = self.shards.lock().unwrap().get(&id).cloned()?;
        let st = entry.state.lock().unwrap();
        let body = match &*st {
            ShardState::Analyzing => {
                obj([("id", id.to_string()), ("state", json_str("analyzing"))])
            }
            ShardState::Analyzed(sa) => obj([
                ("id", id.to_string()),
                ("state", json_str("analyzed")),
                ("min_k", sa.min_k.to_string()),
                ("dd_evals", sa.dd_evals.to_string()),
            ]),
            ShardState::Failed(e) => {
                let mut fields = vec![("id", id.to_string()), ("state", json_str("failed"))];
                match e {
                    GenError::InfeasibleRegion { r } => {
                        fields.push(("kind", json_str("infeasible")));
                        fields.push(("region", r.to_string()));
                    }
                    GenError::KExhausted { r, max_k } => {
                        fields.push(("kind", json_str("k_exhausted")));
                        fields.push(("region", r.to_string()));
                        fields.push(("max_k", max_k.to_string()));
                    }
                    GenError::Cancelled => fields.push(("kind", json_str("cancelled"))),
                }
                obj(fields)
            }
        };
        Some(body)
    }

    /// `POST /shards/:id/sweep` (body `k = <common k>`): block until the
    /// analysis lands, then sweep and encode. Errors are
    /// `(status, json)` pairs ready for the HTTP layer.
    pub fn sweep(&self, id: u64, body: &str) -> Result<Vec<u8>, (u16, String)> {
        let bad = |m: &str| (400u16, obj([("error", json_str(m))]));
        let k = Config::parse(body)
            .and_then(|c| c.get_u32("k")?.ok_or_else(|| "missing k".into()))
            .map_err(|e| bad(&e))?;
        let entry = self
            .shards
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or((404, obj([("error", json_str("no such shard"))])))?;
        let mut st = entry.state.lock().unwrap();
        loop {
            match &*st {
                ShardState::Analyzing => st = entry.cv.wait(st).unwrap(),
                ShardState::Failed(_) => {
                    return Err((409, obj([("error", json_str("shard failed"))])))
                }
                ShardState::Analyzed(sa) => {
                    if k < sa.min_k {
                        return Err(bad(&format!("k={k} below shard minimum {}", sa.min_k)));
                    }
                    let regions = sweep_shard(sa, k);
                    return Ok(encode_pgsh(sa.lo, sa.hi, k, sa.dd_evals, &regions));
                }
            }
        }
    }

    /// `DELETE /shards/:id`: cooperative cancel + unregister.
    pub fn cancel(&self, id: u64) -> bool {
        match self.shards.lock().unwrap().remove(&id) {
            Some(e) => {
                e.cancel.cancel();
                true
            }
            None => false,
        }
    }
}

// ---------------------------------------------------------------------
// Coordinator side.

struct WorkerInfo {
    addr: String,
    last_seen: Instant,
}

/// The coordinator's worker registry + distributed generate driver.
pub(crate) struct Cluster {
    next_id: AtomicU64,
    workers: Mutex<BTreeMap<u64, WorkerInfo>>,
    timeout: Duration,
    auth: Mutex<Option<String>>,
}

impl Cluster {
    pub fn new(timeout: Duration) -> Cluster {
        Cluster {
            next_id: AtomicU64::new(0),
            workers: Mutex::new(BTreeMap::new()),
            timeout,
            auth: Mutex::new(None),
        }
    }

    /// Token forwarded on coordinator → worker calls (the cluster shares
    /// one `--auth-token`).
    pub fn set_auth(&self, token: Option<String>) {
        *self.auth.lock().unwrap() = token;
    }

    fn auth(&self) -> Option<String> {
        self.auth.lock().unwrap().clone()
    }

    /// `POST /workers`: register (or re-register) a worker by address.
    /// Re-registering an address replaces the old entry, so a restarted
    /// worker does not appear twice.
    pub fn register(&self, addr: &str) -> u64 {
        let addr = normalize_addr(addr);
        let mut ws = self.workers.lock().unwrap();
        ws.retain(|_, w| w.addr != addr);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        ws.insert(id, WorkerInfo { addr, last_seen: Instant::now() });
        id
    }

    /// `POST /workers/:id/heartbeat` → `false` = unknown id (the worker
    /// should re-register; the coordinator may have restarted).
    pub fn heartbeat(&self, id: u64) -> bool {
        match self.workers.lock().unwrap().get_mut(&id) {
            Some(w) => {
                w.last_seen = Instant::now();
                true
            }
            None => false,
        }
    }

    /// Registered workers as `(id, addr, alive)`, id-ascending.
    pub fn workers(&self) -> Vec<(u64, String, bool)> {
        let ws = self.workers.lock().unwrap();
        ws.iter()
            .map(|(&id, w)| (id, w.addr.clone(), w.last_seen.elapsed() < self.timeout))
            .collect()
    }

    fn live(&self) -> Vec<(u64, String)> {
        self.workers()
            .into_iter()
            .filter_map(|(id, addr, alive)| alive.then_some((id, addr)))
            .collect()
    }

    fn mark_dead(&self, id: u64) {
        self.workers.lock().unwrap().remove(&id);
    }

    /// Distributed generation: shard `0..2^R` over the live workers,
    /// merge byte-identically to single-node. `None` = no live workers
    /// (caller falls back to the local engine); `ticks` counts analyzed
    /// regions (no `begin` — the caller owns the progress window).
    pub fn generate(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
    ) -> Option<Result<DesignSpace, GenError>> {
        let live = self.live();
        if live.is_empty() {
            return None;
        }
        let nregions = 1u64 << opts.lookup_bits;
        let ranges = shard_ranges(nregions, live.len());
        Some(self.drive(bt, opts, &ranges, cancel, ticks))
    }

    fn drive(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        ranges: &[(u64, u64)],
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
    ) -> Result<DesignSpace, GenError> {
        let auth = self.auth();
        let auth = auth.as_deref();

        // Assign round-robin; a worker that fails the initial POST is
        // immediately treated as dead.
        let mut rr = 0usize;
        let mut slots: Vec<Slot> = ranges
            .iter()
            .map(|&(lo, hi)| self.assign(bt, opts, lo, hi, &mut rr, auth, cancel, ticks))
            .collect();

        // Poll until every slot settles, reassigning slots whose worker
        // died mid-analysis (connection failure or heartbeat timeout).
        loop {
            if cancel.is_some_and(|c| c.is_cancelled()) {
                self.release(&slot_remotes(&slots), auth);
                return Err(GenError::Cancelled);
            }
            let mut pending = false;
            for (i, &(lo, hi)) in ranges.iter().enumerate() {
                let Slot::Remote(worker, remote) = slots[i] else { continue };
                if !self.is_live(worker) {
                    self.mark_dead(worker);
                    slots[i] = self.assign(bt, opts, lo, hi, &mut rr, auth, cancel, ticks);
                    pending |= matches!(slots[i], Slot::Remote(..));
                    continue;
                }
                let polled = self.addr_of(worker).and_then(|a| {
                    http_call(&a, "GET", &format!("/shards/{remote}"), b"", auth).ok()
                });
                match polled {
                    Some((200, body)) => {
                        let body = String::from_utf8_lossy(&body).into_owned();
                        match json_field(&body, "state") {
                            Some("analyzing") => pending = true,
                            Some("analyzed") => {
                                let min_k = json_u64(&body, "min_k").unwrap_or(0) as u32;
                                let dd = json_u64(&body, "dd_evals").unwrap_or(0);
                                if let Some(p) = ticks {
                                    p.add((hi - lo) as usize);
                                }
                                slots[i] = Slot::RemoteDone(worker, remote, min_k, dd);
                            }
                            Some("failed") => {
                                slots[i] = Slot::Failed(decode_error(&body, opts));
                            }
                            _ => {
                                // Unintelligible worker: treat as dead.
                                self.mark_dead(worker);
                                slots[i] =
                                    self.assign(bt, opts, lo, hi, &mut rr, auth, cancel, ticks);
                                pending |= matches!(slots[i], Slot::Remote(..));
                            }
                        }
                    }
                    // Connection refused / timeout / non-200 (including a
                    // worker that restarted and forgot the shard): the
                    // worker is dead to this job — reassign.
                    _ => {
                        self.mark_dead(worker);
                        slots[i] = self.assign(bt, opts, lo, hi, &mut rr, auth, cancel, ticks);
                        pending |= matches!(slots[i], Slot::Remote(..));
                    }
                }
            }
            if !pending {
                break;
            }
            std::thread::sleep(POLL_INTERVAL);
        }

        // Merge phase 1: the error of the failed shard with the smallest
        // `lo` (= lowest slot index) reproduces the single-node ascending
        // loop; otherwise the common k is the max of the shard minima.
        for slot in &slots {
            if let Slot::Failed(e) = slot {
                self.release(&slot_remotes(&slots), auth);
                return Err(e.clone());
            }
        }
        let k = slots
            .iter()
            .map(|s| match s {
                Slot::RemoteDone(_, _, min_k, _) => *min_k,
                Slot::Local(sa) => sa.min_k,
                Slot::Remote(..) | Slot::Failed(_) => 0,
            })
            .max()
            .unwrap_or(0);

        // Merge phase 2: sweep every shard at the common k, in region
        // order; a worker dying here re-analyzes its shard locally
        // (byte-identical by the shard property tests).
        let mut regions: Vec<RegionSpace> = Vec::with_capacity(1usize << opts.lookup_bits);
        let mut dd_evals = 0u64;
        for (i, &(lo, hi)) in ranges.iter().enumerate() {
            match &slots[i] {
                Slot::Local(sa) => {
                    dd_evals += sa.dd_evals;
                    regions.extend(sweep_shard(sa, k));
                }
                Slot::RemoteDone(worker, remote, _, dd) => {
                    let swept = self.addr_of(*worker).and_then(|addr| {
                        let body = format!("k = {k}\n");
                        match http_call(
                            &addr,
                            "POST",
                            &format!("/shards/{remote}/sweep"),
                            body.as_bytes(),
                            auth,
                        ) {
                            Ok((200, bytes)) => decode_pgsh(&bytes)
                                .filter(|p| p.lo == lo && p.hi == hi && p.k == k)
                                .map(|p| (addr, p.regions)),
                            _ => None,
                        }
                    });
                    match swept {
                        Some((addr, sw)) => {
                            dd_evals += dd;
                            regions.extend(sw);
                            let _ =
                                http_call(&addr, "DELETE", &format!("/shards/{remote}"), b"", auth);
                        }
                        None => {
                            self.mark_dead(*worker);
                            match analyze_shard(bt, opts, lo, hi, cancel) {
                                Ok(sa) => {
                                    dd_evals += sa.dd_evals;
                                    regions.extend(sweep_shard(&sa, k));
                                }
                                Err(e) => {
                                    self.release(&slot_remotes(&slots), auth);
                                    return Err(e);
                                }
                            }
                        }
                    }
                }
                Slot::Remote(..) | Slot::Failed(_) => unreachable!("settled above"),
            }
        }
        Ok(merge_shard_spaces(bt, opts, k, regions, dd_evals))
    }

    fn is_live(&self, id: u64) -> bool {
        self.workers
            .lock()
            .unwrap()
            .get(&id)
            .is_some_and(|w| w.last_seen.elapsed() < self.timeout)
    }

    fn addr_of(&self, id: u64) -> Option<String> {
        self.workers.lock().unwrap().get(&id).map(|w| w.addr.clone())
    }

    /// POST one shard to the next live worker (round-robin via `*rr`),
    /// marking workers whose POST fails as dead; when no live worker
    /// remains, analyze in-process.
    #[allow(clippy::too_many_arguments)]
    fn assign(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        lo: u64,
        hi: u64,
        rr: &mut usize,
        auth: Option<&str>,
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
    ) -> Slot {
        let body = shard_request(bt, opts, lo, hi);
        loop {
            let live = self.live();
            if live.is_empty() {
                match analyze_shard(bt, opts, lo, hi, cancel) {
                    Ok(sa) => {
                        if let Some(p) = ticks {
                            p.add((hi - lo) as usize);
                        }
                        return Slot::Local(sa);
                    }
                    Err(e) => return Slot::Failed(e),
                }
            }
            let (worker, addr) = live[*rr % live.len()].clone();
            *rr += 1;
            match http_call(&addr, "POST", "/shards", body.as_bytes(), auth) {
                Ok((201, resp)) => {
                    let resp = String::from_utf8_lossy(&resp).into_owned();
                    match json_u64(&resp, "id") {
                        Some(remote) => return Slot::Remote(worker, remote),
                        None => self.mark_dead(worker),
                    }
                }
                _ => self.mark_dead(worker),
            }
        }
    }

    fn release(&self, remotes: &[(u64, u64)], auth: Option<&str>) {
        for &(worker, remote) in remotes {
            if let Some(addr) = self.addr_of(worker) {
                let _ = http_call(&addr, "DELETE", &format!("/shards/{remote}"), b"", auth);
            }
        }
    }
}

/// One shard's lifecycle during a distributed generate.
enum Slot {
    /// Assigned to `(worker id, remote shard id)`, awaiting analysis.
    Remote(u64, u64),
    /// Analyzed remotely: `(worker id, remote id, min_k, dd_evals)`.
    RemoteDone(u64, u64, u32, u64),
    /// Fallback: analyzed in-process.
    Local(ShardAnalysis),
    /// Failed with the single-node-identical error.
    Failed(GenError),
}

/// Every `(worker, remote shard)` pair still held remotely — the set to
/// release on an error path.
fn slot_remotes(slots: &[Slot]) -> Vec<(u64, u64)> {
    slots
        .iter()
        .filter_map(|s| match s {
            Slot::Remote(w, r) | Slot::RemoteDone(w, r, _, _) => Some((*w, *r)),
            Slot::Local(_) | Slot::Failed(_) => None,
        })
        .collect()
}

// ---------------------------------------------------------------------
// Worker agent: the register/heartbeat loop `--worker` mode runs.

/// Keep this process registered with `coordinator` as a worker reachable
/// at `my_addr`, re-registering whenever the coordinator restarts or the
/// link drops. Runs until `stop` flips. This is the background loop
/// `polygen serve --worker` pairs with its shard-serving listener;
/// re-exported as `polygen::service::run_worker_agent`.
pub fn run_worker_agent(
    coordinator: String,
    my_addr: String,
    auth: Option<String>,
    stop: Arc<std::sync::atomic::AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("polygen-worker-agent".into())
        .spawn(move || {
            let auth = auth.as_deref();
            let mut id: Option<u64> = None;
            while !stop.load(Ordering::Relaxed) {
                match id {
                    None => {
                        let body = obj([("addr", json_str(&my_addr))]);
                        if let Ok((200 | 201, resp)) =
                            http_call(&coordinator, "POST", "/workers", body.as_bytes(), auth)
                        {
                            let resp = String::from_utf8_lossy(&resp).into_owned();
                            id = json_u64(&resp, "id");
                        }
                    }
                    Some(wid) => {
                        let beat = http_call(
                            &coordinator,
                            "POST",
                            &format!("/workers/{wid}/heartbeat"),
                            b"",
                            auth,
                        );
                        if !matches!(beat, Ok((200, _))) {
                            // Coordinator restarted or evicted us:
                            // re-register on the next pass.
                            id = None;
                        }
                    }
                }
                // Sleep in short steps so `stop` is honored promptly.
                for _ in 0..20 {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    std::thread::sleep(HEARTBEAT_INTERVAL / 20);
                }
            }
        })
        .expect("spawn polygen-worker-agent")
}

/// Rebuild the exact [`GenError`] a worker reported.
fn decode_error(body: &str, opts: &GenOptions) -> GenError {
    match json_field(body, "kind") {
        Some("infeasible") => {
            GenError::InfeasibleRegion { r: json_u64(body, "region").unwrap_or(0) }
        }
        Some("k_exhausted") => GenError::KExhausted {
            r: json_u64(body, "region").unwrap_or(0),
            max_k: json_u64(body, "max_k").unwrap_or(opts.max_k as u64) as u32,
        },
        _ => GenError::Cancelled,
    }
}

/// [`crate::pipeline::Generator`] adapter: routes a pipeline's fixed-R
/// generation phase through the cluster when live workers exist,
/// falling back to local generation (by returning `None`) otherwise.
pub(crate) struct ClusterGenerator(pub Arc<Cluster>);

impl crate::pipeline::Generator for ClusterGenerator {
    fn generate(
        &self,
        bt: &BoundTable,
        opts: &GenOptions,
        cancel: Option<&CancelToken>,
        ticks: Option<&Progress>,
    ) -> Option<Result<DesignSpace, GenError>> {
        self.0.generate(bt, opts, cancel, ticks)
    }
}
