//! Durability for the job service (§Cluster in DESIGN.md).
//!
//! Two persistence layers, both rooted in the service's `--state` dir:
//!
//! - **`jobs.log`** — an append-only log of every submission and every
//!   terminal transition, each record framed as
//!   `u32 len | u32 crc32(payload) | payload` (little-endian). Replayed
//!   on startup with WAL semantics: parsing stops at the first
//!   truncated or checksum-failing frame (a crash mid-append loses at
//!   most that one record), so `GET /jobs/:id` survives restarts.
//!   A submission without a matching finish record was interrupted by
//!   the crash and replays as `Failed`.
//! - **the result store** — content-addressed `JobResult` files
//!   (`<fnv64>.pgjr`, versioned binary like the coordinator's PGDS
//!   cache), keyed by the *result-affecting* subset of the job spec:
//!   the canonical TOML with the scheduling-only `threads*` keys
//!   stripped — the same exclusion [`crate::coordinator::cache`]
//!   applies to its filename key. A repeat submission of a popular
//!   spec is answered from here in microseconds without touching the
//!   scheduler. Jobs with `rtl_out` side effects are never stored.
//!
//! Every file embeds the full key (not just its hash) and is verified
//! against it on load, so an FNV collision degrades to a miss, never a
//! wrong result.

use std::fs::{self, File, OpenOptions};
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

use crate::dse::precision::{Encoding, Sign};
use crate::faults::{self, Fault};
use crate::obs::metrics;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{plock, Mutex};

const LOG_FRAMES: metrics::Counter = metrics::counter("store.log_frames");
const LOG_WRITE_ERRORS: metrics::Counter = metrics::counter("store.log_write_errors");
const LOG_QUARANTINED: metrics::Counter = metrics::counter("store.log_quarantined");
const RESULT_HITS: metrics::Counter = metrics::counter("store.result_hits");
const RESULT_MISSES: metrics::Counter = metrics::counter("store.result_misses");
const RESULT_QUARANTINED: metrics::Counter = metrics::counter("store.result_quarantined");
const RESULT_SAVES: metrics::Counter = metrics::counter("store.result_saves");
const STORE_BYTES: metrics::Gauge = metrics::gauge("store.bytes");
const STORE_ENTRIES: metrics::Gauge = metrics::gauge("store.entries");
use crate::dse::Coeffs;
use crate::pipeline::{Degree, Implementation, JobResult, JobSpec, SynthPoint, VerifyReport};

/// CRC-32 (IEEE, reflected) — record framing checksum for `jobs.log`.
pub(crate) fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// FNV-1a 64 — filename hash for the content-addressed store.
fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The content-address of a spec: its canonical TOML with the
/// scheduling-only keys (`threads`, `threads_strict`) stripped — thread
/// counts never change results (property-tested), so they must not
/// split the store. `None` = the job is not storable (it has `rtl_out`
/// filesystem side effects a stored result would silently skip).
pub(crate) fn store_key(spec: &JobSpec) -> Option<String> {
    if spec.rtl_out.is_some() {
        return None;
    }
    let canon: Vec<&str> =
        spec.to_toml().lines().filter(|l| !l.trim_start().starts_with("threads")).collect();
    Some(canon.join("\n"))
}

// ---------------------------------------------------------------------
// Little-endian byte helpers (the PGDS cache idiom).

fn w_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn w_str(out: &mut Vec<u8>, s: &str) {
    w_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn i64(&mut self) -> Option<i64> {
        self.take(8).map(|b| i64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f64(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }

    fn string(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).ok()
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

// ---------------------------------------------------------------------
// The append-only job log.

/// Terminal state of a logged job, as recorded in its finish record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) enum LogOutcome {
    Done,
    Failed(String),
    Cancelled,
}

/// One job reconstructed from the log.
#[derive(Clone, Debug)]
pub(crate) struct ReplayedJob {
    pub id: u64,
    pub spec: JobSpec,
    /// `None` = no finish record (the process died mid-job); the
    /// registry surfaces these as `Failed`.
    pub outcome: Option<LogOutcome>,
    /// Content-address of the stored result, when the finish record
    /// carried one.
    pub store_key: Option<String>,
}

const REC_SUBMIT: u8 = 1;
const REC_FINISH: u8 = 2;

/// Append handle on `jobs.log`. Records are synced to disk per append —
/// jobs run for seconds to minutes, so the fsync is noise, and it is
/// what makes the crash-recovery guarantee real.
pub(crate) struct JobLog {
    file: Mutex<File>,
    write_errors: AtomicU64,
}

impl JobLog {
    /// Open (creating if absent) the log for appending.
    pub fn open(path: &Path) -> std::io::Result<JobLog> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JobLog { file: Mutex::new(file), write_errors: AtomicU64::new(0) })
    }

    fn append(&self, payload: &[u8]) {
        let mut frame = Vec::with_capacity(payload.len() + 8);
        w_u32(&mut frame, payload.len() as u32);
        w_u32(&mut frame, crc32(payload));
        frame.extend_from_slice(payload);
        // Injection taps (inline no-ops unless `fault-injection` is
        // compiled in and armed): the three crash shapes recover/replay
        // must absorb — a torn frame, a flipped payload byte, a write
        // that never reaches the platters.
        match faults::inject("store.log", &[Fault::ShortWrite, Fault::Corrupt, Fault::FsyncFail]) {
            Some(Fault::ShortWrite) => {
                let cut = 1 + faults::rand_below(frame.len().min(8));
                frame.truncate(frame.len() - cut);
            }
            Some(Fault::Corrupt) => {
                let at = 8 + faults::rand_below(frame.len() - 8);
                frame[at] ^= 0x01;
            }
            Some(Fault::FsyncFail) => {
                self.write_errors.fetch_add(1, Ordering::Relaxed);
                LOG_WRITE_ERRORS.inc();
                return;
            }
            _ => {}
        }
        let mut f = plock(&self.file);
        // Durability is best-effort: a full disk must not take the
        // (still correct in-memory) service down, so write errors are
        // counted, not propagated.
        if f.write_all(&frame).and_then(|()| f.sync_data()).is_err() {
            self.write_errors.fetch_add(1, Ordering::Relaxed);
            LOG_WRITE_ERRORS.inc();
        } else {
            LOG_FRAMES.inc();
        }
    }

    /// Log records that could not be written (disk full, ...): the
    /// in-memory registry is still authoritative, but a restart would
    /// forget these jobs.
    #[cfg(test)]
    pub fn write_errors(&self) -> u64 {
        self.write_errors.load(Ordering::Relaxed)
    }

    /// Record a submission (before the job is queued).
    pub fn append_submit(&self, id: u64, spec: &JobSpec) {
        let mut p = Vec::new();
        p.push(REC_SUBMIT);
        w_u64(&mut p, id);
        w_str(&mut p, &spec.to_toml());
        self.append(&p);
    }

    /// Record a terminal transition, optionally naming the stored
    /// result's content-address.
    pub fn append_finish(&self, id: u64, outcome: &LogOutcome, store_key: Option<&str>) {
        let mut p = Vec::new();
        p.push(REC_FINISH);
        w_u64(&mut p, id);
        let (kind, err) = match outcome {
            LogOutcome::Done => (0u8, ""),
            LogOutcome::Failed(e) => (1, e.as_str()),
            LogOutcome::Cancelled => (2, ""),
        };
        p.push(kind);
        w_str(&mut p, err);
        match store_key {
            Some(k) => {
                p.push(1);
                w_str(&mut p, k);
            }
            None => p.push(0),
        }
        self.append(&p);
    }

    /// Replay a log file into per-job records, in first-submission
    /// order. Stops at the first truncated or corrupt frame (WAL
    /// semantics); a finish for an unknown id is ignored; a duplicate
    /// submit for an id keeps the first spec.
    pub fn replay(path: &Path) -> Vec<ReplayedJob> {
        JobLog::scan(path).0
    }

    /// [`JobLog::replay`] plus repair: when the scan stops short of the
    /// file's end (torn or corrupt tail), the damaged log is copied
    /// aside as `<name>.quarantined` and the live file is truncated
    /// back to its valid prefix — so future appends extend good frames
    /// instead of hiding behind a bad one forever. The service's build
    /// path uses this; `replay` stays read-only for tools and tests.
    // lint: fault-ok(log damage is injected at append time via store.log;
    // this repair path is what the chaos suite exercises with it)
    pub fn recover(path: &Path) -> Vec<ReplayedJob> {
        let (jobs, valid, total) = JobLog::scan(path);
        if valid < total {
            LOG_QUARANTINED.inc();
            let mut q = path.as_os_str().to_os_string();
            q.push(".quarantined");
            let q = PathBuf::from(q);
            let _ = fs::copy(path, &q);
            let truncated = OpenOptions::new()
                .write(true)
                .open(path)
                .and_then(|f| f.set_len(valid))
                .is_ok();
            eprintln!(
                "polygen: jobs.log has a corrupt tail ({valid} of {total} bytes valid); \
                 damaged copy quarantined at {}{}",
                q.display(),
                if truncated { ", live log truncated to the valid prefix" } else { "" }
            );
        }
        jobs
    }

    /// Parse the log: the replayed jobs, the byte length of the valid
    /// prefix (frames fully applied), and the file's total length.
    /// `valid == total` means the log is clean.
    // lint: fault-ok(log damage is injected at append time via store.log;
    // the per-frame CRC here is the check that tap exercises)
    fn scan(path: &Path) -> (Vec<ReplayedJob>, u64, u64) {
        let mut buf = Vec::new();
        match File::open(path) {
            Ok(mut f) => {
                if f.read_to_end(&mut buf).is_err() {
                    return (Vec::new(), 0, 0);
                }
            }
            Err(_) => return (Vec::new(), 0, 0),
        }
        let total = buf.len() as u64;
        let mut jobs: Vec<ReplayedJob> = Vec::new();
        let mut rd = Reader::new(&buf);
        let mut valid = 0u64;
        loop {
            let Some(len) = rd.u32() else { break };
            let Some(crc) = rd.u32() else { break };
            let Some(payload) = rd.take(len as usize) else { break };
            if crc32(payload) != crc {
                break;
            }
            let mut p = Reader::new(payload);
            let (Some(kind), Some(id)) = (p.u8(), p.u64()) else { break };
            match kind {
                REC_SUBMIT => {
                    let Some(toml) = p.string() else { break };
                    // An unparseable spec in a checksum-valid frame is a
                    // version skew, not corruption: skip the record but
                    // keep the frame in the valid prefix.
                    if let Ok(spec) = JobSpec::from_toml(&toml) {
                        if jobs.iter().all(|j| j.id != id) {
                            jobs.push(ReplayedJob { id, spec, outcome: None, store_key: None });
                        }
                    }
                }
                REC_FINISH => {
                    let (Some(okind), Some(err)) = (p.u8(), p.string()) else { break };
                    let key = match p.u8() {
                        Some(1) => match p.string() {
                            Some(k) => Some(k),
                            None => break,
                        },
                        Some(0) => None,
                        _ => break,
                    };
                    let outcome = match okind {
                        0 => LogOutcome::Done,
                        1 => LogOutcome::Failed(err),
                        2 => LogOutcome::Cancelled,
                        _ => break,
                    };
                    if let Some(j) = jobs.iter_mut().find(|j| j.id == id) {
                        j.outcome = Some(outcome);
                        j.store_key = key;
                    }
                }
                _ => break,
            }
            valid = rd.pos as u64;
        }
        (jobs, valid, total)
    }
}

// ---------------------------------------------------------------------
// The content-addressed result store.

const PGJR_MAGIC: &[u8; 4] = b"PGJR";
/// v2 appends a whole-file CRC-32 trailer, so *any* flipped bit fails
/// closed (v1 relied on the embedded-key echo plus field decoding,
/// which a coefficient flip could slip past). v1 files fail the
/// trailer check, get quarantined on first load, and are recomputed —
/// the upgrade is self-healing.
const PGJR_VERSION: u32 = 2;

/// What [`ResultStore::load_checked`] found under a key.
pub(crate) enum LoadOutcome {
    /// A CRC-valid result whose embedded key matches.
    Hit(JobResult),
    /// No file, or a CRC-valid file for a *different* key (FNV
    /// collision) — the file is left alone.
    Miss,
    /// The file failed its integrity check and was renamed aside to
    /// the returned path; resubmitting the spec recomputes it.
    Quarantined(PathBuf),
}

/// One stored result, as reported by `GET /store`.
#[derive(Clone, Debug)]
pub struct StoreEntry {
    /// The content key (canonical spec TOML) the file embeds, or
    /// `"(unreadable)"` when even the header cannot be parsed.
    pub key: String,
    /// On-disk size.
    pub bytes: u64,
    /// Seconds since the file was written.
    pub age_secs: u64,
}

/// Content-addressed `JobResult` files under `<state>/results/`,
/// optionally bounded by a byte budget and/or an age limit (both
/// enforced after each save, oldest files first).
pub(crate) struct ResultStore {
    dir: PathBuf,
    max_bytes: Option<u64>,
    ttl: Option<Duration>,
}

impl ResultStore {
    pub fn new(dir: &Path) -> ResultStore {
        ResultStore::with_bounds(dir, None, None)
    }

    pub fn with_bounds(dir: &Path, max_bytes: Option<u64>, ttl: Option<Duration>) -> ResultStore {
        ResultStore { dir: dir.to_path_buf(), max_bytes, ttl }
    }

    /// Where `key`'s result lives (whether or not it exists yet).
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{:016x}.pgjr", fnv1a64(key.as_bytes())))
    }

    /// Persist `res` under `key`. Best-effort and atomic (tmp +
    /// rename): a failed save costs a future recompute, never
    /// corruption.
    pub fn save(&self, key: &str, res: &JobResult) {
        if fs::create_dir_all(&self.dir).is_err() {
            return;
        }
        let mut bytes = encode_result(key, res);
        // Injection tap: a save that lands short or with a flipped bit
        // is exactly what `load_checked`'s quarantine path must absorb.
        match faults::inject("store.result", &[Fault::ShortWrite, Fault::Corrupt]) {
            Some(Fault::ShortWrite) => {
                let cut = 1 + faults::rand_below(bytes.len().min(16));
                bytes.truncate(bytes.len() - cut);
            }
            Some(Fault::Corrupt) => {
                let at = faults::rand_below(bytes.len());
                bytes[at] ^= 0x01;
            }
            _ => {}
        }
        let path = self.path_for(key);
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        let ok = fs::write(&tmp, &bytes).is_ok() && fs::rename(&tmp, &path).is_ok();
        if ok {
            RESULT_SAVES.inc();
        } else {
            let _ = fs::remove_file(&tmp);
        }
        self.prune();
    }

    /// Load the result stored under `key`, verifying the whole-file
    /// CRC and the embedded key; any non-hit degrades to `None`
    /// (corrupt files are still quarantined as a side effect).
    pub fn load(&self, key: &str) -> Option<JobResult> {
        match self.load_checked(key) {
            LoadOutcome::Hit(res) => Some(res),
            _ => None,
        }
    }

    /// Load with the full verdict: hit, miss, or corrupt-and-now-
    /// quarantined (the file is renamed to `<name>.pgjr.quarantined`
    /// so the next submission of the same spec recomputes instead of
    /// tripping over it again).
    // lint: fault-ok(result damage is injected at save time via
    // store.result; the CRC trailer check here is what that tap exercises)
    pub fn load_checked(&self, key: &str) -> LoadOutcome {
        let path = self.path_for(key);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                RESULT_MISSES.inc();
                return LoadOutcome::Miss;
            }
        };
        match decode_checked(key, &bytes) {
            Decoded::Ok(res) => {
                RESULT_HITS.inc();
                LoadOutcome::Hit(res)
            }
            Decoded::KeyMismatch => {
                RESULT_MISSES.inc();
                LoadOutcome::Miss
            }
            Decoded::Corrupt => {
                RESULT_QUARANTINED.inc();
                let mut q = path.as_os_str().to_os_string();
                q.push(".quarantined");
                let q = PathBuf::from(q);
                if fs::rename(&path, &q).is_err() {
                    // Read-only store: leave it; every load re-verifies.
                    let _ = fs::remove_file(&path);
                }
                eprintln!(
                    "polygen: stored result {} failed its integrity check; \
                     quarantined at {} (resubmit to recompute)",
                    path.display(),
                    q.display()
                );
                LoadOutcome::Quarantined(q)
            }
        }
    }

    /// Everything currently stored, key-sorted — the `GET /store`
    /// inventory. Reads each file's embedded key best-effort (corrupt
    /// files still occupy disk, so they are listed too).
    // lint: fault-ok(best-effort maintenance scan; a bad read degrades a
    // listing entry, never a result — integrity lives in load_checked)
    pub fn inventory(&self) -> Vec<StoreEntry> {
        let Ok(entries) = fs::read_dir(&self.dir) else {
            STORE_BYTES.set(0);
            STORE_ENTRIES.set(0);
            return Vec::new();
        };
        let now = SystemTime::now();
        let mut out = Vec::new();
        for e in entries.flatten() {
            let path = e.path();
            if path.extension().map_or(true, |x| x != "pgjr") {
                continue;
            }
            let Ok(md) = e.metadata() else { continue };
            let key = fs::read(&path)
                .ok()
                .and_then(|bytes| embedded_key(&bytes))
                .unwrap_or_else(|| "(unreadable)".into());
            let age_secs = md
                .modified()
                .ok()
                .and_then(|m| now.duration_since(m).ok())
                .map_or(0, |d| d.as_secs());
            out.push(StoreEntry { key, bytes: md.len(), age_secs });
        }
        out.sort_by(|a, b| a.key.cmp(&b.key));
        // The walk already has the totals; publish them so /metrics
        // agrees with what GET /store just reported (cross-checked in
        // tests/obs.rs).
        STORE_BYTES.set(out.iter().map(|e| e.bytes).sum());
        STORE_ENTRIES.set(out.len() as u64);
        out
    }

    /// Enforce the TTL, then the byte budget (oldest files first).
    /// Best-effort: an unreadable directory just skips the pass.
    // lint: fault-ok(best-effort maintenance deletes; a failed remove
    // leaves a file the next prune retries — no integrity boundary)
    fn prune(&self) {
        if self.max_bytes.is_none() && self.ttl.is_none() {
            return;
        }
        let Ok(entries) = fs::read_dir(&self.dir) else { return };
        let mut files: Vec<(PathBuf, u64, SystemTime)> = Vec::new();
        for e in entries.flatten() {
            let path = e.path();
            if path.extension().map_or(true, |x| x != "pgjr") {
                continue;
            }
            let Ok(md) = e.metadata() else { continue };
            let modified = md.modified().unwrap_or(SystemTime::UNIX_EPOCH);
            files.push((path, md.len(), modified));
        }
        if let Some(ttl) = self.ttl {
            let now = SystemTime::now();
            files.retain(|(path, _, modified)| {
                let expired = now.duration_since(*modified).map_or(false, |age| age > ttl);
                if expired {
                    let _ = fs::remove_file(path);
                }
                !expired
            });
        }
        if let Some(cap) = self.max_bytes {
            let mut total: u64 = files.iter().map(|(_, len, _)| *len).sum();
            files.sort_by_key(|(_, _, modified)| *modified);
            for (path, len, _) in &files {
                if total <= cap {
                    break;
                }
                if fs::remove_file(path).is_ok() {
                    total -= len;
                }
            }
        }
    }
}

fn w_encoding(out: &mut Vec<u8>, e: &Encoding) {
    w_u32(out, e.trunc);
    w_u32(out, e.width);
    out.push(match e.sign {
        Sign::NonNeg => 0,
        Sign::NonPos => 1,
        Sign::Signed => 2,
    });
}

fn r_encoding(rd: &mut Reader<'_>) -> Option<Encoding> {
    let trunc = rd.u32()?;
    let width = rd.u32()?;
    let sign = match rd.u8()? {
        0 => Sign::NonNeg,
        1 => Sign::NonPos,
        2 => Sign::Signed,
        _ => return None,
    };
    Some(Encoding { trunc, width, sign })
}

fn encode_result(key: &str, res: &JobResult) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(PGJR_MAGIC);
    w_u32(&mut out, PGJR_VERSION);
    w_str(&mut out, key);
    w_str(&mut out, &res.func);
    w_u32(&mut out, res.bits);
    w_u32(&mut out, res.lookup_bits);
    let im = &res.implementation;
    w_str(&mut out, &im.func);
    w_str(&mut out, &im.accuracy);
    w_u32(&mut out, im.in_bits);
    w_u32(&mut out, im.out_bits);
    w_u32(&mut out, im.lookup_bits);
    w_u32(&mut out, im.k);
    out.push(match im.degree {
        Degree::Linear => 0,
        Degree::Quadratic => 1,
    });
    w_u32(&mut out, im.sq_trunc);
    w_u32(&mut out, im.lin_trunc);
    w_encoding(&mut out, &im.enc_a);
    w_encoding(&mut out, &im.enc_b);
    w_encoding(&mut out, &im.enc_c);
    w_u32(&mut out, im.coeffs.len() as u32);
    for c in &im.coeffs {
        w_i64(&mut out, c.a);
        w_i64(&mut out, c.b);
        w_i64(&mut out, c.c);
    }
    out.push(im.sampled as u8);
    w_f64(&mut out, res.synth.delay_ns);
    w_f64(&mut out, res.synth.area_um2);
    match &res.verify {
        Some(v) => {
            out.push(1);
            w_u64(&mut out, v.total);
            w_u64(&mut out, v.violations);
            match v.first_violation {
                Some(z) => {
                    out.push(1);
                    w_u64(&mut out, z);
                }
                None => out.push(0),
            }
            w_i64(&mut out, v.worst_excess);
        }
        None => out.push(0),
    }
    let crc = crc32(&out);
    w_u32(&mut out, crc);
    out
}

/// Why a `.pgjr` file did not yield a hit for a key.
enum Decoded {
    Ok(JobResult),
    /// CRC-valid file for a different key: a genuine FNV collision, not
    /// damage — the file belongs to some other spec and must survive.
    KeyMismatch,
    /// Failed the CRC trailer, or (CRC-valid but) structurally
    /// unparseable — either way the file is not trustworthy.
    Corrupt,
}

fn decode_checked(key: &str, bytes: &[u8]) -> Decoded {
    // The trailer covers everything before it, so check it first: a
    // single flipped bit anywhere fails closed here.
    if bytes.len() < 4 {
        return Decoded::Corrupt;
    }
    let (payload, tail) = bytes.split_at(bytes.len() - 4);
    let crc = u32::from_le_bytes(tail.try_into().unwrap());
    if crc32(payload) != crc {
        return Decoded::Corrupt;
    }
    let mut rd = Reader::new(payload);
    if rd.take(4) != Some(PGJR_MAGIC.as_slice()) || rd.u32() != Some(PGJR_VERSION) {
        return Decoded::Corrupt;
    }
    match rd.string() {
        Some(k) if k == key => {}
        Some(_) => return Decoded::KeyMismatch,
        None => return Decoded::Corrupt,
    }
    match decode_body(&mut rd) {
        Some(res) if rd.done() => Decoded::Ok(res),
        _ => Decoded::Corrupt,
    }
}

/// Best-effort read of the key a `.pgjr` file embeds — no CRC check,
/// the inventory lists damaged files too.
fn embedded_key(bytes: &[u8]) -> Option<String> {
    let mut rd = Reader::new(bytes);
    if rd.take(4)? != PGJR_MAGIC {
        return None;
    }
    let _version = rd.u32()?;
    rd.string()
}

/// The fields after the embedded key (shared by every version so far).
fn decode_body(rd: &mut Reader<'_>) -> Option<JobResult> {
    let func = rd.string()?;
    let bits = rd.u32()?;
    let lookup_bits = rd.u32()?;
    let im_func = rd.string()?;
    let accuracy = rd.string()?;
    let in_bits = rd.u32()?;
    let out_bits = rd.u32()?;
    let im_lookup = rd.u32()?;
    let k = rd.u32()?;
    let degree = match rd.u8()? {
        0 => Degree::Linear,
        1 => Degree::Quadratic,
        _ => return None,
    };
    let sq_trunc = rd.u32()?;
    let lin_trunc = rd.u32()?;
    let enc_a = r_encoding(&mut rd)?;
    let enc_b = r_encoding(&mut rd)?;
    let enc_c = r_encoding(&mut rd)?;
    let ncoeffs = rd.u32()? as usize;
    let mut coeffs = Vec::with_capacity(ncoeffs);
    for _ in 0..ncoeffs {
        let a = rd.i64()?;
        let b = rd.i64()?;
        let c = rd.i64()?;
        coeffs.push(Coeffs { a, b, c });
    }
    let sampled = match rd.u8()? {
        0 => false,
        1 => true,
        _ => return None,
    };
    let implementation = Implementation {
        func: im_func,
        accuracy,
        in_bits,
        out_bits,
        lookup_bits: im_lookup,
        k,
        degree,
        sq_trunc,
        lin_trunc,
        enc_a,
        enc_b,
        enc_c,
        coeffs,
        sampled,
    };
    let synth = SynthPoint { delay_ns: rd.f64()?, area_um2: rd.f64()? };
    let verify = match rd.u8()? {
        0 => None,
        1 => {
            let total = rd.u64()?;
            let violations = rd.u64()?;
            let first_violation = match rd.u8()? {
                0 => None,
                1 => Some(rd.u64()?),
                _ => return None,
            };
            let worst_excess = rd.i64()?;
            Some(VerifyReport { total, violations, first_violation, worst_excess })
        }
        _ => return None,
    };
    Some(JobResult { func, bits, lookup_bits, implementation, synth, verify, rtl: Vec::new() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::LookupBits;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("polygen_store_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // IEEE CRC-32 check values (RFC 3720 appendix / zlib).
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn store_key_ignores_scheduling_fields() {
        let mut a = JobSpec::new("recip", 8);
        let mut b = a.clone();
        b.threads = 16;
        b.threads_strict = true;
        assert_eq!(store_key(&a), store_key(&b), "thread knobs must not split the store");
        b.max_k = a.max_k + 1;
        assert_ne!(store_key(&a), store_key(&b), "result-affecting fields must split it");
        a.rtl_out = Some(PathBuf::from("out"));
        assert_eq!(store_key(&a), None, "rtl side effects are not storable");
    }

    #[test]
    fn result_store_roundtrips_a_real_job() {
        let dir = tmpdir("roundtrip");
        let mut spec = JobSpec::new("recip", 8);
        spec.lookup = LookupBits::Fixed(4);
        let res = spec.run().unwrap();
        let key = store_key(&spec).unwrap();
        let store = ResultStore::new(&dir);
        assert!(store.load(&key).is_none());
        store.save(&key, &res);
        let back = store.load(&key).expect("saved result must load");
        assert_eq!(back.func, res.func);
        assert_eq!(back.lookup_bits, res.lookup_bits);
        assert_eq!(back.implementation.coeffs, res.implementation.coeffs);
        assert_eq!(back.implementation.enc_a, res.implementation.enc_a);
        assert_eq!(back.synth.delay_ns.to_bits(), res.synth.delay_ns.to_bits());
        assert_eq!(back.verify.as_ref().unwrap().total, res.verify.as_ref().unwrap().total);
        // A different key never aliases onto this file's contents.
        assert!(store.load("other-key").is_none());
        // Corruption fails the whole-file CRC: a strict miss (v1 could
        // let a coefficient flip decode), and the damaged file is
        // quarantined aside so the key recomputes cleanly.
        let path = store.path_for(&key);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        assert!(store.load(&key).is_none(), "any flipped bit must fail closed");
        assert!(!path.exists(), "corrupt file must be moved aside");
        match store.load_checked(&key) {
            LoadOutcome::Miss => {}
            LoadOutcome::Hit(_) => panic!("quarantined key must not hit"),
            LoadOutcome::Quarantined(_) => panic!("quarantine must not repeat"),
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn every_single_byte_flip_is_caught_and_quarantined() {
        // The satellite-4 sweep at the store level: flip each byte of a
        // stored result in turn; every variant must fail closed (no
        // panic, no wrong result) and land in quarantine.
        let dir = tmpdir("flip");
        let mut spec = JobSpec::new("recip", 8);
        spec.lookup = LookupBits::Fixed(4);
        let res = spec.run().unwrap();
        let key = store_key(&spec).unwrap();
        let store = ResultStore::new(&dir);
        store.save(&key, &res);
        let path = store.path_for(&key);
        let clean = fs::read(&path).unwrap();
        let mut q = path.as_os_str().to_os_string();
        q.push(".quarantined");
        let q = PathBuf::from(q);
        for at in 0..clean.len() {
            let mut bad = clean.clone();
            bad[at] ^= 0x01;
            fs::write(&path, &bad).unwrap();
            match store.load_checked(&key) {
                LoadOutcome::Quarantined(p) => assert_eq!(p, q),
                LoadOutcome::Hit(_) => panic!("flip at byte {at} decoded as a hit"),
                LoadOutcome::Miss => panic!("flip at byte {at} read as a plain miss"),
            }
            assert!(!path.exists(), "flip at byte {at} must be moved aside");
            fs::remove_file(&q).ok();
        }
        // The clean bytes still load after all that.
        fs::write(&path, &clean).unwrap();
        assert!(store.load(&key).is_some());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recover_quarantines_and_truncates_a_corrupt_tail() {
        let dir = tmpdir("recover");
        let path = dir.join("jobs.log");
        let log = JobLog::open(&path).unwrap();
        let spec = JobSpec::new("recip", 8);
        log.append_submit(1, &spec);
        let valid_len = fs::metadata(&path).unwrap().len();
        log.append_submit(2, &spec);
        drop(log);
        let mut damaged = fs::read(&path).unwrap();
        let last = damaged.len() - 1;
        damaged[last] ^= 0xFF;
        fs::write(&path, &damaged).unwrap();

        let jobs = JobLog::recover(&path);
        assert_eq!(jobs.len(), 1, "the frame behind the corruption is gone");
        assert_eq!(
            fs::metadata(&path).unwrap().len(),
            valid_len,
            "live log must be truncated back to its valid prefix"
        );
        let q = dir.join("jobs.log.quarantined");
        assert_eq!(
            fs::metadata(&q).unwrap().len() as usize,
            damaged.len(),
            "damaged copy must be kept for forensics"
        );

        // The repaired log accepts appends that replay cleanly —
        // without the truncation they would hide behind the bad frame.
        let log = JobLog::open(&path).unwrap();
        log.append_finish(1, &LogOutcome::Done, None);
        drop(log);
        let jobs = JobLog::recover(&path);
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].outcome, Some(LogOutcome::Done));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_bounds_prune_and_inventory_lists() {
        let dir = tmpdir("bounds");
        let mut spec = JobSpec::new("recip", 8);
        spec.lookup = LookupBits::Fixed(4);
        let res = spec.run().unwrap();
        let key = store_key(&spec).unwrap();

        // Unbounded: the file stays and the inventory reports it.
        let store = ResultStore::new(&dir);
        store.save(&key, &res);
        let inv = store.inventory();
        assert_eq!(inv.len(), 1);
        assert_eq!(inv[0].key, key, "inventory must surface the embedded key");
        assert_eq!(inv[0].bytes, fs::metadata(store.path_for(&key)).unwrap().len());

        // A zero-byte budget evicts everything on the save-time prune.
        let bounded = ResultStore::with_bounds(&dir, Some(0), None);
        bounded.save(&key, &res);
        assert!(bounded.inventory().is_empty(), "byte cap must evict");
        assert!(bounded.load(&key).is_none());

        // A zero TTL expires files as soon as the clock ticks past
        // their mtime; an hour-long TTL keeps them.
        let keeper = ResultStore::with_bounds(&dir, None, Some(Duration::from_secs(3600)));
        keeper.save(&key, &res);
        assert_eq!(keeper.inventory().len(), 1, "young file must survive its TTL");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn job_log_replays_submits_and_finishes() {
        let dir = tmpdir("log");
        let path = dir.join("jobs.log");
        let log = JobLog::open(&path).unwrap();
        let s1 = JobSpec::new("recip", 8);
        let mut s2 = JobSpec::new("log2", 8);
        s2.lookup = LookupBits::Fixed(3);
        log.append_submit(1, &s1);
        log.append_submit(2, &s2);
        log.append_finish(1, &LogOutcome::Done, Some("key-1"));
        // Job 2 never finishes: interrupted by the "crash".
        drop(log);
        assert_eq!(JobLog::replay(&path).len(), 2);
        let jobs = JobLog::replay(&path);
        assert_eq!(jobs[0].id, 1);
        assert_eq!(jobs[0].spec, s1);
        assert_eq!(jobs[0].outcome, Some(LogOutcome::Done));
        assert_eq!(jobs[0].store_key.as_deref(), Some("key-1"));
        assert_eq!(jobs[1].id, 2);
        assert_eq!(jobs[1].spec, s2);
        assert_eq!(jobs[1].outcome, None, "no finish record: interrupted");

        // Reopen appends (no truncation) and failures replay too.
        let log = JobLog::open(&path).unwrap();
        log.append_finish(2, &LogOutcome::Failed("boom".into()), None);
        log.append_submit(3, &s1);
        log.append_finish(3, &LogOutcome::Cancelled, None);
        drop(log);
        let jobs = JobLog::replay(&path);
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[1].outcome, Some(LogOutcome::Failed("boom".into())));
        assert_eq!(jobs[2].outcome, Some(LogOutcome::Cancelled));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn job_log_replay_stops_at_corruption() {
        let dir = tmpdir("corrupt");
        let path = dir.join("jobs.log");
        let log = JobLog::open(&path).unwrap();
        let spec = JobSpec::new("recip", 8);
        log.append_submit(1, &spec);
        log.append_submit(2, &spec);
        drop(log);
        let clean = fs::read(&path).unwrap();

        // Truncate mid-record: only the first submit survives.
        fs::write(&path, &clean[..clean.len() - 3]).unwrap();
        let jobs = JobLog::replay(&path);
        assert_eq!(jobs.len(), 1, "torn tail record must be dropped");
        assert_eq!(jobs[0].id, 1);

        // Flip a payload byte in the second record: checksum rejects it.
        let mut flipped = clean.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        fs::write(&path, &flipped).unwrap();
        let jobs = JobLog::replay(&path);
        assert_eq!(jobs.len(), 1, "checksum-failing record must be dropped");

        // Missing file: empty replay, not an error.
        assert!(JobLog::replay(&dir.join("nope.log")).is_empty());
        fs::remove_dir_all(&dir).ok();
    }
}
