//! Durability for the job service (§Cluster in DESIGN.md).
//!
//! Two persistence layers, both rooted in the service's `--state` dir:
//!
//! - **`jobs.log`** — an append-only log of every submission and every
//!   terminal transition, each record framed as
//!   `u32 len | u32 crc32(payload) | payload` (little-endian). Replayed
//!   on startup with WAL semantics: parsing stops at the first
//!   truncated or checksum-failing frame (a crash mid-append loses at
//!   most that one record), so `GET /jobs/:id` survives restarts.
//!   A submission without a matching finish record was interrupted by
//!   the crash and replays as `Failed`.
//! - **the result store** — content-addressed `JobResult` files
//!   (`<fnv64>.pgjr`, versioned binary like the coordinator's PGDS
//!   cache), keyed by the *result-affecting* subset of the job spec:
//!   the canonical TOML with the scheduling-only `threads*` keys
//!   stripped — the same exclusion [`crate::coordinator::cache`]
//!   applies to its filename key. A repeat submission of a popular
//!   spec is answered from here in microseconds without touching the
//!   scheduler. Jobs with `rtl_out` side effects are never stored.
//!
//! Every file embeds the full key (not just its hash) and is verified
//! against it on load, so an FNV collision degrades to a miss, never a
//! wrong result.

use std::fs::{self, File, OpenOptions};
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::dse::precision::{Encoding, Sign};
use crate::dse::Coeffs;
use crate::pipeline::{Degree, Implementation, JobResult, JobSpec, SynthPoint, VerifyReport};

/// CRC-32 (IEEE, reflected) — record framing checksum for `jobs.log`.
pub(crate) fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// FNV-1a 64 — filename hash for the content-addressed store.
fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The content-address of a spec: its canonical TOML with the
/// scheduling-only keys (`threads`, `threads_strict`) stripped — thread
/// counts never change results (property-tested), so they must not
/// split the store. `None` = the job is not storable (it has `rtl_out`
/// filesystem side effects a stored result would silently skip).
pub(crate) fn store_key(spec: &JobSpec) -> Option<String> {
    if spec.rtl_out.is_some() {
        return None;
    }
    let canon: Vec<&str> =
        spec.to_toml().lines().filter(|l| !l.trim_start().starts_with("threads")).collect();
    Some(canon.join("\n"))
}

// ---------------------------------------------------------------------
// Little-endian byte helpers (the PGDS cache idiom).

fn w_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn w_str(out: &mut Vec<u8>, s: &str) {
    w_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn i64(&mut self) -> Option<i64> {
        self.take(8).map(|b| i64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f64(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }

    fn string(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).ok()
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

// ---------------------------------------------------------------------
// The append-only job log.

/// Terminal state of a logged job, as recorded in its finish record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) enum LogOutcome {
    Done,
    Failed(String),
    Cancelled,
}

/// One job reconstructed from the log.
#[derive(Clone, Debug)]
pub(crate) struct ReplayedJob {
    pub id: u64,
    pub spec: JobSpec,
    /// `None` = no finish record (the process died mid-job); the
    /// registry surfaces these as `Failed`.
    pub outcome: Option<LogOutcome>,
    /// Content-address of the stored result, when the finish record
    /// carried one.
    pub store_key: Option<String>,
}

const REC_SUBMIT: u8 = 1;
const REC_FINISH: u8 = 2;

/// Append handle on `jobs.log`. Records are synced to disk per append —
/// jobs run for seconds to minutes, so the fsync is noise, and it is
/// what makes the crash-recovery guarantee real.
pub(crate) struct JobLog {
    file: Mutex<File>,
    write_errors: AtomicU64,
}

impl JobLog {
    /// Open (creating if absent) the log for appending.
    pub fn open(path: &Path) -> std::io::Result<JobLog> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JobLog { file: Mutex::new(file), write_errors: AtomicU64::new(0) })
    }

    fn append(&self, payload: &[u8]) {
        let mut frame = Vec::with_capacity(payload.len() + 8);
        w_u32(&mut frame, payload.len() as u32);
        w_u32(&mut frame, crc32(payload));
        frame.extend_from_slice(payload);
        let mut f = self.file.lock().unwrap();
        // Durability is best-effort: a full disk must not take the
        // (still correct in-memory) service down, so write errors are
        // counted, not propagated.
        if f.write_all(&frame).and_then(|()| f.sync_data()).is_err() {
            self.write_errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Log records that could not be written (disk full, ...): the
    /// in-memory registry is still authoritative, but a restart would
    /// forget these jobs.
    #[cfg(test)]
    pub fn write_errors(&self) -> u64 {
        self.write_errors.load(Ordering::Relaxed)
    }

    /// Record a submission (before the job is queued).
    pub fn append_submit(&self, id: u64, spec: &JobSpec) {
        let mut p = Vec::new();
        p.push(REC_SUBMIT);
        w_u64(&mut p, id);
        w_str(&mut p, &spec.to_toml());
        self.append(&p);
    }

    /// Record a terminal transition, optionally naming the stored
    /// result's content-address.
    pub fn append_finish(&self, id: u64, outcome: &LogOutcome, store_key: Option<&str>) {
        let mut p = Vec::new();
        p.push(REC_FINISH);
        w_u64(&mut p, id);
        let (kind, err) = match outcome {
            LogOutcome::Done => (0u8, ""),
            LogOutcome::Failed(e) => (1, e.as_str()),
            LogOutcome::Cancelled => (2, ""),
        };
        p.push(kind);
        w_str(&mut p, err);
        match store_key {
            Some(k) => {
                p.push(1);
                w_str(&mut p, k);
            }
            None => p.push(0),
        }
        self.append(&p);
    }

    /// Replay a log file into per-job records, in first-submission
    /// order. Stops at the first truncated or corrupt frame (WAL
    /// semantics); a finish for an unknown id is ignored; a duplicate
    /// submit for an id keeps the first spec.
    pub fn replay(path: &Path) -> Vec<ReplayedJob> {
        let mut buf = Vec::new();
        match File::open(path) {
            Ok(mut f) => {
                if f.read_to_end(&mut buf).is_err() {
                    return Vec::new();
                }
            }
            Err(_) => return Vec::new(),
        }
        let mut jobs: Vec<ReplayedJob> = Vec::new();
        let mut rd = Reader::new(&buf);
        loop {
            let Some(len) = rd.u32() else { break };
            let Some(crc) = rd.u32() else { break };
            let Some(payload) = rd.take(len as usize) else { break };
            if crc32(payload) != crc {
                break;
            }
            let mut p = Reader::new(payload);
            let (Some(kind), Some(id)) = (p.u8(), p.u64()) else { break };
            match kind {
                REC_SUBMIT => {
                    let Some(toml) = p.string() else { break };
                    let Ok(spec) = JobSpec::from_toml(&toml) else { continue };
                    if jobs.iter().all(|j| j.id != id) {
                        jobs.push(ReplayedJob { id, spec, outcome: None, store_key: None });
                    }
                }
                REC_FINISH => {
                    let (Some(okind), Some(err)) = (p.u8(), p.string()) else { break };
                    let key = match p.u8() {
                        Some(1) => match p.string() {
                            Some(k) => Some(k),
                            None => break,
                        },
                        Some(0) => None,
                        _ => break,
                    };
                    let outcome = match okind {
                        0 => LogOutcome::Done,
                        1 => LogOutcome::Failed(err),
                        2 => LogOutcome::Cancelled,
                        _ => break,
                    };
                    if let Some(j) = jobs.iter_mut().find(|j| j.id == id) {
                        j.outcome = Some(outcome);
                        j.store_key = key;
                    }
                }
                _ => break,
            }
        }
        jobs
    }
}

// ---------------------------------------------------------------------
// The content-addressed result store.

const PGJR_MAGIC: &[u8; 4] = b"PGJR";
const PGJR_VERSION: u32 = 1;

/// Content-addressed `JobResult` files under `<state>/results/`.
pub(crate) struct ResultStore {
    dir: PathBuf,
}

impl ResultStore {
    pub fn new(dir: &Path) -> ResultStore {
        ResultStore { dir: dir.to_path_buf() }
    }

    /// Where `key`'s result lives (whether or not it exists yet).
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{:016x}.pgjr", fnv1a64(key.as_bytes())))
    }

    /// Persist `res` under `key`. Best-effort and atomic (tmp +
    /// rename): a failed save costs a future recompute, never
    /// corruption.
    pub fn save(&self, key: &str, res: &JobResult) {
        if fs::create_dir_all(&self.dir).is_err() {
            return;
        }
        let bytes = encode_result(key, res);
        let path = self.path_for(key);
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        let ok = fs::write(&tmp, &bytes).is_ok() && fs::rename(&tmp, &path).is_ok();
        if !ok {
            let _ = fs::remove_file(&tmp);
        }
    }

    /// Load the result stored under `key`, verifying the embedded key
    /// (hash collisions and truncated files degrade to a miss).
    pub fn load(&self, key: &str) -> Option<JobResult> {
        let bytes = fs::read(self.path_for(key)).ok()?;
        decode_result(key, &bytes)
    }
}

fn w_encoding(out: &mut Vec<u8>, e: &Encoding) {
    w_u32(out, e.trunc);
    w_u32(out, e.width);
    out.push(match e.sign {
        Sign::NonNeg => 0,
        Sign::NonPos => 1,
        Sign::Signed => 2,
    });
}

fn r_encoding(rd: &mut Reader<'_>) -> Option<Encoding> {
    let trunc = rd.u32()?;
    let width = rd.u32()?;
    let sign = match rd.u8()? {
        0 => Sign::NonNeg,
        1 => Sign::NonPos,
        2 => Sign::Signed,
        _ => return None,
    };
    Some(Encoding { trunc, width, sign })
}

fn encode_result(key: &str, res: &JobResult) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(PGJR_MAGIC);
    w_u32(&mut out, PGJR_VERSION);
    w_str(&mut out, key);
    w_str(&mut out, &res.func);
    w_u32(&mut out, res.bits);
    w_u32(&mut out, res.lookup_bits);
    let im = &res.implementation;
    w_str(&mut out, &im.func);
    w_str(&mut out, &im.accuracy);
    w_u32(&mut out, im.in_bits);
    w_u32(&mut out, im.out_bits);
    w_u32(&mut out, im.lookup_bits);
    w_u32(&mut out, im.k);
    out.push(match im.degree {
        Degree::Linear => 0,
        Degree::Quadratic => 1,
    });
    w_u32(&mut out, im.sq_trunc);
    w_u32(&mut out, im.lin_trunc);
    w_encoding(&mut out, &im.enc_a);
    w_encoding(&mut out, &im.enc_b);
    w_encoding(&mut out, &im.enc_c);
    w_u32(&mut out, im.coeffs.len() as u32);
    for c in &im.coeffs {
        w_i64(&mut out, c.a);
        w_i64(&mut out, c.b);
        w_i64(&mut out, c.c);
    }
    out.push(im.sampled as u8);
    w_f64(&mut out, res.synth.delay_ns);
    w_f64(&mut out, res.synth.area_um2);
    match &res.verify {
        Some(v) => {
            out.push(1);
            w_u64(&mut out, v.total);
            w_u64(&mut out, v.violations);
            match v.first_violation {
                Some(z) => {
                    out.push(1);
                    w_u64(&mut out, z);
                }
                None => out.push(0),
            }
            w_i64(&mut out, v.worst_excess);
        }
        None => out.push(0),
    }
    out
}

fn decode_result(key: &str, bytes: &[u8]) -> Option<JobResult> {
    let mut rd = Reader::new(bytes);
    if rd.take(4)? != PGJR_MAGIC || rd.u32()? != PGJR_VERSION {
        return None;
    }
    if rd.string()? != key {
        return None; // FNV collision: treat as a miss
    }
    let func = rd.string()?;
    let bits = rd.u32()?;
    let lookup_bits = rd.u32()?;
    let im_func = rd.string()?;
    let accuracy = rd.string()?;
    let in_bits = rd.u32()?;
    let out_bits = rd.u32()?;
    let im_lookup = rd.u32()?;
    let k = rd.u32()?;
    let degree = match rd.u8()? {
        0 => Degree::Linear,
        1 => Degree::Quadratic,
        _ => return None,
    };
    let sq_trunc = rd.u32()?;
    let lin_trunc = rd.u32()?;
    let enc_a = r_encoding(&mut rd)?;
    let enc_b = r_encoding(&mut rd)?;
    let enc_c = r_encoding(&mut rd)?;
    let ncoeffs = rd.u32()? as usize;
    let mut coeffs = Vec::with_capacity(ncoeffs);
    for _ in 0..ncoeffs {
        let a = rd.i64()?;
        let b = rd.i64()?;
        let c = rd.i64()?;
        coeffs.push(Coeffs { a, b, c });
    }
    let sampled = match rd.u8()? {
        0 => false,
        1 => true,
        _ => return None,
    };
    let implementation = Implementation {
        func: im_func,
        accuracy,
        in_bits,
        out_bits,
        lookup_bits: im_lookup,
        k,
        degree,
        sq_trunc,
        lin_trunc,
        enc_a,
        enc_b,
        enc_c,
        coeffs,
        sampled,
    };
    let synth = SynthPoint { delay_ns: rd.f64()?, area_um2: rd.f64()? };
    let verify = match rd.u8()? {
        0 => None,
        1 => {
            let total = rd.u64()?;
            let violations = rd.u64()?;
            let first_violation = match rd.u8()? {
                0 => None,
                1 => Some(rd.u64()?),
                _ => return None,
            };
            let worst_excess = rd.i64()?;
            Some(VerifyReport { total, violations, first_violation, worst_excess })
        }
        _ => return None,
    };
    if !rd.done() {
        return None;
    }
    Some(JobResult { func, bits, lookup_bits, implementation, synth, verify, rtl: Vec::new() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::LookupBits;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("polygen_store_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // IEEE CRC-32 check values (RFC 3720 appendix / zlib).
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn store_key_ignores_scheduling_fields() {
        let mut a = JobSpec::new("recip", 8);
        let mut b = a.clone();
        b.threads = 16;
        b.threads_strict = true;
        assert_eq!(store_key(&a), store_key(&b), "thread knobs must not split the store");
        b.max_k = a.max_k + 1;
        assert_ne!(store_key(&a), store_key(&b), "result-affecting fields must split it");
        a.rtl_out = Some(PathBuf::from("out"));
        assert_eq!(store_key(&a), None, "rtl side effects are not storable");
    }

    #[test]
    fn result_store_roundtrips_a_real_job() {
        let dir = tmpdir("roundtrip");
        let mut spec = JobSpec::new("recip", 8);
        spec.lookup = LookupBits::Fixed(4);
        let res = spec.run().unwrap();
        let key = store_key(&spec).unwrap();
        let store = ResultStore::new(&dir);
        assert!(store.load(&key).is_none());
        store.save(&key, &res);
        let back = store.load(&key).expect("saved result must load");
        assert_eq!(back.func, res.func);
        assert_eq!(back.lookup_bits, res.lookup_bits);
        assert_eq!(back.implementation.coeffs, res.implementation.coeffs);
        assert_eq!(back.implementation.enc_a, res.implementation.enc_a);
        assert_eq!(back.synth.delay_ns.to_bits(), res.synth.delay_ns.to_bits());
        assert_eq!(back.verify.as_ref().unwrap().total, res.verify.as_ref().unwrap().total);
        // A different key never aliases onto this file's contents.
        assert!(store.load("other-key").is_none());
        // Corruption degrades to a miss.
        let path = store.path_for(&key);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        // Either the key echo or a field decode breaks; flipping one
        // byte can land in coeffs, so double-check against the oracle.
        if let Some(loaded) = store.load(&key) {
            assert_ne!(loaded.implementation.coeffs, res.implementation.coeffs);
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn job_log_replays_submits_and_finishes() {
        let dir = tmpdir("log");
        let path = dir.join("jobs.log");
        let log = JobLog::open(&path).unwrap();
        let s1 = JobSpec::new("recip", 8);
        let mut s2 = JobSpec::new("log2", 8);
        s2.lookup = LookupBits::Fixed(3);
        log.append_submit(1, &s1);
        log.append_submit(2, &s2);
        log.append_finish(1, &LogOutcome::Done, Some("key-1"));
        // Job 2 never finishes: interrupted by the "crash".
        drop(log);
        assert_eq!(JobLog::replay(&path).len(), 2);
        let jobs = JobLog::replay(&path);
        assert_eq!(jobs[0].id, 1);
        assert_eq!(jobs[0].spec, s1);
        assert_eq!(jobs[0].outcome, Some(LogOutcome::Done));
        assert_eq!(jobs[0].store_key.as_deref(), Some("key-1"));
        assert_eq!(jobs[1].id, 2);
        assert_eq!(jobs[1].spec, s2);
        assert_eq!(jobs[1].outcome, None, "no finish record: interrupted");

        // Reopen appends (no truncation) and failures replay too.
        let log = JobLog::open(&path).unwrap();
        log.append_finish(2, &LogOutcome::Failed("boom".into()), None);
        log.append_submit(3, &s1);
        log.append_finish(3, &LogOutcome::Cancelled, None);
        drop(log);
        let jobs = JobLog::replay(&path);
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[1].outcome, Some(LogOutcome::Failed("boom".into())));
        assert_eq!(jobs[2].outcome, Some(LogOutcome::Cancelled));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn job_log_replay_stops_at_corruption() {
        let dir = tmpdir("corrupt");
        let path = dir.join("jobs.log");
        let log = JobLog::open(&path).unwrap();
        let spec = JobSpec::new("recip", 8);
        log.append_submit(1, &spec);
        log.append_submit(2, &spec);
        drop(log);
        let clean = fs::read(&path).unwrap();

        // Truncate mid-record: only the first submit survives.
        fs::write(&path, &clean[..clean.len() - 3]).unwrap();
        let jobs = JobLog::replay(&path);
        assert_eq!(jobs.len(), 1, "torn tail record must be dropped");
        assert_eq!(jobs[0].id, 1);

        // Flip a payload byte in the second record: checksum rejects it.
        let mut flipped = clean.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        fs::write(&path, &flipped).unwrap();
        let jobs = JobLog::replay(&path);
        assert_eq!(jobs.len(), 1, "checksum-failing record must be dropped");

        // Missing file: empty replay, not an error.
        assert!(JobLog::replay(&dir.join("nope.log")).is_empty());
        fs::remove_dir_all(&dir).ok();
    }
}
