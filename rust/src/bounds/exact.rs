//! Exact fixed-point floors for the transcendental target functions.
//!
//! The paper's bound functions `l, u` must be *trusted*: a single
//! mis-rounded bound makes the generated design space wrong (either
//! excluding feasible polynomials or, worse, admitting infeasible ones).
//! The paper defers this to "integration with MPFR" as future work; here we
//! build the substrate directly: 128-bit fixed-point evaluation with ≥ 90
//! guard bits and an explicit ambiguity check on every floor. If a value
//! ever lands inside the guard margin of an integer boundary the functions
//! panic rather than return a possibly-wrong bound (this never fires for
//! the ≤ 26-bit formats used anywhere in this repo; dedicated tests
//! exhaustively confirm agreement with directed `f64` evaluation, and
//! `python/activation_mirror.py` re-derives every activation floor
//! bit-for-bit against an 80-digit `Decimal` reference).
//!
//! # Conventions
//!
//! Every `floor_*_scaled` function maps an *integer* input `z` (the raw
//! `m`-bit operand) to `(floor(Y), exact)` where `Y` is the scaled target
//! value — the input scaling (`x = z / 2^s` for a function-specific `s`)
//! and output scaling (`Y = 2^q · f(x)`, possibly shifted) are documented
//! per function and in `DESIGN.md §Workloads`. The `exact` flag is `true`
//! only when `Y` is *provably* an integer (so the caller may tighten its
//! accuracy bounds); any other value is guaranteed to be farther than the
//! guard margin (`2^-90` here) from an integer, by the panic check.
//!
//! ```
//! use polygen::bounds::exact::floor_tanh_scaled;
//!
//! // 12-bit tanh: x = z / 2^9, Y = 2^12 * tanh(x). At z = 512, x = 1.
//! let (floor, exact) = floor_tanh_scaled(512, 12, 12);
//! assert_eq!(floor, (4096.0 * 1.0f64.tanh()).floor() as i64);
//! assert!(!exact); // tanh(1) is irrational
//! ```

use crate::wide::{div_u256_by_u128, div_u256_by_u64, isqrt_u256, mul_u256_by_u64, U256};

// Const-initialized static cache; `OnceLock` has no loom mirror and this
// module is never loom-modeled.
// lint: sync-ok(const-init OnceLock static in never-modeled code)
use std::sync::OnceLock;

/// Fractional bits of the internal fixed-point representation.
const F: u32 = 120;
/// Ambiguity margin in ulps of `2^-F`. The accumulated truncation error of
/// the algorithms below is provably < 2^7 ulps; 2^20 is a very safe guard.
const MARGIN: u128 = 1 << 20;

/// `frac(log2(v))` for `v > 0`, as a Q0.120 fixed-point value, by the
/// classic shift-and-square recurrence on a Q1.127 mantissa.
///
/// Per-step truncation contributes ≤ 2^-127 to `log2(a_i)` which enters the
/// result with weight `2^-i`, so the total error is < (F+2)·2^-127 < 2^-119.
pub fn log2_frac_q120(v: u128) -> u128 {
    assert!(v > 0);
    // Normalize to a in [2^127, 2^128): A = a / 2^127 in [1, 2)
    // (shifting the MSB of v up to bit 127 discards only log2's integer
    // part, which the caller does not want anyway).
    let mut a: u128 = v << v.leading_zeros();
    let mut frac: u128 = 0;
    for _ in 0..F {
        let sq = U256::mul_u128(a, a); // A^2 = sq / 2^254 in [1, 4)
        let bit = (sq.hi >> 127) & 1; // A^2 >= 2  <=>  sq >= 2^255
        frac = (frac << 1) | bit;
        a = if bit == 1 {
            sq.hi // A' = A^2/2: floor(sq / 2^128)
        } else {
            sq.shr(127).lo // A' = A^2: floor(sq / 2^127)
        };
    }
    frac
}

/// `2^(z / 2^m)` for `0 <= z < 2^m`, as a Q1.127 fixed-point value in
/// `[1, 2)`, via the product of repeated square roots of two.
///
/// `2^(z/2^m) = prod over set bits i of z of 2^(2^(i-m))`; the factors
/// `s_j = 2^(2^-j)` come from the chain `s_1 = sqrt 2`, `s_{j+1} =
/// sqrt(s_j)`. Square-rooting *halves* relative error, so the chain error
/// stays ≤ 2^-126 per factor and the ≤ m-term product accumulates
/// < (2m)·2^-127 < 2^-120 total.
pub fn exp2_frac_q127(z: u64, m: u32) -> u128 {
    assert!(m >= 1 && m <= 63 && (z >> m) == 0);
    let roots = sqrt2_chain(m);
    let mut g: u128 = 1u128 << 127; // 1.0 in Q1.127
    for i in 0..m {
        if (z >> i) & 1 == 1 {
            let j = (m - i) as usize; // weight 2^-(m-i)
            g = U256::mul_u128(g, roots[j - 1]).shr(127).lo;
        }
    }
    g
}

/// Depth of the cached square-root-of-two chain: enough for a full
/// Q0.120 fractional exponent, the widest any caller uses.
const CHAIN_DEPTH: u32 = 120;

/// `[ 2^(2^-1), 2^(2^-2), ..., 2^(2^-m) ]` in Q1.127 (`m <= 120`).
///
/// The chain is computed once to full depth and cached: the activation
/// floors call [`exp2w_q127`] per input point (2^16 points for a 16-bit
/// bound table), and each call folds up to 120 chain factors.
fn sqrt2_chain(m: u32) -> &'static [u128] {
    assert!(m <= CHAIN_DEPTH);
    static CHAIN: OnceLock<Vec<u128>> = OnceLock::new();
    let chain = CHAIN.get_or_init(|| {
        let mut roots = Vec::with_capacity(CHAIN_DEPTH as usize);
        // s_1 = sqrt(2) in Q1.127 = isqrt(2 << 254).
        let mut s: u128 = isqrt_u256(U256 { hi: 1u128 << 127, lo: 0 });
        roots.push(s);
        for _ in 1..CHAIN_DEPTH {
            // s_{j+1} = sqrt(s_j): isqrt(s << 127) in Q1.127.
            s = isqrt_u256(U256::from_u128(s).shl(127));
            roots.push(s);
        }
        roots
    });
    &chain[..m as usize]
}

/// `floor(2^q * frac(log2(v)))` with an exactness flag.
///
/// Panics if the value is within the guard margin of an integer boundary
/// (would indicate the 120-bit evaluation cannot decide the floor).
pub fn floor_log2_scaled(v: u128, q: u32) -> (i64, bool) {
    assert!(q < F - 24, "output precision too large for the 120-bit substrate");
    if v.is_power_of_two() {
        return (0, true); // frac(log2) = 0 exactly
    }
    let frac = log2_frac_q120(v);
    split_floor(frac, F - q)
}

/// `floor(2^q * (2^(z/2^m) - 1))` with an exactness flag.
pub fn floor_exp2m1_scaled(z: u64, m: u32, q: u32) -> (i64, bool) {
    assert!(q <= 126 - 24, "output precision too large");
    if z == 0 {
        return (0, true);
    }
    let g = exp2_frac_q127(z, m); // Q1.127 in [1,2)
    let frac = g - (1u128 << 127); // Q0.127
    split_floor(frac, 127 - q)
}

/// `floor(log2(e) * 2^126)`; derived and cross-checked by
/// `python/activation_mirror.py`.
const LOG2E_Q126: u128 = 0x5c55_1d94_ae0b_f85d_df43_ff68_348e_9f44;
/// `floor(sqrt(2/pi) * 2^126)` (the GELU erf-series prefactor).
const SQRT2_OVER_PI_Q126: u128 = 0x3310_8a67_a86c_a11a_1f96_78a0_1757_1c5f;

/// `2^f` for a Q0.120 fraction `f` in `(0, 1)`, as Q1.127.
///
/// Same square-root-chain product as [`exp2_frac_q127`] but over a full
/// 120-bit fraction: bit `i` of `f` has weight `2^(i-120)` and contributes
/// the chain factor `2^(2^-(120-i))`. Each of the ≤ 120 factor folds
/// truncates ≤ 2^-127, so the relative error stays below `2^-119`.
fn exp2w_q127(f: u128) -> u128 {
    debug_assert!(f > 0 && f < (1u128 << 120));
    let roots = sqrt2_chain(CHAIN_DEPTH);
    let mut g: u128 = 1u128 << 127; // 1.0 in Q1.127
    for i in 0..CHAIN_DEPTH {
        if (f >> i) & 1 == 1 {
            let j = (CHAIN_DEPTH - i) as usize; // weight 2^-(120-i)
            g = U256::mul_u128(g, roots[j - 1]).shr(127).lo;
        }
    }
    g
}

/// `E = e^(-lk·x)` for `x = z / 2^(m-3)` and `lk ∈ {1, 2}`, as Q0.124.
///
/// Computed division-free: `lk·x·log2(e) = T + tf` with integer `T` and a
/// Q0.120 fraction `tf`, and `2^-tf = 2^(1-tf) / 2` turns the negative
/// power into one [`exp2w_q127`] call. `x < 8`, so `E > e^-16 > 2^-23.1`
/// and the Q0.124 result keeps ≥ 100 significant bits.
fn exp2neg_q124(z: u64, m: u32, lk: u32) -> u128 {
    debug_assert!(z > 0 && (lk == 1 || lk == 2));
    let sh = m - 3 - (lk == 2) as u32; // lk·x = z / 2^sh
    // P = z·log2(e)·2^126 represents t = lk·x·log2(e) at Q.(126+sh).
    let p = U256::mul_u128(z as u128, LOG2E_Q126);
    let t = p.shr(126 + sh);
    debug_assert!(t.hi == 0 && t.lo <= 24);
    let t = t.lo as u32;
    let tf = p.shr(6 + sh).lo & ((1u128 << 120) - 1);
    if tf == 0 {
        // t is an exact integer (only z = 0 in exact arithmetic, but the
        // truncated tf can underflow to zero; 2^-t is then the best Q0.124
        // value within the substrate's error budget).
        return 1u128 << (124 - t);
    }
    let g2 = exp2w_q127((1u128 << 120) - tf); // 2^(1-tf) in (1, 2), Q1.127
    g2 >> (4 + t)
}

/// Shared tanh/sigmoid floor: `floor(2^q · (1-E)/(1+E))`, `E = e^(-lk·x)`.
///
/// `(1-E)/(1+E) = tanh(lk·x/2)`, so `lk = 2` is tanh and `lk = 1` is the
/// sigmoid via `2σ(x) - 1 = tanh(x/2)`.
fn floor_tanh_like(z: u64, m: u32, q: u32, lk: u32) -> (i64, bool) {
    assert!((4..=16).contains(&m) && q >= 1 && q <= 16 && (z >> m) == 0);
    if z == 0 {
        return (0, true); // tanh(0) = 0 exactly
    }
    let e = exp2neg_q124(z, m, lk);
    // Y·2^110 = (2^124 - e)·2^(q+110) / (2^124 + e) <= 2^(q+110) <= 2^126:
    // the quotient always fits u128 and the divisor exceeds num.hi, so
    // the division is exact-floor (never saturates).
    let num = U256::mul_u128((1u128 << 124) - e, 1u128 << (q + 110));
    let den = (1u128 << 124) + e;
    let quo = div_u256_by_u128(num, den);
    split_floor(quo, 110)
}

/// `floor(2^q · tanh(x))` for `x = z / 2^(m-3) ∈ [0, 8)`.
///
/// Exact only at `z = 0`; `tanh` saturates (`1 - tanh(8) < 2^-22`), which
/// is the bound shape the original four functions never exercise. The
/// negative half follows from odd symmetry: `tanh(-x) = -tanh(x)`.
///
/// ```
/// let (y0, exact) = polygen::bounds::exact::floor_tanh_scaled(0, 8, 8);
/// assert_eq!((y0, exact), (0, true));
/// let (y, _) = polygen::bounds::exact::floor_tanh_scaled(255, 8, 8);
/// assert_eq!(y, 255); // deep in the saturating tail
/// ```
pub fn floor_tanh_scaled(z: u64, m: u32, q: u32) -> (i64, bool) {
    floor_tanh_like(z, m, q, 2)
}

/// `floor(2^(q+1)·σ(x) - 2^q)` for `x = z / 2^(m-3) ∈ [0, 8)`.
///
/// The stored value is the *centered* sigmoid `2σ(x) - 1 = tanh(x/2)`
/// scaled to `q` bits — σ itself spends a full bit on the constant `1/2`;
/// the caller reconstructs `σ(x) = (Y/2^q + 1) / 2` and the negative half
/// via `σ(-x) = 1 - σ(x)`.
pub fn floor_sigmoid_scaled(z: u64, m: u32, q: u32) -> (i64, bool) {
    floor_tanh_like(z, m, q, 1)
}

/// `floor(2^q · log2(1 + e^-x))` for `x = z / 2^(m-3) ∈ [0, 8)`.
///
/// The decaying branch of softplus in base-2 units: `softplus(-x) =
/// ln(1+e^-x) = ln(2)·Y/2^q`, and the growing branch follows from
/// `softplus(x) = x + softplus(-x)`. Exact at `z = 0` (`log2 2 = 1`).
pub fn floor_softplus_scaled(z: u64, m: u32, q: u32) -> (i64, bool) {
    assert!((4..=16).contains(&m) && q >= 1 && q <= 16 && (z >> m) == 0);
    if z == 0 {
        return (1i64 << q, true); // log2(1 + 1) = 1 exactly
    }
    let e = exp2neg_q124(z, m, 1);
    // v = (1 + E)·2^124 ∈ (2^124, 2^125): frac(log2 v) = log2(1 + E).
    let frac = log2_frac_q120((1u128 << 124) + e);
    split_floor(frac, F - q)
}

/// `floor(2^(q+2) · x·Φ(-x))` for `x = z / 2^(m-2) ∈ [0, 4)`, where `Φ` is
/// the standard normal CDF.
///
/// `x·Φ(-x)` is GELU's decaying branch: `gelu(x) = x·Φ(x) = x - x·Φ(-x)`
/// and `gelu(-x) = -x·Φ(-x)`, so one table serves both halves. The `2^(q+2)`
/// scale uses the headroom of `max x·Φ(-x) ≈ 0.17`. Computed as
/// `Y = 2^(q+1)·x - 2^(q+2)·sqrt(2/π)·u·S(u)` with `u = x²/2` and the
/// alternating erf series `S(u) = Σ (-u)^n / (n!(2n+1))`, accumulated in
/// Q.160 with positive and negative partial sums split so every
/// intermediate is exact-floor. `u < 8` keeps the alternating-series error
/// amplification (`~e^u`) far inside the guard margin.
pub fn floor_gelu_scaled(z: u64, m: u32, q: u32) -> (i64, bool) {
    assert!((4..=16).contains(&m) && q >= 1 && q <= 16 && (z >> m) == 0);
    assert!(q + 3 >= m, "gelu scaling needs q >= m - 3");
    if z == 0 {
        return (0, true);
    }
    let uf = 2 * m - 3; // u = x²/2 = z² / 2^uf < 8
    let z2 = z.checked_mul(z).expect("z² overflow");
    let mut term = U256::from_u128(1).shl(160); // uⁿ/n! at Q.160
    let mut pos = U256::ZERO;
    let mut neg = U256::ZERO;
    let mut n: u64 = 0;
    while term != U256::ZERO {
        let c = div_u256_by_u64(term, 2 * n + 1);
        if n % 2 == 0 {
            pos = pos.add(c);
        } else {
            neg = neg.add(c);
        }
        term = div_u256_by_u64(mul_u256_by_u64(term, z2), n + 1).shr(uf);
        n += 1;
        assert!(n < 500, "gelu series failed to terminate");
    }
    // S(u) ∈ [~0.31, 1] at Q.160, then u·S at Q.124 (< 2^127: u < 8).
    let s = pos.checked_sub(neg).expect("gelu series sum went negative");
    let us = mul_u256_by_u64(s, z2).shr(uf + 36);
    debug_assert_eq!(us.hi, 0);
    // D·2^110 with D = 2^(q+2)·sqrt(2/π)·u·S: Q.250 product, shift 138-q.
    let d110 = U256::mul_u128(us.lo, SQRT2_OVER_PI_Q126).shr(138 - q);
    // Y·2^110 = 2^(q+1)·x·2^110 - D·2^110; 2^(q+1)·x = z·2^(q+3-m).
    let lin = U256::from_u128((z as u128) << (q + 3 - m)).shl(110);
    let y110 = lin.checked_sub(d110).expect("gelu went negative");
    debug_assert_eq!(y110.hi, 0);
    split_floor(y110.lo, 110)
}

/// Split a fixed-point fraction into `floor(frac / 2^shift)` and check the
/// remainder is unambiguous (outside the guard margin of both boundaries).
fn split_floor(frac: u128, shift: u32) -> (i64, bool) {
    let floor = (frac >> shift) as i64;
    let rem = frac & ((1u128 << shift) - 1);
    let top = 1u128 << shift;
    assert!(
        rem > MARGIN && rem < top - MARGIN,
        "ambiguous floor: value within guard margin of an integer; \
         raise the working precision (rem = {rem:#x}, shift = {shift})"
    );
    (floor, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_matches_f64() {
        for v in [3u128, 5, 7, 100, 12345, (1 << 20) + 7, (1 << 26) - 1] {
            let frac = log2_frac_q120(v);
            let expect = (v as f64).log2().fract();
            let got = frac as f64 / 2f64.powi(F as i32);
            assert!((got - expect).abs() < 1e-12, "v={v} got={got} expect={expect}");
        }
    }

    #[test]
    fn log2_power_of_two_exact() {
        assert_eq!(floor_log2_scaled(1 << 13, 16), (0, true));
    }

    #[test]
    fn exp2_matches_f64() {
        let m = 16;
        for z in [1u64, 2, 1000, 32767, 32768, 65535] {
            let g = exp2_frac_q127(z, m);
            let got = g as f64 / 2f64.powi(127);
            let expect = 2f64.powf(z as f64 / (1u64 << m) as f64);
            assert!((got - expect).abs() < 1e-12, "z={z} got={got} expect={expect}");
        }
    }

    #[test]
    fn floors_agree_with_f64_sweep() {
        // Exhaustive for a small format: the f64 computation is accurate to
        // ~2^-45 here, far below the 2^-? decision distances at 10-bit.
        let m = 10u32;
        let q = 11u32;
        for z in 1..(1u64 << m) {
            let v = (1u128 << m) + z as u128;
            let (fl, ex) = floor_log2_scaled(v, q);
            assert!(!ex);
            let yf = ((v as f64) / (1u64 << m) as f64).log2() * (1u64 << q) as f64;
            assert_eq!(fl, yf.floor() as i64, "log2 z={z}");

            let (fe, ex2) = floor_exp2m1_scaled(z, m, m);
            assert!(!ex2);
            let ye = (2f64.powf(z as f64 / (1u64 << m) as f64) - 1.0)
                * (1u64 << m) as f64;
            assert_eq!(fe, ye.floor() as i64, "exp2 z={z}");
        }
    }

    #[test]
    fn sqrt2_chain_converges_to_one() {
        let roots = sqrt2_chain(30);
        let last = *roots.last().unwrap();
        // 2^(2^-30) is barely above 1.
        assert!(last > (1u128 << 127));
        assert!(last - (1u128 << 127) < 1u128 << 100);
    }

    fn fnv1a(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0100_0000_01b3)
    }

    fn activation_floor(func: &str, z: u64, m: u32, q: u32) -> (i64, bool) {
        match func {
            "tanh" => floor_tanh_scaled(z, m, q),
            "sigmoid" => floor_sigmoid_scaled(z, m, q),
            "softplus" => floor_softplus_scaled(z, m, q),
            "gelu" => floor_gelu_scaled(z, m, q),
            _ => unreachable!(),
        }
    }

    /// Exhaustive (floor, exact) tables pinned against
    /// `python/activation_mirror.py`, which implements the same integer
    /// algorithms bit-for-bit and checks every floor against an 80-digit
    /// `Decimal` reference. A hash mismatch means the Rust port diverged
    /// from the validated arithmetic.
    #[test]
    fn activation_floors_match_mirror_golden() {
        #[rustfmt::skip]
        let cases: &[(&str, u32, u64)] = &[
            ("gelu", 4, 0x7a1c80185c6478a4),
            ("gelu", 6, 0x332eaf4edf1ad321),
            ("gelu", 8, 0x6edd364ed1234263),
            ("gelu", 10, 0x5f9639d520cbf9f7),
            ("gelu", 12, 0xac27623bddbf5696),
            ("sigmoid", 4, 0x09f2ea23659a058c),
            ("sigmoid", 6, 0x0412cd92b448207a),
            ("sigmoid", 8, 0x5468cb136e929ad4),
            ("sigmoid", 10, 0x478ff12a024b9715),
            ("sigmoid", 12, 0x2b67eccc9f6d883b),
            ("softplus", 4, 0x995227634d4282c9),
            ("softplus", 6, 0x886347ff952e16f1),
            ("softplus", 8, 0xa963d16942f3af81),
            ("softplus", 10, 0x3543b81068a6aee7),
            ("softplus", 12, 0xf27590dbc55536f1),
            ("tanh", 4, 0xddad1ebec026a927),
            ("tanh", 6, 0xc386c4a05345b7a2),
            ("tanh", 8, 0xb2f74f7702bd1bdd),
            ("tanh", 10, 0x1ab3c599e7e67601),
            ("tanh", 12, 0xc058dd0d91fb0bcd),
        ];
        for &(func, m, want) in cases {
            let mut h = 0xcbf2_9ce4_8422_2325u64;
            for z in 0..(1u64 << m) {
                let (fl, ex) = activation_floor(func, z, m, m);
                h = fnv1a(h, fl as u64);
                h = fnv1a(h, ex as u64);
            }
            assert_eq!(h, want, "{func} {m}-bit floor table diverged");
        }
    }

    #[test]
    fn activation_floors_agree_with_f64_sweep() {
        // f64 references are good to ~2^-45 here; skip the (never observed)
        // points whose true value sits closer than 1e-6 to an integer.
        let m = 10u32;
        let q = m;
        let scale = (1u64 << q) as f64;
        for z in 0..(1u64 << m) {
            let x = z as f64 / (1u64 << (m - 3)) as f64;
            let e = (-x).exp();
            let refs = [
                ("tanh", scale * x.tanh()),
                ("sigmoid", scale * (1.0 - e) / (1.0 + e)),
                ("softplus", scale * e.ln_1p() / std::f64::consts::LN_2),
            ];
            for (func, yf) in refs {
                if (yf - yf.round()).abs() < 1e-6 {
                    continue;
                }
                let (fl, _) = activation_floor(func, z, m, q);
                assert_eq!(fl, yf.floor() as i64, "{func} z={z}");
            }
        }
    }

    #[test]
    fn activation_edge_cases_are_exact() {
        assert_eq!(floor_tanh_scaled(0, 12, 12), (0, true));
        assert_eq!(floor_sigmoid_scaled(0, 12, 12), (0, true));
        assert_eq!(floor_softplus_scaled(0, 12, 12), (1 << 12, true));
        assert_eq!(floor_gelu_scaled(0, 12, 12), (0, true));
        // Saturating tail: tanh pins to the top code well before z_max.
        let (top, ex) = floor_tanh_scaled((1 << 12) - 1, 12, 12);
        assert_eq!((top, ex), ((1 << 12) - 1, false));
    }
}
