//! Exact fixed-point `log2` / `exp2` floors.
//!
//! The paper's bound functions `l, u` must be *trusted*: a single
//! mis-rounded bound makes the generated design space wrong (either
//! excluding feasible polynomials or, worse, admitting infeasible ones).
//! The paper defers this to "integration with MPFR" as future work; here we
//! build the substrate directly: 128-bit fixed-point evaluation with ≥ 90
//! guard bits and an explicit ambiguity check on every floor. If a value
//! ever lands inside the guard margin of an integer boundary the functions
//! panic rather than return a possibly-wrong bound (this never fires for
//! the ≤ 26-bit formats used anywhere in this repo; a dedicated test
//! exhaustively confirms agreement with directed `f64` evaluation).

use crate::wide::{isqrt_u256, U256};

/// Fractional bits of the internal fixed-point representation.
const F: u32 = 120;
/// Ambiguity margin in ulps of `2^-F`. The accumulated truncation error of
/// the algorithms below is provably < 2^7 ulps; 2^20 is a very safe guard.
const MARGIN: u128 = 1 << 20;

/// `frac(log2(v))` for `v > 0`, as a Q0.120 fixed-point value, by the
/// classic shift-and-square recurrence on a Q1.127 mantissa.
///
/// Per-step truncation contributes ≤ 2^-127 to `log2(a_i)` which enters the
/// result with weight `2^-i`, so the total error is < (F+2)·2^-127 < 2^-119.
pub fn log2_frac_q120(v: u128) -> u128 {
    assert!(v > 0);
    // Normalize to a in [2^127, 2^128): A = a / 2^127 in [1, 2)
    // (shifting the MSB of v up to bit 127 discards only log2's integer
    // part, which the caller does not want anyway).
    let mut a: u128 = v << v.leading_zeros();
    let mut frac: u128 = 0;
    for _ in 0..F {
        let sq = U256::mul_u128(a, a); // A^2 = sq / 2^254 in [1, 4)
        let bit = (sq.hi >> 127) & 1; // A^2 >= 2  <=>  sq >= 2^255
        frac = (frac << 1) | bit;
        a = if bit == 1 {
            sq.hi // A' = A^2/2: floor(sq / 2^128)
        } else {
            sq.shr(127).lo // A' = A^2: floor(sq / 2^127)
        };
    }
    frac
}

/// `2^(z / 2^m)` for `0 <= z < 2^m`, as a Q1.127 fixed-point value in
/// `[1, 2)`, via the product of repeated square roots of two.
///
/// `2^(z/2^m) = prod over set bits i of z of 2^(2^(i-m))`; the factors
/// `s_j = 2^(2^-j)` come from the chain `s_1 = sqrt 2`, `s_{j+1} =
/// sqrt(s_j)`. Square-rooting *halves* relative error, so the chain error
/// stays ≤ 2^-126 per factor and the ≤ m-term product accumulates
/// < (2m)·2^-127 < 2^-120 total.
pub fn exp2_frac_q127(z: u64, m: u32) -> u128 {
    assert!(m >= 1 && m <= 63 && (z >> m) == 0);
    let roots = sqrt2_chain(m);
    let mut g: u128 = 1u128 << 127; // 1.0 in Q1.127
    for i in 0..m {
        if (z >> i) & 1 == 1 {
            let j = (m - i) as usize; // weight 2^-(m-i)
            g = U256::mul_u128(g, roots[j - 1]).shr(127).lo;
        }
    }
    g
}

/// `[ 2^(2^-1), 2^(2^-2), ..., 2^(2^-m) ]` in Q1.127.
fn sqrt2_chain(m: u32) -> Vec<u128> {
    let mut roots = Vec::with_capacity(m as usize);
    // s_1 = sqrt(2) in Q1.127 = isqrt(2 << 254).
    let mut s: u128 = isqrt_u256(U256 { hi: 1u128 << 127, lo: 0 });
    roots.push(s);
    for _ in 1..m {
        // s_{j+1} = sqrt(s_j): isqrt(s << 127) in Q1.127.
        s = isqrt_u256(U256::from_u128(s).shl(127));
        roots.push(s);
    }
    roots
}

/// `floor(2^q * frac(log2(v)))` with an exactness flag.
///
/// Panics if the value is within the guard margin of an integer boundary
/// (would indicate the 120-bit evaluation cannot decide the floor).
pub fn floor_log2_scaled(v: u128, q: u32) -> (i64, bool) {
    assert!(q < F - 24, "output precision too large for the 120-bit substrate");
    if v.is_power_of_two() {
        return (0, true); // frac(log2) = 0 exactly
    }
    let frac = log2_frac_q120(v);
    split_floor(frac, F - q)
}

/// `floor(2^q * (2^(z/2^m) - 1))` with an exactness flag.
pub fn floor_exp2m1_scaled(z: u64, m: u32, q: u32) -> (i64, bool) {
    assert!(q <= 126 - 24, "output precision too large");
    if z == 0 {
        return (0, true);
    }
    let g = exp2_frac_q127(z, m); // Q1.127 in [1,2)
    let frac = g - (1u128 << 127); // Q0.127
    split_floor(frac, 127 - q)
}

/// Split a fixed-point fraction into `floor(frac / 2^shift)` and check the
/// remainder is unambiguous (outside the guard margin of both boundaries).
fn split_floor(frac: u128, shift: u32) -> (i64, bool) {
    let floor = (frac >> shift) as i64;
    let rem = frac & ((1u128 << shift) - 1);
    let top = 1u128 << shift;
    assert!(
        rem > MARGIN && rem < top - MARGIN,
        "ambiguous floor: value within guard margin of an integer; \
         raise the working precision (rem = {rem:#x}, shift = {shift})"
    );
    (floor, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_matches_f64() {
        for v in [3u128, 5, 7, 100, 12345, (1 << 20) + 7, (1 << 26) - 1] {
            let frac = log2_frac_q120(v);
            let expect = (v as f64).log2().fract();
            let got = frac as f64 / 2f64.powi(F as i32);
            assert!((got - expect).abs() < 1e-12, "v={v} got={got} expect={expect}");
        }
    }

    #[test]
    fn log2_power_of_two_exact() {
        assert_eq!(floor_log2_scaled(1 << 13, 16), (0, true));
    }

    #[test]
    fn exp2_matches_f64() {
        let m = 16;
        for z in [1u64, 2, 1000, 32767, 32768, 65535] {
            let g = exp2_frac_q127(z, m);
            let got = g as f64 / 2f64.powi(127);
            let expect = 2f64.powf(z as f64 / (1u64 << m) as f64);
            assert!((got - expect).abs() < 1e-12, "z={z} got={got} expect={expect}");
        }
    }

    #[test]
    fn floors_agree_with_f64_sweep() {
        // Exhaustive for a small format: the f64 computation is accurate to
        // ~2^-45 here, far below the 2^-? decision distances at 10-bit.
        let m = 10u32;
        let q = 11u32;
        for z in 1..(1u64 << m) {
            let v = (1u128 << m) + z as u128;
            let (fl, ex) = floor_log2_scaled(v, q);
            assert!(!ex);
            let yf = ((v as f64) / (1u64 << m) as f64).log2() * (1u64 << q) as f64;
            assert_eq!(fl, yf.floor() as i64, "log2 z={z}");

            let (fe, ex2) = floor_exp2m1_scaled(z, m, m);
            assert!(!ex2);
            let ye = (2f64.powf(z as f64 / (1u64 << m) as f64) - 1.0)
                * (1u64 << m) as f64;
            assert_eq!(fe, ye.floor() as i64, "exp2 z={z}");
        }
    }

    #[test]
    fn sqrt2_chain_converges_to_one() {
        let roots = sqrt2_chain(30);
        let last = *roots.last().unwrap();
        // 2^(2^-30) is barely above 1.
        assert!(last > (1u128 << 127));
        assert!(last - (1u128 << 127) < 1u128 << 100);
    }
}
