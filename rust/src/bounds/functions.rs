//! The target functions evaluated in the paper (reciprocal, log2, exp2)
//! plus extras (sqrt, arbitrary `f64` closures) behind one trait.
//!
//! Each function maps a *stored input code* `z` (the explicit bits of the
//! paper's `1.x` / `0.x` input) to the exact scaled output
//! `Y(z) = f(z) * 2^q` (with any fixed output prefix bits removed), and
//! reports `floor(Y)` together with an exactness flag. Everything
//! downstream (accuracy specs, bound tables, the design space itself) is
//! derived from these floors, so they are computed with exact integer /
//! 128-bit fixed-point arithmetic — never rounded binary floating point.

use super::exact::{floor_exp2m1_scaled, floor_log2_scaled};
use crate::wide::isqrt_u128;

/// A fixed-point function to approximate, in the paper's framing.
pub trait TargetFunction: Send + Sync {
    /// Short identifier, e.g. `"recip"`.
    fn name(&self) -> &str;
    /// Stored input bits (the paper's `n+m` for the variable part).
    fn in_bits(&self) -> u32;
    /// Stored output bits `q` (after removing any fixed prefix).
    fn out_bits(&self) -> u32;
    /// `(floor(Y(z)), Y(z) is exactly an integer)`.
    fn floor_y(&self, z: u64) -> (i64, bool);
    /// Real-valued `Y(z)` for the Remez / plotting baselines (not used by
    /// the exact design-space math).
    fn y_f64(&self, z: u64) -> f64;
    /// Human-readable description of the mapping, e.g. `0.1y = 1/1.x`.
    fn mapping(&self) -> String;
}

/// `0.1y = 1 / 1.x` — the paper's reciprocal.
///
/// `f = 2^m/(2^m+z) in (1/2, 1]`; stored output `y` with
/// `value = (2^q + y) / 2^(q+1)`, so `Y(z) = 2^(m+q+1)/(2^m+z) - 2^q`.
pub struct Recip {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Recip {
    fn name(&self) -> &str {
        "recip"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        let (m, q) = (self.in_bits, self.out_bits);
        let num: u128 = 1u128 << (m + q + 1);
        let den: u128 = (1u128 << m) + z as u128;
        let fl = (num / den) as i64 - (1i64 << q);
        (fl, num % den == 0)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let (m, q) = (self.in_bits, self.out_bits);
        2f64.powi((m + q + 1) as i32) / ((1u64 << m) as f64 + z as f64)
            - 2f64.powi(q as i32)
    }
    fn mapping(&self) -> String {
        format!("0.1y = 1/1.x  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `0.y = log2(1.x)` — the paper's base-2 logarithm.
/// `Y(z) = 2^q * log2(1 + z/2^m)`.
pub struct Log2 {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Log2 {
    fn name(&self) -> &str {
        "log2"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        let v = (1u128 << self.in_bits) + z as u128;
        floor_log2_scaled(v, self.out_bits)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let m = (1u64 << self.in_bits) as f64;
        (1.0 + z as f64 / m).log2() * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("0.y = log2(1.x)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `1.y = 2^(0.x)` — the paper's base-2 exponential.
/// `Y(z) = 2^q * (2^(z/2^m) - 1)`.
pub struct Exp2 {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Exp2 {
    fn name(&self) -> &str {
        "exp2"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        floor_exp2m1_scaled(z, self.in_bits, self.out_bits)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let m = (1u64 << self.in_bits) as f64;
        (2f64.powf(z as f64 / m) - 1.0) * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("1.y = 2^(0.x)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `1.y = sqrt(1.x)` — extension function (not in the paper's tables but a
/// standard workload for interpolator generators).
/// `Y(z) = 2^q*(sqrt(1 + z/2^m) - 1)`; exact via integer square root when
/// `2q >= m`.
pub struct Sqrt {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Sqrt {
    fn name(&self) -> &str {
        "sqrt"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        let (m, q) = (self.in_bits, self.out_bits);
        assert!(2 * q >= m, "sqrt exact floor needs 2q >= m");
        // floor(2^q sqrt((2^m+z)/2^m)) = isqrt((2^m+z) << (2q-m)).
        let a: u128 = ((1u128 << m) + z as u128) << (2 * q - m);
        let root = isqrt_u128(a);
        ((root as i64) - (1i64 << q), root * root == a)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let m = (1u64 << self.in_bits) as f64;
        ((1.0 + z as f64 / m).sqrt() - 1.0) * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("1.y = sqrt(1.x)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// A user-supplied function via an `f64` closure, for quick experiments
/// (`examples/custom_function.rs`).
///
/// Unlike the built-ins this is **not** exact: the floor is taken on the
/// `f64` value and an ambiguity guard panics when the value is within
/// `margin` of an integer. For production bounds implement
/// [`TargetFunction`] with exact arithmetic instead.
pub struct CustomF64<F: Fn(f64) -> f64 + Send + Sync> {
    pub name: String,
    pub in_bits: u32,
    pub out_bits: u32,
    /// Maps the real input value in `[0,1)` (i.e. `z/2^m`) to the real
    /// output value in `[0,1)`; scaled by `2^q` internally.
    pub f: F,
    /// Ambiguity guard in output ULPs (default 1e-6).
    pub margin: f64,
}

impl<F: Fn(f64) -> f64 + Send + Sync> TargetFunction for CustomF64<F> {
    fn name(&self) -> &str {
        &self.name
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        let y = self.y_f64(z);
        let fl = y.floor();
        let d = y - fl;
        if d < self.margin || d > 1.0 - self.margin {
            // Within the guard band: accept only an exact integer.
            let r = y.round();
            assert!(
                (y - r).abs() < self.margin,
                "CustomF64 {}: ambiguous floor at z={z} (y={y})",
                self.name
            );
            return (r as i64, true);
        }
        (fl as i64, false)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let xin = z as f64 / (1u64 << self.in_bits) as f64;
        (self.f)(xin) * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("custom {} ({} -> {})", self.name, self.in_bits, self.out_bits)
    }
}

/// Construct a built-in function by name at the paper's precisions:
/// `recip: m -> m`, `log2: m -> m+1`, `exp2: m -> m`, `sqrt: m -> m`.
pub fn builtin(name: &str, bits: u32) -> Option<Box<dyn TargetFunction>> {
    match name {
        "recip" => Some(Box::new(Recip { in_bits: bits, out_bits: bits })),
        "log2" => Some(Box::new(Log2 { in_bits: bits, out_bits: bits + 1 })),
        "exp2" => Some(Box::new(Exp2 { in_bits: bits, out_bits: bits })),
        "sqrt" => Some(Box::new(Sqrt { in_bits: bits, out_bits: bits })),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recip_edges() {
        let f = Recip { in_bits: 16, out_bits: 16 };
        // z = 0: f = 1.0 -> Y = 2^16 exactly.
        assert_eq!(f.floor_y(0), (1 << 16, true));
        // z = max: f -> just above 1/2, Y = 2^16/(2^17-1) ~ 0.49997.
        let (fl, ex) = f.floor_y((1 << 16) - 1);
        assert_eq!(fl, 0);
        assert!(!ex);
    }

    #[test]
    fn recip_monotone_decreasing() {
        let f = Recip { in_bits: 12, out_bits: 12 };
        let mut prev = i64::MAX;
        for z in 0..(1u64 << 12) {
            let (fl, _) = f.floor_y(z);
            assert!(fl <= prev);
            prev = fl;
        }
    }

    #[test]
    fn log2_monotone_and_range() {
        let f = Log2 { in_bits: 10, out_bits: 11 };
        let mut prev = -1i64;
        for z in 0..(1u64 << 10) {
            let (fl, _) = f.floor_y(z);
            assert!(fl >= prev);
            assert!(fl >= 0 && fl < (1 << 11));
            prev = fl;
        }
    }

    #[test]
    fn exp2_monotone_and_range() {
        let f = Exp2 { in_bits: 10, out_bits: 10 };
        let mut prev = -1i64;
        for z in 0..(1u64 << 10) {
            let (fl, _) = f.floor_y(z);
            assert!(fl >= prev);
            assert!(fl >= 0 && fl < (1 << 10));
            prev = fl;
        }
    }

    #[test]
    fn sqrt_exact_squares() {
        let f = Sqrt { in_bits: 8, out_bits: 8 };
        // z such that 1+z/256 = (1+k/256)^2 ... check z=0 exact.
        assert_eq!(f.floor_y(0), (0, true));
        let mut prev = -1i64;
        for z in 0..256u64 {
            let (fl, _) = f.floor_y(z);
            assert!(fl >= prev);
            prev = fl;
        }
    }

    #[test]
    fn floors_match_f64() {
        for b in [8u32, 10] {
            for name in ["recip", "log2", "exp2", "sqrt"] {
                let f = builtin(name, b).unwrap();
                for z in 0..(1u64 << b) {
                    let (fl, ex) = f.floor_y(z);
                    let y = f.y_f64(z);
                    if ex {
                        assert!((y - fl as f64).abs() < 1e-6, "{name} z={z}");
                    } else {
                        assert_eq!(fl, y.floor() as i64, "{name} z={z} y={y}");
                    }
                }
            }
        }
    }

    #[test]
    fn custom_f64_sin() {
        let f = CustomF64 {
            name: "sinpi4".into(),
            in_bits: 8,
            out_bits: 8,
            f: |x: f64| (std::f64::consts::FRAC_PI_4 * x).sin(),
            margin: 1e-9,
        };
        let (fl, _) = f.floor_y(128);
        let expect = ((std::f64::consts::FRAC_PI_4 * 0.5).sin() * 256.0).floor() as i64;
        assert_eq!(fl, expect);
    }
}
