//! The target functions evaluated in the paper (reciprocal, log2, exp2)
//! plus extras (sqrt, the NN activation suite, arbitrary `f64` closures)
//! behind one trait.
//!
//! Each function maps a *stored input code* `z` (the explicit bits of the
//! paper's `1.x` / `0.x` input) to the exact scaled output
//! `Y(z) = f(z) * 2^q` (with any fixed output prefix bits removed), and
//! reports `floor(Y)` together with an exactness flag. Everything
//! downstream (accuracy specs, bound tables, the design space itself) is
//! derived from these floors, so they are computed with exact integer /
//! 128-bit fixed-point arithmetic — never rounded binary floating point.
//!
//! The activation functions ([`Tanh`], [`Sigmoid`], [`Gelu`], [`Softplus`])
//! tabulate the *non-negative half* of each symmetric/reflectable curve;
//! `DESIGN.md §Workloads` catalogs the domain scalings and the identities
//! that reconstruct the other half. They exercise bound shapes the paper's
//! functions never hit — odd symmetry, saturating tails, an inflection at
//! zero:
//!
//! ```
//! use polygen::bounds::builtin;
//!
//! let tanh = builtin("tanh", 12).unwrap();
//! // Y(z) = 2^12 * tanh(z / 2^9); z = 512 is x = 1.0.
//! let (floor, _) = tanh.floor_y(512);
//! assert_eq!(floor, (4096.0 * 1.0f64.tanh()) as i64);
//! ```

use super::exact::{
    floor_exp2m1_scaled, floor_gelu_scaled, floor_log2_scaled, floor_sigmoid_scaled,
    floor_softplus_scaled, floor_tanh_scaled,
};
use crate::wide::isqrt_u128;

/// A fixed-point function to approximate, in the paper's framing.
pub trait TargetFunction: Send + Sync {
    /// Short identifier, e.g. `"recip"`.
    fn name(&self) -> &str;
    /// Stored input bits (the paper's `n+m` for the variable part).
    fn in_bits(&self) -> u32;
    /// Stored output bits `q` (after removing any fixed prefix).
    fn out_bits(&self) -> u32;
    /// `(floor(Y(z)), Y(z) is exactly an integer)`.
    fn floor_y(&self, z: u64) -> (i64, bool);
    /// Real-valued `Y(z)` for the Remez / plotting baselines (not used by
    /// the exact design-space math).
    fn y_f64(&self, z: u64) -> f64;
    /// Human-readable description of the mapping, e.g. `0.1y = 1/1.x`.
    fn mapping(&self) -> String;
}

/// `0.1y = 1 / 1.x` — the paper's reciprocal.
///
/// `f = 2^m/(2^m+z) in (1/2, 1]`; stored output `y` with
/// `value = (2^q + y) / 2^(q+1)`, so `Y(z) = 2^(m+q+1)/(2^m+z) - 2^q`.
pub struct Recip {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Recip {
    fn name(&self) -> &str {
        "recip"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        let (m, q) = (self.in_bits, self.out_bits);
        let num: u128 = 1u128 << (m + q + 1);
        let den: u128 = (1u128 << m) + z as u128;
        let fl = (num / den) as i64 - (1i64 << q);
        (fl, num % den == 0)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let (m, q) = (self.in_bits, self.out_bits);
        2f64.powi((m + q + 1) as i32) / ((1u64 << m) as f64 + z as f64)
            - 2f64.powi(q as i32)
    }
    fn mapping(&self) -> String {
        format!("0.1y = 1/1.x  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `0.y = log2(1.x)` — the paper's base-2 logarithm.
/// `Y(z) = 2^q * log2(1 + z/2^m)`.
pub struct Log2 {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Log2 {
    fn name(&self) -> &str {
        "log2"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        let v = (1u128 << self.in_bits) + z as u128;
        floor_log2_scaled(v, self.out_bits)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let m = (1u64 << self.in_bits) as f64;
        (1.0 + z as f64 / m).log2() * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("0.y = log2(1.x)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `1.y = 2^(0.x)` — the paper's base-2 exponential.
/// `Y(z) = 2^q * (2^(z/2^m) - 1)`.
pub struct Exp2 {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Exp2 {
    fn name(&self) -> &str {
        "exp2"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        floor_exp2m1_scaled(z, self.in_bits, self.out_bits)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let m = (1u64 << self.in_bits) as f64;
        (2f64.powf(z as f64 / m) - 1.0) * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("1.y = 2^(0.x)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `1.y = sqrt(1.x)` — extension function (not in the paper's tables but a
/// standard workload for interpolator generators).
/// `Y(z) = 2^q*(sqrt(1 + z/2^m) - 1)`; exact via integer square root when
/// `2q >= m`.
pub struct Sqrt {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Sqrt {
    fn name(&self) -> &str {
        "sqrt"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        let (m, q) = (self.in_bits, self.out_bits);
        assert!(2 * q >= m, "sqrt exact floor needs 2q >= m");
        // floor(2^q sqrt((2^m+z)/2^m)) = isqrt((2^m+z) << (2q-m)).
        let a: u128 = ((1u128 << m) + z as u128) << (2 * q - m);
        let root = isqrt_u128(a);
        ((root as i64) - (1i64 << q), root * root == a)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let m = (1u64 << self.in_bits) as f64;
        ((1.0 + z as f64 / m).sqrt() - 1.0) * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("1.y = sqrt(1.x)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `0.y = tanh(x)` on `x = z/2^(m-3) ∈ [0, 8)` — NN activation workload.
///
/// `Y(z) = 2^q * tanh(z / 2^(m-3))`; the negative half follows from odd
/// symmetry (`tanh(-x) = -tanh(x)`), so the table covers `[0, 8)` only.
/// The saturating tail (`1 - tanh(8) < 2^-22`) forces long flat regions
/// that stress the region dictionary very differently from the paper's
/// monotone-curvature functions.
pub struct Tanh {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Tanh {
    fn name(&self) -> &str {
        "tanh"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        floor_tanh_scaled(z, self.in_bits, self.out_bits)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let x = z as f64 / (1u64 << (self.in_bits - 3)) as f64;
        x.tanh() * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("0.y = tanh(x), x in [0,8)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `0.y = 2σ(x) - 1` on `x = z/2^(m-3) ∈ [0, 8)` — centered sigmoid.
///
/// Storing σ directly wastes a bit on the constant offset `1/2`; the
/// centered form `2σ(x) - 1 = tanh(x/2)` uses the full output range and
/// reconstructs `σ(x) = (Y/2^q + 1)/2`, `σ(-x) = 1 - σ(x)`.
pub struct Sigmoid {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Sigmoid {
    fn name(&self) -> &str {
        "sigmoid"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        floor_sigmoid_scaled(z, self.in_bits, self.out_bits)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let x = z as f64 / (1u64 << (self.in_bits - 3)) as f64;
        let e = (-x).exp();
        (1.0 - e) / (1.0 + e) * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("0.y = 2*sigmoid(x)-1, x in [0,8)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `0.y = x·Φ(-x)` on `x = z/2^(m-2) ∈ [0, 4)` — GELU's decaying branch.
///
/// `gelu(x) = x·Φ(x) = x - x·Φ(-x)` and `gelu(-x) = -x·Φ(-x)`: the one
/// table serves both halves. `Y(z) = 2^(q+2) * x·Φ(-x)` (the extra two
/// bits use the headroom of `max x·Φ(-x) ≈ 0.17`). The inflection of the
/// Gaussian factor near `x = 1` changes the curvature sign — the shape
/// that motivates degree-2 regions.
pub struct Gelu {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Gelu {
    fn name(&self) -> &str {
        "gelu"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        floor_gelu_scaled(z, self.in_bits, self.out_bits)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let x = z as f64 / (1u64 << (self.in_bits - 2)) as f64;
        let phi_neg = 0.5 * (1.0 - erf_f64(x / std::f64::consts::SQRT_2));
        x * phi_neg * 2f64.powi(self.out_bits as i32 + 2)
    }
    fn mapping(&self) -> String {
        format!("0.y = x*Phi(-x), x in [0,4)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `0.y = log2(1 + e^-x)` on `x = z/2^(m-3) ∈ [0, 8)` — softplus tail.
///
/// The decaying branch of softplus in base-2 units: `softplus(-x) =
/// ln(2) · Y/2^q` and `softplus(x) = x + softplus(-x)`. Exact at `z = 0`
/// (`log2 2 = 1`), strictly decreasing, convex — a mirrored counterpart
/// to [`Log2`]'s concave rise.
pub struct Softplus {
    pub in_bits: u32,
    pub out_bits: u32,
}

impl TargetFunction for Softplus {
    fn name(&self) -> &str {
        "softplus"
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        floor_softplus_scaled(z, self.in_bits, self.out_bits)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let x = z as f64 / (1u64 << (self.in_bits - 3)) as f64;
        (-x).exp().ln_1p() / std::f64::consts::LN_2 * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("0.y = log2(1+e^-x), x in [0,8)  ({} -> {})", self.in_bits, self.out_bits)
    }
}

/// `erf` via its alternating Maclaurin series — adequate for the `f64`
/// plotting baseline (`w < 2.83` here, so the series converges fast and
/// the alternating cancellation costs ≲ 12 of the 52 mantissa bits, far
/// inside the `y_f64` tolerance; the exact path never uses this).
fn erf_f64(w: f64) -> f64 {
    let w2 = w * w;
    let mut term = w; // w^(2n+1) / n!
    let mut sum = 0.0;
    let mut n = 0u32;
    loop {
        let c = term / (2 * n + 1) as f64;
        sum += if n % 2 == 0 { c } else { -c };
        if c < 1e-18 && (n as f64) > w2 {
            break;
        }
        n += 1;
        term = term * w2 / n as f64;
    }
    sum * std::f64::consts::FRAC_2_SQRT_PI
}

/// A user-supplied function via an `f64` closure, for quick experiments
/// (`examples/custom_function.rs`).
///
/// Unlike the built-ins this is **not** exact: the floor is taken on the
/// `f64` value and an ambiguity guard panics when the value is within
/// `margin` of an integer. For production bounds implement
/// [`TargetFunction`] with exact arithmetic instead.
pub struct CustomF64<F: Fn(f64) -> f64 + Send + Sync> {
    pub name: String,
    pub in_bits: u32,
    pub out_bits: u32,
    /// Maps the real input value in `[0,1)` (i.e. `z/2^m`) to the real
    /// output value in `[0,1)`; scaled by `2^q` internally.
    pub f: F,
    /// Ambiguity guard in output ULPs (default 1e-6).
    pub margin: f64,
}

impl<F: Fn(f64) -> f64 + Send + Sync> TargetFunction for CustomF64<F> {
    fn name(&self) -> &str {
        &self.name
    }
    fn in_bits(&self) -> u32 {
        self.in_bits
    }
    fn out_bits(&self) -> u32 {
        self.out_bits
    }
    fn floor_y(&self, z: u64) -> (i64, bool) {
        let y = self.y_f64(z);
        let fl = y.floor();
        let d = y - fl;
        if d < self.margin || d > 1.0 - self.margin {
            // Within the guard band: accept only an exact integer.
            let r = y.round();
            assert!(
                (y - r).abs() < self.margin,
                "CustomF64 {}: ambiguous floor at z={z} (y={y})",
                self.name
            );
            return (r as i64, true);
        }
        (fl as i64, false)
    }
    fn y_f64(&self, z: u64) -> f64 {
        let xin = z as f64 / (1u64 << self.in_bits) as f64;
        (self.f)(xin) * 2f64.powi(self.out_bits as i32)
    }
    fn mapping(&self) -> String {
        format!("custom {} ({} -> {})", self.name, self.in_bits, self.out_bits)
    }
}

/// Construct a built-in function by name at the paper's precisions:
/// `recip: m -> m`, `log2: m -> m+1`, `exp2: m -> m`, `sqrt: m -> m`, and
/// the activation suite (`tanh` / `sigmoid` / `gelu` / `softplus`,
/// all `m -> m`, `4 <= m <= 16`).
pub fn builtin(name: &str, bits: u32) -> Option<Box<dyn TargetFunction>> {
    match name {
        "recip" => Some(Box::new(Recip { in_bits: bits, out_bits: bits })),
        "log2" => Some(Box::new(Log2 { in_bits: bits, out_bits: bits + 1 })),
        "exp2" => Some(Box::new(Exp2 { in_bits: bits, out_bits: bits })),
        "sqrt" => Some(Box::new(Sqrt { in_bits: bits, out_bits: bits })),
        "tanh" => Some(Box::new(Tanh { in_bits: bits, out_bits: bits })),
        "sigmoid" => Some(Box::new(Sigmoid { in_bits: bits, out_bits: bits })),
        "gelu" => Some(Box::new(Gelu { in_bits: bits, out_bits: bits })),
        "softplus" => Some(Box::new(Softplus { in_bits: bits, out_bits: bits })),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recip_edges() {
        let f = Recip { in_bits: 16, out_bits: 16 };
        // z = 0: f = 1.0 -> Y = 2^16 exactly.
        assert_eq!(f.floor_y(0), (1 << 16, true));
        // z = max: f -> just above 1/2, Y = 2^16/(2^17-1) ~ 0.49997.
        let (fl, ex) = f.floor_y((1 << 16) - 1);
        assert_eq!(fl, 0);
        assert!(!ex);
    }

    #[test]
    fn recip_monotone_decreasing() {
        let f = Recip { in_bits: 12, out_bits: 12 };
        let mut prev = i64::MAX;
        for z in 0..(1u64 << 12) {
            let (fl, _) = f.floor_y(z);
            assert!(fl <= prev);
            prev = fl;
        }
    }

    #[test]
    fn log2_monotone_and_range() {
        let f = Log2 { in_bits: 10, out_bits: 11 };
        let mut prev = -1i64;
        for z in 0..(1u64 << 10) {
            let (fl, _) = f.floor_y(z);
            assert!(fl >= prev);
            assert!(fl >= 0 && fl < (1 << 11));
            prev = fl;
        }
    }

    #[test]
    fn exp2_monotone_and_range() {
        let f = Exp2 { in_bits: 10, out_bits: 10 };
        let mut prev = -1i64;
        for z in 0..(1u64 << 10) {
            let (fl, _) = f.floor_y(z);
            assert!(fl >= prev);
            assert!(fl >= 0 && fl < (1 << 10));
            prev = fl;
        }
    }

    #[test]
    fn sqrt_exact_squares() {
        let f = Sqrt { in_bits: 8, out_bits: 8 };
        // z such that 1+z/256 = (1+k/256)^2 ... check z=0 exact.
        assert_eq!(f.floor_y(0), (0, true));
        let mut prev = -1i64;
        for z in 0..256u64 {
            let (fl, _) = f.floor_y(z);
            assert!(fl >= prev);
            prev = fl;
        }
    }

    #[test]
    fn floors_match_f64() {
        for b in [8u32, 10] {
            for name in ["recip", "log2", "exp2", "sqrt"] {
                let f = builtin(name, b).unwrap();
                for z in 0..(1u64 << b) {
                    let (fl, ex) = f.floor_y(z);
                    let y = f.y_f64(z);
                    if ex {
                        assert!((y - fl as f64).abs() < 1e-6, "{name} z={z}");
                    } else {
                        assert_eq!(fl, y.floor() as i64, "{name} z={z} y={y}");
                    }
                }
            }
        }
    }

    #[test]
    fn activation_floors_match_f64() {
        // Guard-banded: the f64 reference is good to ~1e-10 here, so skip
        // points within 1e-6 of an integer (never observed; the exact path
        // panics well before an ambiguous floor could pass through).
        for b in [8u32, 10] {
            for name in ["tanh", "sigmoid", "gelu", "softplus"] {
                let f = builtin(name, b).unwrap();
                for z in 0..(1u64 << b) {
                    let (fl, ex) = f.floor_y(z);
                    let y = f.y_f64(z);
                    if ex {
                        assert!((y - fl as f64).abs() < 1e-6, "{name} z={z}");
                    } else if (y - y.round()).abs() > 1e-6 {
                        assert_eq!(fl, y.floor() as i64, "{name} z={z} y={y}");
                    }
                }
            }
        }
    }

    #[test]
    fn activation_shapes() {
        // tanh / sigmoid: strictly monotone up to the saturated tail, and
        // within the q-bit output range. softplus: strictly decreasing from
        // the exact top code. gelu: rises to its mode (~x = 0.75) then
        // decays — the non-monotone shape none of the paper's functions has.
        let m = 12u32;
        for name in ["tanh", "sigmoid"] {
            let f = builtin(name, m).unwrap();
            let mut prev = -1i64;
            for z in 0..(1u64 << m) {
                let (fl, _) = f.floor_y(z);
                assert!(fl >= prev, "{name} not monotone at z={z}");
                assert!(fl >= 0 && fl < (1 << m));
                prev = fl;
            }
        }
        let sp = builtin("softplus", m).unwrap();
        assert_eq!(sp.floor_y(0), (1 << m, true));
        let mut prev = i64::MAX;
        for z in 0..(1u64 << m) {
            let (fl, _) = sp.floor_y(z);
            assert!(fl <= prev, "softplus not decreasing at z={z}");
            prev = fl;
        }
        let gelu = builtin("gelu", m).unwrap();
        let mode = (0..(1u64 << m)).max_by_key(|&z| gelu.floor_y(z).0).unwrap();
        let x_mode = mode as f64 / (1u64 << (m - 2)) as f64;
        assert!((x_mode - 0.75).abs() < 0.1, "gelu mode at x={x_mode}");
        assert!(gelu.floor_y((1 << m) - 1).0 < gelu.floor_y(mode).0);
    }

    #[test]
    fn custom_f64_sin() {
        let f = CustomF64 {
            name: "sinpi4".into(),
            in_bits: 8,
            out_bits: 8,
            f: |x: f64| (std::f64::consts::FRAC_PI_4 * x).sin(),
            margin: 1e-9,
        };
        let (fl, _) = f.floor_y(128);
        let expect = ((std::f64::consts::FRAC_PI_4 * 0.5).sin() * 256.0).floor() as i64;
        assert_eq!(fl, expect);
    }
}
