//! Accuracy specifications and integer bound tables (the paper's `l`, `u`).
//!
//! The design space is defined relative to integer bound functions
//! `l, u : [0, 2^(n+m)) -> Z` such that every acceptable hardware output
//! `out(Z)` satisfies `l(Z) <= out(Z) <= u(Z)`. This module derives those
//! bounds from a [`TargetFunction`]'s exact floors under an
//! [`AccuracySpec`], clamps them to the output format (realizing output
//! saturation at the domain edges), and materializes them as flat tables.

pub mod exact;
pub mod functions;

pub use functions::{
    builtin, CustomF64, Exp2, Gelu, Log2, Recip, Sigmoid, Softplus, Sqrt, Tanh, TargetFunction,
};

/// How much error the generated hardware may commit, in output ULPs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AccuracySpec {
    /// `|out - Y| <= e` (the paper's "one ULP", `e = 1`, matching the
    /// FloPoCo / DesignWare defaults it compares against).
    Ulp(u32),
    /// Faithful rounding, `|out - Y| < 1`: one of the two neighbouring
    /// representable values (exact values must be returned exactly).
    Faithful,
}

impl AccuracySpec {
    /// Integer bounds `(l, u)` for an exact scaled value with
    /// `floor(Y) = fl` and exactness flag `ex`, before clamping.
    pub fn bounds_of_floor(&self, fl: i64, ex: bool) -> (i64, i64) {
        match *self {
            AccuracySpec::Ulp(e) => {
                let e = e as i64;
                // l = ceil(Y - e), u = floor(Y + e).
                if ex {
                    (fl - e, fl + e)
                } else {
                    (fl + 1 - e, fl + e)
                }
            }
            AccuracySpec::Faithful => {
                if ex {
                    (fl, fl)
                } else {
                    (fl, fl + 1)
                }
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            AccuracySpec::Ulp(e) => format!("{e}ulp"),
            AccuracySpec::Faithful => "faithful".into(),
        }
    }
}

/// Flat per-input integer bounds over the whole input space.
///
/// `l`/`u` are `i32`: every format this tool supports has `p + q <= 30`
/// output bits, and bounds are clamped into `[0, 2^q - 1]`.
#[derive(Clone)]
pub struct BoundTable {
    /// Stored input bits (table length is `2^in_bits`).
    pub in_bits: u32,
    /// Stored output bits `q`.
    pub out_bits: u32,
    pub l: Vec<i32>,
    pub u: Vec<i32>,
    /// Function identifier (for cache keys / reports).
    pub func: String,
    /// Accuracy label (for cache keys / reports).
    pub accuracy: String,
}

impl BoundTable {
    /// Evaluate the function's exact floors over the full input space and
    /// derive clamped bounds.
    pub fn build(f: &dyn TargetFunction, acc: AccuracySpec) -> BoundTable {
        let n = 1u64 << f.in_bits();
        let out_max = (1i64 << f.out_bits()) - 1;
        let mut l = Vec::with_capacity(n as usize);
        let mut u = Vec::with_capacity(n as usize);
        for z in 0..n {
            let (fl, ex) = f.floor_y(z);
            let (lo, hi) = acc.bounds_of_floor(fl, ex);
            let (lo, hi) = (lo.clamp(0, out_max), hi.clamp(0, out_max));
            assert!(
                lo <= hi,
                "infeasible accuracy spec at z={z}: bounds [{lo}, {hi}] empty after \
                 clamping to [0, {out_max}]"
            );
            l.push(lo as i32);
            u.push(hi as i32);
        }
        BoundTable {
            in_bits: f.in_bits(),
            out_bits: f.out_bits(),
            l,
            u,
            func: f.name().to_string(),
            accuracy: acc.label(),
        }
    }

    /// Construct directly from explicit bound vectors (tests, custom specs).
    pub fn from_vecs(in_bits: u32, out_bits: u32, l: Vec<i32>, u: Vec<i32>) -> BoundTable {
        assert_eq!(l.len(), 1usize << in_bits);
        assert_eq!(u.len(), l.len());
        assert!(l.iter().zip(&u).all(|(a, b)| a <= b), "l > u somewhere");
        BoundTable { in_bits, out_bits, l, u, func: "custom".into(), accuracy: "custom".into() }
    }

    pub fn len(&self) -> usize {
        self.l.len()
    }

    pub fn is_empty(&self) -> bool {
        self.l.is_empty()
    }

    /// The per-region slices for `R` lookup bits: region `r` covers codes
    /// `[r * 2^xbits, (r+1) * 2^xbits)`.
    pub fn region(&self, lookup_bits: u32, r: u64) -> (&[i32], &[i32]) {
        let xbits = self.in_bits - lookup_bits;
        let n = 1usize << xbits;
        let base = (r as usize) << xbits;
        (&self.l[base..base + n], &self.u[base..base + n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ulp1_bounds_nonexact() {
        // Y = 7.3 -> floor 7, not exact: l = 7, u = 8.
        assert_eq!(AccuracySpec::Ulp(1).bounds_of_floor(7, false), (7, 8));
        // Y = 7 exactly: l = 6, u = 8.
        assert_eq!(AccuracySpec::Ulp(1).bounds_of_floor(7, true), (6, 8));
        assert_eq!(AccuracySpec::Faithful.bounds_of_floor(7, false), (7, 8));
        assert_eq!(AccuracySpec::Faithful.bounds_of_floor(7, true), (7, 7));
        assert_eq!(AccuracySpec::Ulp(2).bounds_of_floor(7, false), (6, 9));
    }

    #[test]
    fn recip_table_saturates_at_zero_input() {
        let f = Recip { in_bits: 8, out_bits: 8 };
        let t = BoundTable::build(&f, AccuracySpec::Ulp(1));
        // z=0: Y = 256 (exact), clamp to 255: bounds [255, 255].
        assert_eq!((t.l[0], t.u[0]), (255, 255));
        assert_eq!(t.len(), 256);
        for i in 0..t.len() {
            assert!(t.l[i] <= t.u[i]);
            assert!(t.l[i] >= 0 && t.u[i] <= 255);
        }
    }

    #[test]
    fn regions_partition_table() {
        let f = Log2 { in_bits: 8, out_bits: 9 };
        let t = BoundTable::build(&f, AccuracySpec::Ulp(1));
        let mut seen = 0usize;
        for r in 0..16u64 {
            let (l, u) = t.region(4, r);
            assert_eq!(l.len(), 16);
            assert_eq!(u.len(), 16);
            seen += l.len();
        }
        assert_eq!(seen, t.len());
        // Region 0 starts at the table start.
        assert_eq!(t.region(4, 0).0[0], t.l[0]);
        // Last region ends at the table end.
        assert_eq!(*t.region(4, 15).0.last().unwrap(), *t.l.last().unwrap());
    }

    #[test]
    fn bounds_contain_true_value() {
        for name in ["recip", "log2", "exp2", "sqrt"] {
            let f = builtin(name, 8).unwrap();
            let t = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
            for z in 0..(1u64 << 8) {
                let y = f.y_f64(z);
                let lo = t.l[z as usize] as f64;
                let hi = t.u[z as usize] as f64;
                // Within 1 ulp (plus clamping slack at the edges).
                assert!(
                    y >= lo - 1.0 - 1e-9 && y <= hi + 1.0 + 1e-9,
                    "{name} z={z}: y={y} not within [{lo}-1, {hi}+1]"
                );
            }
        }
    }
}
