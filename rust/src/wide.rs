//! Minimal 256-bit unsigned helpers for the exact transcendental bound
//! computations in [`crate::bounds`].
//!
//! The exact `log2` / `exp2` substrates work on 128-bit fixed-point
//! mantissas; squaring and square-rooting those needs 256-bit
//! intermediates. Only the handful of operations those algorithms need are
//! implemented — this is not a general bignum.

/// A 256-bit unsigned integer as `(hi, lo)` 128-bit limbs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct U256 {
    pub hi: u128,
    pub lo: u128,
}

impl U256 {
    pub const ZERO: U256 = U256 { hi: 0, lo: 0 };

    pub fn from_u128(v: u128) -> U256 {
        U256 { hi: 0, lo: v }
    }

    /// Full 128x128 -> 256 multiply.
    // lint: overflow-ok(64-bit limb products and carries; every sum is bounded by 2^128 by construction)
    pub fn mul_u128(a: u128, b: u128) -> U256 {
        const MASK: u128 = (1u128 << 64) - 1;
        let (a0, a1) = (a & MASK, a >> 64);
        let (b0, b1) = (b & MASK, b >> 64);
        let p00 = a0 * b0;
        let p01 = a0 * b1;
        let p10 = a1 * b0;
        let p11 = a1 * b1;
        // lo = p00 + ((p01 + p10) << 64), tracking carries.
        let (mid, c1) = p01.overflowing_add(p10);
        let mid_lo = mid << 64;
        let mid_hi = (mid >> 64) + ((c1 as u128) << 64);
        let (lo, c2) = p00.overflowing_add(mid_lo);
        let hi = p11 + mid_hi + c2 as u128;
        U256 { hi, lo }
    }

    /// Logical right shift by `s` bits (`0 <= s < 256`).
    // lint: overflow-ok(limb stitching; the shift amounts are range-matched)
    pub fn shr(self, s: u32) -> U256 {
        match s {
            0 => self,
            1..=127 => U256 { hi: self.hi >> s, lo: (self.lo >> s) | (self.hi << (128 - s)) },
            128 => U256 { hi: 0, lo: self.hi },
            129..=255 => U256 { hi: 0, lo: self.hi >> (s - 128) },
            _ => U256::ZERO,
        }
    }

    /// Left shift by `s` bits (`0 <= s < 256`), discarding overflow.
    // lint: overflow-ok(limb stitching; discarding shifted-out bits is this function's contract)
    pub fn shl(self, s: u32) -> U256 {
        match s {
            0 => self,
            1..=127 => U256 { hi: (self.hi << s) | (self.lo >> (128 - s)), lo: self.lo << s },
            128 => U256 { hi: self.lo, lo: 0 },
            129..=255 => U256 { hi: self.lo << (s - 128), lo: 0 },
            _ => U256::ZERO,
        }
    }

    pub fn cmp256(&self, o: &U256) -> std::cmp::Ordering {
        (self.hi, self.lo).cmp(&(o.hi, o.lo))
    }

    pub fn lt(&self, o: &U256) -> bool {
        self.cmp256(o) == std::cmp::Ordering::Less
    }

    pub fn saturating_to_u128(self) -> u128 {
        if self.hi != 0 {
            u128::MAX
        } else {
            self.lo
        }
    }

    /// Number of significant bits (0 for zero).
    pub fn bits(&self) -> u32 {
        if self.hi != 0 {
            256 - self.hi.leading_zeros()
        } else {
            128 - self.lo.leading_zeros()
        }
    }
}

/// `floor(sqrt(v))` for `u128` by Newton iteration seeded from `f64`.
// lint: overflow-ok(x stays near sqrt(v) from the f64 seed, so x + v/x < 2^66)
pub fn isqrt_u128(v: u128) -> u128 {
    if v == 0 {
        return 0;
    }
    // f64 seed is good to ~2^-52 relative; a few Newton steps pin it down.
    let mut x = (v as f64).sqrt() as u128;
    if x == 0 {
        x = 1;
    }
    for _ in 0..6 {
        let next = (x + v / x) >> 1;
        if next >= x {
            break;
        }
        x = next;
    }
    // Final correction to the exact floor.
    while x.checked_mul(x).map_or(true, |sq| sq > v) {
        x -= 1;
    }
    while (x + 1).checked_mul(x + 1).map_or(false, |sq| sq <= v) {
        x += 1;
    }
    x
}

impl U256 {
    pub fn checked_sub(self, o: U256) -> Option<U256> {
        if self.lt(&o) {
            return None;
        }
        let (lo, borrow) = self.lo.overflowing_sub(o.lo);
        Some(U256 { hi: self.hi - o.hi - borrow as u128, lo })
    }

    pub fn add(self, o: U256) -> U256 {
        let (lo, carry) = self.lo.overflowing_add(o.lo);
        U256 { hi: self.hi.wrapping_add(o.hi).wrapping_add(carry as u128), lo }
    }
}

/// `floor(sqrt(v))` for a 256-bit value, returned as `u128` (the root of a
/// 256-bit value always fits in 128 bits). Classic digit-by-digit method:
/// exact, branch-simple, ~128 iterations.
pub fn isqrt_u256(v: U256) -> u128 {
    if v.hi == 0 {
        return isqrt_u128(v.lo);
    }
    let mut x = v;
    let mut res = U256::ZERO;
    // Highest power of four <= v.
    let mut bit = U256::from_u128(1).shl((v.bits() - 1) & !1);
    while bit != U256::ZERO {
        let sum = res.add(bit);
        if let Some(rem) = x.checked_sub(sum) {
            x = rem;
            res = res.shr(1).add(bit);
        } else {
            res = res.shr(1);
        }
        bit = bit.shr(2);
    }
    debug_assert_eq!(res.hi, 0);
    res.lo
}

/// `floor(v / d)` for 256-bit `v` and 128-bit `d`, saturating to `u128::MAX`.
pub fn div_u256_by_u128(v: U256, d: u128) -> u128 {
    assert!(d != 0, "division by zero");
    if v.hi == 0 {
        return v.lo / d;
    }
    if v.hi >= d {
        return u128::MAX; // quotient does not fit; saturate
    }
    // Long division, bit by bit over the high limb then low limb.
    let mut rem: u128 = 0;
    let mut quo: u128 = 0;
    for i in (0..256).rev() {
        let bit = if i >= 128 { (v.hi >> (i - 128)) & 1 } else { (v.lo >> i) & 1 };
        // rem = rem*2 + bit; if rem >= d { rem -= d; q bit = 1 }
        let carry = rem >> 127;
        rem = (rem << 1) | bit;
        if carry != 0 || rem >= d {
            rem = rem.wrapping_sub(d);
            if i < 128 {
                quo |= 1u128 << i;
            } else {
                return u128::MAX;
            }
        }
    }
    quo
}

/// Exact `v * f` for a `u64` factor, panicking on 256-bit overflow.
///
/// The GELU erf-series accumulation multiplies Q.160 terms by `z²`
/// (`< 2^32` at 16-bit operands); the widest product stays under `2^205`,
/// so the checked high-limb arithmetic never fires in practice — it is
/// the overflow-lint-mandated guard, not a saturation contract.
pub fn mul_u256_by_u64(v: U256, f: u64) -> U256 {
    let p = U256::mul_u128(v.lo, f as u128);
    let hi = v
        .hi
        .checked_mul(f as u128)
        .and_then(|h| h.checked_add(p.hi))
        .expect("mul_u256_by_u64 overflow");
    U256 { hi, lo: p.lo }
}

/// Exact `floor(v / d)` for a `u64` divisor, returning the full 256-bit
/// quotient (unlike [`div_u256_by_u128`], which saturates to `u128`).
///
/// Schoolbook long division over four 64-bit limbs: the rolling remainder
/// stays `< d < 2^64`, so `(rem << 64) | limb` fits `u128` and each limb
/// quotient fits `u64`.
// lint: overflow-ok(rem < d <= 2^64 - 1, so (rem << 64) | limb < 2^128 and cur / d < 2^64)
pub fn div_u256_by_u64(v: U256, d: u64) -> U256 {
    assert!(d != 0, "division by zero");
    const MASK: u128 = (1u128 << 64) - 1;
    let limbs = [v.hi >> 64, v.hi & MASK, v.lo >> 64, v.lo & MASK];
    let d = d as u128;
    let mut rem: u128 = 0;
    let mut q = [0u128; 4];
    for (i, &limb) in limbs.iter().enumerate() {
        let cur = (rem << 64) | limb;
        q[i] = cur / d;
        rem = cur % d;
    }
    U256 { hi: (q[0] << 64) | q[1], lo: (q[2] << 64) | q[3] }
}

/// Sign of `a*b` without multiplying (`-1`, `0`, or `1`).
fn prod_sign(a: i128, b: i128) -> i32 {
    if a == 0 || b == 0 {
        0
    } else if (a < 0) == (b < 0) {
        1
    } else {
        -1
    }
}

/// Exact ordering of `a*b` versus `c*d` over `i128` factors.
///
/// The fast path compares `i128` products; if either product overflows,
/// the comparison widens to 256-bit magnitudes with explicit sign
/// handling instead of wrapping — the widening counterpart the overflow
/// lint demands of the envelope/extrema cross multiplications.
pub fn cmp_i128_products(a: i128, b: i128, c: i128, d: i128) -> std::cmp::Ordering {
    match (a.checked_mul(b), c.checked_mul(d)) {
        (Some(l), Some(r)) => l.cmp(&r),
        _ => {
            let (sl, sr) = (prod_sign(a, b), prod_sign(c, d));
            if sl != sr {
                return sl.cmp(&sr);
            }
            let ml = U256::mul_u128(a.unsigned_abs(), b.unsigned_abs());
            let mr = U256::mul_u128(c.unsigned_abs(), d.unsigned_abs());
            // Same sign: larger magnitude wins for non-negative products,
            // loses for negative ones.
            if sl >= 0 {
                ml.cmp256(&mr)
            } else {
                mr.cmp256(&ml)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_matches_small() {
        let p = U256::mul_u128(u64::MAX as u128, u64::MAX as u128);
        assert_eq!(p.hi, 0);
        assert_eq!(p.lo, (u64::MAX as u128) * (u64::MAX as u128));
    }

    #[test]
    fn mul_big() {
        // (2^127)^2 = 2^254
        let p = U256::mul_u128(1u128 << 127, 1u128 << 127);
        assert_eq!(p.lo, 0);
        assert_eq!(p.hi, 1u128 << 126);
    }

    #[test]
    fn shifts_roundtrip() {
        let v = U256 { hi: 0x1234_5678_9abc_def0, lo: 0x0fed_cba9_8765_4321 };
        for s in [0u32, 1, 63, 64, 127, 128, 129, 200, 255] {
            let w = v.shl(s).shr(s);
            if s == 0 {
                assert_eq!(w, v);
            }
            let x = v.shr(1).shl(1);
            assert_eq!(x.lo & !1, v.lo & !1);
        }
    }

    #[test]
    fn isqrt_u128_exact() {
        for v in [0u128, 1, 2, 3, 4, 15, 16, 17, 1 << 40, (1 << 40) + 1, u64::MAX as u128] {
            let r = isqrt_u128(v);
            assert!(r * r <= v, "v={v}");
            assert!((r + 1).checked_mul(r + 1).map_or(true, |s| s > v), "v={v}");
        }
        // Deterministic pseudo-random sweep.
        let mut s: u128 = 0x9e3779b97f4a7c15;
        for _ in 0..2000 {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = s >> 7;
            let r = isqrt_u128(v);
            assert!(r * r <= v);
            assert!((r + 1).checked_mul(r + 1).map_or(true, |sq| sq > v));
        }
    }

    #[test]
    fn isqrt_u256_exact() {
        // Perfect squares of large values round-trip.
        let mut s: u128 = 0xdeadbeefcafebabe;
        for _ in 0..500 {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let x = s | (1u128 << 120);
            let sq = U256::mul_u128(x, x);
            assert_eq!(isqrt_u256(sq), x);
            // And sq+1 (if not overflowing lo) has the same floor sqrt.
            let sq1 = U256 { hi: sq.hi, lo: sq.lo.wrapping_add(1) };
            if sq1.lo != 0 {
                assert_eq!(isqrt_u256(sq1), x);
            }
        }
    }

    #[test]
    fn div_u256() {
        let v = U256::mul_u128(123456789012345678901234567890u128, 987654321u128);
        assert_eq!(div_u256_by_u128(v, 987654321u128), 123456789012345678901234567890u128);
        let v1 = U256 { hi: v.hi, lo: v.lo + 5 };
        assert_eq!(div_u256_by_u128(v1, 987654321u128), 123456789012345678901234567890u128);
    }

    #[test]
    fn cmp_i128_products_widens_exactly() {
        use std::cmp::Ordering::*;
        // In-range products: plain i128 comparison.
        assert_eq!(cmp_i128_products(3, 4, 2, 7), Less);
        assert_eq!(cmp_i128_products(-3, 4, 2, -6), Equal);
        assert_eq!(cmp_i128_products(5, -2, -3, 4), Greater);
        // Overflowing products, same sign: 2^130 + 2^30 vs 2^130 + 2^100.
        let big = 1i128 << 100;
        assert_eq!(cmp_i128_products(big + 1, 1 << 30, big, (1 << 30) + 1), Less);
        assert_eq!(cmp_i128_products(big, (1 << 30) + 1, big + 1, 1 << 30), Greater);
        // Both negative: the magnitude ordering reverses.
        assert_eq!(cmp_i128_products(-(big + 1), 1 << 30, -big, (1 << 30) + 1), Greater);
        // Equal overflowing products in different factorizations.
        assert_eq!(cmp_i128_products(big + 1, 1 << 30, (big + 1) * 2, 1 << 29), Equal);
        // Mixed: one side overflows, the other is zero or negative.
        assert_eq!(cmp_i128_products(big, big, -1, 1), Greater);
        assert_eq!(cmp_i128_products(0, big, big, big), Less);
        assert_eq!(cmp_i128_products(-big, big, 1, 0), Less);
    }

    #[test]
    fn bits_counts() {
        assert_eq!(U256::ZERO.bits(), 0);
        assert_eq!(U256::from_u128(1).bits(), 1);
        assert_eq!(U256 { hi: 1, lo: 0 }.bits(), 129);
    }
}
