//! `polygen` — CLI for complete polynomial-interpolation design-space
//! generation, exploration, RTL emission, verification and reporting.
//!
//! Subcommands (hand-rolled argument parsing; clap is not available
//! offline):
//!
//! ```text
//! polygen generate --func recip --bits 16 --lub 8 [--naive] [--threads N] [--cache DIR]
//! polygen dse      --func recip --bits 16 --lub 8 [--quadratic|--linear] [--lut-first]
//! polygen rtl      --func recip --bits 10 --lub 5 --out DIR [--tb]
//! polygen verify   --func recip --bits 16 --lub 8 [--engine scalar|xla|pallas] [--artifacts DIR]
//! polygen sweep    --func log2  --bits 10 [--threads N]
//! polygen report   <table1|table2|fig2|fig3|claim|scaling|linear> [--deep] [--out DIR]
//! polygen config   --file job.toml [--set key=value ...]
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use polygen::bounds::AccuracySpec;
use polygen::coordinator::config::Config;
use polygen::coordinator::{best_by_adp, default_r_range, generate_cached, sweep_lub, Workload};
use polygen::designspace::extrema::SearchStrategy;
use polygen::designspace::{generate, GenOptions};
use polygen::dse::{explore, Degree, DseOptions, Procedure};
use polygen::report;
use polygen::rtl;
use polygen::runtime::{Flavor, XlaRuntime};
use polygen::synth::synth_min_delay;
use polygen::verify::{verify_exhaustive, Engine};

/// Tiny flag parser: `--key value` and bare `--switch`.
struct Args {
    cmd: String,
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse() -> Option<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next()?;
        let rest: Vec<String> = it.collect();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < rest.len() {
            if !rest[i].starts_with("--") {
                positional.push(rest[i].clone());
                i += 1;
                continue;
            }
            let k = rest[i].trim_start_matches('-').to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                flags.push((k, Some(rest[i + 1].clone())));
                i += 2;
            } else {
                flags.push((k, None));
                i += 1;
            }
        }
        Some(Args { cmd, positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_deref())
    }

    fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn usage() -> ExitCode {
    eprintln!(
        "usage: polygen <generate|dse|rtl|verify|sweep|report|config> [--flags]\n\
         see rust/src/main.rs header or README.md for details"
    );
    ExitCode::FAILURE
}

fn workload(args: &Args) -> Result<Workload, String> {
    let func = args.get("func").unwrap_or("recip");
    let bits = args.u32_or("bits", 10);
    let acc = match args.get("accuracy").unwrap_or("1ulp") {
        "faithful" => AccuracySpec::Faithful,
        s => AccuracySpec::Ulp(
            s.trim_end_matches("ulp").parse().map_err(|_| format!("bad accuracy {s}"))?,
        ),
    };
    Workload::prepare(func, bits, acc).ok_or_else(|| format!("unknown function {func}"))
}

fn gen_opts(args: &Args) -> GenOptions {
    GenOptions {
        lookup_bits: args.u32_or("lub", 6),
        search: if args.has("naive") { SearchStrategy::Naive } else { SearchStrategy::Pruned },
        max_k: args.u32_or("max-k", 30),
        threads: args.u32_or("threads", 1) as usize,
    }
}

fn dse_opts(args: &Args) -> DseOptions {
    DseOptions {
        procedure: if args.has("lut-first") {
            Procedure::LutFirst
        } else {
            Procedure::SquareFirst
        },
        degree: if args.has("quadratic") {
            Some(Degree::Quadratic)
        } else if args.has("linear") {
            Some(Degree::Linear)
        } else {
            None
        },
        max_b_per_a: args.u32_or("max-b", 512) as usize,
    }
}

fn run() -> Result<(), String> {
    let Some(args) = Args::parse() else { return Err("no command".into()) };
    match args.cmd.as_str() {
        "generate" => {
            let w = workload(&args)?;
            let opts = gen_opts(&args);
            let ds = if let Some(dir) = args.get("cache") {
                generate_cached(&w, opts.lookup_bits, &opts, &PathBuf::from(dir))
            } else {
                generate(&w.bt, &opts)
            }
            .map_err(|e| e.to_string())?;
            println!(
                "design space: {} {}b R={} k={}  regions={}  (a,b) pairs={}  linear_ok={}",
                ds.func,
                ds.in_bits,
                ds.lookup_bits,
                ds.k,
                ds.regions.len(),
                ds.num_ab_pairs(),
                ds.linear_feasible()
            );
            Ok(())
        }
        "dse" => {
            let w = workload(&args)?;
            let opts = gen_opts(&args);
            let ds = generate(&w.bt, &opts).map_err(|e| e.to_string())?;
            let im = explore(&w.bt, &ds, &dse_opts(&args)).ok_or("DSE found no design")?;
            let p = synth_min_delay(&im);
            println!(
                "impl: {:?} k={} i={} j={} LUT {}  min-delay {:.3} ns, {:.1} um2",
                im.degree,
                im.k,
                im.sq_trunc,
                im.lin_trunc,
                im.lut_width_label(),
                p.delay_ns,
                p.area_um2
            );
            for (r, co) in im.coeffs.iter().enumerate().take(8) {
                println!("  r={r}: a={} b={} c={}", co.a, co.b, co.c);
            }
            if im.coeffs.len() > 8 {
                println!("  ... {} more regions", im.coeffs.len() - 8);
            }
            Ok(())
        }
        "rtl" => {
            let w = workload(&args)?;
            let opts = gen_opts(&args);
            let ds = generate(&w.bt, &opts).map_err(|e| e.to_string())?;
            let im = explore(&w.bt, &ds, &dse_opts(&args)).ok_or("DSE found no design")?;
            let dir = PathBuf::from(args.get("out").unwrap_or("rtl_out"));
            std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
            let name = format!("{}_{}b_r{}", im.func, im.in_bits, im.lookup_bits);
            let write = |p: PathBuf, s: String| std::fs::write(p, s).map_err(|e| e.to_string());
            write(dir.join(format!("{name}.v")), rtl::emit_module(&im, &name))?;
            if args.has("tb") {
                write(dir.join(format!("{name}_tb.v")), rtl::emit_testbench(&im, &name))?;
                write(dir.join(format!("{name}_golden.hex")), rtl::emit_golden_hex(&im))?;
            }
            if im.func == "recip" {
                write(
                    dir.join("recip_behavioral.v"),
                    rtl::behavioral::emit_recip_behavioral(im.in_bits, im.out_bits),
                )?;
            }
            println!("wrote RTL to {}", dir.display());
            Ok(())
        }
        "verify" => {
            let w = workload(&args)?;
            let opts = gen_opts(&args);
            let ds = generate(&w.bt, &opts).map_err(|e| e.to_string())?;
            let im = explore(&w.bt, &ds, &dse_opts(&args)).ok_or("DSE found no design")?;
            let engine_name = args.get("engine").unwrap_or("scalar");
            let rt;
            let engine = match engine_name {
                "scalar" => Engine::Scalar,
                "xla" | "pallas" => {
                    let dir = args.get("artifacts").unwrap_or("artifacts");
                    rt = XlaRuntime::load(dir).map_err(|e| e.to_string())?;
                    let flavor =
                        if engine_name == "pallas" { Flavor::Pallas } else { Flavor::Jnp };
                    Engine::Xla { rt: &rt, flavor }
                }
                other => return Err(format!("unknown engine {other}")),
            };
            let rep = verify_exhaustive(&w.bt, &im, &engine).map_err(|e| e.to_string())?;
            println!(
                "verified {} inputs via {engine_name}: {} violations{}",
                rep.total,
                rep.violations,
                rep.first_violation
                    .map(|z| format!(" (first at z={z})"))
                    .unwrap_or_default()
            );
            if im.func == "recip" {
                rtl::behavioral::recip_between_roundings(&im).map_err(|(z, y, lo, hi)| {
                    format!("behavioural bracket failed at z={z}: {y} not in [{lo},{hi}]")
                })?;
                println!("behavioural RTZ/R+inf bracket: ok");
            }
            if rep.violations == 0 {
                Ok(())
            } else {
                Err("verification FAILED".into())
            }
        }
        "sweep" => {
            let w = workload(&args)?;
            let threads = args.u32_or("threads", 4) as usize;
            let pts = sweep_lub(
                &w,
                &default_r_range(w.bt.in_bits),
                &GenOptions::default(),
                &dse_opts(&args),
                threads,
            );
            println!("{}", report::fig3(&w.bt.func.clone(), w.bt.in_bits, threads).0);
            if let Some(best) = best_by_adp(&pts) {
                println!("best ADP at LUB = {}", best.lookup_bits);
            }
            Ok(())
        }
        "report" => {
            let what = args
                .positional
                .first()
                .cloned()
                .unwrap_or_else(|| "table1".into());
            let deep = args.has("deep");
            let threads = args.u32_or("threads", 4) as usize;
            let out_dir = args.get("out").map(PathBuf::from);
            let text = match what.as_str() {
                "table1" => {
                    let mut sizes: Vec<(&str, u32)> = vec![
                        ("recip", 10),
                        ("recip", 16),
                        ("log2", 10),
                        ("log2", 16),
                        ("exp2", 10),
                        ("exp2", 16),
                    ];
                    if deep {
                        sizes.push(("recip", 20));
                        sizes.push(("log2", 20));
                    }
                    report::table1(&sizes, threads)
                }
                "table2" => {
                    let mut cases = vec![("recip", 16, 6), ("log2", 16, 6), ("exp2", 10, 4)];
                    if deep {
                        cases.push(("recip", 20, 9));
                    }
                    report::table2(&cases)
                }
                "fig2" => {
                    let bits = if deep { 20 } else { 16 };
                    let (t, csv) = report::fig2("recip", bits, 7, 14);
                    if let Some(d) = &out_dir {
                        std::fs::create_dir_all(d).ok();
                        std::fs::write(d.join("fig2.csv"), csv).ok();
                    }
                    t
                }
                "fig3" => {
                    let (t10, c10) = report::fig3("log2", 10, threads);
                    let (t16, c16) = report::fig3("log2", 16, threads);
                    if let Some(d) = &out_dir {
                        std::fs::create_dir_all(d).ok();
                        std::fs::write(d.join("fig3_log2_10.csv"), c10).ok();
                        std::fs::write(d.join("fig3_log2_16.csv"), c16).ok();
                    }
                    format!("{t10}\n{t16}")
                }
                "claim" => report::claim_ii1("recip", 16, 8, 3),
                "scaling" => report::scaling("recip", 16, &[6, 7, 8, 9, 10, 11]),
                "linear" => ["recip", "log2", "exp2"]
                    .iter()
                    .map(|f| report::linear_threshold(f, 10))
                    .collect::<String>(),
                other => return Err(format!("unknown report {other}")),
            };
            println!("{text}");
            if let Some(d) = &out_dir {
                std::fs::create_dir_all(d).ok();
                std::fs::write(d.join(format!("{what}.txt")), &text).ok();
            }
            Ok(())
        }
        "config" => {
            let file = args.get("file").ok_or("--file required")?;
            let mut cfg = Config::load(file)?;
            for kv in args.get_all("set") {
                cfg.set(kv)?;
            }
            let func = cfg.get_or("func", "recip").to_string();
            let bits: u32 = cfg.get_u32("bits")?.unwrap_or(10);
            let lub = cfg.get_u32("generate.lookup_bits")?.unwrap_or(6);
            let w = Workload::prepare(&func, bits, AccuracySpec::Ulp(1))
                .ok_or(format!("unknown function {func}"))?;
            let ds = generate(&w.bt, &GenOptions { lookup_bits: lub, ..Default::default() })
                .map_err(|e| e.to_string())?;
            let im = explore(&w.bt, &ds, &DseOptions::default()).ok_or("DSE failed")?;
            let p = synth_min_delay(&im);
            println!(
                "{func} {bits}b R={lub}: {:?} LUT {} — {:.3} ns, {:.1} um2",
                im.degree,
                im.lut_width_label(),
                p.delay_ns,
                p.area_um2
            );
            Ok(())
        }
        _ => Err(format!("unknown command {}", args.cmd)),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            if e == "no command" {
                return usage();
            }
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
