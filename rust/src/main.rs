//! `polygen` — CLI for complete polynomial-interpolation design-space
//! generation, exploration, RTL emission, verification and reporting.
//!
//! Every flow is a [`polygen::pipeline`] run; this file only parses
//! flags ([`polygen::cli`]) and formats stage artifacts.
//!
//! ```text
//! polygen generate --func recip --bits 16 --lub 8 [--degree 1|2] [--naive|--pruned] [--threads N] [--cache DIR]
//! polygen dse      --func recip --bits 16 --lub 8 [--quadratic|--linear] [--procedure P]
//! polygen rtl      --func recip --bits 10 --lub 5 --out DIR [--tb]
//! polygen verify   --func recip --bits 16 --lub 8 [--engine scalar|xla|pallas] [--artifacts DIR]
//! polygen sweep    --func log2  --bits 10 [--threads N]
//! polygen report   <table1|table2|fig2|fig3|claim|scaling|linear|tech|activations> [--deep] [--out DIR]
//! polygen config   --file job.toml [--set key=value ...]
//! polygen batch    job1.toml job2.toml ... [--threads N] [--cache DIR] [--threads-strict]
//! polygen serve    [--port 7878] [--addr 127.0.0.1] [--jobs N] [--cache DIR] [--state DIR]
//!                  [--auth-token TOK] [--max-conns N] [--rate-limit R [--rate-burst B]]
//!                  [--call-timeout SECS] [--retries N] [--breaker-threshold K]
//!                  [--store-max-bytes BYTES] [--store-ttl SECS] [--trace]
//!                  [--worker --coordinator URL [--public-addr ADDR]]
//! polygen trace    <job.toml | JOB_ID> [--out trace.json] [--server HOST:PORT] [--auth-token TOK]
//! ```
//!
//! `--lub auto` (optionally with `--objective area|delay|area_delay`)
//! enables automatic lookup-bit selection on any flow. Every flow takes
//! `--tech asic-ge|fpga-lut6|low-power` (the technology target: cost
//! model + default decision procedure) and `--procedure
//! square_first|lut_first|pareto` to force an ordering (`--lut-first`
//! is kept as a shorthand).

use std::path::PathBuf;
use std::process::ExitCode;

use polygen::cli::Args;
use polygen::pipeline::{
    parse_accuracy, Batch, Config, Degree, Flavor, JobSpec, LubObjective, Pipeline, Procedure,
    SearchStrategy, TechKind, XlaRuntime,
};
use polygen::report;

fn usage() -> ExitCode {
    eprintln!(
        "usage: polygen <generate|dse|rtl|verify|sweep|report|config|batch|serve|trace> [--flags]\n\
         see rust/src/main.rs header or README.md for details"
    );
    ExitCode::FAILURE
}

/// Build a pipeline from the common flags (`--func --bits --accuracy
/// --lub --naive/--pruned --max-k --threads --max-b --quadratic/--linear
/// --lut-first --cache --tb`); the default search is the hull engine.
fn tech_from(args: &Args) -> Result<TechKind, String> {
    match args.get("tech") {
        Some(t) => TechKind::parse(t)
            .ok_or_else(|| format!("bad tech {t} (asic-ge|fpga-lut6|low-power)")),
        None => Ok(TechKind::default()),
    }
}

fn pipeline_from(args: &Args) -> Result<Pipeline, String> {
    let func = args.get("func").unwrap_or("recip");
    let acc = parse_accuracy(args.get("accuracy").unwrap_or("1ulp"))
        .map_err(|e| e.to_string())?;
    let tech = tech_from(args)?;
    let mut p = Pipeline::function(func)
        .bits(args.u32_or("bits", 10))
        .accuracy(acc)
        .technology(tech)
        .search(if args.has("naive") {
            SearchStrategy::Naive
        } else if args.has("pruned") {
            SearchStrategy::Pruned
        } else {
            SearchStrategy::Hull
        })
        .max_k(args.u32_or("max-k", 30))
        .threads(args.u32_or("threads", 1) as usize)
        .max_b_per_a(args.u32_or("max-b", 512) as usize);
    // Generation degree: 2 (default) is the paper's complete quadratic
    // space, 1 generates only the linear b·x + c slice.
    let degree = args.u32_or("degree", 2);
    if degree != 1 && degree != 2 {
        return Err(format!("bad degree {degree} (use 1 or 2)"));
    }
    p = p.gen_degree(degree);
    p = match args.get("lub") {
        Some("auto") => p.auto_lub(match args.get("objective") {
            // No explicit objective: the technology's own default (e.g.
            // minimum activity-weighted area for low-power).
            None => tech.technology().default_objective(),
            Some("area") => LubObjective::Area,
            Some("delay") => LubObjective::Delay,
            Some("area_delay") => LubObjective::AreaDelay,
            Some(other) => {
                return Err(format!("bad objective {other} (area|delay|area_delay)"))
            }
        }),
        Some(v) => p.lub(v.parse().map_err(|_| format!("bad lub {v}"))?),
        None => p.lub(6),
    };
    if args.has("quadratic") {
        p = p.degree(Degree::Quadratic);
    } else if args.has("linear") {
        p = p.degree(Degree::Linear);
    }
    if let Some(proc_) = args.get("procedure") {
        p = p.procedure(match proc_ {
            "square_first" => Procedure::SquareFirst,
            "lut_first" => Procedure::LutFirst,
            "pareto" => Procedure::Pareto,
            other => {
                return Err(format!("bad procedure {other} (square_first|lut_first|pareto)"))
            }
        });
    } else if args.has("lut-first") {
        p = p.procedure(Procedure::LutFirst);
    }
    if let Some(dir) = args.get("cache") {
        p = p.cache_dir(dir);
    }
    if args.has("tb") {
        p = p.testbench(true);
    }
    Ok(p)
}

fn run() -> Result<(), String> {
    let Some(args) = Args::parse() else { return Err("no command".into()) };
    match args.cmd.as_str() {
        "generate" => {
            let spaced = pipeline_from(&args)?
                .prepare()
                .map_err(|e| e.to_string())?
                .generate()
                .map_err(|e| e.to_string())?;
            let ds = &spaced.space;
            // Lazy space: the pair count and linear bit stream over the
            // stored envelopes, so even 20-bit runs stay within the
            // analysis-phase memory footprint (DESIGN.md §Scaling).
            println!(
                "design space: {} {}b R={} k={}  regions={}  (a,b) pairs={}  linear_ok={}",
                ds.func,
                ds.in_bits,
                ds.lookup_bits,
                ds.k,
                ds.num_regions(),
                ds.num_ab_pairs(),
                ds.linear_feasible()
            );
            Ok(())
        }
        "dse" => {
            let s = pipeline_from(&args)?
                .prepare()
                .map_err(|e| e.to_string())?
                .generate()
                .map_err(|e| e.to_string())?
                .explore()
                .map_err(|e| e.to_string())?
                .synthesize();
            let im = &s.implementation;
            // Echo the canonical label and the technology's area unit
            // (the parse already succeeded in pipeline_from; aliases
            // like `fpga` normalize here).
            let tech = tech_from(&args)?;
            println!(
                "impl [{}]: {:?} k={} i={} j={} LUT {}  min-delay {:.3} ns, {:.1} {}",
                tech.label(),
                im.degree,
                im.k,
                im.sq_trunc,
                im.lin_trunc,
                im.lut_width_label(),
                s.synth.delay_ns,
                s.synth.area_um2,
                tech.technology().cost_model().area_unit()
            );
            for (r, co) in im.coeffs.iter().enumerate().take(8) {
                println!("  r={r}: a={} b={} c={}", co.a, co.b, co.c);
            }
            if im.coeffs.len() > 8 {
                println!("  ... {} more regions", im.coeffs.len() - 8);
            }
            Ok(())
        }
        "rtl" => {
            let explored = pipeline_from(&args)?
                .prepare()
                .map_err(|e| e.to_string())?
                .generate()
                .map_err(|e| e.to_string())?
                .explore()
                .map_err(|e| e.to_string())?;
            let dir = PathBuf::from(args.get("out").unwrap_or("rtl_out"));
            let emitted = explored.emit_rtl(&dir).map_err(|e| e.to_string())?;
            println!("wrote RTL to {} ({} files)", dir.display(), emitted.files.len());
            Ok(())
        }
        "verify" => {
            let synthesized = pipeline_from(&args)?
                .prepare()
                .map_err(|e| e.to_string())?
                .generate()
                .map_err(|e| e.to_string())?
                .explore()
                .map_err(|e| e.to_string())?
                .synthesize();
            let engine_name = args.get("engine").unwrap_or("scalar");
            let verified = match engine_name {
                "scalar" => synthesized.verify(),
                "xla" | "pallas" => {
                    let dir = args.get("artifacts").unwrap_or("artifacts");
                    let rt = XlaRuntime::load(dir).map_err(|e| e.to_string())?;
                    let flavor =
                        if engine_name == "pallas" { Flavor::Pallas } else { Flavor::Jnp };
                    synthesized.verify_with(&rt, flavor)
                }
                other => return Err(format!("unknown engine {other}")),
            }
            .map_err(|e| e.to_string())?;
            println!(
                "verified {} inputs via {engine_name}: 0 violations",
                verified.report.total
            );
            verified.check_behavioural_bracket().map_err(|e| e.to_string())?;
            if verified.implementation.func == "recip" {
                println!("behavioural RTZ/R+inf bracket: ok");
            }
            Ok(())
        }
        "sweep" => {
            let func = args.get("func").unwrap_or("recip").to_string();
            let bits = args.u32_or("bits", 10);
            let threads = args.u32_or("threads", 4) as usize;
            let swept = pipeline_from(&args)?.threads(threads).sweep().map_err(|e| e.to_string())?;
            println!("{}", report::fig3(&func, bits, threads).0);
            if let Some(best) = swept.best(LubObjective::AreaDelay) {
                println!("best ADP at LUB = {}", best.lookup_bits);
            }
            Ok(())
        }
        "report" => {
            let what = args
                .positional
                .first()
                .cloned()
                .unwrap_or_else(|| "table1".into());
            let deep = args.has("deep");
            let threads = args.u32_or("threads", 4) as usize;
            let out_dir = args.get("out").map(PathBuf::from);
            let text = match what.as_str() {
                "table1" => {
                    let mut sizes: Vec<(&str, u32)> = vec![
                        ("recip", 10),
                        ("recip", 16),
                        ("log2", 10),
                        ("log2", 16),
                        ("exp2", 10),
                        ("exp2", 16),
                    ];
                    if deep {
                        sizes.push(("recip", 20));
                        sizes.push(("log2", 20));
                    }
                    report::table1(&sizes, threads)
                }
                "table2" => {
                    let mut cases = vec![("recip", 16, 6), ("log2", 16, 6), ("exp2", 10, 4)];
                    if deep {
                        cases.push(("recip", 20, 9));
                    }
                    report::table2(&cases)
                }
                "fig2" => {
                    let bits = if deep { 20 } else { 16 };
                    let (t, csv) = report::fig2("recip", bits, 7, 14);
                    if let Some(d) = &out_dir {
                        std::fs::create_dir_all(d).ok();
                        std::fs::write(d.join("fig2.csv"), csv).ok();
                    }
                    t
                }
                "fig3" => {
                    let (t10, c10) = report::fig3("log2", 10, threads);
                    let (t16, c16) = report::fig3("log2", 16, threads);
                    if let Some(d) = &out_dir {
                        std::fs::create_dir_all(d).ok();
                        std::fs::write(d.join("fig3_log2_10.csv"), c10).ok();
                        std::fs::write(d.join("fig3_log2_16.csv"), c16).ok();
                    }
                    format!("{t10}\n{t16}")
                }
                "claim" => report::claim_ii1("recip", 16, 8, 3),
                "scaling" => report::scaling("recip", 16, &[6, 7, 8, 9, 10, 11]),
                "tech" => {
                    let mut cases = vec![
                        ("recip", 8, 3),
                        ("recip", 10, 4),
                        ("log2", 10, 4),
                        ("exp2", 10, 3),
                    ];
                    if deep {
                        cases.push(("recip", 16, 6));
                        cases.push(("log2", 16, 6));
                    }
                    report::tech_table(&cases)
                }
                "linear" => ["recip", "log2", "exp2"]
                    .iter()
                    .map(|f| report::linear_threshold(f, 10))
                    .collect::<String>(),
                "activations" => report::activations(&[8, 12, 16], if deep { 16 } else { 14 }),
                other => return Err(format!("unknown report {other}")),
            };
            println!("{text}");
            if let Some(d) = &out_dir {
                std::fs::create_dir_all(d).ok();
                std::fs::write(d.join(format!("{what}.txt")), &text).ok();
            }
            Ok(())
        }
        "config" => {
            let file = args.get("file").ok_or("--file required")?;
            let mut cfg = Config::load(file)?;
            for kv in args.get_all("set") {
                cfg.set(kv)?;
            }
            let spec = JobSpec::from_config(&cfg).map_err(|e| e.to_string())?;
            let res = spec.run().map_err(|e| e.to_string())?;
            println!(
                "{} {}b R={}: {:?} LUT {} — {:.3} ns, {:.1} um2",
                res.func,
                res.bits,
                res.lookup_bits,
                res.implementation.degree,
                res.implementation.lut_width_label(),
                res.synth.delay_ns,
                res.synth.area_um2
            );
            Ok(())
        }
        "serve" => {
            // The HTTP/JSON front-end over polygen::service (wire format
            // in DESIGN.md §Service / §Cluster): POST /jobs, GET
            // /jobs[/:id[/result]], DELETE /jobs/:id, plus the worker and
            // shard endpoints. `--port 0` binds an ephemeral port (the
            // actual one is printed). `--state DIR` makes the registry
            // durable; `--worker --coordinator URL` additionally
            // registers this listener as a shard worker there.
            let addr = args.get("addr").unwrap_or("127.0.0.1");
            let port = args.u32_or("port", 7878);
            let jobs = args.u32_or(
                "jobs",
                std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(4),
            ) as usize;
            let token = args.get("auth-token").map(str::to_string);
            // No-op unless the binary was built with `--features
            // fault-injection` AND POLYGEN_FAULT_SEED is set.
            polygen::faults::arm_from_env();
            let mut policy = polygen::net::Policy::default();
            if args.has("call-timeout") {
                let secs = args.f64_or("call-timeout", 10.0).max(0.001);
                policy.call_timeout = std::time::Duration::from_secs_f64(secs);
            }
            policy.retries = args.u32_or("retries", policy.retries);
            policy.breaker_threshold =
                args.u32_or("breaker-threshold", policy.breaker_threshold);
            let mut builder = polygen::service::Service::builder()
                .workers(jobs)
                .policy(policy.clone());
            if let Some(dir) = args.get("cache") {
                builder = builder.cache_dir(dir);
            }
            if let Some(dir) = args.get("state") {
                builder = builder.state_dir(dir);
            }
            if let Some(tok) = &token {
                builder = builder.auth_token(tok.clone());
            }
            if args.has("store-max-bytes") {
                builder = builder.store_max_bytes(args.u64_or("store-max-bytes", 0));
            }
            if args.has("store-ttl") {
                builder = builder
                    .store_ttl(std::time::Duration::from_secs(args.u64_or("store-ttl", 0)));
            }
            if args.has("trace") {
                // Every submitted job gets a span tracer; export with
                // `polygen trace JOB_ID` or `GET /jobs/:id/trace`.
                builder = builder.tracing(true);
            }
            let svc = builder.build();
            let listener = std::net::TcpListener::bind(format!("{addr}:{port}"))
                .map_err(|e| format!("bind {addr}:{port}: {e}"))?;
            let local = listener.local_addr().map_err(|e| e.to_string())?;
            let opts = polygen::service::http::HttpOptions {
                auth_token: token.clone(),
                max_conns: args.u32_or("max-conns", 0) as usize,
                rate_limit: args.f64_or("rate-limit", 0.0),
                rate_burst: args.f64_or("rate-burst", 0.0),
            };
            if args.has("worker") {
                let coordinator = args
                    .get("coordinator")
                    .ok_or("--worker requires --coordinator URL")?
                    .to_string();
                // Workers usually bind 0.0.0.0 (or port 0); --public-addr
                // is the address the coordinator should dial back.
                let my_addr = args
                    .get("public-addr")
                    .map(str::to_string)
                    .unwrap_or_else(|| local.to_string());
                println!(
                    "polygen worker listening on http://{local} (coordinator: {coordinator})"
                );
                let stop = polygen::sync::Arc::new(polygen::sync::atomic::AtomicBool::new(false));
                let _agent = polygen::service::run_worker_agent_with(
                    coordinator,
                    my_addr,
                    token,
                    stop,
                    policy,
                );
            } else {
                println!(
                    "polygen service listening on http://{local} ({jobs} concurrent jobs)"
                );
            }
            polygen::service::http::serve_with(svc, listener, opts);
            Ok(())
        }
        "trace" => {
            // Chrome trace_events export (load in chrome://tracing or
            // Perfetto). Two modes: a job-file argument runs the job
            // locally under a tracer; a numeric id fetches the trace of
            // a job on a running `polygen serve --trace` instance.
            let target = args
                .positional
                .first()
                .cloned()
                .ok_or("trace requires a job file (.toml) or a job id")?;
            let out = PathBuf::from(args.get("out").unwrap_or("trace.json"));
            let json = if target.ends_with(".toml") {
                let text =
                    std::fs::read_to_string(&target).map_err(|e| format!("{target}: {e}"))?;
                let spec = JobSpec::from_toml(&text).map_err(|e| format!("{target}: {e}"))?;
                let ctrl = polygen::sync::Arc::new(polygen::pipeline::JobCtrl::traced());
                let res = spec
                    .run_controlled(None, Some(polygen::sync::Arc::clone(&ctrl)))
                    .map_err(|e| e.to_string())?;
                ctrl.finish_trace();
                let tracer = ctrl.tracer().expect("ctrl built with JobCtrl::traced");
                println!(
                    "{} R={}: {} spans recorded",
                    spec.label(),
                    res.lookup_bits,
                    tracer.spans().len()
                );
                tracer.export_chrome()
            } else {
                let id: u64 =
                    target.parse().map_err(|_| format!("bad job id or file {target}"))?;
                let server = args.get("server").unwrap_or("127.0.0.1:7878");
                fetch_trace(server, id, args.get("auth-token"))?
            };
            std::fs::write(&out, &json).map_err(|e| format!("{}: {e}", out.display()))?;
            println!("wrote {}", out.display());
            Ok(())
        }
        "batch" => {
            let mut files: Vec<String> =
                args.get_all("jobs").iter().map(|s| s.to_string()).collect();
            files.extend(args.positional.iter().cloned());
            if files.is_empty() {
                return Err("batch requires job files (positional or --jobs FILE)".into());
            }
            let mut specs = Vec::with_capacity(files.len());
            for f in &files {
                let text = std::fs::read_to_string(f).map_err(|e| format!("{f}: {e}"))?;
                specs.push(JobSpec::from_toml(&text).map_err(|e| format!("{f}: {e}"))?);
            }
            if args.has("threads-strict") {
                // CLI override for the donation floor (ROADMAP PR-4
                // item): every job keeps its own `threads` as a hard cap.
                for s in &mut specs {
                    s.threads_strict = true;
                }
            }
            let threads = args.u32_or("threads", specs.len().min(8) as u32) as usize;
            let mut batch = Batch::new().threads(threads);
            if let Some(dir) = args.get("cache") {
                batch = batch.cache_dir(dir);
            }
            let results = batch.execute(&specs);
            let mut failed = 0usize;
            for (spec, res) in specs.iter().zip(&results) {
                match res {
                    Ok(j) => println!(
                        "{:<20} ok  R={} {:?} LUT {}  {:.3} ns  {:.1} um2{}",
                        spec.label(),
                        j.lookup_bits,
                        j.implementation.degree,
                        j.implementation.lut_width_label(),
                        j.synth.delay_ns,
                        j.synth.area_um2,
                        j.verify
                            .as_ref()
                            .map(|r| format!("  verified {}", r.total))
                            .unwrap_or_default()
                    ),
                    Err(e) => {
                        failed += 1;
                        println!("{:<20} FAILED: {e}", spec.label());
                    }
                }
            }
            println!("batch: {}/{} jobs succeeded", results.len() - failed, results.len());
            // Graceful shutdown: barrier on the process-wide scheduler so
            // no donated worker is still mid-job when the process exits.
            polygen::pipeline::shutdown();
            if failed > 0 {
                Err(format!("{failed} job(s) failed"))
            } else {
                Ok(())
            }
        }
        _ => Err(format!("unknown command {}", args.cmd)),
    }
}

/// One-shot HTTP GET of `/jobs/:id/trace` for `polygen trace JOB_ID` —
/// the same minimal client shape the integration tests use, kept here
/// so the CLI needs no HTTP dependency.
fn fetch_trace(server: &str, id: u64, token: Option<&str>) -> Result<String, String> {
    use std::io::{Read, Write};
    let addr = server.trim_start_matches("http://").trim_end_matches('/');
    let mut stream =
        std::net::TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let auth =
        token.map(|t| format!("Authorization: Bearer {t}\r\n")).unwrap_or_default();
    let req = format!(
        "GET /jobs/{id}/trace HTTP/1.1\r\nHost: {addr}\r\n{auth}\
         Content-Length: 0\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw).map_err(|e| e.to_string())?;
    let (head, body) = raw.split_once("\r\n\r\n").ok_or("malformed response")?;
    let code = head.split_whitespace().nth(1).unwrap_or("");
    if code != "200" {
        return Err(format!("server replied {code}: {body}"));
    }
    Ok(body.to_string())
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            if e == "no command" {
                return usage();
            }
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
