//! Tiny flag parser for the `polygen` binary: `--key value`, bare
//! `--switch`, repeated flags, and positionals (clap is not available
//! offline).
//!
//! Grammar: a token starting with `--` opens a flag; the next token
//! becomes its value unless that token also starts with `--` (so a bare
//! switch must be followed by another flag or the end of the line —
//! a positional right after a switch is consumed as the switch's value;
//! put positionals first, as `polygen report table1 --deep` does).

/// Parsed command line: `polygen <cmd> [positionals] [--flags]`.
pub struct Args {
    pub cmd: String,
    pub positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    /// Parse the process's own arguments; `None` when no subcommand was
    /// given.
    pub fn parse() -> Option<Args> {
        Args::from_tokens(std::env::args().skip(1).collect())
    }

    /// Parse an explicit token list (first token = subcommand).
    pub fn from_tokens(tokens: Vec<String>) -> Option<Args> {
        let mut it = tokens.into_iter();
        let cmd = it.next()?;
        let rest: Vec<String> = it.collect();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < rest.len() {
            if !rest[i].starts_with("--") {
                positional.push(rest[i].clone());
                i += 1;
                continue;
            }
            let k = rest[i].trim_start_matches('-').to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                flags.push((k, Some(rest[i + 1].clone())));
                i += 2;
            } else {
                flags.push((k, None));
                i += 1;
            }
        }
        Some(Args { cmd, positional, flags })
    }

    /// First value of `--key value` (bare switches yield `None`).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_deref())
    }

    /// Every value of a repeated flag, e.g. `--set a=1 --set b=2`.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    /// Whether the flag appeared at all (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    /// Parse a flag's value as `u32`, falling back to `default` when the
    /// flag is absent, valueless, or unparsable.
    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Like [`Args::u32_or`] but `u64` (byte counts, TTLs in seconds).
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Like [`Args::u32_or`] but `f64` (rates, fractional timeouts).
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::from_tokens(tokens.iter().map(|s| s.to_string()).collect()).unwrap()
    }

    #[test]
    fn empty_command_line_is_none() {
        assert!(Args::from_tokens(Vec::new()).is_none());
    }

    #[test]
    fn flags_values_and_positionals() {
        let a = parse(&["report", "table1", "--threads", "8", "--deep"]);
        assert_eq!(a.cmd, "report");
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.get("threads"), Some("8"));
        assert_eq!(a.u32_or("threads", 4), 8);
        assert!(a.has("deep"));
        assert_eq!(a.get("deep"), None, "bare switch has no value");
    }

    #[test]
    fn repeated_flags_accumulate_in_order() {
        let a = parse(&["config", "--file", "j.toml", "--set", "a=1", "--set", "b=2"]);
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2"]);
        // `get` returns the first occurrence.
        assert_eq!(a.get("set"), Some("a=1"));
        assert_eq!(a.get_all("missing"), Vec::<&str>::new());
    }

    #[test]
    fn switch_followed_by_flag_stays_bare() {
        let a = parse(&["dse", "--quadratic", "--func", "recip"]);
        assert!(a.has("quadratic"));
        assert_eq!(a.get("quadratic"), None);
        assert_eq!(a.get("func"), Some("recip"));
    }

    #[test]
    fn switch_followed_by_positional_consumes_it() {
        // Documented sharp edge: the parser cannot know `--deep` takes no
        // value, so a trailing positional is captured as its value.
        // Positionals must precede switches.
        let a = parse(&["report", "--deep", "table1"]);
        assert_eq!(a.get("deep"), Some("table1"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn missing_or_malformed_values_fall_back() {
        let a = parse(&["generate", "--bits"]);
        assert!(a.has("bits"));
        assert_eq!(a.get("bits"), None);
        assert_eq!(a.u32_or("bits", 10), 10);
        let a = parse(&["generate", "--bits", "many"]);
        assert_eq!(a.u32_or("bits", 10), 10, "unparsable value falls back");
        assert_eq!(a.u32_or("absent", 7), 7);
    }

    #[test]
    fn wide_and_float_variants_parse_and_fall_back() {
        let a = parse(&["serve", "--store-max-bytes", "1048576", "--rate-limit", "2.5"]);
        assert_eq!(a.u64_or("store-max-bytes", 0), 1_048_576);
        assert_eq!(a.f64_or("rate-limit", 0.0), 2.5);
        assert_eq!(a.u64_or("absent", 9), 9);
        assert_eq!(a.f64_or("absent", 1.5), 1.5);
        let a = parse(&["serve", "--rate-limit", "fast"]);
        assert_eq!(a.f64_or("rate-limit", 0.25), 0.25);
    }

    #[test]
    fn single_dash_tokens_are_positionals() {
        let a = parse(&["report", "-deep"]);
        assert_eq!(a.positional, vec!["-deep"]);
        assert!(!a.has("deep"));
    }
}
