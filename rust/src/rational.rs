//! Exact rational arithmetic on `i128`.
//!
//! Every feasibility decision in the design-space generator (Eqns 1–10 of
//! the paper) is a comparison between divided differences — ratios of
//! integers. Floating point would silently mis-classify boundary cases, so
//! all of `designspace` works in exact rationals. Magnitudes are small
//! (numerators ≲ 2^70, denominators ≲ 2^24 even for 23-bit designs), so a
//! reduced `i128` fraction never overflows; the arithmetic is checked, so
//! an operand beyond that envelope fails loudly instead of wrapping, and
//! comparisons stay exact for the full `i128` domain by widening to
//! 256-bit cross products.

use std::cmp::Ordering;
use std::fmt;

/// A reduced fraction `num/den` with `den > 0`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rat {
    num: i128,
    den: i128,
}

/// Greatest common divisor (non-negative inputs, `gcd(0, 0) = 0`).
pub fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Rat {
    pub const ZERO: Rat = Rat { num: 0, den: 1 };
    pub const ONE: Rat = Rat { num: 1, den: 1 };

    /// Construct and reduce. Panics on zero denominator.
    pub fn new(num: i128, den: i128) -> Rat {
        assert!(den != 0, "Rat with zero denominator");
        let (num, den) = if den < 0 {
            (
                num.checked_neg().expect("Rat sign flip overflow"),
                den.checked_neg().expect("Rat sign flip overflow"),
            )
        } else {
            (num, den)
        };
        let g = gcd(num, den);
        if g == 0 {
            return Rat { num: 0, den: 1 };
        }
        Rat { num: num / g, den: den / g }
    }

    pub fn int(v: i128) -> Rat {
        Rat { num: v, den: 1 }
    }

    pub fn num(&self) -> i128 {
        self.num
    }

    pub fn den(&self) -> i128 {
        self.den
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    /// Largest integer `<= self`.
    pub fn floor(&self) -> i128 {
        self.num.div_euclid(self.den)
    }

    /// Smallest integer `>= self`.
    pub fn ceil(&self) -> i128 {
        -((-self.num).div_euclid(self.den))
    }

    pub fn add(&self, o: &Rat) -> Rat {
        let l = self.num.checked_mul(o.den).expect("Rat add overflow");
        let r = o.num.checked_mul(self.den).expect("Rat add overflow");
        let den = self.den.checked_mul(o.den).expect("Rat add overflow");
        Rat::new(l.checked_add(r).expect("Rat add overflow"), den)
    }

    pub fn sub(&self, o: &Rat) -> Rat {
        let l = self.num.checked_mul(o.den).expect("Rat sub overflow");
        let r = o.num.checked_mul(self.den).expect("Rat sub overflow");
        let den = self.den.checked_mul(o.den).expect("Rat sub overflow");
        Rat::new(l.checked_sub(r).expect("Rat sub overflow"), den)
    }

    pub fn mul(&self, o: &Rat) -> Rat {
        // Cross-reduce before multiplying to keep intermediates small.
        let g1 = gcd(self.num, o.den).max(1);
        let g2 = gcd(o.num, self.den).max(1);
        let num = (self.num / g1).checked_mul(o.num / g2).expect("Rat mul overflow");
        let den = (self.den / g2).checked_mul(o.den / g1).expect("Rat mul overflow");
        Rat::new(num, den)
    }

    pub fn div(&self, o: &Rat) -> Rat {
        assert!(o.num != 0, "Rat division by zero");
        self.mul(&Rat::new(o.den, o.num))
    }

    pub fn neg(&self) -> Rat {
        Rat { num: self.num.checked_neg().expect("Rat neg overflow"), den: self.den }
    }

    /// Multiply by `2^k` exactly.
    pub fn shl(&self, k: u32) -> Rat {
        assert!(k < 127, "Rat shl shift out of range");
        Rat::new(self.num.checked_mul(1i128 << k).expect("Rat shl overflow"), self.den)
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// Exact comparison by cross multiplication.
    ///
    /// With reduced operands from this crate's workloads the products fit
    /// `i128` (the fast path); if they do not, the comparison widens to
    /// exact 256-bit magnitudes instead of wrapping, so ordering is
    /// correct for the full `i128` domain in release builds too.
    pub fn cmp_rat(&self, o: &Rat) -> Ordering {
        match (self.num.checked_mul(o.den), o.num.checked_mul(self.den)) {
            (Some(l), Some(r)) => l.cmp(&r),
            _ => {
                // Both dens > 0, so the product signs are the num signs:
                // different signs decide immediately, equal signs compare
                // 256-bit magnitudes (reversed for negatives).
                let (a, b) = (self.num, o.num);
                if a.signum() != b.signum() {
                    return a.signum().cmp(&b.signum());
                }
                let la = crate::wide::U256::mul_u128(a.unsigned_abs(), o.den as u128);
                let rb = crate::wide::U256::mul_u128(b.unsigned_abs(), self.den as u128);
                let ord = la.cmp256(&rb);
                if a >= 0 {
                    ord
                } else {
                    ord.reverse()
                }
            }
        }
    }

    pub fn lt(&self, o: &Rat) -> bool {
        self.cmp_rat(o) == Ordering::Less
    }

    pub fn le(&self, o: &Rat) -> bool {
        self.cmp_rat(o) != Ordering::Greater
    }

    pub fn min_rat(self, o: Rat) -> Rat {
        if o.lt(&self) {
            o
        } else {
            self
        }
    }

    pub fn max_rat(self, o: Rat) -> Rat {
        if self.lt(&o) {
            o
        } else {
            self
        }
    }
}

impl PartialOrd for Rat {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp_rat(other))
    }
}

impl Ord for Rat {
    fn cmp(&self, other: &Self) -> Ordering {
        self.cmp_rat(other)
    }
}

impl fmt::Debug for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl fmt::Display for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_and_sign() {
        let r = Rat::new(4, -8);
        assert_eq!((r.num(), r.den()), (-1, 2));
        assert_eq!(Rat::new(0, -5), Rat::ZERO);
    }

    #[test]
    fn floor_ceil_negative() {
        assert_eq!(Rat::new(-7, 2).floor(), -4);
        assert_eq!(Rat::new(-7, 2).ceil(), -3);
        assert_eq!(Rat::new(7, 2).floor(), 3);
        assert_eq!(Rat::new(7, 2).ceil(), 4);
        assert_eq!(Rat::new(-8, 2).floor(), -4);
        assert_eq!(Rat::new(-8, 2).ceil(), -4);
    }

    #[test]
    fn arithmetic() {
        let a = Rat::new(1, 3);
        let b = Rat::new(1, 6);
        assert_eq!(a.add(&b), Rat::new(1, 2));
        assert_eq!(a.sub(&b), Rat::new(1, 6));
        assert_eq!(a.mul(&b), Rat::new(1, 18));
        assert_eq!(a.div(&b), Rat::int(2));
        assert_eq!(a.shl(3), Rat::new(8, 3));
    }

    #[test]
    fn ordering() {
        assert!(Rat::new(1, 3).lt(&Rat::new(2, 5)));
        assert!(Rat::new(-1, 3).lt(&Rat::ZERO));
        assert_eq!(Rat::new(2, 4).cmp_rat(&Rat::new(1, 2)), Ordering::Equal);
        assert_eq!(Rat::new(5, 3).min_rat(Rat::new(3, 2)), Rat::new(3, 2));
        assert_eq!(Rat::new(5, 3).max_rat(Rat::new(3, 2)), Rat::new(5, 3));
    }

    #[test]
    fn ordering_survives_cross_product_overflow() {
        // Cross products of these need >127 bits; the wide path must
        // still order exactly, for every sign combination.
        let big = (1i128 << 100) + 1; // odd: no reduction possible
        let a = Rat::new(big, 1 << 30);
        let b = Rat::new(1 << 100, (1 << 30) - 1);
        // a < b  <=>  (2^100+1)(2^30-1) < 2^130  <=>  2^30 - 1 < 2^100.
        assert!(a.lt(&b));
        assert!(!b.lt(&a));
        assert!(a.neg().cmp_rat(&b.neg()) == Ordering::Greater);
        assert!(a.neg().lt(&b));
        assert!(b.neg().lt(&a));
        assert_eq!(a.cmp_rat(&a), Ordering::Equal);
        assert_eq!(a.neg().cmp_rat(&a.neg()), Ordering::Equal);
    }

    #[test]
    fn checked_arithmetic_works_at_the_boundary() {
        // Large-but-representable operands still compute exactly.
        let big = Rat::int(1i128 << 125);
        assert_eq!(big.add(&big), Rat::int(1i128 << 126));
        assert_eq!(Rat::int(1i128 << 63).mul(&Rat::int(1i128 << 63)), Rat::int(1i128 << 126));
        assert_eq!(Rat::new(1, 1 << 30).shl(126), Rat::int(1i128 << 96));
        assert_eq!(Rat::int(i128::MAX).neg(), Rat::int(-i128::MAX));
        assert_eq!(Rat::new(i128::MAX, -1), Rat::int(-i128::MAX));
    }

    #[test]
    #[should_panic(expected = "Rat add overflow")]
    fn add_overflow_is_loud() {
        let _ = Rat::int(i128::MAX).add(&Rat::ONE);
    }

    #[test]
    #[should_panic(expected = "Rat shl overflow")]
    fn shl_overflow_is_loud() {
        let _ = Rat::int(1i128 << 100).shl(30);
    }

    #[test]
    #[should_panic(expected = "shift out of range")]
    fn shl_rejects_out_of_range_shift() {
        let _ = Rat::ONE.shl(127);
    }

    #[test]
    fn gcd_edge_cases() {
        assert_eq!(gcd(0, 0), 0);
        assert_eq!(gcd(0, 7), 7);
        assert_eq!(gcd(-12, 18), 6);
    }
}
