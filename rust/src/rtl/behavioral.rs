//! Behavioural reference RTL (paper §IV).
//!
//! "For the reciprocal function, behavioural RTL producing both Round to
//! Zero and Round to +inf can be written using only integer operations" —
//! the paper checks the generated design against these references with
//! HECTOR. We emit the same behavioural modules and, in place of formal
//! equivalence, prove by exhaustive simulation (`verify::` and
//! [`behavioral_bounds_ok`]) that the generated output always lies between
//! the two roundings — which is exactly the 1-ULP containment HECTOR
//! certified.

use crate::bounds::TargetFunction;

/// Behavioural reciprocal: `y = round(2^(m+q+1) / (2^m + z)) - 2^q`,
/// computed in the given direction with pure integer ops.
pub fn recip_behavioral(z: u64, in_bits: u32, out_bits: u32, round_up: bool) -> i64 {
    let num: u128 = 1u128 << (in_bits + out_bits + 1);
    let den: u128 = (1u128 << in_bits) + z as u128;
    let q = if round_up { num.div_ceil(den) } else { num / den };
    let out_max = (1i64 << out_bits) - 1;
    (q as i64 - (1i64 << out_bits)).clamp(0, out_max)
}

/// Emit the behavioural Verilog for reciprocal (both roundings), the
/// reference the paper verifies against.
pub fn emit_recip_behavioral(in_bits: u32, out_bits: u32) -> String {
    let w = in_bits;
    let q = out_bits;
    let nw = in_bits + out_bits + 2;
    format!(
        r#"// Behavioural reciprocal reference (polygen): integer-only RTZ / R+inf.
module recip_behavioral #(parameter ROUND_UP = 0) (
  input  wire [{wm1}:0] z,
  output wire [{qm1}:0] y
);
  wire [{nw}:0] num = {{1'b1, {{{nwm}{{1'b0}}}}}};      // 2^(m+q+1)
  wire [{w}:0]  den = {{1'b1, z}};                 // 2^m + z
  wire [{nw}:0] quo = ROUND_UP ? (num + den - 1) / den : num / den;
  wire [{nw}:0] off = quo - (1 << {q});
  assign y = (quo <= (1 << {q})) ? {{{q}{{1'b0}}}} :
             (off > {{{q}{{1'b1}}}}) ? {{{q}{{1'b1}}}} : off[{qm1}:0];
endmodule
"#,
        wm1 = w - 1,
        qm1 = q - 1,
        nw = nw,
        nwm = nw,
        w = w,
        q = q,
    )
}

/// Exhaustive check that a generated implementation's output lies between
/// RTZ and R+inf behavioural outputs (1-ULP containment; the HECTOR claim
/// for the reciprocal).
pub fn recip_between_roundings(
    im: &crate::dse::Implementation,
) -> Result<(), (u64, i64, i64, i64)> {
    assert_eq!(im.func, "recip");
    for z in 0..(1u64 << im.in_bits) {
        let lo = recip_behavioral(z, im.in_bits, im.out_bits, false) - 1;
        let hi = recip_behavioral(z, im.in_bits, im.out_bits, true) + 1;
        let y = im.eval(z);
        if y < lo || y > hi {
            return Err((z, y, lo, hi));
        }
    }
    Ok(())
}

/// For log2/exp2 the paper "verified that the hardware generated a result
/// between our Python generated bounds using HECTOR" — here: exhaustively
/// against the exact Rust bound functions.
pub fn behavioral_bounds_ok(f: &dyn TargetFunction, im: &crate::dse::Implementation) -> bool {
    let acc = crate::bounds::AccuracySpec::Ulp(1);
    let out_max = (1i64 << f.out_bits()) - 1;
    (0..(1u64 << f.in_bits())).all(|z| {
        let (fl, ex) = f.floor_y(z);
        let (lo, hi) = acc.bounds_of_floor(fl, ex);
        let (lo, hi) = (lo.clamp(0, out_max), hi.clamp(0, out_max));
        let y = im.eval(z);
        y >= lo && y <= hi
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};
    use crate::dse::{explore, DseOptions};

    #[test]
    fn behavioral_roundings_bracket_exact() {
        let f = builtin("recip", 10).unwrap();
        for z in 0..(1u64 << 10) {
            let down = recip_behavioral(z, 10, 10, false);
            let up = recip_behavioral(z, 10, 10, true);
            assert!(down <= up);
            assert!(up - down <= 1);
            let y = f.y_f64(z);
            // down = floor clamped, up = ceil clamped.
            assert!((down as f64) <= y + 1e-9 || down == (1 << 10) - 1);
        }
    }

    #[test]
    fn generated_recip_between_roundings() {
        let f = builtin("recip", 10).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: 5, ..Default::default() }).unwrap();
        let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
        recip_between_roundings(&im).unwrap();
    }

    #[test]
    fn log2_exp2_within_python_bounds_analogue() {
        for name in ["log2", "exp2"] {
            let f = builtin(name, 10).unwrap();
            let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
            let ds =
                generate(&bt, &GenOptions { lookup_bits: 5, ..Default::default() }).unwrap();
            let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
            assert!(behavioral_bounds_ok(f.as_ref(), &im), "{name}");
        }
    }

    #[test]
    fn behavioral_verilog_smoke() {
        let v = emit_recip_behavioral(16, 16);
        assert!(v.contains("module recip_behavioral"));
        assert!(v.contains("parameter ROUND_UP"));
    }
}
