//! RTL generation: Verilog emitter, LUT word encoding, bit-accurate
//! netlist-level simulation, and behavioural references (paper §IV).

pub mod behavioral;
pub mod encode;
pub mod sim;
pub mod verilog;

pub use sim::DatapathSim;
pub use verilog::{emit_golden_hex, emit_module, emit_testbench};
