//! LUT word encoding: pack the selected coefficients into the stored
//! per-region word exactly as the emitted RTL does, and decode them back.
//!
//! This round trip is where width bugs would bite (an Algorithm 1 result
//! one bit too narrow silently corrupts a coefficient), so it is explicit,
//! checked, and exercised by both the RTL simulator and property tests.

use crate::dse::precision::{Encoding, Sign};
use crate::dse::{Coeffs, Degree, Implementation};

/// Encode `v` into its stored field under `enc`. Panics if inadmissible
/// (the DSE guarantees admissibility for selected coefficients).
pub fn encode_field(enc: &Encoding, v: i64) -> u64 {
    assert!(enc.admits(v), "value {v} not admissible under {enc:?}");
    if enc.width == 0 {
        return 0;
    }
    let mag = (v.unsigned_abs() >> enc.trunc) as u64;
    match enc.sign {
        Sign::NonNeg | Sign::NonPos => mag,
        Sign::Signed => {
            // Two's complement in `width` bits.
            let w = enc.width;
            ((v >> enc.trunc) as u64) & ((1u64 << w) - 1)
        }
    }
}

/// Decode a stored field back to the coefficient value.
pub fn decode_field(enc: &Encoding, field: u64) -> i64 {
    if enc.width == 0 {
        return 0;
    }
    debug_assert!(field < (1u64 << enc.width));
    match enc.sign {
        Sign::NonNeg => (field as i64) << enc.trunc,
        Sign::NonPos => -((field as i64) << enc.trunc),
        Sign::Signed => {
            let w = enc.width;
            let signed = if field & (1u64 << (w - 1)) != 0 {
                field as i64 - (1i64 << w)
            } else {
                field as i64
            };
            signed << enc.trunc
        }
    }
}

/// One packed LUT word: `{a_field, b_field, c_field}` (a in the MSBs).
pub fn pack_word(im: &Implementation, co: &Coeffs) -> u64 {
    let (wa, wb, wc) = field_widths(im);
    let a = if wa == 0 { 0 } else { encode_field(&im.enc_a, co.a) };
    let b = encode_field(&im.enc_b, co.b);
    let c = encode_field(&im.enc_c, co.c);
    (a << (wb + wc)) | (b << wc) | c
}

/// Unpack a LUT word into `(a, b, c)` coefficient values.
pub fn unpack_word(im: &Implementation, word: u64) -> Coeffs {
    let (_wa, wb, wc) = field_widths(im);
    let c = decode_field(&im.enc_c, word & ((1u64 << wc) - 1).max(0));
    let b = decode_field(&im.enc_b, (word >> wc) & mask(wb));
    let a = if im.degree == Degree::Linear {
        0
    } else {
        decode_field(&im.enc_a, word >> (wb + wc))
    };
    Coeffs { a, b, c }
}

fn mask(w: u32) -> u64 {
    if w == 0 {
        0
    } else {
        (1u64 << w) - 1
    }
}

/// Stored field widths `(a, b, c)`; the `a` field is absent for linear
/// designs.
pub fn field_widths(im: &Implementation) -> (u32, u32, u32) {
    let wa = if im.degree == Degree::Linear { 0 } else { im.enc_a.width };
    (wa, im.enc_b.width, im.enc_c.width)
}

/// The full encoded LUT contents, one word per region.
pub fn lut_words(im: &Implementation) -> Vec<u64> {
    im.coeffs.iter().map(|co| pack_word(im, co)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};
    use crate::dse::{explore, DseOptions};
    use crate::testutil::for_each_seed;

    #[test]
    fn field_roundtrip_all_signs() {
        for_each_seed(50, |rng| {
            let trunc = rng.below(4) as u32;
            let width = 1 + rng.below(10) as u32;
            for sign in [Sign::NonNeg, Sign::NonPos, Sign::Signed] {
                let enc = Encoding { trunc, width, sign };
                for _ in 0..20 {
                    let raw = rng.range_i64(-(1 << 12), 1 << 12);
                    let v = (raw >> trunc) << trunc;
                    if enc.admits(v) {
                        let f = encode_field(&enc, v);
                        assert!(f < (1u64 << width) || width == 0);
                        assert_eq!(decode_field(&enc, f), v, "enc={enc:?} v={v}");
                    }
                }
            }
        });
    }

    #[test]
    fn lut_words_roundtrip_real_design() {
        for (name, bits, r) in [("recip", 10u32, 5u32), ("log2", 10, 6), ("exp2", 10, 4)] {
            let f = builtin(name, bits).unwrap();
            let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
            let ds =
                generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() }).unwrap();
            let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
            let words = lut_words(&im);
            let (wa, wb, wc) = field_widths(&im);
            for (i, &w) in words.iter().enumerate() {
                assert!(w < (1u64 << (wa + wb + wc)).max(1), "{name} word too wide");
                let co = unpack_word(&im, w);
                assert_eq!(co, im.coeffs[i], "{name} region {i}");
            }
        }
    }
}
