//! Bit-accurate simulator of the *emitted RTL* semantics.
//!
//! Unlike [`Implementation::eval`] (which evaluates the selected
//! coefficients directly), `DatapathSim` goes the long way round, exactly
//! as the hardware does: pack the LUT words, index by `r`, extract and
//! sign-extend the stored fields, evaluate in width-checked integer
//! arithmetic, arithmetic-shift, and truncate to the output width. Every
//! intermediate is asserted to fit its declared RTL width, so an
//! under-sized accumulator or LUT field fails loudly here (and in the
//! exhaustive equivalence test) rather than silently in synthesis.

use super::encode::{field_widths, lut_words, unpack_word};
use crate::dse::{Degree, Implementation};

/// The "netlist-level" model of one generated interpolator.
pub struct DatapathSim {
    im: Implementation,
    lut: Vec<u64>,
    wa: u32,
    wb: u32,
    wc: u32,
}

impl DatapathSim {
    pub fn new(im: &Implementation) -> DatapathSim {
        let lut = lut_words(im);
        let (wa, wb, wc) = field_widths(im);
        DatapathSim { im: im.clone(), lut, wa, wb, wc }
    }

    /// Stored LUT word width.
    pub fn word_width(&self) -> u32 {
        self.wa + self.wb + self.wc
    }

    /// Evaluate one input through the hardware model. Panics on any
    /// declared-width overflow (none exist for DSE-produced designs).
    pub fn eval(&self, z: u64) -> i64 {
        let im = &self.im;
        let xbits = im.x_bits();
        let r = (z >> xbits) as usize;
        let x = z & ((1u64 << xbits) - 1);

        // LUT access and field decode — through the packed word.
        let word = self.lut[r];
        assert!(word < (1u128 << self.word_width().max(1)) as u64);
        let co = unpack_word(im, word);

        // Square path.
        let acc: i128 = if im.degree == Degree::Quadratic {
            let xs = x >> im.sq_trunc; // xs_bits wide
            let xs_bits = xbits - im.sq_trunc;
            assert!(xs < (1u64 << xs_bits.max(1)));
            let sq = (xs as i128) * (xs as i128); // 2*xs_bits wide
            assert!(sq < (1i128 << (2 * xs_bits).max(1)));
            let prod_a = co.a as i128 * sq; // wa + 2*xs_bits (+sign)
            let xl = (x >> im.lin_trunc) as i128;
            let prod_b = co.b as i128 * xl;
            (prod_a << (2 * im.sq_trunc)) + (prod_b << im.lin_trunc) + co.c as i128
        } else {
            let xl = (x >> im.lin_trunc) as i128;
            ((co.b as i128 * xl) << im.lin_trunc) + co.c as i128
        };

        // Accumulator width check mirrors the emitted declaration.
        let xs_bits = xbits - im.sq_trunc;
        let xl_bits = xbits - im.lin_trunc;
        let acc_w = (2 * xs_bits + self.wa + 2 + 2 * im.sq_trunc)
            .max(self.wb + xl_bits + 2 + im.lin_trunc)
            .max(self.wc + im.enc_c.trunc + 2)
            + 2;
        assert!(
            acc.unsigned_abs() < (1u128 << acc_w),
            "accumulator overflow: |{acc}| >= 2^{acc_w}"
        );

        // Output saturation stage, then the out_bits-wide bus.
        let y = (acc >> im.k) as i64;
        y.clamp(0, (1i64 << im.out_bits) - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};
    use crate::dse::{explore, DseOptions};

    #[test]
    fn sim_equals_eval_exhaustively() {
        for (name, bits, r) in [
            ("recip", 10u32, 5u32),
            ("recip", 10, 4),
            ("log2", 10, 6),
            ("exp2", 10, 4),
            ("sqrt", 10, 4),
            ("recip", 8, 4),
        ] {
            let f = builtin(name, bits).unwrap();
            let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
            let Ok(ds) = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
            else {
                continue;
            };
            let im = explore(&bt, &ds, &DseOptions::default()).unwrap();
            let sim = DatapathSim::new(&im);
            for z in 0..(1u64 << bits) {
                assert_eq!(sim.eval(z), im.eval(z), "{name}/{bits} R={r} z={z}");
            }
        }
    }
}
