//! Experiment report generators — one per paper table/figure (DESIGN.md
//! §4). Each returns a rendered text table (and optionally CSV) and is
//! driven both by the `polygen report` CLI and by the `cargo bench`
//! harnesses that regenerate the paper's evaluation.

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use crate::baselines::dw_family;
use crate::baselines::flopoco::flopoco_like;
use crate::bounds::AccuracySpec;
use crate::coordinator::{default_r_range, LubObjective, Workload};
use crate::designspace::extrema::SearchStrategy;
use crate::designspace::{generate, generate_eager, min_lookup_bits, GenOptions};
use crate::dse::{explore, Degree, DseOptions};
use crate::pipeline::Pipeline;
use crate::synth::{sweep as synth_sweep, synth_min_delay_with};
use crate::tech::TechKind;

/// Simple timing helper for the bench harnesses (criterion is not
/// available offline): median of `reps` runs plus the result of the last.
pub fn time_median<T>(reps: usize, mut f: impl FnMut() -> T) -> (Duration, T) {
    assert!(reps >= 1);
    let mut times = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        last = Some(f());
        times.push(t0.elapsed());
    }
    times.sort();
    (times[times.len() / 2], last.unwrap())
}

fn fmt_dur(d: Duration) -> String {
    if d.as_secs_f64() >= 1.0 {
        format!("{:.2} s", d.as_secs_f64())
    } else {
        format!("{:.1} ms", d.as_secs_f64() * 1e3)
    }
}

/// Table I: logic synthesis at the minimum obtainable delay target,
/// proposed (best-ADP LUB) vs the DesignWare-like family.
///
/// `sizes`: (function, bits) pairs; paper defaults are
/// recip {10,16,23}, log2 {10,16,23}, exp2 {10,16} — 23-bit runs take
/// hours (the paper's own scaling wall) and sit behind `--deep`.
pub fn table1(sizes: &[(&str, u32)], threads: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "TABLE I — minimum-delay synthesis, proposed vs DesignWare-like (cost-model units)"
    );
    let _ = writeln!(
        out,
        "{:<8} {:>4} | {:>9} {:>9} | {:>8} {:>9} {:>10} | {:>8} {:>9} {:>10} | {:>6}",
        "func", "bits", "runtime", "LUB", "delay", "area", "area*delay", "dw_delay",
        "dw_area", "dw_a*d", "ratio"
    );
    let mut adp_ratios = Vec::new();
    for &(name, bits) in sizes {
        let t0 = Instant::now();
        let swept = Pipeline::function(name).bits(bits).threads(threads).sweep().unwrap();
        let runtime = t0.elapsed();
        let Some(best) = swept.best(LubObjective::AreaDelay) else {
            let _ = writeln!(out, "{name:<8} {bits:>4} | infeasible in sweep range");
            continue;
        };
        let im = best.implementation.as_ref().unwrap();
        let p = best.synth.unwrap();
        let lub = format!(
            "{} ({})",
            best.lookup_bits,
            if im.degree == Degree::Linear { "lin" } else { "quad" }
        );
        let fam = dw_family(swept.workload.func.as_ref());
        let dw = fam.min_delay_point();
        let (dws, ratio) = match dw {
            Some((dp, _)) => {
                let r = p.area_delay() / dp.area_delay();
                adp_ratios.push(r);
                (
                    format!("{:>8.3} {:>9.1} {:>10.1}", dp.delay_ns, dp.area_um2, dp.area_delay()),
                    format!("{r:>6.2}"),
                )
            }
            None => (format!("{:>8} {:>9} {:>10}", "-", "-", "-"), "     -".into()),
        };
        let _ = writeln!(
            out,
            "{:<8} {:>4} | {:>9} {:>9} | {:>8.3} {:>9.1} {:>10.1} | {} | {}",
            name,
            bits,
            fmt_dur(runtime),
            lub,
            p.delay_ns,
            p.area_um2,
            p.area_delay(),
            dws,
            ratio
        );
    }
    if !adp_ratios.is_empty() {
        let geo = adp_ratios.iter().map(|r| r.ln()).sum::<f64>() / adp_ratios.len() as f64;
        let _ = writeln!(
            out,
            "geomean area-delay ratio (proposed / DW-like): {:.3}  (paper Table I rows: ~0.84)",
            geo.exp()
        );
    }
    out
}

/// Table II: stored LUT field widths `[a, b, c] = total` vs the
/// FloPoCo-like generator at equal LUT height, forced quadratic.
pub fn table2(cases: &[(&str, u32, u32)]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "TABLE II — LUT widths vs FloPoCo-like, equal height, quadratic");
    let _ = writeln!(
        out,
        "{:<8} {:>4} {:>4} | {:>18} | {:>18}",
        "func", "bits", "LUB", "FloPoCo-like", "Proposed"
    );
    for &(name, bits, lub) in cases {
        let prepared = Pipeline::function(name)
            .bits(bits)
            .lub(lub)
            .degree(Degree::Quadratic)
            .prepare()
            .unwrap();
        let fp = flopoco_like(prepared.workload.func.as_ref(), lub, Degree::Quadratic);
        let ours = prepared
            .generate()
            .and_then(|spaced| spaced.explore())
            .map(|explored| explored.implementation)
            .ok();
        let fps = fp.map(|im| im.lut_width_label()).unwrap_or_else(|| "-".into());
        let os = ours.map(|im| im.lut_width_label()).unwrap_or_else(|| "-".into());
        let _ = writeln!(out, "{name:<8} {bits:>4} {lub:>4} | {fps:>18} | {os:>18}");
    }
    out
}

/// Fig. 2: full area-delay profiles, proposed (fixed LUB) vs the
/// DesignWare-like family re-selected per delay target. Returns
/// `(text, csv)`.
pub fn fig2(name: &str, bits: u32, lub: u32, npoints: usize) -> (String, String) {
    let explored = Pipeline::function(name)
        .bits(bits)
        .lub(lub)
        .prepare()
        .unwrap()
        .generate()
        .unwrap_or_else(|e| panic!("{name}/{bits}: {e}"))
        .explore()
        .unwrap_or_else(|e| panic!("{name}/{bits}: {e}"));
    let ours = synth_sweep(&explored.implementation, npoints, 2.5);
    let fam = dw_family(explored.workload.func.as_ref());

    let mut text = format!(
        "FIG 2 — area-delay profile: {name} {bits}-bit, {lub} lookup bits vs DW-like\n"
    );
    let mut csv = String::from("target_ns,ours_area_um2,dw_area_um2,dw_arch\n");
    let _ = writeln!(
        text,
        "{:>10} {:>12} {:>12} {:>10}",
        "target ns", "ours um2", "dw um2", "dw arch"
    );
    for p in &ours {
        let dw = fam.best_at(p.delay_ns);
        let (dwa, arch) = match &dw {
            Some((dp, dim)) => (
                format!("{:.1}", dp.area_um2),
                format!(
                    "R{}{}",
                    dim.lookup_bits,
                    if dim.degree == Degree::Linear { "l" } else { "q" }
                ),
            ),
            None => ("-".into(), "-".into()),
        };
        let _ = writeln!(text, "{:>10.3} {:>12.1} {:>12} {:>10}", p.delay_ns, p.area_um2, dwa, arch);
        let _ = writeln!(csv, "{:.4},{:.1},{},{}", p.delay_ns, p.area_um2, dwa, arch);
    }
    (text, csv)
}

/// Fig. 3: area-delay points at minimum delay for every feasible LUT
/// height (plus the DW-like reference point). Returns `(text, csv)`.
pub fn fig3(name: &str, bits: u32, threads: usize) -> (String, String) {
    let swept = Pipeline::function(name).bits(bits).threads(threads).sweep().unwrap();
    let pts = &swept.points;
    let mut text = format!("FIG 3 — min-delay area/delay per LUT height: {name} {bits}-bit\n");
    let mut csv = String::from("lub,degree,delay_ns,area_um2,adp,k,lin_feasible\n");
    let _ = writeln!(
        text,
        "{:>4} {:>6} {:>9} {:>10} {:>10} {:>3}",
        "LUB", "deg", "delay ns", "area um2", "a*d", "k"
    );
    for p in pts {
        match (&p.implementation, &p.synth) {
            (Some(im), Some(sp)) => {
                let deg = if im.degree == Degree::Linear { "lin" } else { "quad" };
                let _ = writeln!(
                    text,
                    "{:>4} {:>6} {:>9.3} {:>10.1} {:>10.1} {:>3}",
                    p.lookup_bits, deg, sp.delay_ns, sp.area_um2, sp.area_delay(), im.k
                );
                let _ = writeln!(
                    csv,
                    "{},{},{:.4},{:.1},{:.1},{},{}",
                    p.lookup_bits,
                    deg,
                    sp.delay_ns,
                    sp.area_um2,
                    sp.area_delay(),
                    im.k,
                    p.space.as_ref().map(|d| d.linear_feasible()).unwrap_or(false)
                );
            }
            _ => {
                let _ = writeln!(text, "{:>4} infeasible", p.lookup_bits);
            }
        }
    }
    if let Some((dp, dim)) = dw_family(swept.workload.func.as_ref()).min_delay_point() {
        let _ = writeln!(
            text,
            "{:>4} {:>6} {:>9.3} {:>10.1} {:>10.1}   (DW-like, R{})",
            "DW",
            if dim.degree == Degree::Linear { "lin" } else { "quad" },
            dp.delay_ns,
            dp.area_um2,
            dp.area_delay(),
            dim.lookup_bits
        );
        let _ = writeln!(csv, "dw,{:?},{:.4},{:.1},{:.1},,", dim.degree, dp.delay_ns, dp.area_um2, dp.area_delay());
    }
    (text, csv)
}

/// §II-A Claim II.1 experiment: naive vs pruned generation of the same
/// space; returns the rendered comparison.
pub fn claim_ii1(name: &str, bits: u32, lub: u32, reps: usize) -> String {
    let w = Workload::prepare(name, bits, AccuracySpec::Ulp(1)).unwrap();
    let run = |strategy| {
        let opts = GenOptions { lookup_bits: lub, search: strategy, ..Default::default() };
        // Eager: the claim compares *full-space* generation cost, so the
        // timed quantity must include the entry sweeps, not just the
        // lazy analysis phases.
        time_median(reps, || generate_eager(&w.bt, &opts).expect("feasible workload"))
    };
    let (t_naive, ds_naive) = run(SearchStrategy::Naive);
    let (t_pruned, ds_pruned) = run(SearchStrategy::Pruned);
    assert_eq!(ds_naive.k, ds_pruned.k, "strategies must agree");
    let mut out = String::new();
    let _ = writeln!(out, "CLAIM II.1 — {name} {bits}-bit, R={lub} (median of {reps})");
    let _ = writeln!(
        out,
        "  naive : {:>10}   dd_evals = {}",
        fmt_dur(t_naive),
        ds_naive.dd_evals
    );
    let _ = writeln!(
        out,
        "  pruned: {:>10}   dd_evals = {}",
        fmt_dur(t_pruned),
        ds_pruned.dd_evals
    );
    let _ = writeln!(
        out,
        "  speedup: {:.2}x wall, {:.2}x evaluations (paper: ~5x on 16-bit recip)",
        t_naive.as_secs_f64() / t_pruned.as_secs_f64().max(1e-12),
        ds_naive.dd_evals as f64 / ds_pruned.dd_evals.max(1) as f64
    );
    out
}

/// §II-A runtime-vs-R scaling: measures generation time across `R` and
/// fits both `2^(-aR)` and `R^(-b)` exponents.
pub fn scaling(name: &str, bits: u32, rs: &[u32]) -> String {
    let w = Workload::prepare(name, bits, AccuracySpec::Ulp(1)).unwrap();
    let mut out = format!("SCALING — generation runtime vs R: {name} {bits}-bit\n");
    let mut data = Vec::new();
    for &r in rs {
        let opts = GenOptions { lookup_bits: r, ..Default::default() };
        let t0 = Instant::now();
        // Eager: the paper's runtime-vs-R fit covers complete-space
        // materialization (the lazy path would flatten the curve).
        let res = generate_eager(&w.bt, &opts);
        let dt = t0.elapsed();
        let _ = writeln!(
            out,
            "  R={r:>2}: {:>10}  {}",
            fmt_dur(dt),
            if res.is_ok() { "ok" } else { "infeasible" }
        );
        if res.is_ok() {
            data.push((r as f64, dt.as_secs_f64()));
        }
    }
    if data.len() >= 2 {
        // log t = a + b*log R  and  log t = a' + b'*R.
        let fit = |xs: &[f64], ys: &[f64]| -> f64 {
            let n = xs.len() as f64;
            let sx: f64 = xs.iter().sum();
            let sy: f64 = ys.iter().sum();
            let sxx: f64 = xs.iter().map(|x| x * x).sum();
            let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
            (n * sxy - sx * sy) / (n * sxx - sx * sx)
        };
        let logt: Vec<f64> = data.iter().map(|d| d.1.ln()).collect();
        let logr: Vec<f64> = data.iter().map(|d| d.0.ln()).collect();
        let rlin: Vec<f64> = data.iter().map(|d| d.0).collect();
        let _ = writeln!(
            out,
            "  fit: t ~ R^({:.2})   |   t ~ 2^({:.2} R)   (paper reports ~R^-3 empirically)",
            fit(&logr, &logt),
            fit(&rlin, &logt) / std::f64::consts::LN_2
        );
    }
    out
}

/// Technology comparison: the SAME complete design space explored by
/// each shipped technology's default decision procedure and costed by
/// its own model — the paper's closing claim ("targeting alternative
/// hardware technologies simply requires a modified decision procedure")
/// as a table. Rows where the selection differs from `asic-ge` are
/// marked `*`.
pub fn tech_table(cases: &[(&str, u32, u32)]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "TECH — one design space, per-technology procedures (areas in native units)"
    );
    let _ = writeln!(
        out,
        "{:<8} {:>4} {:>3} | {:<10} {:<12} {:>4} {:>2} {:>2} {:>14} | {:>9} {:>12}",
        "func", "bits", "R", "tech", "procedure", "deg", "i", "j", "LUT [a,b,c]", "delay ns",
        "area"
    );
    for &(name, bits, lub) in cases {
        let prepared = match Pipeline::function(name).bits(bits).lub(lub).prepare() {
            Ok(p) => p,
            Err(e) => {
                let _ = writeln!(out, "{name:<8} {bits:>4} {lub:>3} | {e}");
                continue;
            }
        };
        let (bt, opts) = (
            &prepared.workload.bt,
            GenOptions { lookup_bits: lub, ..Default::default() },
        );
        let ds = match generate(bt, &opts) {
            Ok(ds) => ds,
            Err(e) => {
                let _ = writeln!(out, "{name:<8} {bits:>4} {lub:>3} | infeasible: {e}");
                continue;
            }
        };
        let mut baseline: Option<crate::dse::Implementation> = None;
        for tech in TechKind::ALL {
            let dse = DseOptions { tech, ..Default::default() };
            let Some(im) = explore(bt, &ds, &dse) else {
                let _ = writeln!(
                    out,
                    "{name:<8} {bits:>4} {lub:>3} | {:<10} found no design",
                    tech.label()
                );
                continue;
            };
            let cm = tech.technology().cost_model();
            let p = synth_min_delay_with(cm, &im);
            let differs = baseline.as_ref().is_some_and(|b| !b.same_selection(&im));
            let _ = writeln!(
                out,
                "{:<8} {:>4} {:>3} | {:<10} {:<12} {:>4} {:>2} {:>2} {:>14} | {:>9.3} {:>7.1} {:<4}{}",
                name,
                bits,
                lub,
                tech.label(),
                tech.technology().default_procedure().name(),
                if im.degree == Degree::Linear { "lin" } else { "quad" },
                im.sq_trunc,
                im.lin_trunc,
                im.lut_width_label(),
                p.delay_ns,
                p.area_um2,
                cm.area_unit(),
                if differs { " *" } else { "" }
            );
            // The `*` marker is defined against asic-ge specifically; if
            // the ASIC procedure found no design there is no baseline and
            // the other rows stay unmarked.
            if tech == TechKind::AsicGe {
                baseline = Some(im);
            }
        }
    }
    let _ = writeln!(out, "(* = selection differs from asic-ge on the same space)");
    out
}

/// E8: smallest LUT height at which a *linear* interpolator suffices
/// (paper §II: `0 in [a0, a1]` in every region).
pub fn linear_threshold(name: &str, bits: u32) -> String {
    // Generation-layer probe (like claim_ii1/scaling): build the bound
    // table once and generate per R, rather than re-preparing a pipeline
    // for every height.
    let w = Workload::prepare(name, bits, AccuracySpec::Ulp(1)).unwrap();
    for r in default_r_range(bits) {
        if let Ok(ds) = generate(&w.bt, &GenOptions { lookup_bits: r, ..Default::default() }) {
            if ds.linear_feasible() {
                return format!("{name} {bits}-bit: linear feasible from R = {r}\n");
            }
        }
    }
    format!("{name} {bits}-bit: linear never feasible in the default sweep range\n")
}

/// Piecewise-segment counts from the FQA non-uniform activation
/// catalog (arXiv:2606.05627) at matching input/output widths.
/// **Transcribed reference constants**, not computed here: FQA places
/// segment breakpoints non-uniformly, so its counts lower-bound what any
/// uniform-addressing scheme (ours) can reach.
fn fqa_segments(func: &str, bits: u32) -> Option<u32> {
    match (func, bits) {
        ("tanh", 8) => Some(8),
        ("tanh", 12) => Some(32),
        ("tanh", 16) => Some(96),
        ("sigmoid", 8) => Some(6),
        ("sigmoid", 12) => Some(24),
        ("sigmoid", 16) => Some(80),
        ("gelu", 8) => Some(10),
        ("gelu", 12) => Some(40),
        ("gelu", 16) => Some(112),
        ("softplus", 8) => Some(8),
        ("softplus", 12) => Some(28),
        ("softplus", 16) => Some(88),
        _ => None,
    }
}

/// ACTIVATIONS — the activation-function workload suite vs the FQA
/// segment catalog. For every function and precision: the smallest LUT
/// height whose complete *quadratic* space exists (with its common `k`
/// and streamed `(a, b)`-pair count), the smallest height whose *linear*
/// slice exists (`degree = 1` generation), and the FQA reference segment
/// count at the same spec. The ratio is uniform linear regions over
/// FQA's non-uniform segments — the addressing cost of a plain
/// truncate-the-input LUT index.
pub fn activations(specs: &[u32], r_max: u32) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "ACTIVATIONS — complete-space minima vs the FQA segment catalog (arXiv:2606.05627)"
    );
    let _ = writeln!(
        out,
        "{:<9} {:>4} | {:>5} {:>8} {:>3} {:>12} | {:>5} {:>8} | {:>8} {:>6}",
        "func", "bits", "R2", "regions", "k", "(a,b) pairs", "R1", "regions", "FQA seg", "ratio"
    );
    let dash = || "-".to_string();
    for &func in &["tanh", "sigmoid", "gelu", "softplus"] {
        for &bits in specs {
            let Some(w) = Workload::prepare(func, bits, AccuracySpec::Ulp(1)) else {
                continue;
            };
            let cap = r_max.min(bits);
            let quad = GenOptions::default();
            let lin = GenOptions { degree: 1, ..quad };
            let r2 = min_lookup_bits(&w.bt, &quad, cap);
            let r1 = min_lookup_bits(&w.bt, &lin, cap);
            let (regions2, k2, pairs2) = match r2 {
                Some(r) => {
                    let ds = generate(&w.bt, &GenOptions { lookup_bits: r, ..quad })
                        .expect("minimal R probed feasible");
                    (ds.num_regions().to_string(), ds.k.to_string(), ds.num_ab_pairs().to_string())
                }
                None => (dash(), dash(), dash()),
            };
            let regions1 = r1.map_or_else(dash, |r| (1u64 << r).to_string());
            let (fqa, ratio) = match (fqa_segments(func, bits), r1) {
                (Some(s), Some(r)) => {
                    (s.to_string(), format!("{:.2}", (1u64 << r) as f64 / s as f64))
                }
                (Some(s), None) => (s.to_string(), dash()),
                _ => (dash(), dash()),
            };
            let _ = writeln!(
                out,
                "{:<9} {:>4} | {:>5} {:>8} {:>3} {:>12} | {:>5} {:>8} | {:>8} {:>6}",
                func,
                bits,
                r2.map_or_else(dash, |r| r.to_string()),
                regions2,
                k2,
                pairs2,
                r1.map_or_else(dash, |r| r.to_string()),
                regions1,
                fqa,
                ratio
            );
        }
    }
    let _ = writeln!(
        out,
        "(R2/R1 = minimal LUT height for the quadratic space / linear slice; FQA counts are\n\
         transcribed non-uniform-segment references, so ratio > 1 is the uniform-addressing cost)"
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_renders_both_columns() {
        let t = table2(&[("exp2", 10, 4)]);
        assert!(t.contains("exp2"));
        // Both a FloPoCo-like and a proposed width bracket must render.
        assert!(t.matches('[').count() >= 2, "{t}");
    }

    #[test]
    fn fig3_has_rows_and_csv() {
        let (text, csv) = fig3("exp2", 8, 2);
        assert!(text.contains("FIG 3"));
        assert!(csv.lines().count() > 2);
    }

    #[test]
    fn claim_ii1_reports_speedup() {
        let s = claim_ii1("recip", 10, 5, 1);
        assert!(s.contains("speedup"));
    }

    #[test]
    fn tech_table_shows_divergence_marker() {
        // recip 8-bit R=3 is the bundled example where the FPGA
        // technology picks a different implementation than asic-ge.
        let t = tech_table(&[("recip", 8, 3)]);
        assert!(t.contains("asic-ge"), "{t}");
        assert!(t.contains("fpga-lut6"), "{t}");
        assert!(t.contains("low-power"), "{t}");
        assert!(t.contains(" *"), "expected a divergence marker:\n{t}");
    }

    #[test]
    fn linear_threshold_found_for_recip8() {
        let s = linear_threshold("recip", 8);
        assert!(s.contains("linear feasible"), "{s}");
    }

    #[test]
    fn activations_report_renders_every_workload_and_reference() {
        let t = activations(&[8], 8);
        for f in ["tanh", "sigmoid", "gelu", "softplus"] {
            assert!(t.contains(f), "missing {f}:\n{t}");
        }
        assert!(t.contains("2606.05627"), "{t}");
        // 8-bit activations must be feasible somewhere in 0..=8 for both
        // degrees: no dashes in the tanh row.
        let tanh_row = t.lines().find(|l| l.starts_with("tanh")).unwrap();
        assert!(!tanh_row.contains('-'), "infeasible cell in: {tanh_row}");
    }
}
