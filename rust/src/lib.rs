//! **polygen** — complete polynomial-interpolation hardware design-space
//! generation, exploration, RTL emission, and evaluation.
//!
//! Reproduction of *"Automatic Generation of Complete Polynomial
//! Interpolation Hardware Design Space"* (Orloski, Coward, Drane, 2022) as
//! a three-layer Rust + JAX + Pallas system: this crate is Layer 3 (the
//! generator/coordinator); `python/compile/` holds the build-time JAX
//! model (L2) and Pallas kernels (L1) that are AOT-lowered to the
//! `artifacts/*.hlo.txt` the [`runtime`] module executes via PJRT.
//!
//! See `DESIGN.md` for the system inventory and experiment index.

// `--cfg loom` is injected only by the loom model-checking job (see
// DESIGN.md §Static analysis); MSRV 1.75 predates `check-cfg`
// declarations, so the cfg reads as "unexpected" on newer toolchains —
// and `unexpected_cfgs` itself is an unknown lint on 1.75.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]

pub mod baselines;
pub mod bounds;
pub mod cli;
pub mod coordinator;
pub mod designspace;
pub mod dse;
pub mod faults;
pub mod net;
pub mod obs;
pub mod pipeline;
pub mod pool;
pub mod rtl;
pub mod service;
pub mod synth;
pub mod tech;
pub mod runtime;
pub mod verify;
pub mod fixedpoint;
pub mod rational;
pub mod report;
pub mod sync;
pub mod testutil;
pub mod wide;
