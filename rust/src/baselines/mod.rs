//! Comparison generators (DESIGN.md §3 substitutions): a discrete Remez
//! substrate, a FloPoCo/Sollya-style fpminimax generator (Table II), and a
//! DesignWare-style conventional component family (Table I, Fig. 2).

pub mod designware;
pub mod flopoco;
pub mod remez;

pub use designware::{dw_family, DwFamily};
pub use flopoco::flopoco_like;
pub use remez::{remez_fit, MinimaxFit};
