//! DesignWare-style generator (Table I / Fig. 2 comparator).
//!
//! Stands in for the Synopsys DesignWare elementary-function components
//! the paper synthesizes against. Modeled as a solid *conventional*
//! piecewise-polynomial generator — minimax coefficients, round-to-nearest
//! quantization with uniform guard bits, no input truncation, no
//! trailing-zero trimming, widths sized for the worst region — with the
//! one behaviour the paper highlights: "the architecture selected by logic
//! synthesis varies with delay", emulated by keeping a *family* of
//! candidate architectures (degree × LUT height) and letting the delay
//! target pick among them.
//!
//! All candidates are exhaustively verified at construction.

use super::flopoco::{encode_set, trim_for};
use super::remez::remez_fit;
use crate::bounds::{AccuracySpec, BoundTable, TargetFunction};
use crate::dse::{Coeffs, Degree, Implementation};
use crate::synth::{synth_at, synth_min_delay, SynthPoint};

/// The candidate family a DesignWare-like component ships.
pub struct DwFamily {
    pub candidates: Vec<Implementation>,
}

/// Guard bits beyond the error-budget minimum (conventional margin).
const DW_GUARD: u32 = 1;

/// Build the candidate family for a function: degrees {1, 2} across the
/// feasible LUT heights near each degree's minimum.
pub fn dw_family(f: &dyn TargetFunction) -> DwFamily {
    let bt = BoundTable::build(f, AccuracySpec::Ulp(1));
    let mut candidates = Vec::new();
    for degree in [Degree::Quadratic, Degree::Linear] {
        let mut found = 0u32;
        for r in 1..f.in_bits().saturating_sub(1) {
            if let Some(im) = dw_candidate(f, &bt, r, degree) {
                candidates.push(im);
                found += 1;
                if found >= 3 {
                    break; // minimum height + two relaxations per degree
                }
            }
        }
    }
    DwFamily { candidates }
}

/// One conventional design at a fixed height, or `None` if infeasible.
pub fn dw_candidate(
    f: &dyn TargetFunction,
    bt: &BoundTable,
    lookup_bits: u32,
    degree: Degree,
) -> Option<Implementation> {
    let xbits = f.in_bits() - lookup_bits;
    let n = 1usize << xbits;
    let deg = if degree == Degree::Quadratic { 2 } else { 1 };
    if n < deg + 2 || lookup_bits < 1 {
        return None;
    }
    let nreg = 1u64 << lookup_bits;
    let mut fits = Vec::with_capacity(nreg as usize);
    let mut eps: f64 = 0.0;
    for r in 0..nreg {
        let vals: Vec<f64> =
            (0..n).map(|x| f.y_f64(((r as u64) << xbits) + x as u64)).collect();
        let fit = remez_fit(&vals, deg);
        eps = eps.max(fit.error);
        fits.push(fit);
    }
    let slack = 1.0 - 0.5 - eps;
    if slack <= 0.05 {
        return None;
    }
    // Internal precision: round-to-nearest at scale 2^k with a
    // conventional guard, then standard per-coefficient LSB trimming
    // against a conservative error budget (slack/4 per term — real
    // components trim table LSBs too; what they lack is the *complete
    // space* the paper explores, i.e. input truncation, per-region
    // freedom and Algorithm 1's joint trailing-zero/width choice).
    let xmax = ((n - 1) as f64).max(1.0);
    let k_needed = (0.5 * (xmax * xmax + xmax + 1.0) / slack).log2().ceil().max(0.0) as u32;
    let k = k_needed + DW_GUARD;
    let scale = 2f64.powi(k as i32);
    let b4 = slack / 4.0;
    let (ta, tb, tc) =
        (trim_for(b4, xmax * xmax, k), trim_for(b4, xmax, k), trim_for(b4, 1.0, k));
    let round_to = |v: f64, t: u32| -> i64 {
        let step = (1i64 << t) as f64;
        ((v / step).round() * step) as i64
    };

    let mut coeffs = Vec::with_capacity(fits.len());
    for fit in &fits {
        let a = if degree == Degree::Quadratic { fit.coeffs[2] } else { 0.0 };
        coeffs.push(Coeffs {
            a: round_to(a * scale, ta),
            b: round_to(fit.coeffs[1] * scale, tb),
            c: round_to(fit.coeffs[0] * scale + scale / 2.0, tc),
        });
    }
    let im = Implementation {
        func: f.name().to_string(),
        accuracy: "1ulp".into(),
        in_bits: f.in_bits(),
        out_bits: f.out_bits(),
        lookup_bits,
        k,
        degree,
        sq_trunc: 0,
        lin_trunc: 0,
        enc_a: encode_set(coeffs.iter().map(|c| c.a), ta),
        enc_b: encode_set(coeffs.iter().map(|c| c.b), tb),
        enc_c: encode_set(coeffs.iter().map(|c| c.c), tc),
        coeffs,
        sampled: false,
    };
    let ok = (0..(1u64 << bt.in_bits)).all(|z| {
        let y = im.eval(z);
        y >= bt.l[z as usize] as i64 && y <= bt.u[z as usize] as i64
    });
    ok.then_some(im)
}

impl DwFamily {
    /// DC's behaviour at a delay target: every candidate is synthesized and
    /// the smallest-area one that meets the target wins (at unreachable
    /// targets, the fastest candidate).
    pub fn best_at(&self, target_ns: f64) -> Option<(SynthPoint, &Implementation)> {
        let mut meeting: Option<(SynthPoint, &Implementation)> = None;
        let mut fastest: Option<(SynthPoint, &Implementation)> = None;
        for im in &self.candidates {
            let p = synth_at(im, target_ns);
            if p.delay_ns <= target_ns * (1.0 + 1e-9) {
                if meeting.as_ref().map_or(true, |(bp, _)| p.area_um2 < bp.area_um2) {
                    meeting = Some((p, im));
                }
            }
            let pm = synth_min_delay(im);
            if fastest.as_ref().map_or(true, |(bp, _)| {
                (pm.delay_ns, pm.area_um2) < (bp.delay_ns, bp.area_um2)
            }) {
                fastest = Some((pm, im));
            }
        }
        meeting.or(fastest)
    }

    /// The minimum obtainable delay across the family (Table I operating
    /// point).
    pub fn min_delay_point(&self) -> Option<(SynthPoint, &Implementation)> {
        self.candidates
            .iter()
            .map(|im| (synth_min_delay(im), im))
            .min_by(|a, b| {
                a.0.delay_ns
                    .total_cmp(&b.0.delay_ns)
                    .then(a.0.area_um2.total_cmp(&b.0.area_um2))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::builtin;

    #[test]
    fn family_nonempty_and_verified_for_all_functions() {
        for name in ["recip", "log2", "exp2"] {
            let f = builtin(name, 10).unwrap();
            let fam = dw_family(f.as_ref());
            assert!(!fam.candidates.is_empty(), "{name}: empty DW family");
            // dw_candidate only returns verified designs; re-check one.
            let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
            let im = &fam.candidates[0];
            for z in 0..(1u64 << 10) {
                let y = im.eval(z);
                assert!(
                    y >= bt.l[z as usize] as i64 && y <= bt.u[z as usize] as i64,
                    "{name} z={z}"
                );
            }
        }
    }

    #[test]
    fn delay_target_changes_architecture() {
        let f = builtin("recip", 10).unwrap();
        let fam = dw_family(f.as_ref());
        if fam.candidates.len() < 2 {
            return;
        }
        let tight = fam.min_delay_point().unwrap();
        let relaxed = fam.best_at(tight.0.delay_ns * 3.0).unwrap();
        // At a relaxed target the chosen candidate must be no larger.
        assert!(relaxed.0.area_um2 <= tight.0.area_um2 + 1e-9);
    }

    #[test]
    fn min_delay_point_is_actually_min() {
        let f = builtin("log2", 10).unwrap();
        let fam = dw_family(f.as_ref());
        let (best, _) = fam.min_delay_point().unwrap();
        for im in &fam.candidates {
            assert!(synth_min_delay(im).delay_ns >= best.delay_ns - 1e-12);
        }
    }
}
