//! Discrete Remez exchange — minimax polynomial fitting substrate.
//!
//! The comparison generators (FloPoCo-like, DesignWare-like) are built on
//! minimax approximation, the same foundation as Sollya's `fpminimax`
//! that the paper contrasts with. The target function only exists on the
//! integer grid of a region, so this is the *discrete* Chebyshev problem:
//! minimize `max_i |f(x_i) - p(x_i)|` over degree-`d` polynomials. The
//! classic single-point exchange algorithm converges in a handful of
//! iterations; arithmetic is `f64` (baseline quality is ultimately policed
//! by exhaustive verification, not by this fit).

/// Result of a minimax fit.
#[derive(Clone, Debug)]
pub struct MinimaxFit {
    /// Coefficients, lowest degree first: `p(x) = c[0] + c[1] x + ...`.
    pub coeffs: Vec<f64>,
    /// The levelled error `|E|` on the reference set.
    pub error: f64,
    /// Iterations used.
    pub iters: u32,
}

/// Fit a degree-`degree` minimax polynomial to `values[i] ~ p(i)`.
///
/// `values.len()` must be at least `degree + 2`.
pub fn remez_fit(values: &[f64], degree: usize) -> MinimaxFit {
    let n = values.len();
    let m = degree + 2;
    assert!(n >= m, "need at least degree+2 points");

    // Chebyshev-extrema initial reference, mapped to the index range.
    let mut refs: Vec<usize> = (0..m)
        .map(|i| {
            let t = (std::f64::consts::PI * i as f64 / (m - 1) as f64).cos();
            (((1.0 - t) / 2.0) * (n - 1) as f64).round() as usize
        })
        .collect();
    refs.sort_unstable();
    refs.dedup();
    // Dedup may shrink the set on tiny grids; pad with unused indices.
    let mut next = 0usize;
    while refs.len() < m {
        if !refs.contains(&next) {
            refs.push(next);
        }
        next += 1;
    }
    refs.sort_unstable();

    let mut coeffs = vec![0.0; degree + 1];
    let mut lev_err = 0.0f64;
    let mut iters = 0u32;
    for _ in 0..60 {
        iters += 1;
        // Solve p(x_j) + (-1)^j E = f(x_j) on the reference.
        let mut a = vec![vec![0.0f64; m + 1]; m]; // augmented
        for (j, &xi) in refs.iter().enumerate() {
            let x = xi as f64;
            let mut pw = 1.0;
            for c in 0..=degree {
                a[j][c] = pw;
                pw *= x;
            }
            a[j][degree + 1] = if j % 2 == 0 { 1.0 } else { -1.0 };
            a[j][m] = values[xi];
        }
        gauss_solve(&mut a);
        for c in 0..=degree {
            coeffs[c] = a[c][m];
        }
        lev_err = a[degree + 1][m].abs();

        // Error scan over the full grid.
        let err = |x: usize| -> f64 {
            let mut p = 0.0;
            let mut pw = 1.0;
            for c in 0..=degree {
                p += coeffs[c] * pw;
                pw *= x as f64;
            }
            values[x] - p
        };
        let (mut worst, mut worst_e) = (0usize, 0.0f64);
        for x in 0..n {
            let e = err(x).abs();
            if e > worst_e {
                worst_e = e;
                worst = x;
            }
        }
        if worst_e <= lev_err * (1.0 + 1e-9) + 1e-15 {
            break; // converged: no point exceeds the levelled error
        }
        exchange(&mut refs, worst, &err);
    }
    MinimaxFit { coeffs, error: lev_err, iters }
}

/// Single-point exchange preserving sign alternation.
fn exchange(refs: &mut [usize], x_new: usize, err: &dyn Fn(usize) -> f64) {
    let s_new = err(x_new).signum();
    let m = refs.len();
    if x_new < refs[0] {
        if err(refs[0]).signum() == s_new {
            refs[0] = x_new;
        } else {
            // Shift everything up, dropping the last point.
            for i in (1..m).rev() {
                refs[i] = refs[i - 1];
            }
            refs[0] = x_new;
        }
        return;
    }
    if x_new > refs[m - 1] {
        if err(refs[m - 1]).signum() == s_new {
            refs[m - 1] = x_new;
        } else {
            for i in 0..m - 1 {
                refs[i] = refs[i + 1];
            }
            refs[m - 1] = x_new;
        }
        return;
    }
    // Interior: replace the neighbour with matching sign.
    for i in 0..m {
        if refs[i] >= x_new {
            if refs[i] == x_new {
                return;
            }
            let left = i.checked_sub(1);
            if err(refs[i]).signum() == s_new {
                refs[i] = x_new;
            } else if let Some(li) = left {
                refs[li] = x_new;
            }
            return;
        }
    }
}

/// In-place Gaussian elimination with partial pivoting on an augmented
/// matrix; the solution lands in column `m` of each row.
fn gauss_solve(a: &mut [Vec<f64>]) {
    let n = a.len();
    let m = a[0].len() - 1;
    assert_eq!(n, m);
    for col in 0..n {
        // Pivot.
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        a.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-30, "singular system in Remez solve");
        for c in col..=m {
            a[col][c] /= d;
        }
        for row in 0..n {
            if row != col && a[row][col] != 0.0 {
                let f = a[row][col];
                for c in col..=m {
                    a[row][c] -= f * a[col][c];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::for_each_seed;

    #[test]
    fn exact_polynomial_recovered() {
        // f already a quadratic: error ~ 0, coefficients recovered.
        let vals: Vec<f64> = (0..64).map(|x| 3.0 + 2.0 * x as f64 - 0.25 * (x * x) as f64).collect();
        let fit = remez_fit(&vals, 2);
        assert!(fit.error < 1e-9);
        assert!((fit.coeffs[0] - 3.0).abs() < 1e-7);
        assert!((fit.coeffs[1] - 2.0).abs() < 1e-8);
        assert!((fit.coeffs[2] + 0.25).abs() < 1e-9);
    }

    #[test]
    fn known_minimax_abs_on_symmetric_grid() {
        // Degree-1 minimax to |x - c| on a symmetric grid: E = range/4
        // ... sanity: error must beat least-squares-ish bounds and
        // equioscillate.
        let n = 101;
        let vals: Vec<f64> = (0..n).map(|x| ((x as f64) - 50.0).abs()).collect();
        let fit = remez_fit(&vals, 1);
        // f is even about the midpoint, so the best line is the constant
        // 25 with equioscillating error 25 (at x=0, 50, 100).
        assert!((fit.error - 25.0).abs() < 0.5, "E = {}", fit.error);
        assert!(fit.coeffs[1].abs() < 1e-6, "slope should vanish");
    }

    #[test]
    fn minimax_beats_endpoint_interpolation() {
        for_each_seed(20, |rng| {
            let n = 16 + rng.below(100) as usize;
            let a = rng.f64() * 2.0 - 1.0;
            let b = rng.f64() * 4.0;
            let vals: Vec<f64> =
                (0..n).map(|x| (a * (x as f64) * 0.2).exp() + b * (x as f64)).collect();
            let fit = remez_fit(&vals, 2);
            // Max error of the fit over the grid:
            let maxe = (0..n)
                .map(|x| {
                    let p = fit.coeffs[0]
                        + fit.coeffs[1] * x as f64
                        + fit.coeffs[2] * (x as f64) * (x as f64);
                    (vals[x] - p).abs()
                })
                .fold(0.0f64, f64::max);
            assert!(maxe <= fit.error * (1.0 + 1e-6) + 1e-12, "not levelled: {maxe} vs {}", fit.error);
        });
    }

    #[test]
    fn tiny_grids_do_not_panic() {
        let vals = vec![1.0, 2.0, 5.0, 3.0];
        let fit = remez_fit(&vals, 2);
        assert!(fit.error >= 0.0);
        let lin = remez_fit(&vals[..3], 1);
        assert!(lin.error >= 0.0);
    }
}
