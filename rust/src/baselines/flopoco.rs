//! FloPoCo-style generator (Table II comparator).
//!
//! Models FloPoCo's `FixFunctionByPiecewisePoly` / Sollya `fpminimax`
//! pipeline: per-region minimax fit at a *fixed* LUT height, a global
//! error budget split between approximation, coefficient quantization and
//! final rounding, per-coefficient LSB trimming against that budget, and
//! uniform table fields sized for the worst region. Differences from real
//! FloPoCo (documented per DESIGN.md §3): one shared evaluation scale
//! `2^k` instead of per-monomial alignments, and ASIC rather than FPGA
//! cost assumptions downstream. What Table II compares — the stored
//! `[a, b, c]` field widths at equal LUT height — is faithfully produced.
//!
//! The result is a normal [`Implementation`], so the same RTL emitter,
//! verifier and cost model apply; every produced design is exhaustively
//! verified before being returned.

use super::remez::remez_fit;
use crate::bounds::{AccuracySpec, BoundTable, TargetFunction};
use crate::dse::precision::{Encoding, Sign};
use crate::dse::{Coeffs, Degree, Implementation};

/// Generate a FloPoCo-style design at the given LUT height. Returns `None`
/// if no budget closes at this height/degree (use more lookup bits).
pub fn flopoco_like(
    f: &dyn TargetFunction,
    lookup_bits: u32,
    degree: Degree,
) -> Option<Implementation> {
    let in_bits = f.in_bits();
    let xbits = in_bits - lookup_bits;
    let n = 1usize << xbits;
    let nreg = 1u64 << lookup_bits;
    let deg = if degree == Degree::Quadratic { 2 } else { 1 };
    if n < deg + 2 {
        return None;
    }

    // Per-region minimax fits on the exact scaled values.
    let mut fits = Vec::with_capacity(nreg as usize);
    let mut eps: f64 = 0.0;
    for r in 0..nreg {
        let vals: Vec<f64> =
            (0..n).map(|x| f.y_f64(((r as u64) << xbits) + x as u64)).collect();
        let fit = remez_fit(&vals, deg);
        eps = eps.max(fit.error);
        fits.push(fit);
    }
    // Budget: eps (approx) + 0.5 (rounded final truncation) + quant < 1.
    let slack = 1.0 - 0.5 - eps;
    if slack <= 0.05 {
        return None;
    }

    let xmax = (n - 1) as f64;
    // Retry with one extra guard bit if exhaustive verification complains
    // (f64 fit noise at the budget edge).
    let bt = BoundTable::build(f, AccuracySpec::Ulp(1));
    let base_k = k_for(slack / 3.0, xmax * xmax);
    for extra in 0..4u32 {
        let k = base_k + extra;
        if let Some(im) = quantize(f, &fits, lookup_bits, k, slack, degree) {
            if exhaustive_ok(&bt, &im) {
                return Some(im);
            }
        }
    }
    None
}

/// Smallest `k` with round-to-nearest error `0.5 * weight / 2^k <= budget`.
fn k_for(budget_ulp: f64, weight: f64) -> u32 {
    let need = 0.5 * weight / budget_ulp;
    need.log2().ceil().max(0.0) as u32
}

/// Largest trailing-zero trim `t` with `2^(t-1) * weight / 2^k <= budget`.
pub(crate) fn trim_for(budget_ulp: f64, weight: f64, k: u32) -> u32 {
    let t = (budget_ulp * 2f64.powi(k as i32 + 1) / weight).log2().floor();
    t.max(0.0).min(k as f64) as u32
}

fn quantize(
    f: &dyn TargetFunction,
    fits: &[super::remez::MinimaxFit],
    lookup_bits: u32,
    k: u32,
    slack: f64,
    degree: Degree,
) -> Option<Implementation> {
    let xbits = f.in_bits() - lookup_bits;
    let n = 1u64 << xbits;
    let xmax = ((n - 1) as f64).max(1.0);
    let b3 = slack / 3.0;
    let (ta, tb, tc) = (
        trim_for(b3, xmax * xmax, k),
        trim_for(b3, xmax, k),
        trim_for(b3, 1.0, k),
    );
    let scale = 2f64.powi(k as i32);
    let round_to = |v: f64, t: u32| -> i64 {
        let step = (1i64 << t) as f64;
        ((v / step).round() * step) as i64
    };
    let mut coeffs = Vec::with_capacity(fits.len());
    for fit in fits {
        let a = if degree == Degree::Quadratic { fit.coeffs[2] } else { 0.0 };
        let b = fit.coeffs[1];
        // +0.5 output-ulp offset turns the final floor into a round.
        let c = fit.coeffs[0];
        coeffs.push(Coeffs {
            a: round_to(a * scale, ta),
            b: round_to(b * scale, tb),
            c: round_to(c * scale + scale / 2.0, tc),
        });
    }
    let enc_a = encode_set(coeffs.iter().map(|c| c.a), ta);
    let enc_b = encode_set(coeffs.iter().map(|c| c.b), tb);
    let enc_c = encode_set(coeffs.iter().map(|c| c.c), tc);
    Some(Implementation {
        func: f.name().to_string(),
        accuracy: "1ulp".into(),
        in_bits: f.in_bits(),
        out_bits: f.out_bits(),
        lookup_bits,
        k,
        degree,
        sq_trunc: 0,
        lin_trunc: 0,
        enc_a,
        enc_b,
        enc_c,
        coeffs,
        sampled: false,
    })
}

/// Width/sign of a stored field covering every value in the iterator.
pub fn encode_set(values: impl Iterator<Item = i64>, trunc: u32) -> Encoding {
    let vals: Vec<i64> = values.collect();
    let any_neg = vals.iter().any(|&v| v < 0);
    let any_pos = vals.iter().any(|&v| v > 0);
    let magw = vals
        .iter()
        .map(|&v| crate::fixedpoint::bit_width(v.unsigned_abs() >> trunc))
        .max()
        .unwrap_or(0);
    let sign = match (any_neg, any_pos) {
        (true, true) => Sign::Signed,
        (true, false) => Sign::NonPos,
        _ => Sign::NonNeg,
    };
    Encoding { trunc, width: magw + (sign == Sign::Signed) as u32, sign }
}

fn exhaustive_ok(bt: &BoundTable, im: &Implementation) -> bool {
    (0..(1u64 << bt.in_bits))
        .all(|z| {
            let y = im.eval(z);
            y >= bt.l[z as usize] as i64 && y <= bt.u[z as usize] as i64
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::builtin;

    #[test]
    fn flopoco_like_designs_verify() {
        for (name, bits, r, deg) in [
            ("recip", 10u32, 5u32, Degree::Quadratic),
            ("log2", 10, 4, Degree::Quadratic),
            ("exp2", 10, 5, Degree::Linear),
            ("recip", 12, 6, Degree::Quadratic),
        ] {
            let f = builtin(name, bits).unwrap();
            let im = flopoco_like(f.as_ref(), r, deg)
                .unwrap_or_else(|| panic!("{name}/{bits} R={r} budget failed"));
            let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
            assert!(exhaustive_ok(&bt, &im), "{name}/{bits} violates bounds");
            assert_eq!(im.lookup_bits, r);
        }
    }

    #[test]
    fn infeasible_height_returns_none() {
        let f = builtin("recip", 10).unwrap();
        // One region for all of 1/x at 10 bits cannot close the budget.
        assert!(flopoco_like(f.as_ref(), 0, Degree::Quadratic).is_none());
    }

    #[test]
    fn fields_cover_all_regions() {
        let f = builtin("log2", 10).unwrap();
        let im = flopoco_like(f.as_ref(), 5, Degree::Quadratic).unwrap();
        for co in &im.coeffs {
            assert!(im.enc_a.admits(co.a));
            assert!(im.enc_b.admits(co.b));
            assert!(im.enc_c.admits(co.c));
        }
    }
}
