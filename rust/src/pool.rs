//! Process-wide work-stealing scheduler shared by design-space
//! generation ([`crate::designspace`]), lookup-bit sweeps
//! ([`crate::coordinator`]) and batch job execution
//! ([`crate::pipeline::Batch`]).
//!
//! Per-item cost is *not* uniform in any caller: Claim II.1 pruning fires
//! unevenly across regions, and a batch mixes auto-LUB sweeps with
//! fixed-`R` jobs. Static chunking parks finished workers behind the
//! slowest chunk; here workers pull the next index from a shared atomic
//! cursor. Results are written back by index, so the output order — and
//! therefore every downstream artifact — is independent of the thread
//! count and of scheduling (property-tested).
//!
//! # The global scheduler
//!
//! Earlier revisions spawned a fresh scoped pool per call, which made
//! nested parallelism (a threaded batch running threaded generations) an
//! oversubscription hazard that had to be clamped statically
//! (`Batch::inner_thread_cap`, now superseded). Instead there is **one**
//! process-wide [`Scheduler`] ([`global`]) with persistent workers,
//! spawned once on first use and parked between calls:
//!
//! - [`run_indexed`] posts a *job* (an index range + a task closure) and
//!   the calling thread immediately starts executing its own indices, so
//!   a call never deadlocks waiting for workers — even recursively from
//!   inside another job's task.
//! - Idle workers scan the job list and help any job whose concurrency
//!   is still below its requested `threads` budget, picking the job with
//!   the **largest remaining range** first (`pick_job`) rather than
//!   re-joining the oldest. This is the dynamic **budget donation** that
//!   replaces the static split: when a small batch job finishes early,
//!   its worker migrates to the sibling with the most work left instead
//!   of idling behind a per-job cap — and a tiny fixed-`R` job no longer
//!   serializes behind an auto-LUB sweep's tail.
//! - Total parallelism is bounded by the worker pool size (machine
//!   parallelism by default, `POLYGEN_POOL_THREADS` to override) plus
//!   the submitting threads — regardless of how deeply jobs nest.
//! - [`Scheduler::drain`] blocks until every outstanding job has
//!   completed; workers then stay parked, ready for reuse
//!   ([`crate::pipeline::shutdown`] is the pipeline-level entry point).
//!
//! Worker panics are caught per task, forwarded to the submitting call
//! and re-raised there with the original payload (e.g. the region id in
//! generation's invariant-breach message); the pool itself survives and
//! remains reusable.
//!
//! # Model checking
//!
//! Every primitive here comes from [`crate::sync`], so under
//! `--cfg loom` the whole protocol — park/unpark, nested
//! submit-executes-own-job, donation, drain — is explored exhaustively
//! by the `tests/loom` suite against standalone instances
//! ([`Scheduler::new_standalone`]). [`Scheduler::shutdown`] exists for
//! those models (loom requires every spawned thread to be joined before
//! a model ends) and for tests; the global instance is simply never
//! torn down.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::sync::{cwait, plock, thread, Arc, Condvar, Mutex};

use crate::obs::metrics;

/// Jobs currently registered with the scheduler (set under the
/// scheduler lock, so it tracks `Inner::jobs.len()` exactly).
const QUEUE_DEPTH: metrics::Gauge = metrics::gauge("pool.queue_depth");
/// One increment per worker-joins-job donation decision.
const DONATIONS: metrics::Counter = metrics::counter("pool.donations");
/// Contained task panics (the payload still propagates to the submitter).
const TASK_PANICS: metrics::Counter = metrics::counter("pool.task_panics");

/// Cooperative cancellation flag, shared between a job's owner (who calls
/// [`CancelToken::cancel`]) and the task closures running on the
/// scheduler (who poll [`CancelToken::is_cancelled`] at their natural
/// checkpoints — between region sweeps in generation, between points in
/// a lookup-bit sweep, at pipeline phase boundaries).
///
/// Cancellation is *advisory*: the scheduler itself never kills a task.
/// A task that observes the flag returns a cheap placeholder and its
/// caller maps the run to a `Cancelled` error, so scheduler accounting
/// (`completed == n`) stays exact and the pool remains reusable after
/// any cancellation.
#[derive(Clone, Debug)]
pub struct CancelToken(Arc<AtomicBool>);

impl Default for CancelToken {
    fn default() -> CancelToken {
        CancelToken(Arc::new(AtomicBool::new(false)))
    }
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; never blocks.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Monotone work counter threaded into scheduler tasks so a job's owner
/// can observe progress (e.g. "analyzed 37 of 64 regions") without any
/// synchronization beyond two relaxed atomics. [`Progress::begin`]
/// resets the counter for a new phase; concurrent readers may observe
/// `done` mid-update — the pair is a progress *indication*, not a
/// barrier.
#[derive(Debug)]
pub struct Progress {
    done: AtomicUsize,
    total: AtomicUsize,
}

impl Default for Progress {
    fn default() -> Progress {
        Progress { done: AtomicUsize::new(0), total: AtomicUsize::new(0) }
    }
}

impl Progress {
    /// Start a new counted phase of `total` work items.
    pub fn begin(&self, total: usize) {
        self.done.store(0, Ordering::Relaxed);
        self.total.store(total, Ordering::Relaxed);
    }

    /// Record one completed work item.
    pub fn tick(&self) {
        self.done.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` completed work items at once (e.g. a cache hit or a
    /// remote shard covering many regions).
    pub fn add(&self, n: usize) {
        self.done.fetch_add(n, Ordering::Relaxed);
    }

    /// `(done, total)` as last observed.
    pub fn get(&self) -> (usize, usize) {
        (self.done.load(Ordering::Relaxed), self.total.load(Ordering::Relaxed))
    }
}

/// Compute `f(i)` for `i in 0..n` across up to `threads` concurrent
/// executors (the calling thread plus donated pool workers) pulling from
/// a shared cursor; returns `out` with `out[i] = f(i)`.
/// `threads <= 1` (or `n <= 1`) runs inline with no scheduler traffic.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = std::iter::repeat_with(|| None).take(n).collect();
    let slots = Slots(out.as_mut_ptr());
    let task = move |i: usize| {
        let v = f(i);
        // SAFETY: the cursor hands each index to exactly one executor, so
        // this slot is written once, by us, with no concurrent access;
        // the submitter only reads `out` after every task completed.
        unsafe { *slots.0.add(i) = Some(v) };
    };
    global().run_on(n, threads, &task);
    out.into_iter().map(|v| v.expect("scheduler missed an index")).collect()
}

/// Raw slot pointer smuggled into the task closure. Distinct indices
/// address distinct slots, so concurrent writes never alias.
struct Slots<T>(*mut Option<T>);

// SAFETY: see `run_indexed` — per-index exclusive access, completion is
// synchronized through the job's state mutex before the submitter reads.
unsafe impl<T: Send> Send for Slots<T> {}
unsafe impl<T: Send> Sync for Slots<T> {}

/// Type-erased pointer to the submitter's task closure. Only dereferenced
/// while the submitting [`Scheduler::run_on`] frame is alive — it blocks
/// until every task execution has finished, and an exhausted cursor stops
/// workers from ever touching the task again.
struct TaskPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` and outlives all dereferences (above).
unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

/// One posted index range. Lives in the scheduler's job list from
/// submission until the submitter observes completion and removes it.
struct Job {
    task: TaskPtr,
    n: usize,
    /// Requested concurrency budget, counting the submitting thread.
    /// Workers stop joining once `active` reaches it; it is a *target*,
    /// not a reservation — idle capacity flows wherever budgets allow.
    limit: usize,
    /// Next index to hand out (may run past `n`; executors then leave).
    cursor: AtomicUsize,
    /// Executors currently inside [`execute`] for this job.
    active: AtomicUsize,
    state: Mutex<JobState>,
    done_cv: Condvar,
}

struct JobState {
    completed: usize,
    panic: Option<Box<dyn std::any::Any + Send + 'static>>,
}

/// Pull indices from `job` until the cursor is exhausted. Panics in the
/// task are caught and recorded (first payload wins) so accounting stays
/// exact and the worker survives.
fn execute(job: &Job) {
    loop {
        let i = job.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            break;
        }
        // SAFETY: index `i < n` was still available, so this task has not
        // been counted completed — the submitter cannot observe
        // `completed == n` and is still blocked in `Scheduler::run_on`,
        // keeping the closure alive for the duration of this call. (The
        // deref sits after the cursor check on purpose: a worker that
        // claims a just-finished job must break without ever touching
        // the pointer.)
        let task = unsafe { &*job.task.0 };
        let result = catch_unwind(AssertUnwindSafe(|| task(i)));
        let mut st = plock(&job.state);
        if let Err(payload) = result {
            TASK_PANICS.inc();
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.completed += 1;
        if st.completed == job.n {
            job.done_cv.notify_all();
        }
    }
    job.active.fetch_sub(1, Ordering::Relaxed);
}

/// Cost-aware job pick for an idle worker: among jobs that still have
/// unclaimed indices and are under their concurrency budget, choose the
/// one with the **largest remaining range** (ties keep submission
/// order). The earlier FIFO scan always re-joined the oldest eligible
/// job, so when a batch mixed a long auto-LUB sweep with tiny fixed-`R`
/// jobs, every freed worker piled onto the sweep's tail while the tiny
/// jobs waited behind it; largest-remaining-first sends capacity where
/// the most work is left and lets short jobs start immediately.
///
/// The loads are relaxed snapshots — a stale pick is at worst slightly
/// suboptimal, never incorrect (the cursor hands out each index exactly
/// once regardless of which job a worker joins).
fn pick_job(jobs: &[Arc<Job>]) -> Option<Arc<Job>> {
    let mut best: Option<(&Arc<Job>, usize)> = None;
    for j in jobs {
        let cursor = j.cursor.load(Ordering::Relaxed);
        if cursor >= j.n || j.active.load(Ordering::Relaxed) >= j.limit {
            continue;
        }
        let remaining = j.n - cursor;
        // Strict `>` keeps the earliest-submitted job on ties.
        if best.map_or(true, |(_, r)| remaining > r) {
            best = Some((j, remaining));
        }
    }
    best.map(|(j, _)| Arc::clone(j))
}

struct Inner {
    /// Outstanding jobs. Small: one entry per in-flight `run_indexed`.
    jobs: Vec<Arc<Job>>,
    /// Workers spawned so far (monotone, capped at `max_workers`).
    spawned: usize,
    /// Workers currently executing a job.
    busy: usize,
    /// Set by [`Scheduler::shutdown`]: idle workers exit instead of
    /// parking. Never set on the global instance.
    stop: bool,
    /// Join handles for every spawned worker, taken by `shutdown`.
    handles: Vec<thread::JoinHandle<()>>,
}

/// The process-wide scheduler. Obtain via [`global`], or build a
/// private instance with [`Scheduler::new_standalone`] (tests and the
/// loom models, which must own and join every thread they spawn).
pub struct Scheduler {
    inner: Mutex<Inner>,
    /// Parked workers wait here; notified on job submission.
    work_cv: Condvar,
    /// [`Scheduler::drain`] waits here; notified on full idleness.
    idle_cv: Condvar,
    max_workers: usize,
}

// The one sanctioned raw-std static: `OnceLock` has no loom mirror and
// a const initializer; the global instance is never loom-modeled (the
// models drive `new_standalone` schedulers they can join and tear
// down).
// lint: sync-ok(const-init static registry; loom models use new_standalone)
static GLOBAL: std::sync::OnceLock<Arc<Scheduler>> = std::sync::OnceLock::new();

/// The process-wide scheduler, created on first use. Worker threads are
/// spawned lazily as jobs demand them, up to machine parallelism minus
/// one (submitting threads always participate in their own jobs);
/// `POLYGEN_POOL_THREADS` overrides the cap (`0` = no workers, every
/// call runs on its submitting thread alone).
pub fn global() -> &'static Arc<Scheduler> {
    GLOBAL.get_or_init(|| Scheduler::new_standalone(default_workers()))
}

fn default_workers() -> usize {
    if let Ok(v) = std::env::var("POLYGEN_POOL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n;
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1)
}

impl Scheduler {
    /// A private scheduler instance with its own worker pool, capped at
    /// `max_workers` pool threads. The global instance is exactly
    /// `new_standalone(default_workers())`; standalone instances exist
    /// so tests and the loom models can run the *same* protocol code on
    /// a pool they fully own — and can [`Scheduler::shutdown`].
    pub fn new_standalone(max_workers: usize) -> Arc<Scheduler> {
        Arc::new(Scheduler {
            inner: Mutex::new(Inner {
                jobs: Vec::new(),
                spawned: 0,
                busy: 0,
                stop: false,
                handles: Vec::new(),
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            max_workers,
        })
    }

    /// Execute `task(i)` for `i in 0..n` with up to `limit` concurrent
    /// executors (including the calling thread); blocks until every
    /// index has run, then re-raises the first task panic, if any.
    /// [`run_indexed`] is the typed convenience over the global
    /// instance; the loom models drive this directly.
    pub fn run_on(self: &Arc<Self>, n: usize, limit: usize, task: &(dyn Fn(usize) + Sync)) {
        let job = Arc::new(Job {
            task: TaskPtr(task as *const (dyn Fn(usize) + Sync)),
            n,
            limit,
            cursor: AtomicUsize::new(0),
            active: AtomicUsize::new(1), // the submitter, below
            state: Mutex::new(JobState { completed: 0, panic: None }),
            done_cv: Condvar::new(),
        });
        {
            let mut inner = plock(&self.inner);
            inner.jobs.push(Arc::clone(&job));
            QUEUE_DEPTH.set(inner.jobs.len() as u64);
            self.spawn_workers(&mut inner, limit.saturating_sub(1));
            // Wake parked workers to come steal.
            self.work_cv.notify_all();
        }
        // The submitter always works its own job: progress never depends
        // on worker availability, so nested submission cannot deadlock.
        execute(&job);
        // Wait out indices stolen by workers that are still in flight.
        let mut st = plock(&job.state);
        while st.completed < n {
            st = cwait(&job.done_cv, st);
        }
        let panic = st.panic.take();
        drop(st);
        let mut inner = plock(&self.inner);
        inner.jobs.retain(|j| !Arc::ptr_eq(j, &job));
        QUEUE_DEPTH.set(inner.jobs.len() as u64);
        if inner.busy == 0 && inner.jobs.is_empty() {
            self.idle_cv.notify_all();
        }
        drop(inner);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }

    fn spawn_workers(self: &Arc<Self>, inner: &mut Inner, wanted: usize) {
        let mut deficit = wanted.min(self.max_workers.saturating_sub(inner.spawned));
        while deficit > 0 {
            let worker = Arc::clone(self);
            let name = format!("polygen-pool-{}", inner.spawned);
            match thread::spawn_named(name, move || worker.worker_loop()) {
                Some(handle) => {
                    inner.handles.push(handle);
                    inner.spawned += 1;
                    deficit -= 1;
                }
                None => break, // resource exhaustion: degrade to fewer workers
            }
        }
    }

    fn worker_loop(&self) {
        let mut inner = plock(&self.inner);
        loop {
            // Donation: join *any* job still under its budget, not just
            // the one that woke us. The pick is cost-aware (see
            // `pick_job`), not a FIFO scan.
            let claim = pick_job(&inner.jobs);
            match claim {
                Some(job) => {
                    // Under the scheduler lock, so budget checks do not race.
                    job.active.fetch_add(1, Ordering::Relaxed);
                    DONATIONS.inc();
                    inner.busy += 1;
                    drop(inner);
                    execute(&job);
                    inner = plock(&self.inner);
                    inner.busy -= 1;
                    if inner.busy == 0 && inner.jobs.is_empty() {
                        self.idle_cv.notify_all();
                    }
                }
                None if inner.stop => return,
                None => inner = cwait(&self.work_cv, inner),
            }
        }
    }

    /// Graceful drain: block until every outstanding job has completed
    /// and all pool workers are parked. Workers are *not* torn down —
    /// they stay resident for the next batch; this is the shutdown
    /// barrier that lets a caller know no scheduler work remains.
    pub fn drain(&self) {
        let mut inner = plock(&self.inner);
        while !(inner.jobs.is_empty() && inner.busy == 0) {
            inner = cwait(&self.idle_cv, inner);
        }
    }

    /// Drain, then stop and join every pool worker. For standalone
    /// instances (tests, loom models — loom requires every thread a
    /// model spawned to be joined before the model ends); the global
    /// instance is never shut down. A scheduler remains *safe* after
    /// shutdown: submissions still complete, executed entirely by their
    /// submitting thread (the worker respawn path is closed by the
    /// monotone `spawned` count).
    pub fn shutdown(&self) {
        self.drain();
        let handles = {
            let mut inner = plock(&self.inner);
            inner.stop = true;
            std::mem::take(&mut inner.handles)
        };
        self.work_cv.notify_all();
        for h in handles {
            let _ = h.join();
        }
    }

    /// Workers spawned so far (diagnostics; never exceeds the cap).
    pub fn workers_spawned(&self) -> usize {
        plock(&self.inner).spawned
    }

    /// Jobs currently outstanding (posted but not yet fully completed).
    /// Zero after [`Scheduler::drain`] returns; the chaos suite uses this
    /// to assert the pool is drained-but-reusable after a faulted run.
    pub fn outstanding_jobs(&self) -> usize {
        plock(&self.inner).jobs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uneven_work(i: usize) -> u64 {
        // Uneven per-item cost: make some indices much heavier, so static
        // chunking would misassign work but the result must not change.
        let rounds = if i % 7 == 0 { 20_000 } else { 10 };
        let mut acc = i as u64;
        for _ in 0..rounds {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        }
        acc
    }

    #[test]
    fn results_independent_of_thread_count() {
        let want = run_indexed(97, 1, uneven_work);
        for threads in [2usize, 3, 8, 64] {
            assert_eq!(run_indexed(97, threads, uneven_work), want, "threads={threads}");
        }
    }

    #[test]
    fn edge_sizes() {
        assert_eq!(run_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(1, 4, |i| i * 2), vec![0]);
        assert_eq!(run_indexed(5, 100, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn nested_jobs_complete_and_stay_deterministic() {
        // A job whose tasks themselves submit jobs: the global scheduler
        // must neither deadlock (submitters self-drain) nor mix results
        // across jobs.
        let got = run_indexed(6, 3, |i| {
            let inner = run_indexed(20, 4, move |j| (i * 100 + j) as u64);
            inner.iter().sum::<u64>()
        });
        let want: Vec<u64> = (0..6)
            .map(|i| (0..20).map(|j| (i * 100 + j) as u64).sum())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        // Budget donation across independent submitting threads: all
        // jobs complete with correct, independent results.
        let outs: Vec<Vec<u64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|s| scope.spawn(move || run_indexed(50, 4, move |i| (s * 1000 + i) as u64)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (s, out) in outs.iter().enumerate() {
            let want: Vec<u64> = (0..50).map(|i| (s * 1000 + i) as u64).collect();
            assert_eq!(*out, want);
        }
    }

    #[test]
    fn panic_payload_propagates_and_pool_survives() {
        let result = std::panic::catch_unwind(|| {
            run_indexed(16, 4, |i| {
                if i == 9 {
                    panic!("task 9 exploded");
                }
                i
            })
        });
        let payload = result.expect_err("panic must propagate to the submitter");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(String::from)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("task 9 exploded"), "payload lost: {msg:?}");
        // The pool must remain fully usable after a task panic.
        global().drain();
        assert_eq!(run_indexed(8, 4, |i| i * 3), vec![0, 3, 6, 9, 12, 15, 18, 21]);
    }

    #[test]
    fn drain_is_idempotent_and_leaves_pool_reusable() {
        let a = run_indexed(40, 4, uneven_work);
        global().drain();
        global().drain(); // idle drain returns immediately
        let b = run_indexed(40, 4, uneven_work);
        assert_eq!(a, b);
    }

    #[test]
    fn standalone_scheduler_runs_and_shuts_down() {
        // The same protocol the loom models explore, on a private pool:
        // run, drain, run again (parked-but-reusable), then shutdown
        // joins every worker — and a post-shutdown submission still
        // completes (inline on its submitter), never hangs.
        let sched = Scheduler::new_standalone(2);
        let hits = AtomicUsize::new(0);
        let task = |_: usize| {
            hits.fetch_add(1, Ordering::Relaxed);
        };
        sched.run_on(16, 3, &task);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        sched.drain();
        sched.run_on(8, 3, &task);
        assert_eq!(hits.load(Ordering::Relaxed), 24);
        sched.shutdown();
        sched.run_on(4, 3, &task);
        assert_eq!(hits.load(Ordering::Relaxed), 28);
        assert_eq!(sched.outstanding_jobs(), 0);
        assert!(sched.workers_spawned() <= 2);
    }

    /// Build a synthetic job for `pick_job` tests: `n` total indices,
    /// the cursor already at `cursor`, `active` of `limit` executors.
    /// The task pointer is never dereferenced by `pick_job`.
    fn synthetic_job(
        task: &(dyn Fn(usize) + Sync),
        n: usize,
        cursor: usize,
        active: usize,
        limit: usize,
    ) -> Arc<Job> {
        Arc::new(Job {
            task: TaskPtr(task as *const (dyn Fn(usize) + Sync)),
            n,
            limit,
            cursor: AtomicUsize::new(cursor),
            active: AtomicUsize::new(active),
            state: Mutex::new(JobState { completed: 0, panic: None }),
            done_cv: Condvar::new(),
        })
    }

    #[test]
    fn pick_prefers_largest_remaining_range() {
        let noop: &(dyn Fn(usize) + Sync) = &|_| {};
        // The PR-4 ROADMAP scenario: an auto-LUB sweep near its tail
        // (2 indices left) was submitted first; a tiny fixed-R job with
        // all 8 indices left arrives later. A FIFO scan would re-join
        // the sweep; the cost-aware pick must start the tiny job.
        let sweep_tail = synthetic_job(noop, 1000, 998, 1, 8);
        let tiny = synthetic_job(noop, 8, 0, 1, 8);
        let jobs = vec![Arc::clone(&sweep_tail), Arc::clone(&tiny)];
        let picked = pick_job(&jobs).expect("both jobs eligible");
        assert!(Arc::ptr_eq(&picked, &tiny), "picked the sweep tail over the fresh job");

        // Jobs at budget or with an exhausted cursor are never picked.
        let at_budget = synthetic_job(noop, 500, 0, 4, 4);
        let exhausted = synthetic_job(noop, 10, 10, 0, 4);
        assert!(pick_job(&[at_budget, exhausted]).is_none());

        // Ties keep submission order (the first job in the list).
        let first = synthetic_job(noop, 20, 10, 1, 8);
        let second = synthetic_job(noop, 10, 0, 1, 8);
        let picked = pick_job(&[Arc::clone(&first), second]).unwrap();
        assert!(Arc::ptr_eq(&picked, &first), "tie must keep submission order");

        assert!(pick_job(&[]).is_none());
    }

    #[test]
    fn cancel_token_is_shared_and_idempotent() {
        let t = CancelToken::new();
        let clone = t.clone();
        assert!(!t.is_cancelled() && !clone.is_cancelled());
        clone.cancel();
        clone.cancel();
        assert!(t.is_cancelled(), "cancel must be visible through every clone");
    }

    #[test]
    fn progress_counts_across_threads() {
        let p = Progress::default();
        p.begin(64);
        assert_eq!(p.get(), (0, 64));
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..16 {
                        p.tick();
                    }
                });
            }
        });
        assert_eq!(p.get(), (64, 64));
        p.begin(3); // a new phase resets the pair
        assert_eq!(p.get(), (0, 3));
    }

    #[test]
    fn worker_count_is_capped() {
        let _ = run_indexed(64, 64, |i| i);
        let cap = global().max_workers;
        assert!(
            global().workers_spawned() <= cap,
            "spawned {} workers, cap {cap}",
            global().workers_spawned()
        );
    }
}
