//! Work-stealing index pool shared by design-space generation
//! ([`crate::designspace`]) and batch job execution
//! ([`crate::pipeline::Batch`]).
//!
//! Per-item cost is *not* uniform in either caller: Claim II.1 pruning
//! fires unevenly across regions, and a batch mixes auto-LUB sweeps with
//! fixed-`R` jobs. Static chunking parks finished workers behind the
//! slowest chunk; here workers instead pull the next index from one
//! shared atomic cursor. Results are written back by index, so the output
//! order — and therefore every downstream artifact — is independent of
//! the thread count and of scheduling (property-tested).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Compute `f(i)` for `i in 0..n` across up to `threads` workers pulling
/// from a shared cursor; returns `out` with `out[i] = f(i)`.
/// `threads <= 1` (or `n <= 1`) runs inline with no thread setup.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, T)>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(part) => parts.push(part),
                // Preserve the worker's panic payload (e.g. the region id
                // in generation's invariant-breach message) instead of
                // masking it behind a generic join failure.
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    let mut out: Vec<Option<T>> = std::iter::repeat_with(|| None).take(n).collect();
    for part in parts {
        for (i, v) in part {
            debug_assert!(out[i].is_none(), "index {i} computed twice");
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|v| v.expect("pool missed an index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_independent_of_thread_count() {
        // Uneven per-item cost: make high indices much heavier, so static
        // chunking would misassign work but the result must not change.
        let work = |i: usize| -> u64 {
            let rounds = if i % 7 == 0 { 20_000 } else { 10 };
            let mut acc = i as u64;
            for _ in 0..rounds {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            }
            acc
        };
        let want = run_indexed(97, 1, work);
        for threads in [2usize, 3, 8, 64] {
            assert_eq!(run_indexed(97, threads, work), want, "threads={threads}");
        }
    }

    #[test]
    fn edge_sizes() {
        assert_eq!(run_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(1, 4, |i| i * 2), vec![0]);
        assert_eq!(run_indexed(5, 100, |i| i), vec![0, 1, 2, 3, 4]);
    }
}
