//! `polygen::obs` — dependency-free observability: process-wide metrics
//! and per-job span tracing.
//!
//! PR 7's fault layer made every I/O boundary *testable*; this module
//! makes the same boundaries (plus the scheduler and the pipeline's
//! phases) *measurable* in production. Two halves:
//!
//! - [`metrics`] — a statically-registered, process-wide registry of
//!   atomic counters, gauges, and fixed-bucket histograms. The full
//!   metric set is the [`metrics::METRICS`] const (enumerable, rendered
//!   in Prometheus text exposition by `GET /metrics`), and every
//!   recording site resolves its slot at **compile time** via the
//!   `const fn` handles ([`metrics::counter`] and friends) — an
//!   unregistered name is a compile error, and `polygen-lint`'s
//!   `obs-registry` rule cross-checks the registry against the use
//!   sites both ways (a dead metric and an unregistered metric both
//!   fail CI).
//! - [`trace`] — a span-based tracer threaded through
//!   [`crate::pipeline::JobCtrl`]: one span per pipeline phase
//!   (prepare/generate/explore/synthesize/verify) plus per-shard child
//!   spans on the cluster coordinator, exported as Chrome
//!   `trace_events` JSON (`GET /jobs/:id/trace`, `polygen trace`).
//!
//! # Overhead discipline
//!
//! Hot-path recording is a single relaxed atomic RMW — no locks, no
//! allocation, no formatting. Mirroring the `faults::inject`
//! const-false pattern, the `obs-stub` cargo feature compiles every
//! recorder down to an empty inline function (`metrics::COMPILED` is
//! `false`), so minimal builds carry no recording code at all; the
//! default build records, and the tier-1 bench gate runs against it.
//! Span collection allocates only when a job was *built traced*
//! (`ServiceBuilder::tracing` / `polygen serve --trace`): an untraced
//! job's `JobCtrl` holds no tracer and every span call is an
//! `Option::None` check.

pub mod metrics;
pub mod trace;
