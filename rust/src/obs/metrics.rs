//! Process-wide metrics registry: atomic counters, gauges, and
//! fixed-bucket histograms, registered statically by name.
//!
//! Every metric the process can ever record is one entry in
//! [`METRICS`]; storage is a single flat `static` array of relaxed
//! atomics whose layout is computed at compile time from the registry.
//! Recording sites obtain a handle through the `const fn` constructors
//! ([`counter`], [`gauge`], [`histogram`]):
//!
//! ```
//! use polygen::obs::metrics;
//! const DONATIONS: metrics::Counter = metrics::counter("pool.donations");
//! DONATIONS.inc(); // one relaxed fetch_add — no lock, no lookup
//! ```
//!
//! A name that is not in [`METRICS`] (or registered under a different
//! kind) fails the `const` evaluation — i.e. it is a *compile error*,
//! not a runtime panic. The `obs-registry` rule in `polygen-lint`
//! additionally cross-checks the registry against the use sites both
//! ways, so a registered metric nothing records (dead) and a recorded
//! name missing from the registry both fail CI, mirroring the PR 7/8
//! fault-tap `SITES` discipline.
//!
//! # Compile-out
//!
//! With the `obs-stub` cargo feature the recorders compile to empty
//! inline functions ([`COMPILED`] is `false`): cells stay zero, the
//! hot path carries no recording code, and `/metrics` still renders
//! (all zeros). This mirrors `faults::inject`'s const-false pattern —
//! the default build records, and the tier-1 bench gate runs against
//! the default build.

// The cells are const-initialized globals recordable from any thread;
// obs is never loom-modeled (no blocking, single relaxed RMWs only),
// so it deliberately bypasses the crate::sync shim like faults.rs.
// lint: sync-ok(const-init atomic metric cells in never-modeled code)
use std::sync::atomic::{AtomicU64, Ordering};

/// `false` when the `obs-stub` feature compiles recording out.
pub const COMPILED: bool = !cfg!(feature = "obs-stub");

/// Metric kind, fixed at registration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Monotonically increasing count (rendered with a `_total` suffix).
    Counter,
    /// Last-write-wins instantaneous value.
    Gauge,
    /// Fixed-bucket histogram (bucket edges in [`Spec::buckets`]).
    Histogram,
}

impl Kind {
    /// Prometheus `# TYPE` label.
    pub fn label(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// One registered metric.
#[derive(Debug)]
pub struct Spec {
    /// Dotted registry name (`layer.metric`); rendered as
    /// `polygen_<layer>_<metric>[_total]`.
    pub name: &'static str,
    /// Counter / gauge / histogram.
    pub kind: Kind,
    /// One-line `# HELP` text.
    pub help: &'static str,
    /// Upper bucket edges for histograms (empty for counters/gauges).
    pub buckets: &'static [u64],
}

const NO_BUCKETS: &[u64] = &[];
/// Shared latency edges (milliseconds) for the RPC-scale histogram.
const MS_CALL: &[u64] = &[1, 10, 100, 1_000, 10_000];
/// Latency edges (milliseconds) for whole-job durations.
const MS_JOB: &[u64] = &[100, 1_000, 10_000, 60_000, 300_000];

const fn c(name: &'static str, help: &'static str) -> Spec {
    Spec { name, kind: Kind::Counter, help, buckets: NO_BUCKETS }
}
const fn g(name: &'static str, help: &'static str) -> Spec {
    Spec { name, kind: Kind::Gauge, help, buckets: NO_BUCKETS }
}
const fn h(name: &'static str, help: &'static str, buckets: &'static [u64]) -> Spec {
    Spec { name, kind: Kind::Histogram, help, buckets }
}

/// The full static registry. Rendering iterates this; the lint's
/// `obs-registry` rule collects the `name:` literals below and
/// cross-checks them against every `counter("…")`/`gauge("…")`/
/// `histogram("…")` call in the tree.
pub const METRICS: &[Spec] = &[
    // -- scheduler (pool.rs) ------------------------------------------
    g("pool.queue_depth", "jobs currently registered with the work-donating scheduler"),
    c("pool.donations", "times a pool worker donated a slice of work to a foreign job"),
    c("pool.task_panics", "tasks that panicked inside the scheduler and were contained"),
    // -- service (service/mod.rs, service/exec.rs) --------------------
    c("service.submitted", "jobs accepted by Service::submit"),
    c("service.done", "jobs finished successfully (including store-served repeats)"),
    c("service.failed", "jobs finished with an error (including panics and degraded wrap)"),
    c("service.cancelled", "jobs observed cancelled at settle time"),
    c("service.store_submit_hits", "submissions served directly from the result store"),
    g("service.registry_size", "entries currently held in the job registry"),
    h("service.job_ms", "wall-clock job execution time in milliseconds", MS_JOB),
    g("exec.queue_depth", "entries waiting in the executor task queue"),
    g("exec.executors", "executor threads currently alive"),
    // -- cluster (service/cluster.rs) ---------------------------------
    c("cluster.shards_dispatched", "shards assigned to remote workers"),
    c("cluster.shards_reassigned", "shards re-dispatched after a worker failure or timeout"),
    c("cluster.heartbeat_misses", "worker heartbeats that failed and forced re-registration"),
    c("cluster.wire_crc_failures", "shard-protocol payloads rejected by CRC or frame checks"),
    c("cluster.degraded", "times a sharded job fell back to local (degraded) execution"),
    c("cluster.strikes", "protocol strikes recorded against workers"),
    // -- net policies (net.rs) ----------------------------------------
    c("net.calls", "policy-wrapped cluster calls attempted"),
    c("net.retries", "retry attempts spent by the retry policy"),
    c("net.call_failures", "policy-wrapped calls that exhausted retries and failed"),
    c("net.breaker_opened", "circuit breaker closed→open transitions"),
    c("net.breaker_reclosed", "circuit breaker half-open→closed recoveries"),
    g("net.retry_budget_millitokens", "process retry budget level, in 1/1000 tokens"),
    h("net.call_ms", "per-call wall time through net::Policy in milliseconds", MS_CALL),
    // -- durable stores (service/store.rs) ----------------------------
    c("store.log_frames", "frames appended to the jobs.log durable journal"),
    c("store.log_write_errors", "jobs.log append failures tolerated (journal best-effort)"),
    c("store.log_quarantined", "jobs.log files quarantined during startup replay"),
    c("store.result_hits", "result-store (.pgjr) lookups served intact"),
    c("store.result_misses", "result-store (.pgjr) lookups with no entry"),
    c("store.result_quarantined", ".pgjr entries quarantined on CRC/shape mismatch"),
    c("store.result_saves", "results persisted to the store"),
    g("store.bytes", "bytes currently held by the result store"),
    g("store.entries", "entries currently held by the result store"),
    // -- generation disk cache (coordinator/cache.rs) -----------------
    c("cache.hits", "design-space disk cache (.pgds) hits"),
    c("cache.misses", "design-space disk cache (.pgds) misses"),
    c("cache.quarantined", ".pgds files quarantined on CRC/validation failure"),
    // -- fault injection (faults.rs) ----------------------------------
    c("faults.injected", "faults fired by the deterministic injection plan"),
    // -- the tracer itself (obs/trace.rs) -----------------------------
    c("trace.spans", "spans recorded across all traced jobs"),
];

const fn str_eq(a: &str, b: &str) -> bool {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    if a.len() != b.len() {
        return false;
    }
    let mut i = 0;
    while i < a.len() {
        if a[i] != b[i] {
            return false;
        }
        i += 1;
    }
    true
}

const fn find(name: &str) -> usize {
    let mut i = 0;
    while i < METRICS.len() {
        if str_eq(METRICS[i].name, name) {
            return i;
        }
        i += 1;
    }
    panic!("metric name not registered in obs::metrics::METRICS")
}

/// Cells a metric occupies: 1 for counters/gauges; histograms take one
/// per bucket, one overflow (+Inf) bucket, and one running sum.
const fn cells_of(i: usize) -> usize {
    match METRICS[i].kind {
        Kind::Counter | Kind::Gauge => 1,
        Kind::Histogram => METRICS[i].buckets.len() + 2,
    }
}

const fn offset_of(idx: usize) -> usize {
    let mut off = 0;
    let mut i = 0;
    while i < idx {
        off += cells_of(i);
        i += 1;
    }
    off
}

const TOTAL_CELLS: usize = offset_of(METRICS.len());

const ZERO: AtomicU64 = AtomicU64::new(0);
static CELLS: [AtomicU64; TOTAL_CELLS] = [ZERO; TOTAL_CELLS];

/// Compile-time handle to a registered counter.
#[derive(Clone, Copy, Debug)]
pub struct Counter {
    cell: usize,
}

/// Resolve a counter by registry name at compile time. Unregistered
/// names or kind mismatches fail `const` evaluation.
pub const fn counter(name: &str) -> Counter {
    let i = find(name);
    match METRICS[i].kind {
        Kind::Counter => Counter { cell: offset_of(i) },
        _ => panic!("metric is registered, but not as a counter"),
    }
}

impl Counter {
    /// Record one event. A single relaxed `fetch_add`; a no-op under
    /// `obs-stub`.
    #[inline]
    pub fn inc(self) {
        self.add(1);
    }

    /// Record `n` events at once.
    #[inline]
    pub fn add(self, n: u64) {
        if COMPILED {
            CELLS[self.cell].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value (zero forever under `obs-stub`).
    pub fn get(self) -> u64 {
        CELLS[self.cell].load(Ordering::Relaxed)
    }
}

/// Compile-time handle to a registered gauge.
#[derive(Clone, Copy, Debug)]
pub struct Gauge {
    cell: usize,
}

/// Resolve a gauge by registry name at compile time.
pub const fn gauge(name: &str) -> Gauge {
    let i = find(name);
    match METRICS[i].kind {
        Kind::Gauge => Gauge { cell: offset_of(i) },
        _ => panic!("metric is registered, but not as a gauge"),
    }
}

impl Gauge {
    /// Publish the current value (last write wins).
    #[inline]
    pub fn set(self, v: u64) {
        if COMPILED {
            CELLS[self.cell].store(v, Ordering::Relaxed);
        }
    }

    /// Current value (zero forever under `obs-stub`).
    pub fn get(self) -> u64 {
        CELLS[self.cell].load(Ordering::Relaxed)
    }
}

/// Compile-time handle to a registered histogram.
#[derive(Clone, Copy, Debug)]
pub struct Histogram {
    idx: usize,
    cell: usize,
}

/// Resolve a histogram by registry name at compile time.
pub const fn histogram(name: &str) -> Histogram {
    let i = find(name);
    match METRICS[i].kind {
        Kind::Histogram => Histogram { idx: i, cell: offset_of(i) },
        _ => panic!("metric is registered, but not as a histogram"),
    }
}

impl Histogram {
    /// Record one observation: one bucket increment + one sum add.
    #[inline]
    pub fn observe(self, v: u64) {
        if COMPILED {
            let edges = METRICS[self.idx].buckets;
            let mut b = 0;
            while b < edges.len() && v > edges[b] {
                b += 1;
            }
            CELLS[self.cell + b].fetch_add(1, Ordering::Relaxed);
            CELLS[self.cell + edges.len() + 1].fetch_add(v, Ordering::Relaxed);
        }
    }

    /// Total observation count (zero forever under `obs-stub`).
    pub fn count(self) -> u64 {
        let edges = METRICS[self.idx].buckets;
        let mut total = 0;
        for b in 0..=edges.len() {
            total += CELLS[self.cell + b].load(Ordering::Relaxed);
        }
        total
    }
}

/// Rendered (Prometheus) name for a registry entry: `polygen_` prefix,
/// dots mapped to underscores, `_total` suffix on counters.
pub fn prom_name(spec: &Spec) -> String {
    let base = format!("polygen_{}", spec.name.replace('.', "_"));
    match spec.kind {
        Kind::Counter => format!("{base}_total"),
        _ => base,
    }
}

/// Render the whole registry in Prometheus text exposition format.
/// Every registered metric is always present (zeros included), so a
/// scrape is a complete inventory of the registry.
pub fn render_prometheus() -> String {
    let mut out = String::with_capacity(4096);
    for (i, m) in METRICS.iter().enumerate() {
        let name = prom_name(m);
        out.push_str(&format!("# HELP {name} {}\n", m.help));
        out.push_str(&format!("# TYPE {name} {}\n", m.kind.label()));
        let off = offset_of(i);
        match m.kind {
            Kind::Counter | Kind::Gauge => {
                out.push_str(&format!("{name} {}\n", CELLS[off].load(Ordering::Relaxed)));
            }
            Kind::Histogram => {
                let mut cum = 0u64;
                for (b, edge) in m.buckets.iter().enumerate() {
                    cum += CELLS[off + b].load(Ordering::Relaxed);
                    out.push_str(&format!("{name}_bucket{{le=\"{edge}\"}} {cum}\n"));
                }
                cum += CELLS[off + m.buckets.len()].load(Ordering::Relaxed);
                out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                let sum = CELLS[off + m.buckets.len() + 1].load(Ordering::Relaxed);
                out.push_str(&format!("{name}_sum {sum}\n"));
                out.push_str(&format!("{name}_count {cum}\n"));
            }
        }
    }
    out
}

/// Runtime lookup of a metric's current value by registry name —
/// counter/gauge value, or observation count for histograms. Linear
/// scan; for tests and debugging, not hot paths.
pub fn value(name: &str) -> u64 {
    let i = find(name);
    let off = offset_of(i);
    match METRICS[i].kind {
        Kind::Counter | Kind::Gauge => CELLS[off].load(Ordering::Relaxed),
        Kind::Histogram => {
            let mut total = 0;
            for b in 0..=METRICS[i].buckets.len() {
                total += CELLS[off + b].load(Ordering::Relaxed);
            }
            total
        }
    }
}

/// Zero every cell. Test helper — the registry is process-global, so
/// tests asserting deltas take [`test_serial_lock`] and reset first.
pub fn reset_all() {
    for cell in CELLS.iter() {
        cell.store(0, Ordering::Relaxed);
    }
}

// lint: sync-ok(test-only serializer over the global metric cells)
static TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Serialize tests that reset/assert the process-global cells
/// (poisoning is ignored — the cells themselves can't be corrupted).
pub fn test_serial_lock() -> std::sync::MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_well_formed() {
        for (i, m) in METRICS.iter().enumerate() {
            assert!(
                m.name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '.' || c == '_'),
                "bad metric name {:?}",
                m.name
            );
            assert!(m.name.contains('.'), "metric {:?} lacks a layer prefix", m.name);
            assert!(!m.help.is_empty());
            for other in &METRICS[..i] {
                assert_ne!(m.name, other.name, "duplicate metric name");
            }
            match m.kind {
                Kind::Histogram => {
                    assert!(!m.buckets.is_empty(), "{}: histogram without buckets", m.name);
                    assert!(m.buckets.windows(2).all(|w| w[0] < w[1]), "{}: edges not ascending", m.name);
                }
                _ => assert!(m.buckets.is_empty(), "{}: buckets on non-histogram", m.name),
            }
        }
    }

    #[test]
    fn counters_and_gauges_record() {
        let _guard = test_serial_lock();
        reset_all();
        const C: Counter = counter("trace.spans");
        C.inc();
        C.add(2);
        assert_eq!(C.get(), if COMPILED { 3 } else { 0 });
        const G: Gauge = gauge("pool.queue_depth");
        G.set(7);
        assert_eq!(G.get(), if COMPILED { 7 } else { 0 });
        assert_eq!(value("trace.spans"), C.get());
        reset_all();
        assert_eq!(C.get(), 0);
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_render() {
        let _guard = test_serial_lock();
        reset_all();
        const H: Histogram = histogram("net.call_ms");
        H.observe(0); // le=1
        H.observe(5); // le=10
        H.observe(100_000); // +Inf overflow
        assert_eq!(H.count(), if COMPILED { 3 } else { 0 });
        let text = render_prometheus();
        if COMPILED {
            assert!(text.contains("polygen_net_call_ms_bucket{le=\"1\"} 1"));
            assert!(text.contains("polygen_net_call_ms_bucket{le=\"10\"} 2"));
            assert!(text.contains("polygen_net_call_ms_bucket{le=\"+Inf\"} 3"));
            assert!(text.contains("polygen_net_call_ms_sum 100005"));
            assert!(text.contains("polygen_net_call_ms_count 3"));
        }
        reset_all();
    }

    #[test]
    fn render_covers_every_registered_metric() {
        let text = render_prometheus();
        for m in METRICS {
            let name = prom_name(m);
            assert!(
                text.contains(&format!("# TYPE {name} ")),
                "{name} missing from render"
            );
        }
    }
}
