//! Span-based tracing with a Chrome `trace_events` exporter.
//!
//! A [`Tracer`] is attached to a job's `pipeline::JobCtrl` when the job
//! is built traced (`ServiceBuilder::tracing` / `polygen serve --trace`
//! / `JobCtrl::traced`). The pipeline's single phase funnel
//! (`JobCtrl::set_phase`) then turns every phase transition into a
//! complete span, and the cluster coordinator records per-shard child
//! spans around its dispatch/collect calls. Untraced jobs carry no
//! tracer at all — span recording is one `Option::None` check.
//!
//! Spans are duration events: `{name, cat, tid, start_us, dur_us}`
//! with timestamps relative to the tracer's birth. [`Tracer::export_chrome`]
//! renders the `chrome://tracing` / Perfetto JSON array form:
//!
//! ```json
//! {"traceEvents":[{"name":"generate","cat":"phase","ph":"X",
//!   "ts":412,"dur":180234,"pid":1,"tid":1}],"displayTimeUnit":"ms"}
//! ```
//!
//! Phase spans render on `tid` [`TID_PHASES`]; shard call spans on
//! `TID_SHARDS + shard index` so each shard gets its own lane.

use crate::sync::{plock, Mutex};
use std::time::Instant;

use super::metrics;

const SPANS: metrics::Counter = metrics::counter("trace.spans");

/// Chrome-trace lane for the job's pipeline phases.
pub const TID_PHASES: u64 = 1;
/// First chrome-trace lane for per-shard cluster calls; shard `i`
/// renders on `TID_SHARDS + i`.
pub const TID_SHARDS: u64 = 2;

/// One completed span, timestamps in microseconds since tracer birth.
#[derive(Clone, Debug)]
pub struct Span {
    /// Display name (phase label, or `shard <i> <op>`).
    pub name: String,
    /// Category: `"phase"` for pipeline phases, `"shard"` for cluster calls.
    pub cat: &'static str,
    /// Chrome-trace lane.
    pub tid: u64,
    /// Start offset from tracer birth, µs.
    pub start_us: u64,
    /// Duration, µs.
    pub dur_us: u64,
}

/// Per-job span collector. Cheap to create; all recording is one short
/// mutex push (never on the per-task hot path — phases and shard calls
/// are coarse events).
#[derive(Debug)]
pub struct Tracer {
    t0: Instant,
    spans: Mutex<Vec<Span>>,
    /// The currently-open phase span, fed by `enter_phase`.
    open: Mutex<Option<(&'static str, Instant)>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    /// A tracer born now, with no spans.
    pub fn new() -> Tracer {
        Tracer {
            t0: Instant::now(),
            spans: Mutex::new(Vec::new()),
            open: Mutex::new(None),
        }
    }

    fn us_since_birth(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.t0).as_micros() as u64
    }

    /// Record a completed span covering `start..end`.
    pub fn record(&self, name: String, cat: &'static str, tid: u64, start: Instant, end: Instant) {
        let start_us = self.us_since_birth(start);
        let dur_us = end.saturating_duration_since(start).as_micros() as u64;
        plock(&self.spans).push(Span { name, cat, tid, start_us, dur_us });
        SPANS.inc();
    }

    /// Phase funnel: close the currently-open phase span (if any) and
    /// open one for `label`. Called by `JobCtrl::set_phase`.
    pub fn enter_phase(&self, label: &'static str) {
        let now = Instant::now();
        let prev = plock(&self.open).replace((label, now));
        if let Some((name, started)) = prev {
            self.record(name.to_string(), "phase", TID_PHASES, started, now);
        }
    }

    /// Close the open phase span, if any. Called when the job settles;
    /// idempotent.
    pub fn finish(&self) {
        let now = Instant::now();
        if let Some((name, started)) = plock(&self.open).take() {
            self.record(name.to_string(), "phase", TID_PHASES, started, now);
        }
    }

    /// Snapshot of all spans so far, in recording order. A still-open
    /// phase span is included as if it ended now, so live exports of a
    /// running job show the current phase.
    pub fn spans(&self) -> Vec<Span> {
        let mut out = plock(&self.spans).clone();
        if let Some((name, started)) = *plock(&self.open) {
            let now = Instant::now();
            out.push(Span {
                name: name.to_string(),
                cat: "phase",
                tid: TID_PHASES,
                start_us: self.us_since_birth(started),
                dur_us: now.saturating_duration_since(started).as_micros() as u64,
            });
        }
        out
    }

    /// Aggregate phase durations (µs) by span name, in first-seen
    /// order — the `timings` object in job status JSON.
    pub fn timings(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = Vec::new();
        for s in self.spans() {
            if s.cat != "phase" {
                continue;
            }
            match out.iter_mut().find(|(n, _)| *n == s.name) {
                Some((_, d)) => *d += s.dur_us,
                None => out.push((s.name, s.dur_us)),
            }
        }
        out
    }

    /// Render the Chrome `trace_events` JSON document.
    pub fn export_chrome(&self) -> String {
        export_chrome(&self.spans())
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render spans as a Chrome `trace_events` JSON document (`ph:"X"`
/// complete events, µs timestamps, `pid` fixed at 1).
pub fn export_chrome(spans: &[Span]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
            escape(&s.name),
            escape(s.cat),
            s.start_us,
            s.dur_us,
            s.tid
        ));
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn phase_funnel_closes_previous_span() {
        let t = Tracer::new();
        t.enter_phase("prepare");
        t.enter_phase("generate");
        t.finish();
        t.finish(); // idempotent
        let spans = t.spans();
        let names: Vec<&str> = spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["prepare", "generate"]);
        assert!(spans.iter().all(|s| s.cat == "phase" && s.tid == TID_PHASES));
        // Ordering invariant: spans close in the order they were opened.
        assert!(spans[0].start_us <= spans[1].start_us);
    }

    #[test]
    fn open_span_is_visible_in_snapshots() {
        let t = Tracer::new();
        t.enter_phase("prepare");
        let spans = t.spans();
        assert_eq!(spans.len(), 1, "open phase must show in live snapshots");
        assert_eq!(spans[0].name, "prepare");
        assert_eq!(t.timings().len(), 1);
    }

    #[test]
    fn timings_aggregate_by_name() {
        let t = Tracer::new();
        let now = Instant::now();
        t.record("generate".into(), "phase", TID_PHASES, now, now);
        t.record("generate".into(), "phase", TID_PHASES, now, now);
        t.record("shard 0 sweep".into(), "shard", TID_SHARDS, now, now);
        let timings = t.timings();
        assert_eq!(timings.len(), 1, "shard spans are not phases");
        assert_eq!(timings[0].0, "generate");
    }

    #[test]
    fn chrome_export_is_wellformed() {
        let t = Tracer::new();
        t.enter_phase("prepare");
        t.finish();
        let json = t.export_chrome();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"name\":\"prepare\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.ends_with("],\"displayTimeUnit\":\"ms\"}"));
        assert_eq!(escape("a\"b\\c\n"), "a\\\"b\\\\c\\u000a");
    }
}
