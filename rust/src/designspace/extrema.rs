//! Divided-difference extremum searches — the computational core of design
//! space generation (paper §II-A).
//!
//! Two kinds of search appear:
//!
//! 1. **Diagonal extrema** `M(t) = max_{x<y, x+y=t} (l(y)-u(x)-1)/(y-x)`
//!    and `m(t) = min_{w<z, w+z=t} (u(z)+1-l(w))/(z-w)` over a region's
//!    bound slices — O(N²) over all diagonals; this is the part the XLA /
//!    Pallas kernel can also compute (see `python/compile/kernels/`).
//!
//! 2. **2-D searches of the form `max_{x<y} D(x,y)`,
//!    `D(x,y) = (g(y)-h(x))/(y-x)`** — the Eqn 10 bounds on `a` (over
//!    diagonal index pairs `t < s`) and, in the paper-faithful per-`a`
//!    path, the Eqn 3/4 bounds on `b`. These are the searches **Claim
//!    II.1** prunes: iterating `x` in ascending order with the incumbent
//!    `(x', y')`, the whole inner loop over `y` can be skipped whenever
//!    `D(x', y') <= (h(x) - h(x'))/(x - x')`.
//!
//! All comparisons are exact (integer cross-multiplication / `Rat`).

use crate::rational::Rat;
use std::cmp::Ordering;

/// Which implementation the generator uses for the Eqn 10 searches (and,
/// with [`SearchStrategy::Hull`], the diagonal-extrema inner loops).
/// `Naive` exists for the E5 benchmark and the equivalence property
/// tests; `Pruned` is the Claim II.1 skip rule; `Hull` is the §Perf
/// envelope engine ([`max_dd_hull`] + [`diagonal_extrema_fast`]) and the
/// default. All three are value-identical (property-tested).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SearchStrategy {
    Naive,
    Pruned,
    Hull,
}

/// Result of a 2-D divided-difference search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DdMax {
    pub value: Rat,
    pub x: usize,
    pub y: usize,
    /// Number of `D` evaluations performed (for the speedup benches).
    pub evals: u64,
}

/// `max_{x<y} (g(y) - h(x)) / (y - x)` by exhaustive scan.
/// Returns `None` when fewer than two points.
pub fn max_dd_naive(g: &[Rat], h: &[Rat]) -> Option<DdMax> {
    let n = g.len();
    assert_eq!(n, h.len());
    let mut best: Option<DdMax> = None;
    let mut evals = 0u64;
    for x in 0..n.saturating_sub(1) {
        for y in x + 1..n {
            let d = g[y].sub(&h[x]).div(&Rat::int((y - x) as i128));
            evals += 1;
            if best.map_or(true, |b| b.value.lt(&d)) {
                best = Some(DdMax { value: d, x, y, evals: 0 });
            }
        }
    }
    best.map(|mut b| {
        b.evals = evals;
        b
    })
}

/// `max_{x<y} (g(y) - h(x)) / (y - x)` with the Claim II.1 skip rule.
///
/// Invariant maintained: `best` is the maximum over all pairs with first
/// argument `<= x` processed so far. For a new `x`, if
/// `best.value <= (h(x) - h(best.x)) / (x - best.x)` then (Claim II.1) no
/// `y` can improve on `best`, and the inner loop is skipped entirely.
pub fn max_dd_pruned(g: &[Rat], h: &[Rat]) -> Option<DdMax> {
    let n = g.len();
    assert_eq!(n, h.len());
    if n < 2 {
        return None;
    }
    let mut best: Option<DdMax> = None;
    let mut evals = 0u64;
    for x in 0..n - 1 {
        if let Some(b) = best {
            debug_assert!(x > b.x);
            let slope = h[x].sub(&h[b.x]).div(&Rat::int((x - b.x) as i128));
            if b.value.le(&slope) {
                continue; // Claim II.1: no y improves the incumbent
            }
        }
        for y in x + 1..n {
            let d = g[y].sub(&h[x]).div(&Rat::int((y - x) as i128));
            evals += 1;
            if best.map_or(true, |b| b.value.lt(&d)) {
                best = Some(DdMax { value: d, x, y, evals: 0 });
            }
        }
    }
    best.map(|mut b| {
        b.evals = evals;
        b
    })
}

/// `min_{x<y} (g(y) - h(x)) / (y - x)` via the max search on negated data.
pub fn min_dd(g: &[Rat], h: &[Rat], strategy: SearchStrategy) -> Option<DdMax> {
    // (g(y)-h(x))/(y-x) = -[((-g)(y) - (-h)(x))/(y-x)], so the min is the
    // negated max over g' = -g, h' = -h.
    let ng: Vec<Rat> = g.iter().map(|v| v.neg()).collect();
    let nh: Vec<Rat> = h.iter().map(|v| v.neg()).collect();
    let r = match strategy {
        SearchStrategy::Naive => max_dd_naive(&ng, &nh),
        SearchStrategy::Pruned => max_dd_pruned(&ng, &nh),
        SearchStrategy::Hull => {
            let gr: Vec<RawFrac> = ng.iter().map(RawFrac::from_rat).collect();
            let hr: Vec<RawFrac> = nh.iter().map(RawFrac::from_rat).collect();
            max_dd_hull(&gr, &hr)
        }
    };
    r.map(|mut b| {
        b.value = b.value.neg();
        b
    })
}

/// An unreduced `i128` fraction with positive denominator — the gcd-free
/// representation the *fast* search paths use (§Perf: reducing through
/// `Rat::new`'s gcd on every divided difference dominated generation
/// time). Magnitude analysis for every caller in this crate: numerators
/// stay below 2^60 and denominators below 2^40, so cross-multiplied
/// comparisons fit `i128` with >25 bits of headroom. Neither comparisons
/// nor divided-difference formation trust that envelope: both are
/// checked, falling back to reduced/widened arithmetic on overflow
/// ([`RawFrac::lt`], [`dd_raw`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RawFrac {
    pub num: i128,
    pub den: i128,
}

impl RawFrac {
    #[inline]
    pub fn from_rat(r: &Rat) -> RawFrac {
        RawFrac { num: r.num(), den: r.den() }
    }

    #[inline]
    pub fn to_rat(&self) -> Rat {
        Rat::new(self.num, self.den)
    }

    /// `self < o` by cross multiplication (both dens > 0).
    ///
    /// The fast path multiplies in `i128` directly — the documented
    /// magnitude envelope (numerators `< 2^60`, denominators `< 2^40`)
    /// leaves >25 bits of headroom. Beyond the envelope the release build
    /// no longer silently wraps: on `checked_mul` overflow the comparison
    /// falls back to reduced [`Rat`]s, whose own comparison widens to
    /// 256 bits when even the reduced cross products overflow.
    #[inline]
    pub fn lt(&self, o: &RawFrac) -> bool {
        debug_assert!(self.den > 0 && o.den > 0);
        match (self.num.checked_mul(o.den), o.num.checked_mul(self.den)) {
            (Some(l), Some(r)) => l < r,
            _ => self.to_rat().lt(&o.to_rat()),
        }
    }

    #[inline]
    pub fn le(&self, o: &RawFrac) -> bool {
        !o.lt(self)
    }
}

/// Divided difference `(a - b) / gap` as an unreduced fraction, formed
/// with checked products. When the raw `i128` cross products would
/// overflow, the formation falls back to reduced [`Rat`] arithmetic —
/// exact whenever the reduced result is representable, and a loud panic
/// (never a silent wrap) when even that is not.
#[inline]
fn dd_raw(a: &RawFrac, b: &RawFrac, gap: i128) -> RawFrac {
    let num = a
        .num
        .checked_mul(b.den)
        .zip(b.num.checked_mul(a.den))
        .and_then(|(l, r)| l.checked_sub(r));
    let den = a.den.checked_mul(b.den).and_then(|v| v.checked_mul(gap));
    match (num, den) {
        (Some(num), Some(den)) => RawFrac { num, den },
        _ => RawFrac::from_rat(&a.to_rat().sub(&b.to_rat()).div(&Rat::int(gap))),
    }
}

/// Gcd-free `max_{x<y} (g(y) - h(x)) / (y - x)` over raw fractions.
/// `pruned` selects the Claim II.1 skip rule. Identical results to the
/// `Rat` implementations (property-tested).
pub fn max_dd_fracs(g: &[RawFrac], h: &[RawFrac], pruned: bool) -> Option<DdMax> {
    let n = g.len();
    assert_eq!(n, h.len());
    if n < 2 {
        return None;
    }
    let mut best: Option<(RawFrac, usize, usize)> = None;
    let mut evals = 0u64;
    for x in 0..n - 1 {
        if pruned {
            if let Some((bd, bx, _)) = best {
                // Claim II.1: slope = (h(x) - h(bx)) / (x - bx).
                let slope = dd_raw(&h[x], &h[bx], (x - bx) as i128);
                if bd.le(&slope) {
                    continue;
                }
            }
        }
        for y in x + 1..n {
            let d = dd_raw(&g[y], &h[x], (y - x) as i128);
            evals += 1;
            if best.map_or(true, |(b, _, _)| b.lt(&d)) {
                best = Some((d, x, y));
            }
        }
    }
    best.map(|(v, x, y)| DdMax { value: v.to_rat(), x, y, evals })
}

/// `max_{x<y} (g(y) - h(x)) / (y - x)` in O(n log n): incremental lower
/// convex hull of the points `(x, h(x))` plus a tangent binary search
/// from each query point `(y, g(y))` (§Perf: the Eqn 10 bounds are
/// max-slope problems, so they can be swept with a hull instead of
/// rescanned — the same structure Brisebarre & Muller exploit for
/// truncated-polynomial coefficient bounds).
///
/// Correctness: the maximizing `x` for a fixed `y` lies on the lower hull
/// of the points seen so far, and the slope from the hull to the query
/// point is unimodal along the hull (each slope to the query is a mediant
/// of its hull-edge slope and the next slope to the query), so a binary
/// search on "still ascending" finds the tangent. Value-identical to
/// [`max_dd_naive`] (property-tested). `evals` counts tangent-search
/// slope comparisons, the hull analogue of `D` evaluations.
///
/// **Tie-breaking is pinned**: the `(x, y)` witness is the argmax pair
/// minimizing `(x, y)` lexicographically — exactly what the naive scan's
/// iteration order (ascending `x` outer, ascending `y` inner, strict
/// improvement) returns, so consumers may rely on the witness itself.
/// Three pieces make this hold: collinear hull points are popped but the
/// *left* endpoint of any tangent-contact run survives as a vertex; the
/// tangent search ascends only on a *strictly* greater slope, landing on
/// the leftmost maximizer (the slope sequence is unimodal with equal
/// adjacent values only at its maximum — an equal pair forces both to
/// coincide with the edge slope, and the next edge is strictly steeper);
/// and value ties across queries keep the lexicographically smaller
/// witness. The big-magnitude fallback ([`max_dd_fracs`] with pruning)
/// shares the naive scan's iteration order, so its witness agrees by
/// construction.
///
/// All comparisons are exact cross multiplications of gcd-free fractions:
/// triple products bounded by `2^57 * 2^25 * 2^24 = 2^106` for the widest
/// supported format. Inputs are magnitude-prechecked once; anything that
/// could push a triple product past `i128` is routed to the pruned
/// search, whose comparisons carry a checked overflow fallback —
/// value-identical, just slower.
pub fn max_dd_hull(g: &[RawFrac], h: &[RawFrac]) -> Option<DdMax> {
    let n = g.len();
    assert_eq!(n, h.len());
    if n < 2 {
        return None;
    }
    // Worst triple product: (num-diff <= 2^(nb+db+1)) * (index gap
    // <= 2^xb) * (den <= 2^db) — demand it fits i128 with a sign bit.
    let bits = |v: i128| 128 - v.unsigned_abs().leading_zeros();
    let mut nb = 0u32;
    let mut db = 0u32;
    for f in g.iter().chain(h.iter()) {
        nb = nb.max(bits(f.num));
        db = db.max(bits(f.den));
    }
    // lint: overflow-ok(u32 bit-count sums, bounded by a few hundred)
    if nb + 2 * db + bits(n as i128) + 1 > 126 {
        return max_dd_fracs(g, h, true);
    }
    // Referenced from debug_assert! conditions (type-checked, compiled
    // out of release binaries).
    fn fits(a: i128, b: i128, c: i128) -> bool {
        a.checked_mul(b).and_then(|v| v.checked_mul(c)).is_some()
    }
    // Lower hull of (x, h(x)), stored as indices into h; consecutive hull
    // slopes strictly increase.
    let mut hull: Vec<usize> = Vec::with_capacity(n);
    let mut best: Option<(RawFrac, usize, usize)> = None;
    let mut evals = 0u64;
    for y in 1..n {
        let p = y - 1; // the newly available point (p, h(p))
        while hull.len() >= 2 {
            let i1 = hull[hull.len() - 2];
            let i2 = hull[hull.len() - 1];
            let (v1, v2, vp) = (h[i1], h[i2], h[p]);
            // Pop i2 iff slope(i1, i2) >= slope(i2, p).
            debug_assert!(
                fits(v2.num * v1.den - v1.num * v2.den, (p - i2) as i128, vp.den)
                    && fits(vp.num * v2.den - v2.num * vp.den, (i2 - i1) as i128, v1.den),
                "hull domination overflow"
            );
            // lint: overflow-ok(triple products magnitude-prechecked above; beyond-envelope inputs routed to max_dd_fracs)
            let lhs = (v2.num * v1.den - v1.num * v2.den) * ((p - i2) as i128) * vp.den;
            let rhs = (vp.num * v2.den - v2.num * vp.den) * ((i2 - i1) as i128) * v1.den;
            if lhs >= rhs {
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(p);
        // Tangent search: maximize slope(hull[i] -> (y, g(y))) over i.
        let q = g[y];
        let (mut lo, mut hi) = (0usize, hull.len() - 1);
        while lo < hi {
            let mid = (lo + hi) / 2; // lint: overflow-ok(usize midpoint of in-bounds hull indices)
            let (ia, ib) = (hull[mid], hull[mid + 1]);
            let (va, vb) = (h[ia], h[ib]);
            evals += 1;
            // Ascend iff slope(ib, Q) > slope(ia, Q) — strictly, so ties
            // resolve to the leftmost maximizer (the pinned witness).
            debug_assert!(
                fits(q.num * vb.den - vb.num * q.den, (y - ia) as i128, va.den)
                    && fits(q.num * va.den - va.num * q.den, (y - ib) as i128, vb.den),
                "tangent comparison overflow"
            );
            // lint: overflow-ok(triple products magnitude-prechecked above; beyond-envelope inputs routed to max_dd_fracs)
            let lhs = (q.num * vb.den - vb.num * q.den) * ((y - ia) as i128) * va.den;
            let rhs = (q.num * va.den - va.num * q.den) * ((y - ib) as i128) * vb.den;
            if lhs > rhs {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        let ix = hull[lo];
        let vx = h[ix];
        let d = dd_raw(&q, &vx, (y - ix) as i128);
        evals += 1;
        // Strict improvement, or an equal value with a lexicographically
        // smaller (x, y) — matching the naive scan's first-found witness.
        let better = match &best {
            None => true,
            Some((b, bx, by)) => b.lt(&d) || (!d.lt(b) && (ix < *bx || (ix == *bx && y < *by))),
        };
        if better {
            best = Some((d, ix, y));
        }
    }
    best.map(|(v, x, y)| DdMax { value: v.to_rat(), x, y, evals })
}

/// An unreduced small fraction with positive denominator, used in the hot
/// diagonal loops (`i64` numerators, cross-multiplied in `i128`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Frac {
    pub num: i64,
    pub den: i64,
}

impl Frac {
    #[inline]
    pub fn lt(&self, o: &Frac) -> bool {
        debug_assert!(self.den > 0 && o.den > 0);
        (self.num as i128) * (o.den as i128) < (o.num as i128) * (self.den as i128)
    }

    pub fn to_rat(self) -> Rat {
        Rat::new(self.num as i128, self.den as i128)
    }
}

/// Per-diagonal extrema of a region's bound slices.
///
/// `m_upper[t-1]` = the paper's `m(r, t)` (min of upper-chord slopes) and
/// `m_lower[t-1]` = `M(r, t)` (max of lower-chord slopes), for diagonals
/// `t in [1, 2N-3]`; a region needs `N >= 2`.
#[derive(Clone, Debug)]
pub struct DiagExtrema {
    /// `M(t)`, indexed by `t - 1`.
    pub big_m: Vec<Rat>,
    /// `m(t)`, indexed by `t - 1`.
    pub small_m: Vec<Rat>,
}

/// Compute `M(t)`/`m(t)` for all diagonals by direct scan — O(N²) total.
pub fn diagonal_extrema(l: &[i32], u: &[i32]) -> DiagExtrema {
    let n = l.len();
    assert_eq!(n, u.len());
    assert!(n >= 2, "diagonal extrema need at least 2 points");
    let tmax = 2 * n - 3; // largest t with an x < y pair
    let mut big_m = Vec::with_capacity(tmax);
    let mut small_m = Vec::with_capacity(tmax);
    for t in 1..=tmax {
        // x < y, x + y = t, both in [0, n): x in [max(0, t-n+1), ceil(t/2)-1].
        let x0 = t.saturating_sub(n - 1);
        let x1 = (t - 1) / 2;
        let mut best_m = Frac { num: i64::MIN / 4, den: 1 }; // M: max
        let mut best_s = Frac { num: i64::MAX / 4, den: 1 }; // m: min
        for x in x0..=x1 {
            let y = t - x;
            let den = (y - x) as i64;
            // M candidate: (l(y) - u(x) - 1) / (y - x)
            let fm = Frac { num: l[y] as i64 - u[x] as i64 - 1, den };
            if best_m.lt(&fm) {
                best_m = fm;
            }
            // m candidate: (u(y) + 1 - l(x)) / (y - x)
            let fs = Frac { num: u[y] as i64 + 1 - l[x] as i64, den };
            if fs.lt(&best_s) {
                best_s = fs;
            }
        }
        big_m.push(best_m.to_rat());
        small_m.push(best_s.to_rat());
    }
    DiagExtrema { big_m, small_m }
}

/// Exact ordering of `a*b` versus `c*d` over `i64` factors: the fast path
/// multiplies in `i64` (checked), and on overflow the comparison widens
/// to `i128` — two `i64` factors always fit there, so it never wraps.
#[inline]
fn prod_i64_cmp(a: i64, b: i64, c: i64, d: i64) -> Ordering {
    match (a.checked_mul(b), c.checked_mul(d)) {
        (Some(l), Some(r)) => l.cmp(&r),
        _ => ((a as i128) * (b as i128)).cmp(&((c as i128) * (d as i128))),
    }
}

/// [`diagonal_extrema`] with the inner comparisons kept entirely in `i64`
/// (§Perf). Bound values are `i32` (numerator magnitudes `<= 2^32`) and
/// separations are `< 2^24`, so cross products stay below `2^57` and the
/// checked `i64` fast path of [`prod_i64_cmp`] always hits — no `i128`
/// widening in the O(N²) hot loop, and no silent wrap if an input ever
/// leaves that envelope. Value-identical to [`diagonal_extrema`]
/// (property-tested), which is retained as the reference for the XLA
/// extrema kernel cross-checks and the pre-envelope oracle engine.
pub fn diagonal_extrema_fast(l: &[i32], u: &[i32]) -> DiagExtrema {
    let n = l.len();
    assert_eq!(n, u.len());
    assert!(n >= 2, "diagonal extrema need at least 2 points");
    debug_assert!(n < (1 << 24), "separation magnitude envelope exceeded");
    let tmax = 2 * n - 3;
    let mut big_m = Vec::with_capacity(tmax);
    let mut small_m = Vec::with_capacity(tmax);
    for t in 1..=tmax {
        let x0 = t.saturating_sub(n - 1);
        let x1 = (t - 1) / 2;
        // Seed with the first pair so incumbents are always real
        // candidates (no sentinel whose cross product could overflow).
        let y0 = t - x0;
        let d0 = (y0 - x0) as i64;
        let mut mn = l[y0] as i64 - u[x0] as i64 - 1;
        let mut md = d0;
        let mut sn = u[y0] as i64 + 1 - l[x0] as i64;
        let mut sd = d0;
        for x in x0 + 1..=x1 {
            let y = t - x;
            let d = (y - x) as i64;
            // M candidate: (l(y) - u(x) - 1) / (y - x), strict improvement
            // keeps the first maximizer like the reference scan.
            let a = l[y] as i64 - u[x] as i64 - 1;
            if prod_i64_cmp(a, md, mn, d) == Ordering::Greater {
                mn = a;
                md = d;
            }
            // m candidate: (u(y) + 1 - l(x)) / (y - x).
            let b = u[y] as i64 + 1 - l[x] as i64;
            if prod_i64_cmp(b, sd, sn, d) == Ordering::Less {
                sn = b;
                sd = d;
            }
        }
        big_m.push(Rat::new(mn as i128, md as i128));
        small_m.push(Rat::new(sn as i128, sd as i128));
    }
    DiagExtrema { big_m, small_m }
}

/// Construct `DiagExtrema` from raw `(num, den)` pairs, e.g. as returned by
/// the XLA extrema kernel. Entries with `den == 0` are invalid.
pub fn diag_extrema_from_fracs(
    m_pairs: &[(i64, i64)],
    s_pairs: &[(i64, i64)],
    tmax: usize,
) -> DiagExtrema {
    let mut big_m = Vec::with_capacity(tmax);
    let mut small_m = Vec::with_capacity(tmax);
    for t in 0..tmax {
        let (mn, md) = m_pairs[t];
        let (sn, sd) = s_pairs[t];
        assert!(md > 0 && sd > 0, "invalid diagonal {t} from kernel");
        big_m.push(Rat::new(mn as i128, md as i128));
        small_m.push(Rat::new(sn as i128, sd as i128));
    }
    DiagExtrema { big_m, small_m }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{for_each_seed, Rng};

    fn rand_rats(rng: &mut Rng, n: usize, mag: i64) -> Vec<Rat> {
        (0..n).map(|_| Rat::int(rng.range_i64(-mag, mag) as i128)).collect()
    }

    #[test]
    fn pruned_equals_naive_property() {
        for_each_seed(60, |rng| {
            let n = 2 + rng.below(40) as usize;
            let g = rand_rats(rng, n, 50);
            let h = rand_rats(rng, n, 50);
            let a = max_dd_naive(&g, &h).unwrap();
            let b = max_dd_pruned(&g, &h).unwrap();
            assert_eq!(a.value, b.value, "g={g:?} h={h:?}");
            // Pruned must never evaluate more than naive.
            assert!(b.evals <= a.evals);
        });
    }

    #[test]
    fn min_dd_equals_negated_naive() {
        for_each_seed(40, |rng| {
            let n = 2 + rng.below(20) as usize;
            let g = rand_rats(rng, n, 30);
            let h = rand_rats(rng, n, 30);
            let mn = min_dd(&g, &h, SearchStrategy::Pruned).unwrap();
            // Brute force min.
            let mut best: Option<Rat> = None;
            for x in 0..n - 1 {
                for y in x + 1..n {
                    let d = g[y].sub(&h[x]).div(&Rat::int((y - x) as i128));
                    if best.map_or(true, |b| d.lt(&b)) {
                        best = Some(d);
                    }
                }
            }
            assert_eq!(mn.value, best.unwrap());
        });
    }

    #[test]
    fn pruning_actually_skips_on_smooth_data() {
        // Steeply increasing h with flat g puts the maximum at small x and
        // makes the Claim II.1 skip rule fire on every later x.
        let n = 200usize;
        let g: Vec<Rat> = (0..n).map(|_| Rat::ZERO).collect();
        let h: Vec<Rat> = (0..n).map(|i| Rat::int((i * i) as i128)).collect();
        let a = max_dd_naive(&g, &h).unwrap();
        let b = max_dd_pruned(&g, &h).unwrap();
        assert_eq!(a.value, b.value);
        assert!(
            b.evals * 3 < a.evals,
            "expected substantial pruning: naive={} pruned={}",
            a.evals,
            b.evals
        );
    }

    #[test]
    fn raw_frac_search_equals_rat_search() {
        for_each_seed(60, |rng| {
            let n = 2 + rng.below(40) as usize;
            let g = rand_rats(rng, n, 50);
            let h = rand_rats(rng, n, 50);
            let gr: Vec<RawFrac> = g.iter().map(RawFrac::from_rat).collect();
            let hr: Vec<RawFrac> = h.iter().map(RawFrac::from_rat).collect();
            let want = max_dd_naive(&g, &h).unwrap();
            for pruned in [false, true] {
                let got = max_dd_fracs(&gr, &hr, pruned).unwrap();
                assert_eq!(got.value, want.value, "pruned={pruned} g={g:?} h={h:?}");
            }
        });
    }

    #[test]
    fn diagonal_extrema_match_bruteforce() {
        for_each_seed(30, |rng| {
            let n = 2 + rng.below(24) as usize;
            let l: Vec<i32> = (0..n).map(|_| rng.range_i64(-40, 40) as i32).collect();
            let u: Vec<i32> = l.iter().map(|&v| v + rng.range_i64(0, 6) as i32).collect();
            let de = diagonal_extrema(&l, &u);
            for t in 1..=(2 * n - 3) {
                let mut bm: Option<Rat> = None;
                let mut bs: Option<Rat> = None;
                for x in 0..n {
                    for y in (x + 1)..n {
                        if x + y != t {
                            continue;
                        }
                        let fm = Rat::new(
                            l[y] as i128 - u[x] as i128 - 1,
                            (y - x) as i128,
                        );
                        let fs = Rat::new(
                            u[y] as i128 + 1 - l[x] as i128,
                            (y - x) as i128,
                        );
                        bm = Some(bm.map_or(fm, |b: Rat| b.max_rat(fm)));
                        bs = Some(bs.map_or(fs, |b: Rat| b.min_rat(fs)));
                    }
                }
                assert_eq!(de.big_m[t - 1], bm.unwrap(), "M(t), t={t}, n={n}");
                assert_eq!(de.small_m[t - 1], bs.unwrap(), "m(t), t={t}, n={n}");
            }
        });
    }

    #[test]
    fn hull_search_equals_naive_property() {
        for_each_seed(80, |rng| {
            let n = 2 + rng.below(50) as usize;
            // Mix of integer, collinear, and fractional inputs — collinear
            // h exercises the hull's equal-slope pops.
            let (g, h): (Vec<Rat>, Vec<Rat>) = match rng.below(3) {
                0 => (rand_rats(rng, n, 50), rand_rats(rng, n, 50)),
                1 => {
                    let s = rng.range_i64(-3, 3);
                    let h = (0..n)
                        .map(|i| Rat::int(s as i128 * i as i128 + rng.below(2) as i128))
                        .collect();
                    (rand_rats(rng, n, 20), h)
                }
                _ => {
                    let fr = |rng: &mut Rng| {
                        Rat::new(rng.range_i64(-60, 60) as i128, 1 + rng.below(9) as i128)
                    };
                    let g: Vec<Rat> = (0..n).map(|_| fr(rng)).collect();
                    let h: Vec<Rat> = (0..n).map(|_| fr(rng)).collect();
                    (g, h)
                }
            };
            let want = max_dd_naive(&g, &h).unwrap();
            let gr: Vec<RawFrac> = g.iter().map(RawFrac::from_rat).collect();
            let hr: Vec<RawFrac> = h.iter().map(RawFrac::from_rat).collect();
            let got = max_dd_hull(&gr, &hr).unwrap();
            assert_eq!(got.value, want.value, "g={g:?} h={h:?}");
        });
    }

    #[test]
    fn hull_min_dd_equals_naive() {
        for_each_seed(30, |rng| {
            let n = 2 + rng.below(20) as usize;
            let g = rand_rats(rng, n, 30);
            let h = rand_rats(rng, n, 30);
            let want = min_dd(&g, &h, SearchStrategy::Naive).unwrap();
            let got = min_dd(&g, &h, SearchStrategy::Hull).unwrap();
            assert_eq!(got.value, want.value);
        });
    }

    #[test]
    fn hull_witness_matches_naive_on_value_ties() {
        // The pinned tie-breaking contract (ROADMAP open item): on
        // value-equal argmax sets the hull must return the naive scan's
        // witness — the pair minimizing (x, y) lexicographically. Tiny
        // value ranges, collinear and constant h slices make ties dense.
        for_each_seed(150, |rng| {
            let n = 2 + rng.below(14) as usize;
            let (g, h): (Vec<Rat>, Vec<Rat>) = match rng.below(4) {
                0 => (rand_rats(rng, n, 2), rand_rats(rng, n, 2)),
                1 => {
                    // Collinear h (with jitter): tangent contact runs.
                    let s = rng.range_i64(-2, 2);
                    let h = (0..n)
                        .map(|i| Rat::int(s as i128 * i as i128 + rng.below(2) as i128))
                        .collect();
                    (rand_rats(rng, n, 1), h)
                }
                2 => {
                    // Constant h, constant g: every pair ties per gap.
                    let h = vec![Rat::ZERO; n];
                    let g = vec![Rat::int(rng.range_i64(-1, 1) as i128); n];
                    (g, h)
                }
                _ => (rand_rats(rng, n, 1), rand_rats(rng, n, 3)),
            };
            let want = max_dd_naive(&g, &h).unwrap();
            let gr: Vec<RawFrac> = g.iter().map(RawFrac::from_rat).collect();
            let hr: Vec<RawFrac> = h.iter().map(RawFrac::from_rat).collect();
            let got = max_dd_hull(&gr, &hr).unwrap();
            assert_eq!(got.value, want.value, "g={g:?} h={h:?}");
            assert_eq!(
                (got.x, got.y),
                (want.x, want.y),
                "witness tie-break drifted: g={g:?} h={h:?}"
            );
            // The pruned fallback path shares the pinned witness too.
            let pr = max_dd_fracs(&gr, &hr, true).unwrap();
            assert_eq!((pr.x, pr.y), (want.x, want.y), "pruned witness: g={g:?} h={h:?}");
        });
    }

    #[test]
    fn hull_witness_pinned_on_collinear_plateau() {
        // Deterministic plateau: g and h on the same line, so EVERY pair
        // (x, y) has slope exactly 1 — the whole search space ties. The
        // contract picks the lex-smallest pair (0, 1).
        let n = 8usize;
        let g: Vec<Rat> = (0..n).map(|i| Rat::int(i as i128)).collect();
        let h: Vec<Rat> = (0..n).map(|i| Rat::int(i as i128)).collect();
        let want = max_dd_naive(&g, &h).unwrap();
        let gr: Vec<RawFrac> = g.iter().map(RawFrac::from_rat).collect();
        let hr: Vec<RawFrac> = h.iter().map(RawFrac::from_rat).collect();
        let got = max_dd_hull(&gr, &hr).unwrap();
        assert_eq!((got.x, got.y, got.value), (want.x, want.y, want.value));
        assert_eq!((got.x, got.y), (0, 1), "lex-smallest argmax expected");
    }

    #[test]
    fn hull_search_is_sublinear_in_evals() {
        // On a long input the tangent searches cost O(n log n) total,
        // far below the naive n^2/2.
        let n = 512usize;
        let g: Vec<RawFrac> = (0..n)
            .map(|i| RawFrac { num: (i as i128 * i as i128) % 97, den: 1 + (i as i128 % 5) })
            .collect();
        let h: Vec<RawFrac> = (0..n)
            .map(|i| RawFrac { num: (7 * i as i128) % 89 - 40, den: 1 + (i as i128 % 3) })
            .collect();
        let hull = max_dd_hull(&g, &h).unwrap();
        let naive_evals = (n * (n - 1) / 2) as u64;
        assert!(
            hull.evals * 10 < naive_evals,
            "expected order-of-magnitude fewer evals: hull={} naive={naive_evals}",
            hull.evals
        );
    }

    #[test]
    fn hull_falls_back_on_huge_magnitudes() {
        // Magnitudes beyond the hull's triple-product precheck: the
        // search must route through the checked pruned path and stay
        // exact (cross products here need the Rat/U256 fallbacks too).
        let g: Vec<RawFrac> = (0..6)
            .map(|i| RawFrac { num: (1i128 << 100) + i as i128, den: (1i128 << 20) + 1 })
            .collect();
        let h: Vec<RawFrac> = (0..6)
            .map(|i| RawFrac { num: -(1i128 << 100) - (i * i) as i128, den: (1i128 << 20) - 1 })
            .collect();
        let hull = max_dd_hull(&g, &h).unwrap();
        let naive = max_dd_fracs(&g, &h, false).unwrap();
        assert_eq!(hull.value, naive.value);
    }

    #[test]
    fn fast_diagonal_extrema_matches_reference() {
        for_each_seed(40, |rng| {
            let n = 2 + rng.below(40) as usize;
            let l: Vec<i32> = (0..n).map(|_| rng.range_i64(-300, 300) as i32).collect();
            let u: Vec<i32> = l.iter().map(|&v| v + rng.range_i64(0, 9) as i32).collect();
            let a = diagonal_extrema(&l, &u);
            let b = diagonal_extrema_fast(&l, &u);
            assert_eq!(a.big_m, b.big_m, "l={l:?} u={u:?}");
            assert_eq!(a.small_m, b.small_m, "l={l:?} u={u:?}");
        });
    }

    #[test]
    fn prod_i64_cmp_survives_i64_overflow() {
        use std::cmp::Ordering::*;
        let m = i64::MAX;
        // Products near 2^126 overflow i64; ground truth is the widened
        // i128 comparison.
        assert_eq!(prod_i64_cmp(m, m, m - 1, m), Greater);
        assert_eq!(prod_i64_cmp(m - 1, m, m, m), Less);
        assert_eq!(prod_i64_cmp(m, m, m, m), Equal);
        assert_eq!(prod_i64_cmp(i64::MIN, m, m, m), Less);
        assert_eq!(prod_i64_cmp(-m, -m, m, m), Equal);
        assert_eq!(prod_i64_cmp(i64::MIN, i64::MIN, m, m), Greater);
        // In-envelope operands take the i64 fast path and agree.
        assert_eq!(prod_i64_cmp(3, 4, 2, 7), Less);
        assert_eq!(prod_i64_cmp(-3, 4, 2, -6), Equal);
    }

    #[test]
    fn fast_diagonal_extrema_at_i32_extremes() {
        // Full-range i32 bounds: numerators reach 2^32 + 1, the largest
        // magnitude the fast loop can see. Fast and reference scans must
        // agree exactly.
        let l = vec![i32::MIN, i32::MAX, i32::MIN, i32::MAX, 0, i32::MIN];
        let u = vec![i32::MAX, i32::MAX, i32::MIN, i32::MAX, i32::MAX, i32::MIN];
        let a = diagonal_extrema(&l, &u);
        let b = diagonal_extrema_fast(&l, &u);
        assert_eq!(a.big_m, b.big_m);
        assert_eq!(a.small_m, b.small_m);
    }

    #[test]
    fn frac_search_survives_den_product_overflow() {
        // Denominators of 2^63 make the unreduced divided-difference
        // denominator product overflow i128 for every gap >= 2, forcing
        // dd_raw through its reduced-Rat fallback; gap-1 pairs still take
        // the raw path, so both agree within one search.
        let n = 5usize;
        let g: Vec<RawFrac> =
            (0..n).map(|i| RawFrac { num: i as i128, den: 1i128 << 63 }).collect();
        let h: Vec<RawFrac> =
            (0..n).map(|i| RawFrac { num: -((i * i) as i128), den: 1i128 << 63 }).collect();
        let gr: Vec<Rat> = g.iter().map(RawFrac::to_rat).collect();
        let hr: Vec<Rat> = h.iter().map(RawFrac::to_rat).collect();
        let want = max_dd_naive(&gr, &hr).unwrap();
        for pruned in [false, true] {
            let got = max_dd_fracs(&g, &h, pruned).unwrap();
            assert_eq!(got.value, want.value, "pruned={pruned}");
            assert_eq!((got.x, got.y), (want.x, want.y), "pruned={pruned}");
        }
        // The hull front-end prechecks these magnitudes and routes here.
        let hull = max_dd_hull(&g, &h).unwrap();
        assert_eq!(hull.value, want.value);
    }

    #[test]
    fn raw_frac_lt_survives_overflow_magnitudes() {
        // The documented envelope is num < 2^60, den < 2^40 (cross
        // products < 2^100 — fast path). These operands sit far beyond
        // it: cross products need 131 bits, so the checked fallback must
        // decide through reduced Rats instead of silently wrapping.
        let a = RawFrac { num: (1i128 << 90) + 1, den: 1i128 << 40 };
        let b = RawFrac { num: 1i128 << 90, den: (1i128 << 40) - 1 };
        // a < b  <=>  (2^90+1)(2^40-1) < 2^130  <=>  2^40 - 1 < 2^90.
        assert!(a.lt(&b));
        assert!(!b.lt(&a));
        assert!(a.le(&b) && !b.le(&a));
        // Equal values across different representations still compare equal.
        let a2 = RawFrac { num: ((1i128 << 90) + 1) * 2, den: 1i128 << 41 };
        assert!(!a.lt(&a2) && !a2.lt(&a));
        // At the documented envelope edge the fast path still runs and
        // agrees with the exact Rat ordering.
        let c = RawFrac { num: (1i128 << 60) - 1, den: (1i128 << 40) - 1 };
        let d = RawFrac { num: (1i128 << 60) - 3, den: (1i128 << 40) - 3 };
        assert_eq!(c.lt(&d), c.to_rat().lt(&d.to_rat()));
        assert_eq!(d.lt(&c), d.to_rat().lt(&c.to_rat()));
    }

    #[test]
    fn frac_comparison_exact() {
        assert!(Frac { num: 1, den: 3 }.lt(&Frac { num: 2, den: 5 }));
        assert!(!Frac { num: 2, den: 4 }.lt(&Frac { num: 1, den: 2 }));
        assert!(Frac { num: -5, den: 2 }.lt(&Frac { num: -2, den: 1 }));
    }
}
